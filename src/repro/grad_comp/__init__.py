from .topk_roaring import (compress_leaf, decompress_leaf, compress_tree,
                           decompress_tree, compressed_crosspod_mean,
                           compression_ratio, leaf_overlap, leaf_jaccard,
                           leaf_overlap_many, leaf_topk_overlap)

__all__ = ["compress_leaf", "decompress_leaf", "compress_tree",
           "decompress_tree", "compressed_crosspod_mean", "compression_ratio",
           "leaf_overlap", "leaf_jaccard", "leaf_overlap_many",
           "leaf_topk_overlap"]
