"""Roaring top-k gradient compression for cross-pod data parallelism.

Top-k magnitude sparsification turns a gradient leaf into (indices, values).
The index set is exactly the paper's workload: sorted 32-bit integers, often
clustered (attention sinks, hot embedding rows) — so it is encoded as a
Roaring *slab* (jax_roaring): chunked by high-16 bits, array containers for
scattered coordinates, bitmap containers for dense hot regions, per-chunk
cardinality counters for exact sizing without decompression.

Cross-pod sync then all-gathers the compressed (slab, values) payloads over
the "pod" axis and merges with the many-way union discipline of Algorithm 4
(bitmap-domain OR accumulation, deferred cardinality) — realized here as a
scatter-add of each pod's sparse contribution, which is the linear-algebra
analogue (values must sum, not OR).

Wire cost per pod: 16k + k*4 bits vs 32N dense — e.g. k = N/100 gives ~50x.
``compression_ratio`` reports the exact roaring-encoded size via the
cardinality counters.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import roaring
from repro.core import jax_roaring as jr
from repro.roaring import RoaringSlab


class CompressedLeaf(NamedTuple):
    """Compressed gradient leaf: the index set as a pytree ``RoaringSlab``
    (flows through all_gather / tree_map natively) + packed values."""

    slab: RoaringSlab       # index set (keys/kinds/cards/nruns/payload)
    values: jax.Array       # f32[k] (aligned with ascending index order)


def _capacity_for(n: int, k: int) -> int:
    """Static container capacity: every 2^16-chunk the indices could touch."""
    return max(1, min((n + jr.CHUNK_SIZE - 1) // jr.CHUNK_SIZE, 2 * k))


def compress_leaf(g: jax.Array, k: int) -> CompressedLeaf:
    """Top-k by |g|; indices roaring-encoded, values packed in index order."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    k = min(k, n)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx)                              # ascending (roaring order)
    vals = flat[idx]
    cap = _capacity_for(n, k)
    slab = RoaringSlab.from_indices(idx, jnp.ones((k,), bool), cap)
    return CompressedLeaf(slab, vals)


def decompress_leaf(c: CompressedLeaf, shape, dtype) -> jax.Array:
    """Scatter values back to a dense leaf."""
    idx, valid = c.slab.to_indices(c.values.shape[0])
    n = int(np.prod(shape))
    out = jnp.zeros((n,), jnp.float32).at[jnp.where(valid, idx, n)].add(
        c.values * valid.astype(jnp.float32), mode="drop")
    return out.reshape(shape).astype(dtype)


def compress_tree(grads, ratio: float = 0.01, min_k: int = 64):
    """Compress every leaf to ceil(ratio * n) entries (static shapes)."""
    def one(g):
        k = max(min_k, int(np.ceil(g.size * ratio)))
        return compress_leaf(g, k)
    return jax.tree.map(one, grads)


def decompress_tree(compressed, like):
    return jax.tree.map(
        lambda c, p: decompress_leaf(c, p.shape, p.dtype), compressed, like,
        is_leaf=lambda x: isinstance(x, CompressedLeaf))


def leaf_overlap(c1: CompressedLeaf, c2: CompressedLeaf) -> jax.Array:
    """|idx(c1) ∩ idx(c2)| via the cardinality-only dispatch fast path.

    The top-k *support stability* between consecutive steps — the quantity
    error-feedback schedules key off — computed without decompressing either
    leaf or materializing the intersection."""
    return c1.slab.and_card(c2.slab)


def leaf_jaccard(c1: CompressedLeaf, c2: CompressedLeaf) -> jax.Array:
    """Jaccard similarity of two compressed index sets (one dispatch pass)."""
    return c1.slab.jaccard(c2.slab)


def leaf_overlap_many(c: CompressedLeaf, others) -> jax.Array:
    """i32[N] of |idx(c) ∩ idx(o_i)| over many compressed leaves at once.

    The support-stability scan (how much of this step's top-k survives in
    each of N history steps / pod replicas), previously N sequential
    ``leaf_overlap`` calls — now one stacked batched-meta dispatch launch
    through the query engine, nothing decompressed or materialized.
    Host-driven (like the stack construction itself): the stack capacity is
    sized to the exact merged live-key count across the history leaves.
    """
    from repro import index
    if not others:
        return jnp.zeros((0,), jnp.int32)
    slabs = [o.slab for o in others]
    live = np.unique(np.concatenate([np.asarray(s.keys) for s in slabs]))
    cap = max(1, int((live != int(jr.KEY_SENTINEL)).sum()))
    stack = roaring.stack(slabs, capacity=cap)
    return index.batched_and_card(stack, c.slab)


def leaf_topk_overlap(c: CompressedLeaf, others, k: int):
    """Top-k of ``leaf_overlap_many`` — (scores i32[k], indices i32[k]):
    which history steps' supports this leaf's top-k overlaps most."""
    return jax.lax.top_k(leaf_overlap_many(c, others), k)


def compression_ratio(c: CompressedLeaf, n: int) -> float:
    """Exact roaring-encoded bits vs dense f32 gradient bits.

    Uses the per-container cardinality counters (paper S2): array containers
    cost 16 bits/index, bitmap containers 2^16 bits flat, plus 32-bit
    header per container; values add 32 bits each.
    """
    card = np.asarray(c.slab.cards)
    kind = np.asarray(c.slab.kinds)
    bits = 32 * int((kind != 0).sum())
    bits += int((16 * card[kind == 1]).sum())
    bits += int((kind == 2).sum()) * (1 << 16)
    bits += 32 * int(c.values.shape[0])
    return bits / (32.0 * n)


def compressed_crosspod_mean(grads, *, axis_name: str, ratio: float = 0.01,
                             min_k: int = 64):
    """Drop-in replacement for ``jax.lax.pmean`` over the pod axis.

    Inside shard_map/pjit with a "pod" axis: compress locally, all-gather the
    compressed payloads (16k + 32k bits instead of 32N), scatter-add every
    pod's sparse contribution (the Alg. 4 merge, additive form), divide by
    pod count. Error feedback is left to the caller (train loop keeps the
    residual).
    """
    n_pods = jax.lax.axis_size(axis_name)

    def one(g):
        k = max(min_k, int(np.ceil(g.size * ratio)))
        c = compress_leaf(g, k)
        # all-gather compressed payloads across pods: [P, ...]
        gathered = jax.lax.all_gather(c, axis_name)
        dense = jnp.zeros((g.size,), jnp.float32)

        def add_pod(i, acc):
            ci = jax.tree.map(lambda x: x[i], gathered)
            return acc + decompress_leaf(
                ci, (g.size,), jnp.float32)

        dense = jax.lax.fori_loop(0, n_pods, add_pod, dense)
        return (dense / n_pods).reshape(g.shape).astype(g.dtype)

    return jax.tree.map(one, grads)
