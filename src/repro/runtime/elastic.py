"""Elastic scaling: rebuild the mesh from surviving devices and re-shard.

On a real fleet, losing a pod (or scaling one in) changes the device set;
the recovery path is: (1) rebuild a mesh over the surviving devices with the
same logical axis names, (2) re-apply the sharding rules (they are logical,
so they re-resolve against the new mesh shape — `_prune` drops axes that no
longer divide), (3) `jax.device_put` the restored checkpoint onto the new
shardings. Data parallel batch size follows the new "data" axis size; the
data pipeline's shard count is updated accordingly.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import params_shardings


def elastic_remesh(axis_names: Sequence[str],
                   devices: Optional[list] = None,
                   model_parallel: int = 1) -> Mesh:
    """Build the largest mesh with the given axes from available devices.

    Keeps the model axis fixed (parameter layout must still fit) and absorbs
    device loss on the data axis — the standard elastic-DP policy.
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    data = n // model_parallel
    if len(axis_names) == 2:
        shape = (data, model_parallel)
    elif len(axis_names) == 3:
        shape = (1, data, model_parallel)
    else:
        raise ValueError(axis_names)
    dev_array = np.asarray(devices[: int(np.prod(shape))]).reshape(shape)
    return Mesh(dev_array, tuple(axis_names))


def reshard_tree(tree, mesh: Mesh):
    """Re-apply logical sharding rules against a (possibly new) mesh."""
    sh = params_shardings(tree, mesh)
    return jax.tree.map(jax.device_put, tree, sh)
