from .fault_tolerance import (ResilientTrainer, HeartbeatMonitor,
                              StragglerPolicy, simulate_failure)
from .elastic import elastic_remesh, reshard_tree

__all__ = ["ResilientTrainer", "HeartbeatMonitor", "StragglerPolicy",
           "simulate_failure", "elastic_remesh", "reshard_tree"]
