from .fault_tolerance import (FaultPlan, HeartbeatMonitor, InjectedFault,
                              ResilientTrainer, StragglerPolicy, fault_scope,
                              simulate_failure)
from .elastic import elastic_remesh, reshard_tree

__all__ = ["ResilientTrainer", "HeartbeatMonitor", "StragglerPolicy",
           "simulate_failure", "elastic_remesh", "reshard_tree",
           "FaultPlan", "InjectedFault", "fault_scope"]
