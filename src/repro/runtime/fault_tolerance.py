"""Fault tolerance: checkpoint/restart, heartbeats, straggler mitigation.

Single-host container, thousand-node design:

  * ``ResilientTrainer`` wraps a train step with (a) periodic async
    checkpoints, (b) exception-triggered restore-and-retry (preemption, OOM,
    ICI failure surfaces as XlaRuntimeError on real fleets), (c) an injectable
    failure source for tests;
  * ``HeartbeatMonitor`` tracks per-step wall times; steps slower than
    ``straggler_factor`` x rolling median mark the step a straggler, which on
    a fleet triggers the StragglerPolicy (log / re-dispatch / drop to backup
    — here: recorded + surfaced as metrics, policy hooks are pluggable);
  * restart reproducibility: RNG + data-pipeline cursor live in the
    checkpoint `extra`, so the post-restore batch stream is identical.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, restore_checkpoint, latest_step


@dataclasses.dataclass
class StragglerPolicy:
    factor: float = 2.0          # straggler = step_time > factor * median
    window: int = 32
    action: str = "record"       # "record" | "raise"


class HeartbeatMonitor:
    def __init__(self, policy: StragglerPolicy):
        self.policy = policy
        self.times: deque = deque(maxlen=policy.window)
        self.stragglers = 0
        self.last_heartbeat = time.monotonic()

    def beat(self, step_time: float) -> bool:
        """Record one step; returns True if it was a straggler."""
        self.last_heartbeat = time.monotonic()
        is_straggler = False
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if step_time > self.policy.factor * med:
                self.stragglers += 1
                is_straggler = True
                if self.policy.action == "raise":
                    raise RuntimeError(
                        f"straggler: {step_time:.3f}s vs median {med:.3f}s")
        self.times.append(step_time)
        return is_straggler


class ResilientTrainer:
    """Run a step function with checkpoint/restart fault tolerance."""

    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 50, max_retries: int = 3,
                 policy: Optional[StragglerPolicy] = None,
                 failure_source: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.monitor = HeartbeatMonitor(policy or StragglerPolicy())
        self.failure_source = failure_source
        self.restarts = 0

    def run(self, state: Any, batches: Callable[[int], Any], n_steps: int,
            extra_state: Optional[dict] = None):
        """``batches(step)`` must be deterministic in step for exact replay."""
        step = int(np.asarray(state["step"])) if "step" in state else 0
        extra_state = dict(extra_state or {})
        if latest_step(self.ckpt_dir) is None:
            # durable step-0 checkpoint: a failure before the first periodic
            # save must restore the *initial* state, not replay onto a
            # partially-trained one
            from repro.checkpoint import save_checkpoint
            save_checkpoint(self.ckpt_dir, step, state, extra_state)
        while step < n_steps:
            try:
                if self.failure_source is not None:
                    self.failure_source(step)          # may raise (test hook)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batches(step))
                jax.block_until_ready(jax.tree.leaves(state)[0])
                self.monitor.beat(time.monotonic() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    extra_state["data_step"] = step
                    self.ckpt.save(step, state, extra_state)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.restarts += 1
                if self.restarts > self.max_retries:
                    raise
                self.ckpt.wait()
                state, extra_state, step = restore_checkpoint(
                    self.ckpt_dir, state)
        self.ckpt.wait()
        return state, extra_state


def simulate_failure(at_steps: set[int], exc: type = RuntimeError):
    """Failure source for tests: raise once at each given step."""
    fired = set()

    def src(step: int):
        if step in at_steps and step not in fired:
            fired.add(step)
            raise exc(f"injected failure at step {step}")
    return src


# =============================================================================
# dispatch-granularity fault injection for the roaring data plane
# =============================================================================

class InjectedFault(RuntimeError):
    """Raised by a ``FaultPlan`` in place of a real device/runtime failure
    (the XlaRuntimeError class preemption and ICI faults surface as)."""


@dataclasses.dataclass
class FaultPlan:
    """Injectable kernel-dispatch failures for the roaring query engine.

    The query-path mirror of ``ResilientTrainer``'s ``failure_source``: a
    plan counts every kernel launch on the targeted ``backend`` and raises
    ``exc`` on the chosen ones, so tests (and chaos drills) can prove the
    Pallas→XLA-ref degradation ladder in ``repro.index.execute`` returns
    bit-identical results under dispatch failures.

    ``fail_on`` names 0-based dispatch indices to fail; ``every`` fails each
    N-th dispatch instead; ``max_failures`` caps total injections (None =
    unlimited). ``dispatches``/``failures`` are live counters.
    """

    fail_on: frozenset = frozenset()
    every: Optional[int] = None
    backend: str = "pallas"
    exc: type = InjectedFault
    max_failures: Optional[int] = None
    dispatches: int = 0
    failures: int = 0

    def on_dispatch(self, backend: str) -> None:
        """The ``kernels.roaring.ops`` fault-hook entry point."""
        if backend != self.backend:
            return
        i = self.dispatches
        self.dispatches += 1
        if self.max_failures is not None and self.failures >= self.max_failures:
            return
        hit = i in self.fail_on or (
            self.every is not None and (i + 1) % self.every == 0)
        if hit:
            self.failures += 1
            raise self.exc(
                f"injected {self.backend} fault at dispatch {i}")


class fault_scope:
    """Context manager installing a ``FaultPlan`` as the roaring dispatch
    fault hook (``kernels.roaring.ops.set_fault_hook``); restores the
    previous hook on exit.

    >>> with fault_scope(FaultPlan(fail_on=frozenset({0}))):
    ...     out = index.execute(stack, expr, backend="pallas")
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._prev = None

    def __enter__(self) -> FaultPlan:
        from repro.kernels.roaring import ops as _kops
        self._prev = _kops.set_fault_hook(self.plan.on_dispatch)
        return self.plan

    def __exit__(self, *exc) -> None:
        from repro.kernels.roaring import ops as _kops
        _kops.set_fault_hook(self._prev)
