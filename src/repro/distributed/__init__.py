from . import context, sharding

__all__ = ["context", "sharding"]
