"""Sharding rules: parameter-path patterns -> PartitionSpec.

Strategy (single pod, mesh ("data", "model")):
  * tensor parallelism on "model": heads / mlp / experts / vocab;
  * FSDP (ZeRO-3) on "data": the remaining large dimension of each matrix;
  * activations: batch on "data", heads on "model" (via einsum sharding
    propagation), long-context KV sharded on sequence over "data"
    (sequence parallelism for the long_500k cells).

Multi-pod mesh ("pod", "data", "model"): parameters are replicated across
pods (pure DP); the batch is additionally split over "pod". Gradient sync on
the pod axis is where roaring gradient compression plugs in (grad_comp).

All rules are *logical*: `spec_for_path` pattern-matches parameter tree paths
so models never hard-code mesh names.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex on 'path', rank) -> PartitionSpec dims; first match wins.
# paths look like: blocks/0/attn/wq, embed/table, blocks/2/moe/wi ...
_RULES: list[tuple[str, tuple]] = [
    # embeddings: vocab on model (vocab-parallel logits). The embed dim
    # stays unsharded: sharding it on "data" would turn every logits einsum
    # into a [B,S,V/16] all-reduce over the data axis (measured 13.6 GB
    # buffers on whisper-base before this rule changed).
    (r"embed/table$", ("model", None)),
    (r"unembed/table$", ("model", None)),
    # attention projections (leading layer-stack dim handled generically)
    (r"attn/wq$", ("data", "model", None)),
    (r"attn/wk$", ("data", "model", None)),
    (r"attn/wv$", ("data", "model", None)),
    (r"attn/wo$", ("model", None, "data")),
    (r"xattn/w[qkv]$", ("data", "model", None)),
    (r"xattn/wo$", ("model", None, "data")),
    # dense MLP
    (r"mlp/w[ig]$", ("data", "model")),
    (r"mlp/wo$", ("model", "data")),
    # MoE: expert parallelism on "model", FSDP inside each expert on "data"
    (r"moe/router$", (None, "model")),
    (r"moe/w[ig]$", ("model", "data", None)),
    (r"moe/wo$", ("model", "data", None)),
    # mamba
    (r"mamba/in_proj$", ("data", "model")),
    (r"mamba/out_proj$", ("model", "data")),
    (r"mamba/x_proj$", ("model", None)),
    (r"mamba/conv_w$", (None, "model")),
    # rwkv time/channel mix
    (r"tm/w[rkvg]$", ("data", "model")),
    (r"tm/wo$", ("model", "data")),
    (r"tm/w_decay$", ("data", "model")),
    (r"tm/cwi$", ("data", "model")),
    (r"tm/cwo$", ("model", "data")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# optimizer-state suffixes: same layout as the parameter (m, v), row/col
# factored stats (vr drops the last dim, vc the second-to-last), or flat
# quantized blocks (replicated — they are 1-D reshapes).
_OPT_SUFFIXES = {"m": "same", "v": "same", "vr": "drop_last",
                 "vc": "drop_second_last", "mq": "flat", "vq": "flat",
                 "ms": "flat", "vs": "flat"}


def spec_for_path(path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter (or optimizer-state) leaf.

    Layer-stack leading dims pass through unsharded; small vectors replicate.
    Optimizer states inherit the parameter's spec through their path prefix —
    without this, a 398B model's Adam/Adafactor state silently replicates
    (measured 27 GB/device on qwen2-vl before this rule existed).
    """
    s = _path_str(path)
    parts = s.split("/")
    mode = "same"
    if parts and parts[-1] in _OPT_SUFFIXES:
        mode = _OPT_SUFFIXES[parts[-1]]
        s = "/".join(parts[:-1])
        if mode == "flat":
            return P()
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    for pat, dims in _RULES:
        if re.search(pat, s):
            dims = tuple(dims)
            if mode == "drop_last":
                dims = dims[:-1]
            elif mode == "drop_second_last":
                dims = dims[:-2] + dims[-1:] if len(dims) >= 2 else dims
            extra = ndim - len(dims)          # leading stack dims (scan)
            if extra < 0:
                dims = dims[-ndim:] if ndim > 0 else ()
                extra = 0
            spec = (None,) * extra + tuple(dims)
            return P(*_prune(spec, leaf, mesh))
    return P()                                 # replicate (norms, biases, ...)


def _prune(spec, leaf, mesh: Mesh):
    """Drop axis assignments that don't divide the dimension size."""
    shape = leaf.shape
    out = []
    for i, ax in enumerate(spec):
        if ax is None or ax not in mesh.shape:
            out.append(None)
        elif shape[i] % mesh.shape[ax] == 0 and shape[i] >= mesh.shape[ax]:
            out.append(ax)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return tuple(out)


def params_shardings(params, mesh: Mesh, mode: str = "auto"):
    """NamedSharding tree matching a parameter pytree.

    mode="auto": the FSDP+TP rules above. mode="replicate": pure data
    parallelism — right for models whose matrices are too small to pay for
    model-axis collectives (whisper-base: d=512 over 16 TP shards spent 73ms
    in collectives per 9ms of compute; see EXPERIMENTS.md §Perf).
    """
    if mode == "replicate":
        return jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for_path(path, leaf, mesh)),
        params)


def batch_spec(mesh: Mesh, mode: str = "auto") -> P:
    """Token batches: batch dim over every data-parallel axis present; in
    "replicate" (pure-DP) mode the model axis carries batch too."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    if mode == "replicate":
        axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    return P(tuple(axes) if len(axes) > 1 else axes[0]) if axes else P()


def seq_sharded_cache_spec(mesh: Mesh) -> P:
    """Long-context KV caches: [B, S, KVH, hd] with sequence over 'data'
    (sequence parallelism) and heads over 'model'."""
    return P(None, "data", "model", None)


def kv_cache_spec(mesh: Mesh) -> P:
    """Standard decode caches: batch over data axes, heads over model."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    b = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
    return P(b, None, "model", None)


def activation_spec(mesh: Mesh) -> P:
    return batch_spec(mesh)
