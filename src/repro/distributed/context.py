"""Activation-sharding context: anchors for XLA's sharding propagation.

SPMD propagation can lose the batch sharding through long scan chains and
custom-vjp boundaries (observed: unsharded [global_batch, S, block, block]
mask broadcasts in whisper's backward). The launcher declares the data-
parallel axes once; the model body then pins its per-layer activations with
``constrain_batch`` — a no-op outside a mesh context (unit tests).
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_DATA_AXES: Optional[tuple] = None
_DATA_COUNT: int = 1
_MODEL_AXIS: Optional[str] = None


@contextlib.contextmanager
def data_axes(axes: Sequence[str], count: int = 1,
              model_axis: Optional[str] = "model"):
    """Declare the mesh axes carrying the batch dim and their total size."""
    global _DATA_AXES, _DATA_COUNT, _MODEL_AXIS
    prev = (_DATA_AXES, _DATA_COUNT, _MODEL_AXIS)
    _DATA_AXES, _DATA_COUNT, _MODEL_AXIS = tuple(axes), int(count), model_axis
    try:
        yield
    finally:
        _DATA_AXES, _DATA_COUNT, _MODEL_AXIS = prev


def data_shard_count() -> int:
    """Number of data-parallel shards (1 outside a launcher context)."""
    return _DATA_COUNT if _DATA_AXES else 1


def _axis(name):
    if name == "data":
        return _DATA_AXES if len(_DATA_AXES) > 1 else _DATA_AXES[0]
    if name == "model":
        return _MODEL_AXIS
    if name == "all":                      # every axis (long-context seq dim)
        axes = tuple(_DATA_AXES)
        if _MODEL_AXIS and _MODEL_AXIS not in axes:
            axes = axes + (_MODEL_AXIS,)
        return axes
    return None


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim 0 of an activation to the data axes (rest unconstrained)."""
    if _DATA_AXES is None or x.ndim < 1:
        return x
    spec = P(_axis("data"), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """Pin arbitrary dims: dims entries are "data" | "model" | None."""
    if _DATA_AXES is None:
        return x
    spec = P(*[_axis(d) for d in dims])
    return jax.lax.with_sharding_constraint(x, spec)
