from .optimizers import (adamw, adafactor, adamw8bit, OptimizerDef,
                         cosine_schedule, clip_by_global_norm)

__all__ = ["adamw", "adafactor", "adamw8bit", "OptimizerDef",
           "cosine_schedule", "clip_by_global_norm"]
