"""Optimizers implemented from scratch (no optax in this container).

  * ``adamw``      — fused AdamW with decoupled weight decay.
  * ``adafactor``  — factored second moments (Shazeer & Stern): the TPU
    giant-model default; optimizer state for a [n, m] matrix is n + m floats
    instead of 2nm — what lets the 398B/400B cells fit 16 GB/chip at 256
    chips (napkin math in EXPERIMENTS.md §Dry-run).
  * ``adamw8bit``  — block-wise dynamically-quantized Adam states (256-value
    lookup against per-block absmax), the distributed-memory trick for dense
    giants when factored stats are not wanted.

All follow one protocol:
    init(params)                  -> opt_state
    update(grads, state, params)  -> (updates, new_state)
and updates are *subtracted* from params by the trainer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptimizerDef:
    init: Callable
    update: Callable          # (grads, state, params, step) -> (updates, state)
    name: str = "opt"


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# =============================================================================
# AdamW
# =============================================================================

def adamw(lr: Callable | float, b1=0.9, b2=0.95, eps=1e-8, wd=0.1) -> OptimizerDef:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            u = lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                        + wd * p.astype(jnp.float32))
            return u.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return OptimizerDef(init, update, "adamw")


# =============================================================================
# Adafactor (factored second moments)
# =============================================================================

def adafactor(lr: Callable | float, eps=1e-30, clip_thresh=1.0,
              decay_pow=0.8, min_dim_factored=8) -> OptimizerDef:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(p):
        # factor whenever the trailing 2-D tile is non-trivial: a stacked
        # [layers, d, H, hd] attention weight factors per (H x hd) tile; the
        # unfactored fallback would keep a full-f32 second moment (21 GB on
        # qwen2-vl's wq alone)
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored
                and p.shape[-1] * p.shape[-2] >= 4096)

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_pow)
        lr_t = lr_fn(step)

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v_est = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                u = g * jax.lax.rsqrt(v_est + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS of update <= clip_thresh)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            return (lr_t * u).astype(p.dtype), new_s

        flat = jax.tree_util.tree_map_with_path(
            lambda path, g, s, p: one(g, s, p), grads, state, params,
            is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x))
        updates = jax.tree.map(lambda o: o[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return OptimizerDef(init, update, "adafactor")


# =============================================================================
# 8-bit AdamW (block-wise dynamic quantization of m and v)
# =============================================================================

_QBLOCK = 256


def _quantize(x: jax.Array, power: float = 2.0):
    """Block-wise absmax int8 quantization with a power-law code.

    Linear absmax codes zero out entries below absmax/127, which explodes
    Adam's ``m/sqrt(v)`` when v underflows. The power-law code
    ``q = round(127 * (|x|/absmax)^(1/power))`` concentrates resolution near
    zero (dynamic range (1/127)^power), the same idea as bitsandbytes'
    dynamic map.
    """
    xb = x.reshape(-1, _QBLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12)
    frac = jnp.abs(xb) / scale
    q = jnp.round(127.0 * frac ** (1.0 / power)) * jnp.sign(xb)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def _dequantize(q: jax.Array, scale: jax.Array, power: float = 2.0):
    qb = q.reshape(-1, _QBLOCK).astype(jnp.float32)
    frac = (jnp.abs(qb) / 127.0) ** power
    return (jnp.sign(qb) * frac * scale[:, None]).reshape(-1)


def adamw8bit(lr: Callable | float, b1=0.9, b2=0.95, eps=1e-8, wd=0.1) -> OptimizerDef:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _pad(n):
        return (n + _QBLOCK - 1) // _QBLOCK * _QBLOCK

    def init(params):
        def one(p):
            n = _pad(p.size)
            return {"mq": jnp.zeros((n,), jnp.int8),
                    "ms": jnp.zeros((n // _QBLOCK,), jnp.float32),
                    "vq": jnp.zeros((n,), jnp.int8),
                    "vs": jnp.zeros((n // _QBLOCK,), jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def one(g, s, p):
            n = _pad(p.size)
            gf = jnp.pad(g.astype(jnp.float32).reshape(-1), (0, n - p.size))
            m = _dequantize(s["mq"], s["ms"], power=2.0)
            v = _dequantize(s["vq"], s["vs"], power=4.0)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            u = (lr_t * (mhat / (jnp.sqrt(vhat) + eps)))[: p.size].reshape(p.shape)
            u = u + lr_t * wd * p.astype(jnp.float32)
            mq, ms = _quantize(m, power=2.0)
            vq, vs = _quantize(v, power=4.0)
            return u.astype(p.dtype), {"mq": mq, "ms": ms, "vq": vq, "vs": vs}

        flat = jax.tree.map(one, grads, state, params,
                            is_leaf=lambda x: isinstance(x, dict) and "mq" in x)
        updates = jax.tree.map(lambda o: o[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return OptimizerDef(init, update, "adamw8bit")
