"""Sharded checkpointing with async save and elastic restore.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json        — tree structure, shapes, dtypes, chunking, meta
        shard_00000.npz      — flat chunks (chunked by byte budget)
        ...

Design points for the 1000-node target (documented, exercised single-host):
  * every leaf is chunked along axis 0 so hosts write disjoint files — the
    restore path reassembles from any chunking (elastic re-shard: a restore
    onto a different mesh simply re-applies the new shardings via
    ``jax.device_put``);
  * writes go to a temp dir + atomic rename, so a mid-save failure never
    corrupts the latest checkpoint (crash-consistent);
  * ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
    writes in a background thread (training continues) — the standard
    overlap trick;
  * data-pipeline state and RNG are part of the manifest for exact restart.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_CHUNK_BYTES = 256 * 1024 * 1024

# dtypes numpy's npz format can't serialize natively -> stored as raw views
_RAW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
               "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _encode_arr(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _RAW_DTYPES:
        return arr.view(_RAW_DTYPES[name][1]), name
    return arr, name


def _decode_arr(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _RAW_DTYPES:
        return arr.view(_RAW_DTYPES[dtype_name][0])
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous save: atomic per-step directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "extra": extra or {}, "leaves": []}
    shard_id = 0
    buf: dict[str, np.ndarray] = {}
    buf_bytes = 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        arr_enc, dtype_name = _encode_arr(arr)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": dtype_name,
            "shard": shard_id, "key": f"leaf_{i}"})
        buf[f"leaf_{i}"] = arr_enc
        buf_bytes += arr.nbytes
        if buf_bytes >= _CHUNK_BYTES:
            np.savez(os.path.join(tmp, f"shard_{shard_id:05d}.npz"), **buf)
            buf, buf_bytes = {}, 0
            shard_id += 1
    if buf:
        np.savez(os.path.join(tmp, f"shard_{shard_id:05d}.npz"), **buf)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``like``. ``shardings`` (optional pytree
    of NamedSharding) re-shards for the *current* mesh — elastic restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(like_leaves), "tree structure changed"
    shards: dict[int, Any] = {}
    leaves = []
    for meta in manifest["leaves"]:
        sid = meta["shard"]
        if sid not in shards:
            shards[sid] = np.load(os.path.join(d, f"shard_{sid:05d}.npz"))
        arr = _decode_arr(shards[sid][meta["key"]], meta["dtype"])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["extra"], step


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except Exception as e:           # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
