"""Train-step builder: remat, microbatching, mixed precision, grad clipping,
optional roaring gradient compression on the pod axis.

The built step is pjit-ready: the launcher supplies shardings from
``repro.distributed.sharding`` and donates the state buffers.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import OptimizerDef, clip_by_global_norm


def TrainState(params, opt_state, step) -> dict:
    return {"params": params, "opt": opt_state,
            "step": jnp.asarray(step, jnp.int32)}


def make_train_step(cfg: ModelConfig, optimizer: OptimizerDef, *,
                    microbatch: Optional[int] = None,
                    remat: str = "none",              # none|full|dots
                    max_grad_norm: float = 1.0,
                    grad_compression: Optional[dict] = None,
                    block_lists=None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    batch: {"tokens": i32[B, S+1], "mask": f32[B, S+1]} — inputs are
    tokens[:, :-1], labels tokens[:, 1:].
    """

    def loss_fn(params, tokens, labels, mask, extra_embeds=None, memory=None):
        logits, aux = T.forward(params, tokens, cfg, block_lists=block_lists,
                                extra_embeds=extra_embeds, memory=memory,
                                remat=remat)
        logits = logits.astype(jnp.float32)
        # logsumexp + masked-reduction form: neither materializes [B,S,V]
        # log-probs nor gathers across the model-sharded vocab (a
        # take_along_axis over sharded V all-gathers logits — 13.6 GB/device
        # buffers before this form)
        lse = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        ll = jnp.sum(jnp.where(vocab_iota[None, None, :] == labels[..., None],
                               logits, 0.0), axis=-1)
        nll = lse - ll
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom + 0.01 * aux

    # remat is applied at the layer-scan body inside T.forward (per
    # super-block), not around the whole loss: whole-loss checkpointing still
    # lets the scan backward stash per-iteration residuals.
    grad_fn = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        tokens = batch["tokens"][:, :-1]
        labels = batch["tokens"][:, 1:]
        mask = batch["mask"][:, 1:]
        extra = batch.get("extra_embeds")
        memory = batch.get("memory")
        if microbatch is None:
            return grad_fn(params, tokens, labels, mask, extra, memory)
        B = tokens.shape[0]
        assert B % microbatch == 0
        n_micro = B // microbatch

        import os as _os
        acc_dt = (jnp.bfloat16
                  if _os.environ.get("REPRO_ACCUM_DTYPE") == "bf16"
                  else jnp.float32)

        def mb(i, acc):
            loss_acc, g_acc = acc
            sl = lambda x: (None if x is None else jax.lax.dynamic_slice_in_dim(
                x, i * microbatch, microbatch, axis=0))
            l, g = grad_fn(params, sl(tokens), sl(labels), sl(mask),
                           sl(extra), sl(memory))
            return (loss_acc + l / n_micro,
                    jax.tree.map(
                        lambda a, b: (a.astype(jnp.float32)
                                      + b.astype(jnp.float32) / n_micro
                                      ).astype(acc_dt), g_acc, g))

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        return jax.lax.fori_loop(0, n_micro, mb, (jnp.float32(0.0), zeros))

    def train_step(state, batch):
        loss, grads = compute_grads(state["params"], batch)
        import os as _os
        if _os.environ.get("REPRO_GRAD_AR_DTYPE") == "bf16":
            # halve the DP gradient all-reduce wire cost (standard practice;
            # optimizer math stays f32 via clip_by_global_norm's upcast)
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        if grad_compression is not None:
            from repro.grad_comp import compressed_crosspod_mean
            grads = compressed_crosspod_mean(
                grads, axis_name=grad_compression.get("axis", "pod"),
                ratio=grad_compression.get("ratio", 0.01))
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, new_opt = optimizer.update(grads, state["opt"],
                                            state["params"], state["step"])
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - u.astype(jnp.float32)).astype(p.dtype),
            state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def train_loop(cfg: ModelConfig, *, steps: int, batch: int, seq_len: int,
               optimizer: OptimizerDef, data_iter, seed: int = 0,
               jit: bool = True, log_every: int = 10,
               remat: str = "none", microbatch=None,
               callback: Optional[Callable] = None):
    """Single-host reference loop (examples + integration tests)."""
    rng = jax.random.PRNGKey(seed)
    params = T.init_lm(rng, cfg)
    opt_state = optimizer.init(params)
    state = TrainState(params, opt_state, 0)
    step_fn = make_train_step(cfg, optimizer, remat=remat,
                              microbatch=microbatch)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    for s in range(steps):
        batch_data = data_iter(s)
        state, metrics = step_fn(state, batch_data)
        losses.append(float(metrics["loss"]))
        if callback is not None:
            callback(s, state, metrics)
    return state, losses
