"""Architecture registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

from .base import SHAPES, ShapeSpec

ARCHS = {
    "gemma2-2b": "gemma2_2b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-1.6b": "stablelm_1_6b",
    "stablelm-3b": "stablelm_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-base": "whisper_base",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}


def get_config(arch: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def list_archs():
    return list(ARCHS)
