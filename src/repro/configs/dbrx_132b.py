"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE on every layer.
[hf:databricks/dbrx-base; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100_352,
    layer_pattern="moe",
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="dbrx-132b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    layer_pattern="moe", n_experts=4, top_k=2,
)
