"""whisper-base [audio] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed 2x-downsampled frame embeddings).
[arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    n_layers=6,                      # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    layer_pattern="encdec",
    frontend="audio",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="whisper-base-reduced",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, layer_pattern="encdec", frontend="audio",
)
