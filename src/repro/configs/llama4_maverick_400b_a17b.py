"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, alternating
dense/MoE layers; early-fusion multimodal (text path here; the fusion
embeddings arrive via input_specs like the other frontend stubs).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    layer_pattern="moe_alt",        # dense / MoE alternation
    n_experts=128,
    top_k=1,
    rope_theta=500_000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="llama4-maverick-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    layer_pattern="moe_alt", n_experts=8, top_k=1, tie_embeddings=False,
)
