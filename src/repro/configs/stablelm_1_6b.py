"""stablelm-1.6b [dense] — 32 heads with kv=32 (full MHA-style GQA).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100_352,
    layer_pattern="dense",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="stablelm-1.6b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    layer_pattern="dense",
)
