"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256_000,
    head_dim=256,
    layer_pattern="local_global",
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
# 26 layers = 13 local/global super-blocks; sqrt(d) embedding scaling is
# enabled via logit_softcap (gemma family convention).

REDUCED = ModelConfig(
    name="gemma2-2b-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, layer_pattern="local_global", window=64,
    attn_softcap=50.0, logit_softcap=30.0,
)
