"""Shape grid and shared helpers for the assigned architecture pool.

Every (arch x shape) pair is one dry-run/roofline cell:
  * train_4k    : train_step,  seq 4096,   global batch 256
  * prefill_32k : prefill,     seq 32768,  global batch 32
  * decode_32k  : serve_step (1 new token, KV cache 32768), batch 128
  * long_500k   : serve_step (1 new token, KV/state 524288), batch 1
                  — sub-quadratic path required (roaring sparse attention for
                  quadratic archs; native linear for ssm/rwkv)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
