"""jamba-1.5-large-398b [hybrid] — Mamba + attention 7:1 interleave, MoE 16e
top-2 on alternate layers. [arXiv:2403.19887; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65_536,
    layer_pattern="jamba",          # 9 super-blocks of (7 mamba + 1 attn)
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-reduced",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    layer_pattern="jamba", n_experts=4, top_k=2, ssm_state=4, ssm_conv=4,
    ssm_expand=2,
)
