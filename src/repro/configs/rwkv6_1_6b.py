"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]

The paper's roaring-sparse-attention technique is INAPPLICABLE to this
attention-free architecture (DESIGN.md S5); roaring gradient compression and
the bitmap-indexed data pipeline still apply. long_500k runs natively (O(1)
state per token).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,                      # d / 64 notional (rwkv head size 64)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65_536,
    layer_pattern="rwkv",
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b-reduced",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256, vocab=512,
    layer_pattern="rwkv", tie_embeddings=False,
)
