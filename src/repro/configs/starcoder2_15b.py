"""starcoder2-15b [dense] — GQA kv=4, RoPE, GPT-style (non-gated) MLP.
[arXiv:2402.19173; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49_152,
    layer_pattern="dense",
    rope_theta=100_000.0,
    gated_mlp=False,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="starcoder2-15b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    layer_pattern="dense", gated_mlp=False,
)
