"""stablelm-3b [dense] — 32 heads with kv=32.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50_304,
    layer_pattern="dense",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="stablelm-3b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    layer_pattern="dense",
)
