"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision (STUB frontend:
input_specs provides precomputed patch embeddings). [arXiv:2409.12191; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152_064,
    layer_pattern="dense",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # t/h/w frequency split of head_dim/2 = 64
    frontend="vision",
    tie_embeddings=False,
    param_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, layer_pattern="dense", mrope_sections=(2, 3, 3),
    frontend="vision", tie_embeddings=False,
)
