"""Batched sharded query engine for wide Boolean queries over Roaring slabs.

Since the ``repro.roaring`` object API, the stacked-slab *type* is
``repro.roaring.RoaringSlab`` with a leading batch axis (built by
``roaring.stack``); this package keeps the expression layer:

  * ``engine`` — Boolean expression trees (AND/OR/ANDNOT over stack members
    or directly-attached ``leaf(slab)`` operands) evaluated as log-depth
    kind-dispatching tree reductions with a single deferred canonicalization,
    cardinality-only and top-k-by-cardinality scoring through the
    batched-meta dispatch kernel, and ``shard_map`` sharding of the slab
    axis across a device mesh.

``SlabStack`` / ``stack_from_slabs`` / ``union_many_batched`` remain as
deprecated shims over the ``repro.roaring`` equivalents.

Consumers: ``serve.kv_cache`` pool rebuilds, ``sparsity.masks`` pattern
unions, and ``grad_comp`` leaf-overlap scans.
"""

from repro.index.engine import (And, AndNot, DegradationStats, Expr, Leaf, Or,
                                SlabLeaf, and_, andnot, batched_and_card,
                                batched_and_card_sharded, degradation_stats,
                                execute, execute_card, launch_model, leaf,
                                or_, reset_degradation, topk_by_card,
                                topk_by_card_sharded, union_many_batched,
                                wide_intersect, wide_union)
from repro.index.stack import SlabStack, stack_from_slabs

__all__ = [
    "SlabStack", "stack_from_slabs",
    "Expr", "Leaf", "SlabLeaf", "And", "Or", "AndNot",
    "leaf", "and_", "or_", "andnot",
    "execute", "execute_card", "wide_union", "wide_intersect",
    "batched_and_card", "batched_and_card_sharded",
    "topk_by_card", "topk_by_card_sharded",
    "union_many_batched", "launch_model",
    "DegradationStats", "degradation_stats", "reset_degradation",
]
