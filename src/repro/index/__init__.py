"""Batched sharded query engine for wide Boolean queries over Roaring slabs.

Layers (bottom-up):

  * ``stack`` — ``SlabStack``: N key-aligned slabs packed into stacked
    arrays, aligned once so wide combines are pure leading-axis reductions;
  * ``engine`` — Boolean expression trees (AND/OR/ANDNOT over leaves)
    evaluated as log-depth kind-dispatching tree reductions with a single
    deferred canonicalization, cardinality-only and top-k-by-cardinality
    scoring through the batched-meta dispatch kernel, and ``shard_map``
    sharding of the slab axis across a device mesh.

Consumers: ``jax_roaring.union_many_slabs`` (the Algorithm 4 tree),
``serve.kv_cache`` pool rebuilds, ``sparsity.masks`` pattern unions, and
``grad_comp`` leaf-overlap scans.
"""

from repro.index.stack import SlabStack, stack_from_slabs
from repro.index.engine import (Expr, Leaf, And, Or, AndNot, leaf, and_, or_,
                                andnot, execute, execute_card, wide_union,
                                wide_intersect, batched_and_card,
                                batched_and_card_sharded, topk_by_card,
                                topk_by_card_sharded, union_many_batched)

__all__ = [
    "SlabStack", "stack_from_slabs",
    "Expr", "Leaf", "And", "Or", "AndNot",
    "leaf", "and_", "or_", "andnot",
    "execute", "execute_card", "wide_union", "wide_intersect",
    "batched_and_card", "batched_and_card_sharded",
    "topk_by_card", "topk_by_card_sharded",
    "union_many_batched",
]
