"""The batched wide-query executor: Boolean expression trees over a SlabStack.

The paper's headline wins are *horizontal*: Algorithm 4 unions many bitmaps
at once, and the library-grade Roaring implementations (CRoaring's
aggregation layer) earn their keep on exactly these wide AND/OR/ANDNOT
queries. This module evaluates an expression tree whose leaves are rows of a
key-aligned ``SlabStack``:

  * every binary combine is one *row-state* step from the kind-dispatch
    engine (``jax_roaring._and_rows`` / ``_or_rows`` / ``_andnot_rows``),
    classifying each aligned container pair against the declarative registry
    in ``kernels.roaring.dispatch`` — so run rows gallop/range-mask and
    sparse array pairs merge packed at *every* tree level, not just the
    leaves;
  * n-ary AND/OR nodes reduce in log depth (``_tree_reduce_rows`` over the
    stacked leaf axis when all children are leaves, balanced pairing
    otherwise);
  * canonicalization (best-of-three runOptimize) is deferred to a single
    ``_finalize_rows`` at the root — an N-way query pays one pass, not N-1;
  * cardinality-only evaluation (``execute_card``) skips materialization
    entirely: per-level fused popcounts are the whole answer;
  * ``batched_and_card`` / ``topk_by_card`` score *all* N stacked slabs
    against one query in a single batched-meta dispatch launch
    (``kernels.roaring.ops.intersect_dispatch_stacked``), and the
    ``*_sharded`` variants ``shard_map`` the slab axis across a device mesh
    (``launch/mesh.py``) with the query replicated.

Everything is jit-/vmap-compatible; expression shapes are static Python.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import jax_roaring as jr
from repro.index.stack import SlabStack

__all__ = [
    "Expr", "Leaf", "And", "Or", "AndNot",
    "leaf", "and_", "or_", "andnot",
    "execute", "execute_card", "wide_union", "wide_intersect",
    "batched_and_card", "batched_and_card_sharded",
    "topk_by_card", "topk_by_card_sharded",
    "union_many_batched",
]


# =============================================================================
# expression trees
# =============================================================================

@dataclasses.dataclass(frozen=True)
class Expr:
    """Base class for wide Boolean query expressions (static structure)."""


@dataclasses.dataclass(frozen=True)
class Leaf(Expr):
    """Slab ``i`` of the stack."""

    i: int


@dataclasses.dataclass(frozen=True)
class And(Expr):
    """N-ary intersection of child expressions (log-depth reduction)."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    """N-ary union of child expressions (log-depth reduction)."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class AndNot(Expr):
    """Difference ``a \\ b``."""

    a: Expr
    b: Expr


def leaf(i: int) -> Leaf:
    """Leaf selecting slab ``i`` of the stack (bounds-checked against the
    stack at evaluation time — jnp's silent index clamping must never turn
    a bad leaf into a plausible wrong answer)."""
    if int(i) < 0:
        raise ValueError(f"leaf index must be >= 0, got {i}")
    return Leaf(int(i))


def and_(*children: Expr) -> Expr:
    """N-ary AND node (``and_(x)`` collapses to ``x``; >= 1 child
    required — fail at construction, not mid-evaluation)."""
    if not children:
        raise ValueError("and_() needs at least one child expression")
    return children[0] if len(children) == 1 else And(tuple(children))


def or_(*children: Expr) -> Expr:
    """N-ary OR node (``or_(x)`` collapses to ``x``; >= 1 child
    required — fail at construction, not mid-evaluation)."""
    if not children:
        raise ValueError("or_() needs at least one child expression")
    return children[0] if len(children) == 1 else Or(tuple(children))


def andnot(a: Expr, b: Expr) -> AndNot:
    """Difference node ``a \\ b``."""
    return AndNot(a, b)


# =============================================================================
# evaluation (row states: (data u16[C, 4096], card i32[C], kind i32[C]))
# =============================================================================

def _leaf_state(stack: SlabStack, i: int):
    if not 0 <= i < stack.n_slabs:
        raise IndexError(
            f"leaf({i}) out of range for a stack of {stack.n_slabs} slabs")
    return stack.data[i], stack.card[i], stack.kind[i]


def _fold_states(states, combine):
    """Balanced pairwise fold (log depth) over already-evaluated states."""
    states = list(states)
    while len(states) > 1:
        nxt = []
        for i in range(0, len(states) - 1, 2):
            a, b = states[i], states[i + 1]
            nxt.append(combine(a[0], a[1], a[2], b[0], b[1], b[2]))
        if len(states) % 2:
            nxt.append(states[-1])
        states = nxt
    return states[0]


def _nary(stack: SlabStack, children, combine):
    if all(isinstance(c, Leaf) for c in children):
        # vectorized: slice the stacked leaf axis and tree-reduce flat —
        # every level is ONE combine over (n/2)*C rows, not n/2 traced calls
        for c in children:
            if not 0 <= c.i < stack.n_slabs:
                raise IndexError(f"leaf({c.i}) out of range for a stack of "
                                 f"{stack.n_slabs} slabs")
        idx = jnp.asarray([c.i for c in children], jnp.int32)
        return jr._tree_reduce_rows(stack.data[idx], stack.card[idx],
                                    stack.kind[idx], combine)
    return _fold_states([_eval(stack, c) for c in children], combine)


def _eval(stack: SlabStack, expr: Expr):
    if isinstance(expr, Leaf):
        return _leaf_state(stack, expr.i)
    if isinstance(expr, And):
        return _nary(stack, expr.children, jr._and_rows)
    if isinstance(expr, Or):
        return _nary(stack, expr.children, jr._or_rows)
    if isinstance(expr, AndNot):
        a = _eval(stack, expr.a)
        b = _eval(stack, expr.b)
        return jr._andnot_rows(a[0], a[1], a[2], b[0], b[1], b[2])
    raise TypeError(f"not an Expr: {expr!r}")


def execute(stack: SlabStack, expr: Expr) -> jr.RoaringSlab:
    """Evaluate ``expr`` over the stack -> canonical RoaringSlab.

    One deferred best-of-three canonicalization at the root; output is
    bit-identical (values, card, kind, packed payload) to evaluating the
    same expression with ``py_roaring`` set algebra.
    """
    data, card, kind = _eval(stack, expr)
    return jr._finalize_rows(stack.keys[0], data, card, kind)


def execute_card(stack: SlabStack, expr: Expr) -> jax.Array:
    """|expr| without materializing a result slab — every combine level
    already maintains exact per-row cardinalities (fused popcounts on the
    bitmap-domain paths), so the root's counter sum is the answer."""
    _, card, _ = _eval(stack, expr)
    return jnp.sum(card)


def wide_union(stack: SlabStack) -> jr.RoaringSlab:
    """Union of all N stacked slabs (Algorithm 4): log-depth tree reduction,
    kind-dispatching at every level, deferred cardinality (one recount at
    the root), single deferred canonicalization."""
    data, card, kind = jr._tree_reduce_rows(stack.data, stack.card,
                                            stack.kind, jr._or_rows_deferred)
    card = jr._recount_bitmap_rows(data, card, kind)
    return jr._finalize_rows(stack.keys[0], data, card, kind)


def wide_intersect(stack: SlabStack) -> jr.RoaringSlab:
    """Intersection of all N stacked slabs: log-depth tree of registry
    dispatch steps (arrays gallop, runs range-mask, bitmaps word-AND with
    fused popcount), single deferred canonicalization."""
    data, card, kind = jr._tree_reduce_rows(stack.data, stack.card,
                                            stack.kind, jr._and_rows)
    return jr._finalize_rows(stack.keys[0], data, card, kind)


# =============================================================================
# batched scoring: all N slabs against one query in one dispatch launch
# =============================================================================

def _align_query(stack: SlabStack, query: jr.RoaringSlab):
    """Gather the query's rows aligned to the stack's key row."""
    qd, qc, qk = jr._gather_raw(query, stack.keys[0])
    return qd, qc, qk, jr._rows_nruns(qd, qk)


def _stack_scores(data, card, kind, nruns, qd, qc, qk, qr):
    """Per-slab |slab_n ∩ query| via the stacked batched-meta dispatch."""
    from repro.kernels.roaring import ops as _kops
    N, C = kind.shape
    qdn = jnp.broadcast_to(qd, (N,) + qd.shape)
    meta = jnp.stack([
        kind, jnp.broadcast_to(qk, (N, C)),
        card, jnp.broadcast_to(qc, (N, C)),
        nruns, jnp.broadcast_to(qr, (N, C)),
    ], axis=2).reshape(N, 6 * C).astype(jnp.int32)
    _, rc = _kops.intersect_dispatch_stacked(data, qdn, meta)
    return jnp.sum(rc, axis=1)


def batched_and_card(stack: SlabStack, query: jr.RoaringSlab) -> jax.Array:
    """i32[N] of |slab_n ∩ query| — the wide-query scoring primitive.

    One ``intersect_dispatch_stacked`` launch covers all N*C container
    pairs (run x run pairs score via the in-kernel coverage AND); nothing is
    materialized or canonicalized.
    """
    qd, qc, qk, qr = _align_query(stack, query)
    return _stack_scores(stack.data, stack.card, stack.kind, stack.nruns,
                         qd, qc, qk, qr)


def topk_by_card(stack: SlabStack, query: jr.RoaringSlab, k: int):
    """Top-k stacked slabs by intersection cardinality with ``query``.

    Returns ``(scores i32[k], indices i32[k])`` — ``jax.lax.top_k`` over the
    batched scores (the "which posting lists match this query best"
    primitive).
    """
    return jax.lax.top_k(batched_and_card(stack, query), k)


# =============================================================================
# sharding: slab axis across the device mesh, query replicated
# =============================================================================

def _shard_map():
    try:                         # jax >= 0.4.35 exposes it at top level
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    return shard_map


def batched_and_card_sharded(stack: SlabStack, query: jr.RoaringSlab,
                             mesh, axis: str = "data") -> jax.Array:
    """``batched_and_card`` with the slab axis sharded over ``mesh[axis]``.

    Each device scores its N/axis_size shard of the stack against the
    replicated query locally (one stacked dispatch launch per device, no
    cross-device traffic until the caller reduces the i32[N] scores).
    ``stack.n_slabs`` must divide evenly by the mesh axis size.
    """
    from jax.sharding import PartitionSpec as P

    qd, qc, qk, qr = _align_query(stack, query)
    f = _shard_map()(
        _stack_scores, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(), P(), P()),
        out_specs=P(axis))
    return f(stack.data, stack.card, stack.kind, stack.nruns, qd, qc, qk, qr)


def topk_by_card_sharded(stack: SlabStack, query: jr.RoaringSlab, k: int,
                         mesh, axis: str = "data"):
    """Sharded ``topk_by_card``: local scoring per device shard, global
    ``top_k`` over the gathered i32[N] scores (k*axis_size candidate traffic,
    never slab payloads)."""
    return jax.lax.top_k(
        batched_and_card_sharded(stack, query, mesh, axis=axis), k)


# =============================================================================
# batched (vmapped) wide union — the mask-compiler consumer's shape
# =============================================================================

def union_many_batched(slabs: Sequence[jr.RoaringSlab],
                       capacity: int) -> jr.RoaringSlab:
    """N-way union vmapped over a leading batch axis.

    ``slabs``: N same-capacity RoaringSlabs whose arrays carry a leading
    batch axis ``[B, ...]`` (e.g. one slab per attention pattern, batched
    over mask rows). Returns the batched union slab ``[B, ...]`` — the tree
    reduction with its ``lax.cond`` laziness guards lowered to selects by
    vmap (every pass runs batched; correct, and still log-depth).
    """
    return jax.vmap(
        lambda *ss: jr.union_many_slabs(list(ss), capacity))(*slabs)
