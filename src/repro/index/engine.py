"""The batched wide-query executor: Boolean expression trees over stacked slabs.

The paper's headline wins are *horizontal*: Algorithm 4 unions many bitmaps
at once, and the library-grade Roaring implementations (CRoaring's
aggregation layer) earn their keep on exactly these wide AND/OR/ANDNOT
queries. This module evaluates an expression tree whose leaves are members
of a key-aligned stacked ``repro.roaring.RoaringSlab`` (``ndim == 2``) — or
``RoaringSlab`` objects attached to the tree directly via ``leaf(slab)``:

  * every binary combine is one *row-state* step from the kind-dispatch
    engine (``jax_roaring._and_rows`` / ``_or_rows`` / ``_andnot_rows``),
    classifying each aligned container pair against the declarative registry
    in ``kernels.roaring.dispatch`` — so run rows gallop/range-mask and
    sparse array pairs merge packed at *every* tree level, not just the
    leaves;
  * n-ary AND/OR nodes reduce in log depth (``_tree_reduce_rows`` over the
    stacked leaf axis when all children are stack members, balanced pairing
    otherwise);
  * canonicalization (best-of-three runOptimize) is deferred to a single
    ``_finalize_rows`` at the root — an N-way query pays one pass, not N-1;
  * cardinality-only evaluation (``execute_card``) skips materialization
    entirely: per-level fused popcounts are the whole answer;
  * ``batched_and_card`` / ``topk_by_card`` score *all* N stacked slabs
    against one query in a single batched-meta dispatch launch
    (``kernels.roaring.ops.intersect_dispatch_stacked``), and the
    ``*_sharded`` variants ``shard_map`` the slab axis across a device mesh
    (``launch/mesh.py``) with the query replicated.

``execute`` returns a canonical ``repro.roaring.RoaringSlab``. Everything is
jit-/vmap-compatible; expression shapes are static Python.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.core import jax_roaring as jr
from repro.kernels.roaring import fused as _fused
from repro.roaring.slab import RoaringSlab, SlabLike, _to_internal, _wrap

__all__ = [
    "Expr", "Leaf", "SlabLeaf", "And", "Or", "AndNot",
    "leaf", "and_", "or_", "andnot",
    "execute", "execute_card", "wide_union", "wide_intersect",
    "batched_and_card", "batched_and_card_sharded",
    "topk_by_card", "topk_by_card_sharded",
    "union_many_batched", "launch_model",
    "DegradationStats", "degradation_stats", "reset_degradation",
]


# =============================================================================
# expression trees
# =============================================================================

@dataclasses.dataclass(frozen=True)
class Expr:
    """Base class for wide Boolean query expressions (static structure)."""


@dataclasses.dataclass(frozen=True)
class Leaf(Expr):
    """Member ``i`` of the stacked slab."""

    i: int


@dataclasses.dataclass(frozen=True, eq=False)
class SlabLeaf(Expr):
    """A ``RoaringSlab`` operand attached to the tree directly (no stack
    membership, no manual tuple unpack) — its rows are gathered key-aligned
    to the query's shared key row at evaluation time."""

    slab: SlabLike


@dataclasses.dataclass(frozen=True)
class And(Expr):
    """N-ary intersection of child expressions (log-depth reduction)."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    """N-ary union of child expressions (log-depth reduction)."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class AndNot(Expr):
    """Difference ``a \\ b``."""

    a: Expr
    b: Expr


def leaf(x: Union[int, SlabLike]) -> Expr:
    """Leaf node: an ``int`` selects member ``x`` of the stacked slab
    (bounds-checked at evaluation time — jnp's silent index clamping must
    never turn a bad leaf into a plausible wrong answer); a ``RoaringSlab``
    becomes its own operand (``SlabLeaf``)."""
    if isinstance(x, (RoaringSlab, jr.RoaringSlab)):
        if isinstance(x, RoaringSlab) and x.ndim != 1:
            raise ValueError("leaf(slab) needs a single slab (ndim == 1)")
        return SlabLeaf(x)
    if int(x) < 0:
        raise ValueError(f"leaf index must be >= 0, got {x}")
    return Leaf(int(x))


def and_(*children: Expr) -> Expr:
    """N-ary AND node (``and_(x)`` collapses to ``x``; >= 1 child
    required — fail at construction, not mid-evaluation)."""
    if not children:
        raise ValueError("and_() needs at least one child expression")
    return children[0] if len(children) == 1 else And(tuple(children))


def or_(*children: Expr) -> Expr:
    """N-ary OR node (``or_(x)`` collapses to ``x``; >= 1 child
    required — fail at construction, not mid-evaluation)."""
    if not children:
        raise ValueError("or_() needs at least one child expression")
    return children[0] if len(children) == 1 else Or(tuple(children))


def andnot(a: Expr, b: Expr) -> AndNot:
    """Difference node ``a \\ b``."""
    return AndNot(a, b)


# =============================================================================
# evaluation (row states: (data u16[C, 4096], card i32[C], kind i32[C]))
# =============================================================================

def _slab_leaves(expr: Expr) -> list:
    if isinstance(expr, SlabLeaf):
        return [expr.slab]
    if isinstance(expr, (And, Or)):
        return [s for c in expr.children for s in _slab_leaves(c)]
    if isinstance(expr, AndNot):
        return _slab_leaves(expr.a) + _slab_leaves(expr.b)
    return []


def _shared_keys(stack: Optional[RoaringSlab], expr: Expr,
                 capacity: Optional[int]) -> jax.Array:
    """The shared key row every leaf aligns to: the stack's aligned key row
    when a stack is given, else the merged key set of all slab leaves.
    Slab leaves with keys outside the stack's row contribute nothing there —
    pass ``stack=None`` (or restack) when leaf keys may exceed the stack's.
    """
    if stack is not None:
        return stack.keys[0]
    slabs = [_to_internal(s) for s in _slab_leaves(expr)]
    if not slabs:
        raise ValueError("execute(stack=None, ...) needs slab leaves")
    if capacity is None:
        capacity = sum(s.keys.shape[-1] for s in slabs)
    return jr._merge_keys_many([s.keys for s in slabs], capacity)


def _leaf_state(stack: Optional[RoaringSlab], i: int):
    if stack is None:
        raise ValueError(f"leaf({i}) needs a stacked slab; this expression "
                         "was executed without one")
    if not 0 <= i < stack.n_slabs:
        raise IndexError(
            f"leaf({i}) out of range for a stack of {stack.n_slabs} slabs")
    return stack.payload[i], stack.cards[i], stack.kinds[i]


def _fold_states(states, combine):
    """Balanced pairwise fold (log depth) over already-evaluated states."""
    states = list(states)
    while len(states) > 1:
        nxt = []
        for i in range(0, len(states) - 1, 2):
            a, b = states[i], states[i + 1]
            nxt.append(combine(a[0], a[1], a[2], b[0], b[1], b[2]))
        if len(states) % 2:
            nxt.append(states[-1])
        states = nxt
    return states[0]


def _nary(stack, keys, children, combine):
    if stack is not None and all(isinstance(c, Leaf) for c in children):
        # vectorized: slice the stacked leaf axis and tree-reduce flat —
        # every level is ONE combine over (n/2)*C rows, not n/2 traced calls
        for c in children:
            if not 0 <= c.i < stack.n_slabs:
                raise IndexError(f"leaf({c.i}) out of range for a stack of "
                                 f"{stack.n_slabs} slabs")
        idx = jnp.asarray([c.i for c in children], jnp.int32)
        return jr._tree_reduce_rows(stack.payload[idx], stack.cards[idx],
                                    stack.kinds[idx], combine)
    return _fold_states([_eval(stack, keys, c) for c in children], combine)


def _eval(stack, keys, expr: Expr):
    if isinstance(expr, Leaf):
        return _leaf_state(stack, expr.i)
    if isinstance(expr, SlabLeaf):
        return jr._gather_raw(_to_internal(expr.slab), keys)
    if isinstance(expr, And):
        return _nary(stack, keys, expr.children, jr._and_rows)
    if isinstance(expr, Or):
        return _nary(stack, keys, expr.children, jr._or_rows)
    if isinstance(expr, AndNot):
        a = _eval(stack, keys, expr.a)
        b = _eval(stack, keys, expr.b)
        return jr._andnot_rows(a[0], a[1], a[2], b[0], b[1], b[2])
    raise TypeError(f"not an Expr: {expr!r}")


def _normalize(stack, expr):
    """Allow ``execute(expr)`` when every leaf carries its own slab."""
    if isinstance(stack, Expr) and expr is None:
        return None, stack
    if expr is None:
        raise TypeError("execute needs an expression")
    return stack, expr


# =============================================================================
# fused evaluation: the whole tree in ONE launch (kernels.roaring.fused)
# =============================================================================

def _lower_tree(expr: Expr) -> tuple:
    """Structural lowering shared by the fused compiler and the launch
    model: ``(tree, order)`` where ``tree`` is the hash-consable structure
    with distinct leaves replaced by dense operand indices and ``order`` the
    deduplicated leaf list — a leaf referenced twice streams from HBM
    once."""
    order: list = []
    index_of: dict = {}

    def visit(e):
        if isinstance(e, Leaf):
            key = ("leaf", e.i)
        elif isinstance(e, SlabLeaf):
            key = ("slab", id(e.slab))
        elif isinstance(e, And):
            return ("and",) + tuple(visit(c) for c in e.children)
        elif isinstance(e, Or):
            return ("or",) + tuple(visit(c) for c in e.children)
        elif isinstance(e, AndNot):
            return ("andnot", visit(e.a), visit(e.b))
        else:
            raise TypeError(f"not an Expr: {e!r}")
        if key not in index_of:
            index_of[key] = len(order)
            order.append(e)
        return index_of[key]

    return visit(expr), order


def launch_model(expr: Expr, *, stacked: bool = True) -> dict:
    """Analytic kernel-launch accounting for one expression — the model the
    ``repro.obs`` telemetry plane cross-checks measured launch counters
    against (``obs.launch_crosscheck``).

    Two granularities matter. ``per_op_combines`` is the roofline model's
    logical combine count (``fused.plan_stats``'s ``launches_per_op``:
    N-1 for an N-leaf tree). ``per_op_dispatches`` is what the per-op
    engine *actually* launches through ``ops.intersect_dispatch``: AND
    combines over all-``Leaf`` children batch into a log-depth tree reduce
    (``ceil(log2 n)`` dispatches when ``stacked``, the ``execute``
    default), mixed-children ANDs fold pairwise (n-1 dispatches), and
    OR/ANDNOT combines run as jnp-level row algebra — zero kernel
    dispatches. ``fused_launches`` is always 1: the whole tree is one
    ``ops.fused_tree`` launch.
    """
    tree, order = _lower_tree(expr)
    plan = _fused.plan_tape(tree)

    def dispatches(e) -> int:
        if isinstance(e, (Leaf, SlabLeaf)):
            return 0
        if isinstance(e, And):
            n = len(e.children)
            if stacked and all(isinstance(c, Leaf) for c in e.children):
                return (n - 1).bit_length()
            return (n - 1) + sum(dispatches(c) for c in e.children)
        if isinstance(e, Or):
            return sum(dispatches(c) for c in e.children)
        if isinstance(e, AndNot):
            return dispatches(e.a) + dispatches(e.b)
        raise TypeError(f"not an Expr: {e!r}")

    return {
        "n_operands": len(order),
        "per_op_combines": int(plan.n_ops),
        "per_op_dispatches": dispatches(expr),
        "fused_launches": 1,
    }


def _fused_compile(stack, keys, expr: Expr):
    """Lower an ``Expr`` to the fused evaluator's inputs: the structural
    tree (via ``_lower_tree``), the stacked operand rows u16[N, C, 4096],
    and the packed lift meta."""
    tree, order = _lower_tree(expr)
    states = []
    for e in order:
        if isinstance(e, Leaf):
            d, c, k = _leaf_state(stack, e.i)
            r = stack.nruns[e.i]
        else:
            d, c, k = jr._gather_raw(_to_internal(e.slab), keys)
            r = jr._rows_nruns(d, k)
        states.append((d, c, k, r))
    data = jnp.stack([s[0] for s in states])
    meta = _fused.pack_lift_meta(jnp.stack([s[2] for s in states]),
                                 jnp.stack([s[1] for s in states]),
                                 jnp.stack([s[3] for s in states]))
    return _fused.plan_tape(tree), data, meta


def _fused_eval(stack, keys, expr: Expr):
    """Row-state result of the fused path: one ``ops.fused_tree`` launch,
    root rows in bitmap domain (kind from the fused per-column card)."""
    from repro.kernels.roaring import ops as _kops

    plan, data, meta = _fused_compile(stack, keys, expr)
    bits, card = _kops.fused_tree(data, meta, plan)
    kind = jnp.where(card > 0, jr.KIND_BITMAP, jr.KIND_EMPTY).astype(
        jnp.int32)
    # empty rows carry the packed-array padding fill, matching the per-op
    # pipeline's convention for dead payloads
    bits = jnp.where((card > 0)[:, None], bits, jnp.uint16(0xFFFF))
    return bits, card, kind


# =============================================================================
# graceful degradation: the Pallas -> XLA-ref fallback ladder
# =============================================================================

@dataclasses.dataclass
class DegradationStats:
    """Snapshot view of the engine's failure-ladder counters: how many
    dispatch attempts failed, how many retries the preferred backend got,
    and how many queries completed degraded on a lower rung.

    PR 9: the live counters moved to the ``repro.obs`` metrics registry
    (``index.dispatch_failures`` / ``index.retries`` / ``index.fallbacks``,
    plus per-rung ``index.rung_taken{kind,backend}``); this class survives
    as the deprecated ``degradation_stats()`` return type."""

    dispatch_failures: int = 0
    retries: int = 0
    fallbacks: int = 0

    def snapshot(self) -> "DegradationStats":
        return DegradationStats(self.dispatch_failures, self.retries,
                                self.fallbacks)


# failure classes the ladder absorbs: injected faults and device/runtime
# errors (preemption, OOM, ICI failures surface as XlaRuntimeError, a
# JaxRuntimeError subclass; RuntimeError covers interpret-mode lowering
# failures). Shape/type/user errors (ValueError, IndexError, ...) propagate
# untouched — degrading cannot fix a malformed query.
_FALLBACK_ERRORS = (RuntimeError, jax.errors.JaxRuntimeError)

_LADDER_COUNTERS = ("index.dispatch_failures", "index.retries",
                    "index.fallbacks", "index.rung_taken")


def degradation_stats() -> DegradationStats:
    """Deprecated: read the ``repro.obs`` registry instead —
    ``obs.registry().value("index.dispatch_failures")`` etc. This shim
    snapshots those counters into the legacy ``DegradationStats`` shape."""
    import warnings

    warnings.warn(
        "repro.index.degradation_stats() is deprecated; read the "
        "repro.obs metrics registry ('index.dispatch_failures', "
        "'index.retries', 'index.fallbacks') instead",
        DeprecationWarning, stacklevel=2)
    reg = obs.registry()
    return DegradationStats(
        int(reg.value("index.dispatch_failures")),
        int(reg.value("index.retries")),
        int(reg.value("index.fallbacks")))


def reset_degradation() -> None:
    """Zero the engine-wide degradation counters (test isolation)."""
    reg = obs.registry()
    for name in _LADDER_COUNTERS:
        reg.remove(name)


def _run_ladder(rungs, max_retries: int, backoff_s: float):
    """Run the first workable rung of ``rungs``: ordered ``(backend, kind,
    fn)`` triples, most-preferred first (``kind`` is the evaluator rung:
    ``"fused"`` / ``"per_op"``).

    The first rung gets ``max_retries`` retries with exponential backoff
    (transient device faults deserve a second chance before giving up on
    the fast path); later rungs get one attempt each. Every failed attempt
    counts in ``index.dispatch_failures``; every rung drop counts in
    ``index.fallbacks``; the winning rung counts in
    ``index.rung_taken{kind,backend}``. Each attempt runs under an
    ``index.rung`` span, so injected faults show up as errored child spans
    in the trace. A failure on the last rung propagates — there is nothing
    left to degrade to.
    """
    from repro.kernels.roaring import ops as _kops

    reg = obs.registry()
    for r, (rung_backend, rung_kind, fn) in enumerate(rungs):
        tries = (max_retries + 1) if r == 0 else 1
        for attempt in range(tries):
            try:
                with obs.span("index.rung", kind=rung_kind,
                              backend=rung_backend, attempt=attempt):
                    with _kops.backend_scope(rung_backend):
                        out = fn()
                reg.counter("index.rung_taken", kind=rung_kind,
                            backend=rung_backend).inc()
                return out
            except _FALLBACK_ERRORS:
                if r == len(rungs) - 1 and attempt == tries - 1:
                    raise
                reg.counter("index.dispatch_failures").inc()
                if attempt < tries - 1:
                    reg.counter("index.retries").inc()
                    if backoff_s > 0:
                        time.sleep(backoff_s * (2 ** attempt))
        reg.counter("index.fallbacks").inc()


def _run_degradable(fn, backend: Optional[str], max_retries: int,
                    backoff_s: float):
    """Run ``fn`` with the per-op Pallas->XLA-ref fallback ladder.

    ``backend=None``/"auto" resolves to the hardware default. A preferred
    non-"xla" backend gets ``max_retries`` retries with exponential backoff;
    when they are exhausted the query degrades to the XLA reference backend
    (bit-identical math, counted in the registry's ``index.fallbacks``).
    """
    from repro.kernels.roaring import ops as _kops

    preferred = backend or _kops.current_backend()
    if preferred == "xla":
        with obs.span("index.rung", kind="per_op", backend="xla"):
            with _kops.backend_scope("xla"):
                out = fn()
        obs.registry().counter("index.rung_taken", kind="per_op",
                               backend="xla").inc()
        return out
    return _run_ladder([(preferred, "per_op", fn), ("xla", "per_op", fn)],
                       max_retries, backoff_s)


def _run_query(fused_fn, per_op_fn, fused: bool, backend: Optional[str],
               max_retries: int, backoff_s: float):
    """Ladder selection for one query: ``fused=False`` runs the classic
    two-rung per-op ladder; ``fused=True`` prepends the fused evaluator —
    preferred-backend-fused -> preferred-backend-per-op -> XLA-ref-per-op
    (the per-op tree-reduce stays the bit-identity reference and the rung
    of last resort)."""
    from repro.kernels.roaring import ops as _kops

    if not fused:
        return _run_degradable(per_op_fn, backend, max_retries, backoff_s)
    preferred = backend or _kops.current_backend()
    rungs = [(preferred, "fused", fused_fn), (preferred, "per_op", per_op_fn)]
    if preferred != "xla":
        rungs.append(("xla", "per_op", per_op_fn))
    return _run_ladder(rungs, max_retries, backoff_s)


def execute(stack: Optional[RoaringSlab], expr: Optional[Expr] = None,
            capacity: Optional[int] = None, *, fused: bool = False,
            backend: Optional[str] = None, max_retries: int = 1,
            backoff_s: float = 0.0) -> RoaringSlab:
    """Evaluate ``expr`` over the stacked slab -> canonical ``RoaringSlab``.

    One deferred best-of-three canonicalization at the root; output is
    bit-identical (values, card, kind, packed payload) to evaluating the
    same expression with ``py_roaring`` set algebra. ``stack`` may be
    ``None`` (or omitted) when every leaf is a ``leaf(slab)`` — the shared
    key row is then the merged key set of the slab leaves (``capacity``
    bounds it, defaulting to the sum of leaf capacities).

    ``fused=True`` evaluates the whole tree in ONE kernel launch
    (``kernels.roaring.fused``): leaves stream from HBM once, every
    intermediate stays in VMEM scratch, and the per-op tree-reduce becomes
    the fallback rung — same bytes out either way.

    ``backend`` picks the dispatch backend ("pallas" / "xla" / None=auto).
    Dispatch failures on a non-"xla" backend (real device faults or a
    ``runtime.fault_tolerance.FaultPlan``) retry ``max_retries`` times with
    exponential backoff, then degrade rung by rung — fused to per-op,
    preferred backend to the XLA reference — incrementing the ladder
    counters on the ``repro.obs`` registry while results stay
    bit-identical.
    """
    stack, expr = _normalize(stack, expr)
    keys = _shared_keys(stack, expr, capacity)

    def per_op() -> RoaringSlab:
        data, card, kind = _eval(stack, keys, expr)
        return _wrap(jr._finalize_rows(keys, data, card, kind))

    def fused_attempt() -> RoaringSlab:
        data, card, kind = _fused_eval(stack, keys, expr)
        return _wrap(jr._finalize_rows(keys, data, card, kind))

    with obs.span("index.execute", fused=fused, backend=backend or "auto"):
        if obs.enabled() and stack is not None:
            obs.record_kinds("index.input_kinds", stack.kinds)
        out = _run_query(fused_attempt, per_op, fused, backend, max_retries,
                         backoff_s)
        if obs.enabled():
            obs.record_kinds("index.output_kinds", out.kinds)
        return out


def execute_card(stack: Optional[RoaringSlab],
                 expr: Optional[Expr] = None,
                 capacity: Optional[int] = None, *, fused: bool = False,
                 backend: Optional[str] = None, max_retries: int = 1,
                 backoff_s: float = 0.0) -> jax.Array:
    """|expr| without materializing a result slab — every combine level
    already maintains exact per-row cardinalities (fused popcounts on the
    bitmap-domain paths), so the root's counter sum is the answer.
    ``fused=True`` gets it from the mega-kernel's per-column root popcount
    (one launch, no canonicalization at all). Runs the same degradation
    ladder as ``execute``."""
    stack, expr = _normalize(stack, expr)
    keys = _shared_keys(stack, expr, capacity)

    def per_op() -> jax.Array:
        _, card, _ = _eval(stack, keys, expr)
        return jnp.sum(card)

    def fused_attempt() -> jax.Array:
        _, card, _ = _fused_eval(stack, keys, expr)
        return jnp.sum(card)

    with obs.span("index.execute_card", fused=fused,
                  backend=backend or "auto"):
        if obs.enabled() and stack is not None:
            obs.record_kinds("index.input_kinds", stack.kinds)
        return _run_query(fused_attempt, per_op, fused, backend, max_retries,
                          backoff_s)


def wide_union(stack: RoaringSlab) -> RoaringSlab:
    """Union of all N stacked slabs (Algorithm 4): log-depth tree reduction,
    kind-dispatching at every level, deferred cardinality (one recount at
    the root), single deferred canonicalization."""
    data, card, kind = jr._tree_reduce_rows(stack.payload, stack.cards,
                                            stack.kinds, jr._or_rows_deferred)
    card = jr._recount_bitmap_rows(data, card, kind)
    return _wrap(jr._finalize_rows(stack.keys[0], data, card, kind))


def wide_intersect(stack: RoaringSlab) -> RoaringSlab:
    """Intersection of all N stacked slabs: log-depth tree of registry
    dispatch steps (arrays gallop, runs range-mask, bitmaps word-AND with
    fused popcount), single deferred canonicalization."""
    data, card, kind = jr._tree_reduce_rows(stack.payload, stack.cards,
                                            stack.kinds, jr._and_rows)
    return _wrap(jr._finalize_rows(stack.keys[0], data, card, kind))


# =============================================================================
# batched scoring: all N slabs against one query in one dispatch launch
# =============================================================================

def _align_query(stack: RoaringSlab, query: SlabLike):
    """Gather the query's rows aligned to the stack's key row."""
    qd, qc, qk = jr._gather_raw(_to_internal(query), stack.keys[0])
    return qd, qc, qk, jr._rows_nruns(qd, qk)


def _stack_scores(data, card, kind, nruns, qd, qc, qk, qr):
    """Per-slab |slab_n ∩ query| via the stacked batched-meta dispatch."""
    from repro.kernels.roaring import ops as _kops
    N, C = kind.shape
    qdn = jnp.broadcast_to(qd, (N,) + qd.shape)
    meta = jnp.stack([
        kind, jnp.broadcast_to(qk, (N, C)),
        card, jnp.broadcast_to(qc, (N, C)),
        nruns, jnp.broadcast_to(qr, (N, C)),
    ], axis=2).reshape(N, 6 * C).astype(jnp.int32)
    _, rc = _kops.intersect_dispatch_stacked(data, qdn, meta)
    return jnp.sum(rc, axis=1)


def batched_and_card(stack: RoaringSlab, query: SlabLike) -> jax.Array:
    """i32[N] of |slab_n ∩ query| — the wide-query scoring primitive.

    One ``intersect_dispatch_stacked`` launch covers all N*C container
    pairs (run x run pairs score via the in-kernel coverage AND); nothing is
    materialized or canonicalized.
    """
    qd, qc, qk, qr = _align_query(stack, query)
    return _stack_scores(stack.payload, stack.cards, stack.kinds, stack.nruns,
                         qd, qc, qk, qr)


def topk_by_card(stack: RoaringSlab, query: SlabLike, k: int):
    """Top-k stacked slabs by intersection cardinality with ``query``.

    Returns ``(scores i32[k], indices i32[k])`` — ``jax.lax.top_k`` over the
    batched scores (the "which posting lists match this query best"
    primitive).
    """
    return jax.lax.top_k(batched_and_card(stack, query), k)


# =============================================================================
# sharding: slab axis across the device mesh, query replicated
# =============================================================================

def _shard_map():
    try:                         # jax >= 0.4.35 exposes it at top level
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    return shard_map


def batched_and_card_sharded(stack: RoaringSlab, query: SlabLike,
                             mesh, axis: str = "data") -> jax.Array:
    """``batched_and_card`` with the slab axis sharded over ``mesh[axis]``.

    Each device scores its N/axis_size shard of the stack against the
    replicated query locally (one stacked dispatch launch per device, no
    cross-device traffic until the caller reduces the i32[N] scores).
    ``stack.n_slabs`` must divide evenly by the mesh axis size.
    """
    from jax.sharding import PartitionSpec as P

    qd, qc, qk, qr = _align_query(stack, query)
    f = _shard_map()(
        _stack_scores, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(), P(), P()),
        out_specs=P(axis))
    return f(stack.payload, stack.cards, stack.kinds, stack.nruns,
             qd, qc, qk, qr)


def topk_by_card_sharded(stack: RoaringSlab, query: SlabLike, k: int,
                         mesh, axis: str = "data"):
    """Sharded ``topk_by_card``: local scoring per device shard, global
    ``top_k`` over the gathered i32[N] scores (k*axis_size candidate traffic,
    never slab payloads)."""
    return jax.lax.top_k(
        batched_and_card_sharded(stack, query, mesh, axis=axis), k)


# =============================================================================
# batched (vmapped) wide union — deprecated shim over repro.roaring.union_all
# =============================================================================

def union_many_batched(slabs: Sequence[SlabLike],
                       capacity: int) -> RoaringSlab:
    """Deprecated: use ``repro.roaring.union_all`` (same vmapped tree)."""
    import warnings

    from repro.roaring.slab import union_all
    warnings.warn(
        "repro.index.union_many_batched is deprecated; use "
        "repro.roaring.union_all(slabs, capacity=...)",
        DeprecationWarning, stacklevel=2)
    return union_all(slabs, capacity=capacity)
