"""Deprecated module: ``SlabStack`` is absorbed by ``repro.roaring``.

A stacked slab is just a ``repro.roaring.RoaringSlab`` whose leaves carry a
leading batch axis (``ndim == 2``) — ``roaring.stack(slabs)`` performs the
one-time key alignment the old ``stack_from_slabs`` did, and the expression
executor, the batched-meta dispatch kernel, and the ``shard_map`` scoring
variants all consume the same type.

``stack_from_slabs`` is a working shim (``DeprecationWarning``).
``SlabStack`` is only a *typing/isinstance* alias: the old NamedTuple
interface is gone — field names changed (``card``/``kind``/``data`` →
``cards``/``kinds``/``payload``), ``.slab(i)`` is ``s[i]``, and
``isinstance(x, SlabStack)`` now matches any ``RoaringSlab`` regardless of
batch shape. See ``docs/MIGRATION.md``.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

from repro.roaring.slab import RoaringSlab, SlabLike
from repro.roaring.slab import stack as _stack

__all__ = ["SlabStack", "stack_from_slabs"]

# deprecated alias: the stacked-slab *type* is the object API type itself
SlabStack = RoaringSlab


def stack_from_slabs(slabs: Sequence[SlabLike],
                     capacity: Optional[int] = None) -> RoaringSlab:
    """Deprecated: use ``repro.roaring.stack`` (same alignment semantics)."""
    warnings.warn(
        "repro.index.stack_from_slabs is deprecated; use "
        "repro.roaring.stack(slabs, capacity=...)",
        DeprecationWarning, stacklevel=2)
    return _stack(slabs, capacity=capacity)
