"""SlabStack: N key-aligned Roaring slabs packed into stacked arrays.

The wide-query layout (paper Algorithm 4, the 2018 CRoaring paper's
aggregation layer): a Boolean query over many bitmaps wants every operand's
container for chunk ``k`` resident in the *same* row position, so the
N-way combine is a pure leading-axis reduction with no per-level key
re-alignment. ``stack_from_slabs`` pays the alignment once — merged key
set, one gather per slab — and everything downstream (the expression
executor, the batched-meta dispatch kernel, ``shard_map`` sharding over the
slab axis) indexes ``[n, c]`` directly.

Layout (``C = capacity``, static):

``keys  i32[N, C]``  per-slab key rows — all identical after alignment
                     (``keys[0]`` is *the* key row), ``KEY_SENTINEL`` padded
``card  i32[N, C]``  per-row cardinality counters
``kind  i32[N, C]``  container kind tags (0 empty / 1 array / 2 bitmap / 3 run)
``nruns i32[N, C]``  per-row run counts (0 for non-run rows) — precomputed so
                     the dispatch kernels' scalar-prefetch meta is a reshape,
                     not a payload scan per query
``data  u16[N, C, 4096]``  raw container rows in native form (packed arrays /
                     bitmap words / run pairs — never lifted)
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import jax_roaring as jr

__all__ = ["SlabStack", "stack_from_slabs"]


class SlabStack(NamedTuple):
    """N key-aligned slabs as stacked arrays (see module docstring)."""

    keys: jax.Array    # i32[N, C]
    card: jax.Array    # i32[N, C]
    kind: jax.Array    # i32[N, C]
    nruns: jax.Array   # i32[N, C]
    data: jax.Array    # u16[N, C, 4096]

    @property
    def n_slabs(self) -> int:
        return self.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.keys.shape[1]

    def slab(self, i: int) -> jr.RoaringSlab:
        """Row ``i`` back as a plain (non-canonicalized) RoaringSlab view."""
        return jr.RoaringSlab(keys=self.keys[i], card=self.card[i],
                              kind=self.kind[i], data=self.data[i])


def stack_from_slabs(slabs: Sequence[jr.RoaringSlab],
                     capacity: int | None = None) -> SlabStack:
    """Pack N slabs into one key-aligned SlabStack.

    The merged key set over all N slabs is computed once (sort + dedupe of
    the concatenated key columns); each slab's rows are then gathered
    key-aligned in native container form — a slab missing a key contributes
    an EMPTY row there. ``capacity`` is the static output key capacity and
    must cover the merged distinct key count (defaults, conservatively, to
    the sum of input capacities). Per-row run counts are precomputed into
    ``nruns`` so downstream dispatch meta is assembly-free.
    """
    if not slabs:
        raise ValueError("stack_from_slabs needs at least one slab")
    if capacity is None:
        capacity = sum(s.capacity for s in slabs)
    keys = jr._merge_keys_many([s.keys for s in slabs], capacity)
    gathered = [jr._gather_raw(s, keys) for s in slabs]
    data = jnp.stack([g[0] for g in gathered])
    card = jnp.stack([g[1] for g in gathered])
    kind = jnp.stack([g[2] for g in gathered])
    nruns = jnp.stack([jr._rows_nruns(g[0], g[2]) for g in gathered])
    return SlabStack(keys=jnp.broadcast_to(keys, (len(slabs),) + keys.shape),
                     card=card, kind=kind, nruns=nruns, data=data)
