import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST stay first: jax locks the device count at first
# initialization, and the production meshes need 512 host placeholder
# devices. (Tests/benches import other entry points and see 1 device.)
#
# Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
# cell against the production meshes, prove memory fits
# (``memory_analysis``), and extract the roofline inputs (HLO FLOPs/bytes,
# per-device collective bytes with layer-scan trip-count correction, and the
# analytic FLOP model) into artifacts/dryrun/*.json.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun \
#       --archs all --shapes all --meshes single,multi --out artifacts/dryrun

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models import flops as F

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12            # bf16 FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link

# cheap-first ordering: fast feedback, giants last
ARCH_ORDER = [
    "whisper-base", "stablelm-1.6b", "rwkv6-1.6b", "gemma2-2b",
    "stablelm-3b", "starcoder2-15b", "qwen2-vl-72b", "dbrx-132b",
    "llama4-maverick-400b-a17b", "jamba-1.5-large-398b",
]


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape}__{mesh_name}"
    path = os.path.join(out_dir, cell_id + ".json")
    os.makedirs(out_dir, exist_ok=True)
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            print(f"[{cell_id}] cached ok")
            return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    spec = SHAPES[shape]
    cfg = get_config(arch)
    record = {"cell": cell_id, "arch": arch, "shape": shape,
              "mesh": dict(mesh.shape), "chips": n_chips, "ok": False}
    try:
        from repro.distributed.context import data_axes
        fn, args_sds, in_sh, donate, meta = build_cell(arch, shape, mesh)
        record.update(meta)
        batch_axes = ("pod", "data", "model") \
            if os.environ.get("REPRO_SHARDING_MODE") == "replicate" \
            else ("pod", "data")
        daxes = [a for a in batch_axes if a in mesh.shape]
        dcount = int(np.prod([mesh.shape[a] for a in daxes]))
        t0 = time.time()
        with mesh, data_axes(daxes, dcount):
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args_sds)
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        print(f"[{cell_id}] memory_analysis: {record['memory_analysis']}",
              flush=True)
        ca = compiled.cost_analysis() or {}
        record["cost_analysis"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        }
        print(f"[{cell_id}] cost_analysis: "
              f"flops={record['cost_analysis']['flops']:.3e} "
              f"bytes={record['cost_analysis']['bytes_accessed']:.3e}",
              flush=True)

        # collective bytes from the partitioned module; while-bodies are
        # multiplied by known trip counts. Depth order: microbatch loop (if
        # any) is outermost, then the layer scan, then sequence/flash scans.
        inner = max(spec.seq_len // 512, 1)
        micro = record.get("microbatch")
        n_micro = (spec.global_batch // micro) if micro else None
        trips = ([n_micro] if n_micro else []) + \
            [cfg.n_superblocks, inner, inner]
        hlo = compiled.as_text()
        per_kind, total_coll, counts = collective_bytes(hlo, trips)
        record["collectives"] = {"per_kind": per_kind, "counts": counts,
                                 "per_device_bytes": total_coll,
                                 "trip_counts": trips}

        # analytic FLOP/byte model (XLA cost analysis counts loop bodies
        # once; see models/flops.py and tests/test_flops_model.py).
        # Roaring active-window decode shrinks the live KV (long_window).
        seq_eff = record.get("long_window", spec.seq_len)
        fc = F.cell_flops(cfg, kind=spec.kind, seq_len=seq_eff,
                          global_batch=spec.global_batch)
        mf = F.model_flops_reference(cfg, kind=spec.kind,
                                     seq_len=seq_eff,
                                     global_batch=spec.global_batch)
        hbm = F.cell_hbm_bytes(cfg, kind=spec.kind, seq_len=seq_eff,
                               global_batch=spec.global_batch,
                               optimizer=record.get("optimizer", "adamw"))
        record["analytic"] = {
            "flops_total": fc.total, "flops_matmul": fc.matmul,
            "flops_attention": fc.attention,
            "flops_elementwise": fc.elementwise,
            "model_flops_ref": mf, "hbm_bytes": hbm}

        compute_term = fc.total / (n_chips * PEAK_FLOPS)
        memory_term = hbm / (n_chips * HBM_BW)
        collective_term = total_coll / ICI_BW
        terms = {"compute_s": compute_term, "memory_s": memory_term,
                 "collective_s": collective_term}
        dominant = max(terms, key=terms.get)
        record["roofline"] = {
            **terms, "dominant": dominant,
            "useful_ratio": mf / max(fc.total, 1.0),
            "roofline_fraction": compute_term / max(sum(terms.values()), 1e-30),
        }
        record["ok"] = True
        print(f"[{cell_id}] roofline: compute={compute_term:.4f}s "
              f"memory={memory_term:.4f}s collective={collective_term:.4f}s "
              f"dominant={dominant}", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]
        print(f"[{cell_id}] FAILED: {record['error']}", flush=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="all")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_ORDER if args.archs == "all" else args.archs.split(",")
    shapes = list(SHAPES) if args.shapes == "all" else args.shapes.split(",")
    meshes = args.meshes.split(",")

    results = []
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                rec = run_cell(arch, shape, m == "multi", args.out,
                               skip_existing=not args.no_skip_existing)
                results.append(rec)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n=== dry-run: {ok}/{len(results)} cells ok ===")
    for r in results:
        if not r.get("ok"):
            print(f"  FAILED {r['cell']}: {r.get('error', '?')}")


if __name__ == "__main__":
    main()
