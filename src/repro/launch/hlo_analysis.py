"""Post-SPMD HLO analysis: collective bytes, loop-aware accounting.

``compiled.as_text()`` is the partitioned per-device module; collective ops
appear with their *per-device* result shapes. XLA's HloCostAnalysis counts
while-loop bodies once, so ops inside the layer scan must be multiplied by
the trip count — computations are walked with their while-nesting depth and
the caller supplies per-depth trip counts (depth 1 = layer scan, deeper =
inner scans like flash/ssm over sequence blocks).

Parsing notes (validated against jax 0.8 / XLA HLO text):
  * computation headers look like ``%region_4.4_spmd (arg: (...)) -> (...) {``
    — parameter lists nest parentheses, so headers are matched on the
    trailing ``-> ... {`` instead of a balanced-paren scan;
  * while ops carry ``condition=%name, body=%name``;
  * async collectives appear as ``<kind>-start`` / ``<kind>-done`` pairs —
    only the ``-start`` (or the sync form) is counted.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"=\s*(?P<type>.*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\(")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every array shape in a (possibly tuple) type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> instruction lines (flat; bodies end at '}')"""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            current = m.group(1)
            comps[current] = []
            continue
        stripped = line.strip()
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None and "=" in stripped:
            comps[current].append(stripped)
    return comps


def while_body_depths(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """While-nesting depth per computation (0 = outside any while)."""
    parent_while: Dict[str, str] = {}    # body/cond comp -> comp with the while
    called_by: Dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln or "= while(" in ln:
                for m in _WHILE_BODY_RE.finditer(ln):
                    parent_while[m.group(1)] = cname
                for m in _WHILE_COND_RE.finditer(ln):
                    parent_while[m.group(1)] = cname
            for m in _CALL_RE.finditer(ln):
                called_by.setdefault(m.group(1), cname)

    def depth(c, seen=frozenset()):
        if c in seen:
            return 0
        seen = seen | {c}
        if c in parent_while:
            return 1 + depth(parent_while[c], seen)
        if c in called_by:
            return depth(called_by[c], seen)
        return 0

    return {c: depth(c) for c in comps}


def collective_bytes(hlo_text: str, trip_counts: List[int] | None = None):
    """Returns (per_kind bytes, total bytes, per_kind counts), loop-aware.

    ``trip_counts[d]`` multiplies ops at while depth d+1 (cumulative).
    Missing depths default to 1.
    """
    trip_counts = trip_counts or []
    comps = parse_computations(hlo_text)
    depths = while_body_depths(comps)
    per_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for cname, lines in comps.items():
        d = depths.get(cname, 0)
        mult = 1.0
        for lvl in range(d):
            mult *= trip_counts[lvl] if lvl < len(trip_counts) else 1.0
        for ln in lines:
            m = _OP_RE.search(ln)
            if not m or m.group("variant") == "-done":
                continue
            b = _shape_bytes(m.group("type"))
            per_kind[m.group("kind")] += b * mult
            counts[m.group("kind")] += 1
    return dict(per_kind), float(sum(per_kind.values())), dict(counts)
