"""Training driver: bitmap-indexed data pipeline -> pjit train step ->
fault-tolerant loop with async checkpoints.

On real TPU fleets this binary runs once per host (jax.distributed
initialize) against the production mesh; in this container it drives the
same code single-host (optionally over a small host-device test mesh).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50 --batch 8 --seq 256 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import BitmapIndex, DataPipeline, PipelineState, SyntheticCorpus
from repro.models import transformer as T
from repro.optim import adamw, cosine_schedule
from repro.runtime import ResilientTrainer
from repro.train import TrainState, make_train_step


def build_data(cfg, batch: int, seq: int, query: str, seed: int = 0,
               n_docs: int = 5000):
    corpus = SyntheticCorpus(n_docs=n_docs, vocab=cfg.vocab, seed=seed,
                             mean_len=max(64, seq // 4))
    index = BitmapIndex(corpus)
    pipe = DataPipeline(index, PipelineState(query=query, seed=seed),
                        batch=batch, seq_len=seq)
    return pipe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--query", default="quality>=1&!dedup_dup")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"pattern={cfg.layer_pattern}")
    pipe = build_data(cfg, args.batch, args.seq, args.query)
    print(f"selection: {pipe.selection.size} docs for '{args.query}'")

    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps))
    state = TrainState(params, opt.init(params), 0)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=args.remat),
                      donate_argnums=(0,))

    batches = {}

    def batch_at(step):
        # deterministic-in-step batches for exact replay after restart
        while len(batches) <= step:
            toks, mask, _ = pipe.next_batch()
            batches[len(batches)] = {"tokens": jnp.asarray(toks),
                                     "mask": jnp.asarray(mask)}
        return batches[step]

    losses = []
    t_start = time.time()

    def logging_step(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        s = int(np.asarray(state["step"]))
        if s % args.log_every == 0:
            tok_s = args.batch * args.seq * s / max(time.time() - t_start, 1e-9)
            print(f"step {s:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} tok/s {tok_s:,.0f}")
        return state, metrics

    trainer = ResilientTrainer(logging_step, args.ckpt,
                               ckpt_every=args.ckpt_every)
    state, _ = trainer.run(state, batch_at, n_steps=args.steps)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({args.steps} steps, restarts={trainer.restarts})")
    return losses


if __name__ == "__main__":
    main()
