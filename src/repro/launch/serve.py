"""Serving driver: batched requests against the roaring-paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      n_pages=args.n_pages, page_size=args.page_size,
                      max_pages_per_seq=64)
    rnp = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    prompt=rnp.integers(1, cfg.vocab, rnp.integers(4, 12)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    peak_util = 0.0
    steps = 0
    while eng.queue or eng.active:
        eng.step()
        steps += 1
        peak_util = max(peak_util, eng.utilization())
        if steps > 10_000:
            raise RuntimeError("serve loop did not converge")
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s), peak page util {peak_util:.2%}, "
          f"final util {eng.utilization():.2%}")
    for r in reqs[:3]:
        print(f"  req {r.req_id}: prompt {r.prompt.tolist()} -> {r.generated}")


if __name__ == "__main__":
    main()
