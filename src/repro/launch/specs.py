"""Cell builders: (architecture x input shape x mesh) -> a jit-able step
function + ShapeDtypeStruct inputs + shardings. No device allocation happens
here (everything flows through ``jax.eval_shape``)."""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adafactor, adamw, cosine_schedule
from repro.train import make_train_step

GIANT_PARAM_THRESHOLD = 50e9          # above this: adafactor (factored stats)
ENC_FRAMES = 256                      # audio stub frames (whisper)
VIS_TOKENS = 64                       # vision stub patches (qwen2-vl)

# --- perf-experiment knobs (EXPERIMENTS.md SPerf); baselines leave them unset
import os as _os

def _sharding_mode(cfg) -> str:
    """auto | replicate. REPRO_SHARDING_MODE overrides; 'replicate' is the
    pure-DP layout for small models (whisper hillclimb)."""
    env = _os.environ.get("REPRO_SHARDING_MODE")
    if env:
        return env
    return "auto"


def _long_window() -> int | None:
    """REPRO_LONG_WINDOW=<tokens>: roaring sliding-window + sink active set
    for long_500k decode (the serving layer's page table keeps only the
    window plus global-sink pages live; see serve/kv_cache.py)."""
    v = _os.environ.get("REPRO_LONG_WINDOW")
    return int(v) if v else None


def pick_optimizer(cfg: ModelConfig):
    lr = cosine_schedule(3e-4, warmup=2000, total=100_000)
    if cfg.param_count() > GIANT_PARAM_THRESHOLD:
        return adafactor(lr)
    return adamw(lr)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the given cell.

    train:   {tokens, mask[, extra_embeds][, memory]}
    prefill: {tokens[, extra_embeds][, memory]}
    decode:  {tokens (B,1), pos (B,)[, memory]}  (+ caches, built separately)
    """
    cfg = get_config(arch)
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    out: dict[str, Any] = {}
    if spec.kind == "train":
        out["tokens"] = _sds((B, S + 1), jnp.int32)
        out["mask"] = _sds((B, S + 1), jnp.float32)
    elif spec.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:
        out["tokens"] = _sds((B, 1), jnp.int32)
        out["pos"] = _sds((B,), jnp.int32)
    if cfg.frontend == "vision" and spec.kind == "train":
        out["extra_embeds"] = _sds((B, VIS_TOKENS, cfg.d_model), jnp.bfloat16)
    if cfg.layer_pattern == "encdec":
        out["memory"] = _sds((B, ENC_FRAMES, cfg.d_model), jnp.bfloat16)
    return out


def _batch_shardings(batch_sds: dict, mesh: Mesh, mode: str = "auto"):
    bspec = sh.batch_spec(mesh, mode)
    out = {}
    for k, v in batch_sds.items():
        dims = [None] * len(v.shape)
        if v.shape and v.shape[0] > 1:
            dims[0] = bspec[0] if len(bspec) else None
        out[k] = NamedSharding(mesh, P(*dims))
    return out


def cache_shardings(caches_sds, mesh: Mesh, long: bool = False):
    """Decode caches: batch over data axes; heads (or head_dim when the KV
    head count doesn't divide the model axis) over 'model'; long-context
    caches shard the sequence dimension over 'data' (sequence parallelism)."""
    model_n = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_n = int(np.prod([mesh.shape[a] for a in data_axes]))
    batch_axes = data_axes if len(data_axes) > 1 else data_axes[0]

    def spec_for(path, leaf):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = str(p.key)
                break
        shp = leaf.shape
        def div(i, n):
            return shp[i] % n == 0 and shp[i] >= n
        if key in ("k", "v"):                 # [n_sb, B, S, KVH, hd]
            if long and div(2, data_n * model_n):
                # long-context: shard the sequence over BOTH axes. Sharding
                # head_dim on 'model' instead forces a per-layer all-gather
                # of the KV cache over the model axis (134 MB x 80 layers on
                # qwen2@524k); with S fully sharded, attention reduces to
                # shard-local partial softmax + KB-scale all-reduces.
                return P(None, None, (*data_axes, "model"), None, None)
            heads = "model" if div(3, model_n) else None
            hd = "model" if heads is None and div(4, model_n) else None
            if long and div(2, data_n):
                return P(None, None, batch_axes, heads, hd)
            b = batch_axes if div(1, data_n) else None
            return P(None, b, None, heads, hd)
        if key == "conv":                      # [n_sb, B, K-1, di]
            b = batch_axes if div(1, data_n) else None
            return P(None, b, None, "model" if div(3, model_n) else None)
        if key == "h":                         # [n_sb, B, di, st]
            b = batch_axes if div(1, data_n) else None
            if b is None and div(2, data_n * model_n):
                return P(None, None, (*data_axes, "model"), None)
            return P(None, b, "model" if div(2, model_n) else None, None)
        if key == "S":                         # [n_sb, B, H, hd, hd]
            b = batch_axes if div(1, data_n) else None
            return P(None, b, "model" if div(2, model_n) else None, None, None)
        if key in ("x_tm", "x_cm"):            # [n_sb, B, d]
            b = batch_axes if div(1, data_n) else None
            return P(None, b, "model" if div(2, model_n) else None)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf)),
        caches_sds)


def build_cell(arch: str, shape: str, mesh: Mesh):
    """Returns (fn, args_sds tuple, in_shardings tuple, donate_argnums,
    meta dict). ``jax.jit(fn, in_shardings=..., donate_argnums=...)
    .lower(*args_sds).compile()`` is the dry-run contract."""
    cfg = get_config(arch)
    if _os.environ.get("REPRO_PARAM_DTYPE"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, param_dtype=_os.environ["REPRO_PARAM_DTYPE"])
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    mode = _sharding_mode(cfg)
    batch_sds = input_specs(arch, shape)
    batch_sh = _batch_shardings(batch_sds, mesh, mode)
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda: T.init_lm(rng, cfg))
    params_sh = sh.params_shardings(params_sds, mesh, mode)
    meta = {"arch": arch, "shape": shape, "kind": spec.kind,
            "seq_len": S, "global_batch": B,
            "n_superblocks": cfg.n_superblocks,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if spec.kind == "train":
        opt = pick_optimizer(cfg)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sh = sh.params_shardings(opt_sds, mesh, mode)  # mirrors params
        state_sds = {"params": params_sds, "opt": opt_sds,
                     "step": _sds((), jnp.int32)}
        state_sh = {"params": params_sh, "opt": opt_sh,
                    "step": NamedSharding(mesh, P())}
        # gradient accumulation for wide models: keep ~2 sequences per data
        # shard per microstep so layer-scan carries fit HBM (80 x [B_loc, S,
        # d] bf16 at d=8192 is 86 GB/device without it)
        daxes = [a for a in ("pod", "data") if a in mesh.shape]
        dcount = int(np.prod([mesh.shape[a] for a in daxes]))
        micro = None
        if cfg.d_model >= 4096:
            micro = max(dcount * 2 // (1 if cfg.d_model < 8000 else 2),
                        dcount)
            while B % micro:
                micro //= 2
        if _os.environ.get("REPRO_MICROBATCH"):
            micro = int(_os.environ["REPRO_MICROBATCH"]) or None
        step = make_train_step(cfg, opt, remat="full", microbatch=micro)
        meta["optimizer"] = opt.name
        meta["microbatch"] = micro
        return (step, (state_sds, batch_sds), (state_sh, batch_sh), (0,), meta)

    if spec.kind == "prefill":
        def prefill(params, batch):
            logits, _ = T.forward(params, batch["tokens"], cfg,
                                  extra_embeds=batch.get("extra_embeds"),
                                  memory=batch.get("memory"))
            return logits
        return (prefill, (params_sds, batch_sds), (params_sh, batch_sh),
                (), meta)

    # decode: serve_step over a dense KV/state cache of seq_len tokens
    long = S >= (1 << 19)
    S_cache = S
    if long and _long_window() and all(
            k.startswith("attn") for k in cfg.block_kinds()):
        # roaring active-set decode: window + global-sink pages only (the
        # page table evicts the rest via ANDNOT); cache shrinks accordingly
        S_cache = min(S, _long_window())
        meta["long_window"] = S_cache
    caches_sds = jax.eval_shape(
        lambda: T.init_decode_caches(cfg, B, s_max=S_cache))
    caches_sh = cache_shardings(caches_sds, mesh, long=long)
    memory = batch_sds.get("memory")

    def serve_step(params, caches, batch):
        logits, new_caches = T.decode_step(
            params, caches, batch["tokens"], batch["pos"], cfg,
            memory=batch.get("memory"))
        return logits, new_caches

    return (serve_step, (params_sds, caches_sds, batch_sds),
            (params_sh, caches_sh, batch_sh), (1,), meta)
