"""Production meshes.

Single pod: 256 chips as ("data", "model") = (16, 16).
Multi-pod:  512 chips as ("pod", "data", "model") = (2, 16, 16) — the pod
axis carries pure data parallelism (per-pod parameter replicas, gradient
sync over ICI/DCN, optionally roaring-compressed via repro.grad_comp).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
