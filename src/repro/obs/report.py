"""Telemetry export: machine-readable JSON, a human text report, and the
measured-vs-analytic kernel-launch cross-check.

``collect()`` snapshots the metrics registry (refreshing the fused plan-
cache gauges from ``fused.plan_tape.cache_info()``), the completed span
trees, and the environment (jax/jaxlib versions, backend, host);
``write_report()`` dumps it as JSON (the bench harness writes
``artifacts/telemetry.json`` next to ``bench.json``); ``render_text()`` is
the terminal-friendly view (launch counts, kind histograms, span tree).

``launch_crosscheck()`` is the accounting audit the PR 7 roofline model
(``fused.plan_stats`` / ``benchmarks.roofline.fused_model``) is checked
against: it executes one expression through the eager engine on both paths
and asserts the *measured* launch counters equal the analytic model —
one ``fused_tree`` launch for the whole tree on the fused path, and
``index.launch_model``'s dispatch count (AND combines at tree-reduce
granularity; OR/ANDNOT combines are jnp-level, not kernel dispatches) on
the per-op path.
"""

from __future__ import annotations

import json
import os
import platform
import sys
from typing import Any, Dict, Optional

from repro.obs import metrics as _m
from repro.obs import trace as _t

__all__ = ["environment", "collect", "write_report", "render_text",
           "launch_crosscheck"]


def environment() -> dict:
    """Host + accelerator-stack metadata stamped onto every report."""
    info: Dict[str, Any] = {
        "python": sys.version.split()[0],
        "host": platform.node(),
        "platform": platform.platform(),
    }
    try:
        import jax
        import jaxlib
        info["jax"] = jax.__version__
        info["jaxlib"] = jaxlib.__version__
        info["backend"] = jax.default_backend()
        info["device_count"] = jax.device_count()
    except Exception:                         # report must never crash
        pass
    return info


def _refresh_derived_gauges() -> None:
    """Pull pull-model stats (the fused plan cache) into registry gauges so
    snapshots carry them without the planner pushing on every compile."""
    try:
        from repro.kernels.roaring import fused
        ci = fused.plan_tape.cache_info()
        g = _m.registry()
        g.gauge("fused.plan_cache.hits").set(ci.hits)
        g.gauge("fused.plan_cache.misses").set(ci.misses)
        g.gauge("fused.plan_cache.entries").set(ci.currsize)
    except Exception:
        pass


def collect(extra: Optional[dict] = None) -> dict:
    """One JSON-ready report: environment + metrics + span trees (+ any
    caller-provided ``extra`` keys, e.g. the bench harness's per-section
    wall times)."""
    _refresh_derived_gauges()
    rep: dict = {
        "environment": environment(),
        "metrics": _m.registry().snapshot(),
        "spans": [s.to_dict() for s in _t.span_trees()],
    }
    if extra:
        rep.update(extra)
    return rep


def write_report(path: str, extra: Optional[dict] = None) -> dict:
    """``collect()`` -> pretty-printed JSON at ``path``; returns the dict."""
    rep = collect(extra)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rep, f, indent=1, default=str)
    return rep


# -- human text report --------------------------------------------------------

def _span_lines(sp: dict, indent: int, out: list) -> None:
    dur = sp.get("duration_s")
    dur_s = "open" if dur is None else f"{dur * 1e3:.2f} ms"
    attrs = sp.get("attrs") or {}
    attr_s = ("  [" + ", ".join(f"{k}={v}" for k, v in sorted(attrs.items()))
              + "]") if attrs else ""
    flag = " !" if sp.get("status") == "error" else ""
    out.append(f"{'  ' * indent}{sp['name']} ({dur_s}){flag}{attr_s}")
    for ev in sp.get("events", []):
        extra = {k: v for k, v in ev.items() if k not in ("name", "offset_s")}
        ev_s = " ".join(f"{k}={v}" for k, v in sorted(extra.items()))
        out.append(f"{'  ' * (indent + 1)}* {ev['name']} {ev_s}".rstrip())
    for c in sp.get("children", []):
        _span_lines(c, indent + 1, out)


def render_text(report: Optional[dict] = None) -> str:
    """Terminal view of a report: environment, launch counters, kind
    histograms, remaining counters/gauges, and the span trees."""
    rep = report if report is not None else collect()
    env = rep.get("environment", {})
    lines = ["# telemetry report",
             f"environment: jax {env.get('jax', '?')} "
             f"({env.get('backend', '?')}) on {env.get('host', '?')}"]
    counters = rep.get("metrics", {}).get("counters", {})
    launches = {k: v for k, v in counters.items()
                if k.startswith("roaring.launches")}
    kinds = {k: v for k, v in counters.items() if "_kinds" in k}
    other = {k: v for k, v in counters.items()
             if k not in launches and k not in kinds}
    if launches:
        lines.append("## kernel launches")
        lines += [f"  {k:58s} {v}" for k, v in launches.items()]
    if kinds:
        lines.append("## container-kind histograms")
        grouped: Dict[str, list] = {}
        for k, v in kinds.items():
            base, _, lbl = k.partition("{")
            kind = "?"
            for part in lbl.rstrip("}").split(","):
                if part.startswith("kind="):
                    kind = part[5:]
            grouped.setdefault(base, []).append(f"{kind}={v}")
        lines += [f"  {base}: " + " ".join(sorted(parts))
                  for base, parts in sorted(grouped.items())]
    if other:
        lines.append("## counters")
        lines += [f"  {k:58s} {v}" for k, v in other.items()]
    gauges = rep.get("metrics", {}).get("gauges", {})
    if gauges:
        lines.append("## gauges")
        lines += [f"  {k:58s} {v}" for k, v in gauges.items()]
    spans = rep.get("spans", [])
    if spans:
        lines.append("## spans")
        for sp in spans:
            _span_lines(sp, 1, lines)
    return "\n".join(lines)


# -- measured-vs-analytic launch accounting -----------------------------------

def launch_crosscheck(stack, expr, *, backend: Optional[str] = None) -> dict:
    """Execute ``expr`` over ``stack`` on both engine paths (eagerly) and
    compare the *measured* launch counters against the analytic models.

    Fused: the whole tree must cost exactly ``plan_stats(...)
    ["launches_fused"]`` (= 1) ``fused_tree`` dispatch — the same model
    ``benchmarks.roofline.fused_model`` tabulates. Per-op: the
    ``intersect_dispatch`` count must equal ``index.launch_model(expr)
    ["per_op_dispatches"]`` (AND combines at the engine's tree-reduce call
    granularity). Returns both sides plus ``match``; telemetry is enabled
    for the duration (restored after).
    """
    import repro.obs as obs
    from repro import index
    from repro.index import engine as _e
    from repro.kernels.roaring import fused as _f

    model = index.launch_model(expr)
    tree, _ = _e._lower_tree(expr)
    st = _f.plan_stats(_f.plan_tape(tree), int(stack.C))
    reg = _m.registry()
    with obs.telemetry_scope():
        f0 = reg.total("roaring.launches", entry="fused_tree")
        index.execute(stack, expr, fused=True, backend=backend)
        fused_measured = reg.total("roaring.launches",
                                   entry="fused_tree") - f0
        p0 = reg.total("roaring.launches", entry="intersect_dispatch")
        index.execute(stack, expr, backend=backend)
        per_op_measured = reg.total("roaring.launches",
                                    entry="intersect_dispatch") - p0
    return {
        "n_operands": model["n_operands"],
        "fused_measured": int(fused_measured),
        "fused_model": int(st["launches_fused"]),
        "per_op_measured": int(per_op_measured),
        "per_op_model": int(model["per_op_dispatches"]),
        "per_op_combines": int(st["launches_per_op"]),
        "match": (fused_measured == st["launches_fused"]
                  and per_op_measured == model["per_op_dispatches"]),
    }
