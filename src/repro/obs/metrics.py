"""Process-global metrics registry: counters, gauges, histograms.

Zero-dependency (pure Python) so every layer — kernel launch hooks, the
query engine's degradation ladder, the store's jit-query cache, the serving
engine — can publish without import cycles or device round trips. Metrics
are keyed by ``(name, sorted labels)``; the rendered form is Prometheus-ish
(``roaring.launches{backend=xla,entry=fused_tree}``).

Counters are plain Python ints guarded by the GIL (increments are a dict
lookup + integer add — cheap enough for always-on accounting like the
ladder's failure counters), so the registry itself has no on/off switch;
*instrumentation sites* that would cost real work (host syncs for kind
histograms, span bookkeeping) gate on ``repro.obs.enabled()`` instead.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, Iterable, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "registry", "reset_metrics", "render_key"]

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def render_key(key: _Key) -> str:
    """``(name, labels)`` -> ``name{k=v,...}`` (plain ``name`` unlabeled)."""
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class Counter:
    """Monotonic (between resets) event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written point-in-time value (queue depth, cache entries, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Power-of-two-bucketed value distribution (count/sum/min/max kept
    exact; buckets index ``floor(log2(value))``, with <1 in bucket 0)."""

    __slots__ = ("count", "sum", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.buckets: Dict[int, int] = {}

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        b = 0 if v < 1.0 else int(math.log2(v)) + 1
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def reset(self) -> None:
        self.__init__()

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max,
                "buckets": {f"<2^{b}": n
                            for b, n in sorted(self.buckets.items())}}


class MetricsRegistry:
    """Name+label-keyed metric store; metrics are created on first use."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[_Key, Counter] = {}
        self._gauges: Dict[_Key, Gauge] = {}
        self._histograms: Dict[_Key, Histogram] = {}

    def _get(self, table: dict, cls, name: str, labels: dict):
        k = _key(name, labels)
        m = table.get(k)
        if m is None:
            with self._lock:
                m = table.setdefault(k, cls())
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(self._histograms, Histogram, name, labels)

    def value(self, name: str, **labels: Any) -> float:
        """Current value of a counter or gauge (0 when never touched)."""
        k = _key(name, labels)
        if k in self._counters:
            return self._counters[k].value
        if k in self._gauges:
            return self._gauges[k].value
        return 0

    def total(self, name: str, **labels: Any) -> int:
        """Sum of every counter named ``name`` whose labels include all the
        given ones (e.g. launches for one ``entry`` across backends)."""
        want = set((k, str(v)) for k, v in labels.items())
        return sum(c.value for (n, lbl), c in list(self._counters.items())
                   if n == name and want <= set(lbl))

    def counters(self) -> Iterable[Tuple[_Key, Counter]]:
        return list(self._counters.items())

    def remove(self, name: str) -> None:
        """Drop every metric (any type, any labels) with this name."""
        with self._lock:
            for table in (self._counters, self._gauges, self._histograms):
                for k in [k for k in table if k[0] == name]:
                    del table[k]

    def reset(self) -> None:
        """Forget every metric (test isolation / fresh report windows)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> dict:
        """JSON-exportable state: rendered-name -> value tables."""
        return {
            "counters": {render_key(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {render_key(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {render_key(k): h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every instrumented layer publishes to."""
    return _REGISTRY


def reset_metrics() -> None:
    """Zero the process-global registry."""
    _REGISTRY.reset()
