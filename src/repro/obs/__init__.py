"""`repro.obs` — the zero-dependency telemetry plane.

Two halves: :mod:`repro.obs.trace` (context-manager spans with a
thread-local active stack, collected into exportable span trees) and
:mod:`repro.obs.metrics` (a process-global registry of counters / gauges /
histograms). :mod:`repro.obs.report` exports both as JSON / text and
cross-checks measured kernel-launch counts against the roofline analytic
model.

Off by default: ``enable()`` flips the tracing flag *and* subscribes the
launch-event hook in ``kernels/roaring/ops.py`` so every kernel dispatch
increments ``roaring.launches{entry,backend}`` and lands as an event on the
innermost open span. ``disable()`` undoes both. The metrics registry itself
has no switch — bare-int counters (ladder failures, cache hits) are cheap
enough to stay always-on — but instrumentation sites that cost real work
(host syncs for kind histograms, gauge refreshes, span bookkeeping) gate on
``enabled()``.
"""

from __future__ import annotations

from contextlib import contextmanager

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               registry, reset_metrics)
from repro.obs.report import (collect, environment, launch_crosscheck,
                              render_text, write_report)
from repro.obs.trace import (Span, current_span, reset_traces, span,
                             span_trees, tracing)
from repro.obs import trace as _trace

__all__ = [
    # switches
    "enable", "disable", "enabled", "telemetry_scope",
    # tracing
    "Span", "span", "current_span", "span_trees", "reset_traces",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "reset_metrics", "record_kinds", "KIND_NAMES",
    # reporting
    "collect", "write_report", "render_text", "launch_crosscheck",
    "environment",
]

KIND_NAMES = ("empty", "array", "bitmap", "run")

_HOOKED = False


def _on_launch(ev) -> None:
    """Launch-hook subscriber: count the dispatch and pin it to the
    innermost open span as an event."""
    registry().counter("roaring.launches",
                       entry=ev.entry, backend=ev.backend).inc()
    sp = current_span()
    if sp is not None:
        sp.add_event("launch", entry=ev.entry, backend=ev.backend)


def enable() -> None:
    """Turn telemetry on: record spans and subscribe the kernel launch
    hook. Idempotent."""
    global _HOOKED
    _trace.set_tracing(True)
    if not _HOOKED:
        from repro.kernels.roaring import ops as kops
        kops.add_launch_hook(_on_launch)
        _HOOKED = True


def disable() -> None:
    """Turn telemetry off (the default). Collected spans/metrics are kept
    until ``reset_traces()`` / ``reset_metrics()``."""
    global _HOOKED
    _trace.set_tracing(False)
    if _HOOKED:
        from repro.kernels.roaring import ops as kops
        kops.remove_launch_hook(_on_launch)
        _HOOKED = False


def enabled() -> bool:
    """Whether telemetry is currently on."""
    return _trace.tracing()


@contextmanager
def telemetry_scope(on: bool = True):
    """Temporarily force telemetry on (default) or off, restoring the
    previous state on exit — e.g. ``with telemetry_scope(): store.query(p)``
    or ``with telemetry_scope(on=False):`` around a timing window."""
    was = enabled()
    (enable if on else disable)()
    try:
        yield
    finally:
        (enable if was else disable)()


def record_kinds(name: str, kinds) -> None:
    """Bump per-container-kind counters (``<name>{kind=...}``) from a kinds
    vector. Safe to call from instrumented paths that may run under
    ``jax.jit`` tracing: tracers (no concrete values) are skipped, and the
    host sync only happens while telemetry is enabled."""
    if not enabled():
        return
    try:
        import jax
        import numpy as np
        if isinstance(kinds, jax.core.Tracer):
            return
        counts = np.bincount(
            np.asarray(kinds).astype(np.int64).ravel(),
            minlength=len(KIND_NAMES))
    except Exception:
        return
    reg = registry()
    for i, kname in enumerate(KIND_NAMES):
        n = int(counts[i]) if i < counts.size else 0
        if n:
            reg.counter(name, kind=kname).inc(n)
