"""Context-manager spans: the tracing half of the telemetry plane.

A span measures one phase of a query's life (``span("store.query")`` →
``span("store.execute")`` → kernel-launch events) with monotonic wall
times, arbitrary attributes, and point-in-time events. Spans nest through a
thread-local active-span stack; a span opened while another is active
becomes its child, and completed *root* spans are collected into a bounded
process-global list exportable as a span tree (``span_trees()``).

Cost contract: tracing is **off by default**. When disabled, ``span()``
returns a shared no-op context manager — one attribute read and two no-op
method calls per span site, never an allocation — so instrumented hot paths
(the jitted ``BitmapStore.query`` dispatch wrapper) pay well under the 5%
overhead budget ``benchmarks/obs_bench.py`` gates in CI. Spans wrap
*dispatch* (Python-level phases around jitted calls); they never trace into
kernels — inside a ``jax.jit`` trace the span body runs once at trace time
and costs nothing per launch afterwards.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "span", "current_span", "span_trees", "reset_traces",
           "set_tracing", "tracing"]

_MAX_ROOT_SPANS = 4096         # bounded collection: drop oldest roots

_ENABLED = False               # module-global fast flag (obs.enable flips it)
_LOCK = threading.Lock()
_FINISHED: List["Span"] = []   # completed root spans, oldest first
_TLS = threading.local()


def set_tracing(on: bool) -> None:
    """Flip the process-wide tracing flag (use ``repro.obs.enable()`` /
    ``disable()`` — they also manage the kernel launch-hook subscription)."""
    global _ENABLED
    _ENABLED = bool(on)


def tracing() -> bool:
    """Whether spans are currently being recorded."""
    return _ENABLED


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class Span:
    """One timed phase: name, monotonic start/end, attrs, events, children.

    ``duration_s`` is ``None`` while the span is open. ``events`` are
    point-in-time markers (e.g. one per kernel-launch dispatch) recorded at
    an offset from the span start.
    """

    __slots__ = ("name", "attrs", "events", "children", "t0", "t1", "status")

    def __init__(self, name: str, attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self.events: List[Dict[str, Any]] = []
        self.children: List[Span] = []
        self.t0 = time.monotonic()
        self.t1: Optional[float] = None
        self.status = "open"

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def add_event(self, name: str, **attrs: Any) -> None:
        ev = {"name": name, "offset_s": time.monotonic() - self.t0}
        if attrs:
            ev.update(attrs)
        self.events.append(ev)

    def to_dict(self) -> dict:
        """JSON-exportable span tree rooted here."""
        d: dict = {"name": self.name, "status": self.status,
                   "duration_s": self.duration_s}
        if self.attrs:
            d["attrs"] = {k: _jsonable(v) for k, v in self.attrs.items()}
        if self.events:
            d["events"] = [
                {k: _jsonable(v) for k, v in ev.items()}
                for ev in self.events]
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self) -> str:
        dur = self.duration_s
        return (f"Span({self.name!r}, {self.status}, "
                f"{'open' if dur is None else f'{dur * 1e3:.2f}ms'}, "
                f"{len(self.children)} children)")


class _NullSpan:
    """Shared do-nothing span handed out when tracing is disabled."""

    __slots__ = ()

    def set_attr(self, key: str, value: Any) -> None:
        pass

    def add_event(self, name: str, **attrs: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class span:
    """``with span("store.query", fused=True) as sp:`` — record one phase.

    Disabled tracing yields the shared no-op span. An exception escaping the
    body marks the span ``status="error"`` (and records the exception type)
    before propagating — fallback rungs show up as errored child spans.
    """

    __slots__ = ("_name", "_attrs", "_span")

    def __init__(self, name: str, **attrs: Any):
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self):
        if not _ENABLED:
            return _NULL_SPAN
        s = Span(self._name, self._attrs)
        st = _stack()
        if st:
            st[-1].children.append(s)
        st.append(s)
        self._span = s
        return s

    def __exit__(self, etype, evalue, tb):
        s = self._span
        if s is None:
            return False
        self._span = None
        s.t1 = time.monotonic()
        if etype is not None:
            s.status = "error"
            s.attrs.setdefault("error", etype.__name__)
        else:
            s.status = "ok"
        st = _stack()
        # tolerate enable/disable flips mid-span: pop only what we pushed
        if s in st:
            while st and st[-1] is not s:
                st.pop()
            st.pop()
        if not st:
            with _LOCK:
                _FINISHED.append(s)
                if len(_FINISHED) > _MAX_ROOT_SPANS:
                    del _FINISHED[: len(_FINISHED) - _MAX_ROOT_SPANS]
        return False


def current_span() -> Optional[Span]:
    """The innermost active span on this thread, or ``None`` (also ``None``
    whenever tracing is disabled)."""
    if not _ENABLED:
        return None
    st = _stack()
    return st[-1] if st else None


def span_trees() -> List[Span]:
    """Snapshot of the completed root spans (each the root of its tree)."""
    with _LOCK:
        return list(_FINISHED)


def reset_traces() -> None:
    """Drop every collected root span and this thread's open-span stack."""
    with _LOCK:
        _FINISHED.clear()
    _stack().clear()


def _jsonable(v: Any):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
