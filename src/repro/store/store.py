"""``BitmapStore`` — the paper's database scenario as a real store layer.

The 2014 paper's headline numbers (Table 3 / Figure 2) come from *bitmap
indexes*: per-column, per-value bitmaps over a table's rows, combined with
Boolean algebra to answer predicate queries. This module is that store:

  * **Equality columns** ingest to one posting slab per distinct value —
    the bitmap of row ids where ``column == value`` (the classic bitmap
    index).
  * **Bit-sliced columns** (``bsi=...`` at build time) ingest integer
    columns as one slab per *bit* of the value (O'Neil/Quass bit-sliced
    index) — ``b = max_value.bit_length()`` slabs answer any range or
    aggregate query, instead of one slab per distinct value.
  * All slabs — plus the row **universe** (slot 0) and a canonical **empty**
    slab (slot 1) — are ingested into ONE key-aligned stacked
    ``repro.roaring.RoaringSlab``, so a compiled predicate is an
    ``repro.index`` expression tree over stack members and every query runs
    through the fused executor (``execute(..., fused=True)``) and its
    Pallas→XLA degradation ladder unchanged.

Compilation is total: ``eq`` on an unseen value compiles to the empty slab,
``not_`` compiles to ``ANDNOT`` against the universe, ``range_`` on a
bit-sliced column compiles to the slice-comparison tree (``v <= K`` as the
MSB-down prefix walk), and ``range_`` on an integer-valued equality column
compiles to an OR over the stored values inside the bounds. The result is
bit-identical — values, cardinality, kinds, serialized bytes — to filtering
the raw records row by row (the differential oracle in
``tests/test_store.py`` checks exactly this).

Durability: ``save()`` emits every column slab through the portable
``RoaringFormatSpec`` codec (each blob is a standard Roaring interchange
stream a CRoaring/PyRoaring client can read) inside a small store container
format; ``load()`` treats the bytes as untrusted — see ``repro.store.io``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro import index as ix
from repro.core import jax_roaring as jr
from repro.core import py_roaring as pr
from repro.roaring import RoaringSlab
from repro.store import predicate as P

__all__ = ["BitmapStore", "EqColumn", "BsiColumn",
           "UNIVERSE_SLOT", "EMPTY_SLOT"]

UNIVERSE_SLOT = 0          # all rows — the NOT / open-range operand
EMPTY_SLOT = 1             # no rows — the unseen-value / empty-IN operand
_RESERVED_SLOTS = 2

_STORE_IDS = itertools.count()   # distinguishes per-store telemetry gauges


@dataclasses.dataclass(frozen=True)
class EqColumn:
    """An equality column: one posting slab per distinct value.

    ``values`` is the sorted tuple of distinct values (all int or all str —
    ``vkind`` names which); value ``values[i]`` lives at stack slot
    ``base_slot + i``.
    """

    name: str
    vkind: str                       # "int" | "str"
    values: Tuple
    base_slot: int

    @property
    def n_slabs(self) -> int:
        return len(self.values)


@dataclasses.dataclass(frozen=True)
class BsiColumn:
    """A bit-sliced integer column: slab ``base_slot + j`` holds the rows
    whose value has bit ``j`` set (LSB first), ``j < bits``."""

    name: str
    bits: int
    base_slot: int

    @property
    def n_slabs(self) -> int:
        return self.bits


def _chunks_for(n_rows: int) -> int:
    return max(1, -(-n_rows // jr.CHUNK_SIZE))


def _posting(row_ids: np.ndarray) -> pr.RoaringBitmap:
    """Sorted row ids -> best-of-three canonical host bitmap (canonical
    kinds are what make store bytes match the engine's query outputs)."""
    return pr.RoaringBitmap.from_sorted_unique(
        np.asarray(row_ids, np.int64)).run_optimize()


def _stack_bitmaps(bitmaps: Sequence[pr.RoaringBitmap], n_rows: int,
                   n_chunks: int) -> RoaringSlab:
    """Host bitmaps -> ONE stacked ``RoaringSlab`` aligned to the row
    universe's chunk keys.

    Every posting is a subset of ``[0, n_rows)``, so the shared key row is
    just ``arange(n_chunks)`` — no merge pass, no per-slab device gather
    (``roaring.stack`` would dispatch one gather per slab; a store routinely
    holds thousands of slabs, so rows are placed host-side in one shot).
    """
    N = len(bitmaps)
    kinds = np.zeros((N, n_chunks), np.int32)
    cards = np.zeros((N, n_chunks), np.int32)
    nruns = np.zeros((N, n_chunks), np.int32)
    payload = np.zeros((N, n_chunks, jr.ROW_WORDS), np.uint16)
    for s, rb in enumerate(bitmaps):
        for k, c in zip(rb.keys, rb.containers):
            cards[s, k] = c.cardinality
            if isinstance(c, pr.RunContainer):
                kinds[s, k] = jr.KIND_RUN
                nruns[s, k] = c.n_runs
                row = np.full((jr.ROW_WORDS,), 0xFFFF, np.uint16)
                row[0:2 * c.n_runs:2] = c.starts.astype(np.uint16)
                row[1:2 * c.n_runs:2] = c.lengths.astype(np.uint16)
                payload[s, k] = row
            elif isinstance(c, pr.BitmapContainer):
                kinds[s, k] = jr.KIND_BITMAP
                payload[s, k] = c.words.view(np.uint16)
            else:
                kinds[s, k] = jr.KIND_ARRAY
                row = np.full((jr.ROW_WORDS,), 0xFFFF, np.uint16)
                row[: c.arr.size] = c.arr
                payload[s, k] = row
    if n_rows > 0:
        keys_row = np.arange(n_chunks, dtype=np.int32)
    else:
        keys_row = np.full((n_chunks,), int(jr.KEY_SENTINEL), np.int32)
    keys = np.broadcast_to(keys_row, (N, n_chunks))
    return RoaringSlab(keys=jnp.asarray(keys), kinds=jnp.asarray(kinds),
                       cards=jnp.asarray(cards), nruns=jnp.asarray(nruns),
                       payload=jnp.asarray(payload), C=n_chunks)


def _norm_column(name: str, col: np.ndarray):
    """Column array -> (vkind, normalized values). Ints (any numpy integer
    dtype or bool) and strings are supported; anything else is rejected at
    ingest, not discovered at query time."""
    arr = np.asarray(col)
    if arr.ndim != 1:
        raise ValueError(f"column {name!r} must be 1-D, got shape "
                         f"{arr.shape}")
    if arr.dtype.kind in "iub":
        return "int", arr.astype(np.int64)
    if arr.dtype.kind in "US":
        return "str", arr.astype(str)
    if arr.dtype.kind == "O":
        kinds = {type(v) for v in arr.tolist()}
        if kinds <= {int, bool}:
            return "int", arr.astype(np.int64)
        if kinds == {str}:
            return "str", arr.astype(str)
        raise TypeError(f"column {name!r} mixes value types {sorted(k.__name__ for k in kinds)}")
    raise TypeError(f"column {name!r} has unsupported dtype {arr.dtype} "
                    "(store columns hold ints or strings)")


class BitmapStore:
    """Per-(column, value) Roaring bitmap index over columnar records."""

    def __init__(self, n_rows: int, columns: Sequence, bitmaps: Sequence):
        """Internal constructor — use ``build`` (from records) or ``load``
        (from a saved stream). ``bitmaps`` is the full slot-ordered list,
        including the universe and empty slots."""
        self.n_rows = int(n_rows)
        self.columns: Tuple = tuple(columns)
        self._bitmaps: List[pr.RoaringBitmap] = list(bitmaps)
        self._by_name: Dict[str, object] = {c.name: c for c in self.columns}
        self._eq_slot: Dict[Tuple[str, object], int] = {}
        for c in self.columns:
            if isinstance(c, EqColumn):
                for i, v in enumerate(c.values):
                    self._eq_slot[(c.name, v)] = c.base_slot + i
        self.n_chunks = _chunks_for(self.n_rows)
        self._stack = _stack_bitmaps(self._bitmaps, self.n_rows,
                                     self.n_chunks)
        # jitted whole-call executors per (expr, fused, backend): the engine
        # evaluates eagerly, where per-combine dispatch plus the root
        # finalize cost seconds per query; jitting the full tree makes the
        # steady state milliseconds (expression dataclasses are frozen, so
        # they hash as cache keys)
        self._query_fns: Dict[Tuple, Callable] = {}
        self._id = next(_STORE_IDS)
        self._cache_hits = 0       # key already held a jitted executor
        self._cache_misses = 0     # cold compile: new executor jitted
        self._cache_fallbacks = 0  # jitted call failed -> eager ladder

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, records: Dict[str, np.ndarray], *,
              bsi: Sequence[str] = ()) -> "BitmapStore":
        """Ingest columnar ``records`` (name -> equal-length 1-D arrays).

        Columns named in ``bsi`` must be non-negative integers and become
        bit-sliced-index columns (``range_`` / ``eq`` / ``in_`` / ``sum_``
        via slice algebra); every other column becomes an equality column
        with one posting slab per distinct value.
        """
        if not records:
            raise ValueError("build needs at least one column")
        bsi = set(bsi)
        unknown = bsi - set(records)
        if unknown:
            raise ValueError(f"bsi names not in records: {sorted(unknown)}")
        lengths = {name: len(np.asarray(col)) for name, col in records.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"column lengths differ: {lengths}")
        n_rows = next(iter(lengths.values()))

        universe = pr.RoaringBitmap.from_ranges([(0, n_rows)]) if n_rows \
            else pr.RoaringBitmap()
        bitmaps: List[pr.RoaringBitmap] = [universe, pr.RoaringBitmap()]
        columns: List = []
        for name, col in records.items():
            vkind, arr = _norm_column(name, col)
            if name in bsi:
                if vkind != "int":
                    raise TypeError(f"bsi column {name!r} must be integer")
                if n_rows and int(arr.min()) < 0:
                    raise ValueError(f"bsi column {name!r} holds negative "
                                     "values")
                bits = max(1, int(arr.max()).bit_length()) if n_rows else 1
                columns.append(BsiColumn(name, bits, len(bitmaps)))
                for j in range(bits):
                    rows = np.nonzero((arr >> j) & 1)[0]
                    bitmaps.append(_posting(rows))
            else:
                # stable argsort groups equal values with ascending row ids
                order = np.argsort(arr, kind="stable")
                svals = arr[order]
                if n_rows:
                    bounds = np.nonzero(svals[1:] != svals[:-1])[0] + 1
                    starts = np.concatenate(([0], bounds))
                    ends = np.concatenate((bounds, [n_rows]))
                else:
                    starts = ends = np.empty(0, np.int64)
                values = []
                base = len(bitmaps)
                for s, e in zip(starts.tolist(), ends.tolist()):
                    v = svals[s]
                    values.append(int(v) if vkind == "int" else str(v))
                    bitmaps.append(_posting(np.sort(order[s:e])))
                columns.append(EqColumn(name, vkind, tuple(values), base))
        return cls(n_rows, columns, bitmaps)

    # -- persistence ----------------------------------------------------------
    def save(self) -> bytes:
        """Store -> durable byte stream (``repro.store.io`` container format;
        every slab is a portable ``RoaringFormatSpec`` blob)."""
        from repro.store import io as _io
        return _io.save_store(self)

    @classmethod
    def load(cls, data: bytes, *, limits=None, check: bool = False
             ) -> "BitmapStore":
        """Untrusted byte stream -> store (typed rejection on any structural
        violation; see ``repro.store.io.load_store``)."""
        from repro.store import io as _io
        return _io.load_store(data, limits=limits, check=check)

    # -- schema introspection --------------------------------------------------
    def column(self, name: str):
        """The ``EqColumn`` / ``BsiColumn`` schema entry for ``name``."""
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"no column {name!r}; store has "
                           f"{sorted(self._by_name)}") from None

    @property
    def n_slabs(self) -> int:
        """Total stacked slabs (columns + the universe and empty slots)."""
        return len(self._bitmaps)

    def index_size_in_bytes(self) -> int:
        """Serialized size of the column slabs (the paper's index-size
        metric; the derivable universe/empty slots are excluded)."""
        return sum(rb.size_in_bytes()
                   for rb in self._bitmaps[_RESERVED_SLOTS:])

    def slot_bitmap(self, slot: int) -> pr.RoaringBitmap:
        """Host bitmap at a stack slot (interop/debug surface)."""
        return self._bitmaps[slot]

    # -- predicate compilation -------------------------------------------------
    def compile(self, pred: P.Pred) -> ix.Expr:
        """Predicate -> ``repro.index`` expression tree over the store's
        stacked slabs. Total: every well-typed predicate compiles, with
        unseen values landing on the empty slab."""
        if isinstance(pred, P.Eq):
            return self._compile_eq(pred.col, pred.value)
        if isinstance(pred, P.In):
            if not pred.values:
                return ix.leaf(EMPTY_SLOT)
            return ix.or_(*[self._compile_eq(pred.col, v)
                            for v in dict.fromkeys(pred.values)])
        if isinstance(pred, P.Range):
            return self._compile_range(pred.col, pred.lo, pred.hi)
        if isinstance(pred, P.AndP):
            return ix.and_(*[self.compile(c) for c in pred.children])
        if isinstance(pred, P.OrP):
            return ix.or_(*[self.compile(c) for c in pred.children])
        if isinstance(pred, P.NotP):
            return ix.andnot(ix.leaf(UNIVERSE_SLOT), self.compile(pred.child))
        raise TypeError(f"not a store predicate: {pred!r}")

    def _compile_eq(self, name: str, value) -> ix.Expr:
        col = self.column(name)
        if isinstance(col, EqColumn):
            if isinstance(value, str) != (col.vkind == "str"):
                raise TypeError(f"column {name!r} holds {col.vkind} values, "
                                f"predicate names {value!r}")
            slot = self._eq_slot.get((name, value))
            return ix.leaf(EMPTY_SLOT if slot is None else slot)
        v = int(value)
        if v < 0 or v >= (1 << col.bits):
            return ix.leaf(EMPTY_SLOT)
        # AND over all slices: bit set -> slice, bit clear -> NOT slice
        terms = [ix.leaf(col.base_slot + j) if (v >> j) & 1
                 else self._not(ix.leaf(col.base_slot + j))
                 for j in range(col.bits)]
        return ix.and_(*terms)

    def _compile_range(self, name: str, lo: Optional[int],
                       hi: Optional[int]) -> ix.Expr:
        col = self.column(name)
        if isinstance(col, EqColumn):
            if col.vkind != "int":
                raise TypeError(f"range_ over column {name!r} needs integer "
                                "values, column holds strings")
            hits = [col.base_slot + i for i, v in enumerate(col.values)
                    if (lo is None or v >= lo) and (hi is None or v <= hi)]
            if not hits:
                return ix.leaf(EMPTY_SLOT)
            return ix.or_(*[ix.leaf(s) for s in hits])
        # bit-sliced: [lo, hi] == LE(hi) ANDNOT LE(lo - 1)
        upper = self._bsi_le(col, hi) if hi is not None else \
            ix.leaf(UNIVERSE_SLOT)
        if lo is None or lo <= 0:
            return upper
        return ix.andnot(upper, self._bsi_le(col, lo - 1))

    def _bsi_le(self, col: BsiColumn, k: int) -> ix.Expr:
        """Rows with ``value <= k`` over the bit slices: the O'Neil/Quass
        MSB-down walk emitted as an expression tree — one OR of per-bit
        "strictly below at bit j" terms plus the all-bits-equal term, with
        the shared equality prefix reused as one sub-expression (the fused
        planner hash-conses it; the per-op path re-evaluates ``O(bits)``
        small combines)."""
        if k < 0:
            return ix.leaf(EMPTY_SLOT)
        if k >= (1 << col.bits) - 1:
            return ix.leaf(UNIVERSE_SLOT)
        below: List[ix.Expr] = []
        prefix: Optional[ix.Expr] = None      # "equal on all higher bits"
        for j in reversed(range(col.bits)):
            s_j = ix.leaf(col.base_slot + j)
            if (k >> j) & 1:
                term = self._not(s_j) if prefix is None else \
                    ix.and_(prefix, self._not(s_j))
                below.append(term)
                prefix = s_j if prefix is None else ix.and_(prefix, s_j)
            else:
                prefix = self._not(s_j) if prefix is None else \
                    ix.and_(prefix, self._not(s_j))
        return ix.or_(*below, prefix)

    @staticmethod
    def _not(e: ix.Expr) -> ix.Expr:
        return ix.andnot(ix.leaf(UNIVERSE_SLOT), e)

    # -- queries ---------------------------------------------------------------
    def cache_stats(self) -> dict:
        """Jit-query-cache accounting: ``hits`` (key already held a jitted
        executor), ``misses`` (cold compiles), ``fallbacks`` (jitted call
        failed and the query re-ran on the eager ladder — counted separately
        from cold compiles), ``entries``, and what the cache is keyed by.
        Also refreshes the ``store.query_cache.*{store=<id>}`` registry
        gauges."""
        self._publish_cache_gauges()
        return {"hits": self._cache_hits, "misses": self._cache_misses,
                "fallbacks": self._cache_fallbacks,
                "entries": len(self._query_fns),
                "keyed_by": "(expr, fused, backend)"}

    def _publish_cache_gauges(self) -> None:
        reg = obs.registry()
        sid = self._id
        reg.gauge("store.query_cache.hits", store=sid).set(self._cache_hits)
        reg.gauge("store.query_cache.misses",
                  store=sid).set(self._cache_misses)
        reg.gauge("store.query_cache.fallbacks",
                  store=sid).set(self._cache_fallbacks)
        reg.gauge("store.query_cache.entries",
                  store=sid).set(len(self._query_fns))

    def _run_cached(self, key: Tuple, make_fn: Callable, eager_fn: Callable):
        """One cached-executor run: cache lookup (hit/miss accounting), the
        jitted call under a ``store.execute`` span, and the eager-ladder
        fallback (its own span + counter) when the jitted call fails."""
        fn = self._query_fns.get(key)
        if fn is None:
            # jax.jit wrapping is lazy: the actual trace+compile cost lands
            # inside the first call, i.e. the cache=miss execute span
            self._cache_misses += 1
            cache = "miss"
            fn = make_fn()
            self._query_fns[key] = fn
        else:
            self._cache_hits += 1
            cache = "hit"
        try:
            with obs.span("store.execute", cache=cache):
                return fn(self._stack)
        except Exception:
            self._cache_fallbacks += 1
            with obs.span("store.fallback_eager"):
                return eager_fn()

    def query(self, pred: P.Pred, *, fused: bool = False,
              backend: Optional[str] = None, max_retries: int = 1,
              backoff_s: float = 0.0) -> RoaringSlab:
        """Rows matching ``pred`` as a canonical ``RoaringSlab`` of row ids —
        one ``index.execute`` run (``fused=True`` = one kernel launch for the
        whole tree) through the engine's degradation ladder.

        The whole call is jitted per compiled tree shape (first use pays one
        compile, repeats are launch-only). A failure inside the jitted call
        falls back to the eager engine, whose runtime retry/backoff ladder
        the jit boundary would otherwise swallow. With telemetry enabled
        (``repro.obs.enable()``) the call records a compile -> execute span
        tree, output-kind histograms, and the query-cache gauges.
        """
        with obs.span("store.query", fused=fused):
            with obs.span("store.compile"):
                expr = self.compile(pred)
            out = self._run_cached(
                (expr, fused, backend),
                lambda: jax.jit(lambda stack: ix.execute(
                    stack, expr, fused=fused, backend=backend)),
                lambda: ix.execute(self._stack, expr, fused=fused,
                                   backend=backend, max_retries=max_retries,
                                   backoff_s=backoff_s))
            if obs.enabled():
                obs.record_kinds("store.output_kinds", out.kinds)
                self._publish_cache_gauges()
            return out

    def count(self, pred: P.Pred, *, fused: bool = False,
              backend: Optional[str] = None, max_retries: int = 1,
              backoff_s: float = 0.0) -> int:
        """|rows matching ``pred``| without materializing the result slab
        (jitted whole-call with the same cache/fallback as ``query``)."""
        with obs.span("store.count", fused=fused):
            with obs.span("store.compile"):
                expr = self.compile(pred)
            out = self._run_cached(
                ("card", expr, fused, backend),
                lambda: jax.jit(lambda stack: ix.execute_card(
                    stack, expr, fused=fused, backend=backend)),
                lambda: ix.execute_card(self._stack, expr, fused=fused,
                                        backend=backend,
                                        max_retries=max_retries,
                                        backoff_s=backoff_s))
            if obs.enabled():
                self._publish_cache_gauges()
            return int(out)

    def query_indices(self, pred: P.Pred, **kw) -> np.ndarray:
        """Matching row ids as a sorted host ``int64`` array."""
        return self.query(pred, **kw).to_roaring().to_array()

    def sum_(self, name: str, pred: Optional[P.Pred] = None) -> int:
        """Sum of a bit-sliced column over the rows matching ``pred``
        (all rows when ``None``): Σ_j 2^j · |slice_j ∩ rows| — one batched
        scoring launch over the column's slices, nothing materialized per
        bit."""
        col = self.column(name)
        if not isinstance(col, BsiColumn):
            raise TypeError(f"sum_ needs a bit-sliced column; {name!r} is "
                            "an equality column")
        rows = self.query(pred) if pred is not None else \
            ix.execute(self._stack, ix.leaf(UNIVERSE_SLOT))
        slots = jnp.arange(col.base_slot, col.base_slot + col.bits)
        per_bit = np.asarray(ix.batched_and_card(self._stack[slots], rows))
        weights = np.asarray([1 << j for j in range(col.bits)], np.int64)
        return int(per_bit.astype(np.int64) @ weights)

    def __repr__(self) -> str:
        parts = ", ".join(
            f"{c.name}:{'bsi' + str(c.bits) if isinstance(c, BsiColumn) else len(c.values)}"
            for c in self.columns)
        return (f"BitmapStore(n_rows={self.n_rows}, slabs={self.n_slabs}, "
                f"columns=[{parts}])")
