"""The store's predicate language — a tiny, closed AST over named columns.

This is the *logical* query surface of ``repro.store``: ``eq`` / ``in_`` /
``range_`` atoms over columns combined with ``and_`` / ``or_`` / ``not_``.
Predicates are plain frozen dataclasses with no knowledge of bitmaps — the
``BitmapStore`` compiles them into ``repro.index`` expression trees over its
posting slabs (equality columns) and bit-sliced slices (integer columns), so
every query runs through the fused executor and its degradation ladder.

Atoms are schema-checked at *compile* time (unknown column, ``range_`` over
a non-integer equality column, malformed bounds), not at construction —
the same predicate object can be compiled against any store whose schema
supports it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

__all__ = [
    "Pred", "Eq", "In", "Range", "AndP", "OrP", "NotP",
    "eq", "in_", "range_", "and_", "or_", "not_",
]

# column values a predicate may name: the store's equality columns hold
# python ints or strings (numpy scalars are normalized at build time)
Value = Union[int, str]


@dataclasses.dataclass(frozen=True)
class Pred:
    """Base class for store predicates (static structure)."""


@dataclasses.dataclass(frozen=True)
class Eq(Pred):
    """``column == value``."""

    col: str
    value: Value


@dataclasses.dataclass(frozen=True)
class In(Pred):
    """``column ∈ values`` (an OR of equalities; duplicates are harmless)."""

    col: str
    values: Tuple[Value, ...]


@dataclasses.dataclass(frozen=True)
class Range(Pred):
    """``lo <= column <= hi`` (closed bounds; ``None`` leaves a side open).

    On a bit-sliced integer column this compiles to the O'Neil/Quass
    slice-comparison tree; on an integer-valued equality column it compiles
    to an OR over the stored values inside the bounds.
    """

    col: str
    lo: Optional[int]
    hi: Optional[int]


@dataclasses.dataclass(frozen=True)
class AndP(Pred):
    """N-ary conjunction."""

    children: Tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class OrP(Pred):
    """N-ary disjunction."""

    children: Tuple[Pred, ...]


@dataclasses.dataclass(frozen=True)
class NotP(Pred):
    """Complement over the store's full row universe."""

    child: Pred


def eq(col: str, value: Value) -> Eq:
    """``col == value`` atom."""
    return Eq(col, _norm_value(value))


def in_(col: str, values) -> In:
    """``col IN values`` atom (any iterable of values)."""
    return In(col, tuple(_norm_value(v) for v in values))


def range_(col: str, lo: Optional[int] = None,
           hi: Optional[int] = None) -> Range:
    """``lo <= col <= hi`` atom — closed bounds, ``None`` = unbounded.

    At least one bound is required (an all-open range is just the universe,
    which a query never needs to spell as a range).
    """
    if lo is None and hi is None:
        raise ValueError("range_ needs at least one bound")
    lo_i = None if lo is None else int(lo)
    hi_i = None if hi is None else int(hi)
    if lo_i is not None and hi_i is not None and lo_i > hi_i:
        raise ValueError(f"range_ bounds inverted: lo {lo_i} > hi {hi_i}")
    return Range(col, lo_i, hi_i)


def and_(*children: Pred) -> Pred:
    """N-ary AND (``and_(p)`` collapses to ``p``; >= 1 child required)."""
    if not children:
        raise ValueError("and_() needs at least one child predicate")
    _check_preds(children)
    return children[0] if len(children) == 1 else AndP(tuple(children))


def or_(*children: Pred) -> Pred:
    """N-ary OR (``or_(p)`` collapses to ``p``; >= 1 child required)."""
    if not children:
        raise ValueError("or_() needs at least one child predicate")
    _check_preds(children)
    return children[0] if len(children) == 1 else OrP(tuple(children))


def not_(child: Pred) -> NotP:
    """Complement over the store's row universe."""
    _check_preds((child,))
    return NotP(child)


def _check_preds(children) -> None:
    for c in children:
        if not isinstance(c, Pred):
            raise TypeError(f"not a store predicate: {c!r}")


def _norm_value(v) -> Value:
    """Normalize a column value to a plain python int or str (numpy scalars
    and bools fold to int) so predicate equality and JSON metadata agree."""
    if isinstance(v, str):
        return v
    if isinstance(v, (bool,)):
        return int(v)
    try:
        return int(v)            # numpy integer scalars land here
    except (TypeError, ValueError):
        raise TypeError(f"unsupported column value type: {type(v).__name__} "
                        f"({v!r}); store columns hold ints or strings")
