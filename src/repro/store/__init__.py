"""``repro.store`` — columnar bitmap-index store + predicate compiler.

The database workload the paper's headline results are about: ingest
columnar records into per-(column, value) Roaring posting slabs (equality
columns) and bit-sliced-index slabs (integer range/aggregate columns), then
answer ``eq / in_ / range_ / and_ / or_ / not_`` predicate queries by
compiling them into ``repro.index`` expression trees over ONE key-aligned
stacked slab — every query runs through the fused executor and its
degradation ladder. ``save`` / ``load`` serialize each slab through the
portable ``RoaringFormatSpec`` codec (CRoaring/PyRoaring-readable blobs)
with the hardened parser on the load path.

Quick tour::

    from repro import store

    s = store.BitmapStore.build(records, bsi=("age",))
    rows = s.query(store.and_(store.eq("sex", 1),
                              store.range_("age", 30, 40)), fused=True)
    n = s.count(store.not_(store.in_("state", [3, 7])))
    blob = s.save()
    s2 = store.BitmapStore.load(blob)      # typed rejection on bad bytes
"""

from repro.store.io import STORE_MAGIC, StoreFormatError
from repro.store.predicate import (AndP, Eq, In, NotP, OrP, Pred, Range,
                                   and_, eq, in_, not_, or_, range_)
from repro.store.store import (EMPTY_SLOT, UNIVERSE_SLOT, BitmapStore,
                               BsiColumn, EqColumn)

__all__ = [
    "BitmapStore", "EqColumn", "BsiColumn",
    "Pred", "Eq", "In", "Range", "AndP", "OrP", "NotP",
    "eq", "in_", "range_", "and_", "or_", "not_",
    "StoreFormatError", "STORE_MAGIC",
    "UNIVERSE_SLOT", "EMPTY_SLOT",
]
