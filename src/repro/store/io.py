"""Durable store streams — the ``BitmapStore`` container format.

Layout (all integers little-endian)::

    magic    8 bytes   b"RBSTORE1"
    u32      metadata length M
    M bytes  canonical JSON metadata: {"version": 1, "n_rows": N,
             "columns": [{"kind": "eq", "name": ..., "vkind": "int"|"str",
                          "values": [...sorted...]} |
                         {"kind": "bsi", "name": ..., "bits": b}, ...]}
    then, one entry per column slab in slot order (eq values in sorted
    order, bsi slices LSB first):
    u32      blob length L
    L bytes  a portable ``RoaringFormatSpec`` stream (the standard Roaring
             interchange format — each blob is independently readable by
             CRoaring / PyRoaring clients)

The universe and empty slots are not stored — they are derivable from
``n_rows``. Metadata is *canonical* JSON (sorted keys, no whitespace), and
``load_store`` rejects any stream whose metadata bytes differ from the
canonical re-dump of their parsed value — so every accepted stream re-saves
byte-identically, the same contract the slab codec keeps.

``load_store`` treats input as untrusted: every read is bounds-checked,
metadata is schema-validated (version, unique column names, sorted-unique
typed values, bit widths), each blob goes through the hardened
``RoaringFormatSpec.deserialize`` (with the caller's ``DecodeLimits``), and
each decoded posting must stay inside the declared row universe. Any
violation raises a typed ``StoreFormatError`` / ``RoaringFormatError`` —
never a bare struct/json/numpy error, and never a silently-wrong store.
"""

from __future__ import annotations

import json
import struct
from typing import List, Optional

import numpy as np

from repro.core import py_roaring as pr
from repro.roaring.format import (DecodeLimits, RoaringFormatError,
                                  RoaringFormatSpec)

__all__ = ["STORE_MAGIC", "StoreFormatError", "save_store", "load_store"]

STORE_MAGIC = b"RBSTORE1"

_MAX_META_BYTES = 1 << 24          # 16 MiB of metadata is already absurd
_MAX_BSI_BITS = 64
_MAX_ROWS = 1 << 32                # the 32-bit row universe slabs address
# stacked-slab cells (slabs x chunks) a load may materialize: the stack
# payload is cells x 8 KiB, so 2^17 cells caps the device allocation at
# 1 GiB.  Metadata declaring more (a forged n_rows near 2^32, or millions
# of posting values) is an allocation bomb, not a store.
_MAX_STACK_CELLS = 1 << 17


class StoreFormatError(RoaringFormatError):
    """A store stream violated the container-format contract (magic,
    metadata, blob framing, or posting/universe consistency). Subclasses
    ``RoaringFormatError``, so one ``except`` arm covers the whole load
    path — inner slab-blob violations keep their own typed classes."""


def _canon_meta(meta: dict) -> bytes:
    return json.dumps(meta, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def save_store(store) -> bytes:
    """``BitmapStore`` -> durable byte stream (format above)."""
    from repro.store.store import BsiColumn, _RESERVED_SLOTS

    cols = []
    for c in store.columns:
        if isinstance(c, BsiColumn):
            cols.append({"kind": "bsi", "name": c.name, "bits": c.bits})
        else:
            cols.append({"kind": "eq", "name": c.name, "vkind": c.vkind,
                         "values": list(c.values)})
    meta = _canon_meta({"version": 1, "n_rows": store.n_rows,
                        "columns": cols})
    out = bytearray(STORE_MAGIC)
    out += struct.pack("<I", len(meta))
    out += meta
    for rb in store._bitmaps[_RESERVED_SLOTS:]:
        blob = RoaringFormatSpec.serialize(rb)
        out += struct.pack("<I", len(blob))
        out += blob
    return bytes(out)


def _need(data: bytes, pos: int, k: int, what: str) -> None:
    if pos + k > len(data):
        raise StoreFormatError(
            f"truncated store stream: {what} needs {k} bytes, "
            f"{len(data) - pos} remain", offset=pos)


def _check_meta(meta, offset: int) -> None:
    """Schema-validate parsed metadata; raise ``StoreFormatError`` on any
    shape violation (typed, with the metadata's byte offset)."""
    def bad(msg: str):
        raise StoreFormatError(f"bad store metadata: {msg}", offset=offset)

    if not isinstance(meta, dict):
        bad("top level is not an object")
    if set(meta) != {"version", "n_rows", "columns"}:
        bad(f"keys {sorted(meta)} != ['columns', 'n_rows', 'version']")
    if meta["version"] != 1:
        bad(f"unsupported version {meta['version']!r}")
    n_rows = meta["n_rows"]
    if not isinstance(n_rows, int) or isinstance(n_rows, bool) \
            or not 0 <= n_rows <= _MAX_ROWS:
        bad(f"n_rows {n_rows!r} outside [0, 2^32]")
    if not isinstance(meta["columns"], list) or not meta["columns"]:
        bad("columns must be a non-empty list")
    names = set()
    for ci, col in enumerate(meta["columns"]):
        if not isinstance(col, dict) or "kind" not in col \
                or "name" not in col or not isinstance(col["name"], str):
            bad(f"column {ci} malformed")
        if col["name"] in names:
            bad(f"duplicate column name {col['name']!r}")
        names.add(col["name"])
        if col["kind"] == "bsi":
            if set(col) != {"kind", "name", "bits"}:
                bad(f"bsi column {col['name']!r} keys {sorted(col)}")
            b = col["bits"]
            if not isinstance(b, int) or isinstance(b, bool) \
                    or not 1 <= b <= _MAX_BSI_BITS:
                bad(f"bsi column {col['name']!r} bits {b!r} outside "
                    f"[1, {_MAX_BSI_BITS}]")
        elif col["kind"] == "eq":
            if set(col) != {"kind", "name", "vkind", "values"}:
                bad(f"eq column {col['name']!r} keys {sorted(col)}")
            vkind, values = col["vkind"], col["values"]
            if vkind not in ("int", "str"):
                bad(f"eq column {col['name']!r} vkind {vkind!r}")
            if not isinstance(values, list):
                bad(f"eq column {col['name']!r} values not a list")
            want = str if vkind == "str" else int
            for v in values:
                if not isinstance(v, want) or isinstance(v, bool):
                    bad(f"eq column {col['name']!r} value {v!r} is not "
                        f"{vkind}")
            if any(values[i] >= values[i + 1]
                   for i in range(len(values) - 1)):
                bad(f"eq column {col['name']!r} values not sorted-unique")
        else:
            bad(f"column {col['name']!r} kind {col['kind']!r}")


def load_store(data: bytes, *, limits: Optional[DecodeLimits] = None,
               check: bool = False):
    """Untrusted store stream -> ``BitmapStore``.

    Structural validation always runs; ``check=True`` additionally audits
    every decoded bitmap (``RoaringFormatSpec.deserialize(check=True)``).
    ``limits`` bounds each slab blob's decode (container count / bytes).
    """
    from repro.store.store import BitmapStore, BsiColumn, EqColumn

    _need(data, 0, len(STORE_MAGIC) + 4, "magic + metadata length")
    if data[:len(STORE_MAGIC)] != STORE_MAGIC:
        raise StoreFormatError(
            f"not a bitmap-store stream (magic {data[:8]!r})", offset=0)
    pos = len(STORE_MAGIC)
    (meta_len,) = struct.unpack_from("<I", data, pos)
    pos += 4
    if meta_len > _MAX_META_BYTES:
        raise StoreFormatError(
            f"metadata of {meta_len} bytes exceeds the {_MAX_META_BYTES}-"
            "byte ceiling", offset=pos - 4)
    _need(data, pos, meta_len, "metadata")
    meta_pos, raw = pos, data[pos:pos + meta_len]
    pos += meta_len
    try:
        meta = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise StoreFormatError(f"metadata is not valid JSON: {e}",
                               offset=meta_pos) from None
    _check_meta(meta, meta_pos)
    if _canon_meta(meta) != raw:
        raise StoreFormatError(
            "metadata is not canonical JSON (re-save would not be "
            "byte-identical)", offset=meta_pos)

    n_rows = meta["n_rows"]
    n_slabs = 2 + sum(col["bits"] if col["kind"] == "bsi"
                      else len(col["values"]) for col in meta["columns"])
    n_chunks = max(1, -(-n_rows // (1 << 16)))
    if n_slabs * n_chunks > _MAX_STACK_CELLS:
        raise StoreFormatError(
            f"store would stack {n_slabs} slabs x {n_chunks} chunks = "
            f"{n_slabs * n_chunks} cells, over the {_MAX_STACK_CELLS}-cell "
            "(1 GiB payload) ceiling", offset=meta_pos)
    universe = pr.RoaringBitmap.from_ranges([(0, n_rows)]) if n_rows \
        else pr.RoaringBitmap()
    bitmaps: List[pr.RoaringBitmap] = [universe, pr.RoaringBitmap()]
    columns: List = []
    for col in meta["columns"]:
        base = len(bitmaps)
        if col["kind"] == "bsi":
            n_blobs = col["bits"]
            columns.append(BsiColumn(col["name"], col["bits"], base))
        else:
            n_blobs = len(col["values"])
            columns.append(EqColumn(col["name"], col["vkind"],
                                    tuple(col["values"]), base))
        for b in range(n_blobs):
            what = f"column {col['name']!r} slab {b}"
            _need(data, pos, 4, f"{what} length")
            (blob_len,) = struct.unpack_from("<I", data, pos)
            pos += 4
            _need(data, pos, blob_len, f"{what} payload")
            rb = RoaringFormatSpec.deserialize(
                data[pos:pos + blob_len], limits=limits, check=check)
            vals = rb.to_array()
            if vals.size and int(vals[-1]) >= n_rows:
                raise StoreFormatError(
                    f"{what} holds row id {int(vals[-1])} outside the "
                    f"declared universe of {n_rows} rows", offset=pos)
            bitmaps.append(rb)
            pos += blob_len
    if pos != len(data):
        raise StoreFormatError(
            f"{len(data) - pos} trailing bytes after the last slab blob",
            offset=pos)
    return BitmapStore(n_rows, columns, bitmaps)
