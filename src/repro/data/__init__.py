from .pipeline import (SyntheticCorpus, BitmapIndex, DataPipeline,
                       PipelineState)

__all__ = ["SyntheticCorpus", "BitmapIndex", "DataPipeline", "PipelineState"]
