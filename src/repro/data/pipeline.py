"""Bitmap-indexed data pipeline — the paper's native workload, serving tokens.

A corpus of documents carries categorical attributes (language, quality
bucket, length bucket, dedup cluster). Each attribute value is indexed as a
paper-faithful RoaringBitmap over document ids; a training *mixture query*
(e.g. ``lang:en AND quality>=3 AND NOT dedup_dup``) is evaluated with Roaring
AND/OR/ANDNOT — milliseconds over millions of docs, with exact cardinalities
for mixture accounting.

Determinism + fault tolerance: the pipeline state is (epoch, cursor, the
selection bitmap's query string, permutation seed). Restoring the state
replays the same batches; the selection bitmap is re-derived from the query
so checkpoints stay small.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core import RoaringBitmap


# =============================================================================
# synthetic corpus (documents + attributes + tokens)
# =============================================================================

class SyntheticCorpus:
    """Deterministic synthetic corpus: doc i reproducibly generates tokens and
    attributes from (seed, i) without storing the whole corpus."""

    def __init__(self, n_docs: int, vocab: int, seed: int = 0,
                 mean_len: int = 512):
        self.n_docs = n_docs
        self.vocab = vocab
        self.seed = seed
        self.mean_len = mean_len
        rng = np.random.default_rng(seed)
        self.lang = rng.integers(0, 8, n_docs).astype(np.int32)
        self.quality = rng.integers(0, 5, n_docs).astype(np.int32)
        self.length_bucket = rng.integers(0, 4, n_docs).astype(np.int32)
        self.dedup_dup = rng.random(n_docs) < 0.08

    def tokens(self, doc_id: int, max_len: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 20) ^ doc_id)
        ln = max(8, int(rng.poisson(self.mean_len)))
        ln = min(ln, max_len)
        # zipf-like unigram structure (low ids frequent) so LMs have a
        # learnable signal; uniform tokens pin the loss at ln(vocab)
        frac = rng.beta(0.5, 4.0, ln)
        return np.clip((frac * self.vocab).astype(np.int32), 1,
                       self.vocab - 1)


class BitmapIndex:
    """Attribute -> value -> RoaringBitmap of doc ids."""

    def __init__(self, corpus: SyntheticCorpus):
        self.corpus = corpus
        self.index: Dict[str, Dict[int, RoaringBitmap]] = {}
        doc_ids = np.arange(corpus.n_docs, dtype=np.int64)
        for attr in ("lang", "quality", "length_bucket"):
            vals = getattr(corpus, attr)
            self.index[attr] = {
                int(v): RoaringBitmap.from_sorted_unique(doc_ids[vals == v])
                for v in np.unique(vals)}
        self.index["dedup_dup"] = {
            1: RoaringBitmap.from_sorted_unique(doc_ids[corpus.dedup_dup])}

    def bitmap(self, attr: str, value: int) -> RoaringBitmap:
        rb = self.index.get(attr, {}).get(int(value))
        if rb is None:
            return RoaringBitmap()
        return rb

    def query(self, spec: str) -> RoaringBitmap:
        """Tiny query language: 'lang=1&quality>=3&!dedup_dup' or
        'lang=1|lang=2'. & binds over |; ! negates one attribute."""
        universe = RoaringBitmap.from_sorted_unique(
            np.arange(self.corpus.n_docs, dtype=np.int64))
        result: Optional[RoaringBitmap] = None
        for conj in spec.split("&"):
            conj = conj.strip()
            acc: Optional[RoaringBitmap] = None
            for term in conj.split("|"):
                term = term.strip()
                neg = term.startswith("!")
                term = term.lstrip("!")
                if ">=" in term:
                    attr, v = term.split(">=")
                    bm = RoaringBitmap()
                    for val, rb in self.index[attr.strip()].items():
                        if val >= int(v):
                            bm = bm | rb
                elif "=" in term:
                    attr, v = term.split("=")
                    bm = self.bitmap(attr.strip(), int(v))
                else:
                    bm = self.bitmap(term, 1)
                if neg:
                    bm = universe.andnot(bm)
                acc = bm if acc is None else (acc | bm)
            result = acc if result is None else (result & acc)
        return result if result is not None else universe


# =============================================================================
# deterministic sharded loader
# =============================================================================

@dataclasses.dataclass
class PipelineState:
    query: str
    epoch: int = 0
    cursor: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(**d)


class DataPipeline:
    """Packs selected documents into fixed [batch, seq] token blocks.

    ``next_batch`` is deterministic in (state, shard_id): every data-parallel
    shard draws disjoint document slices of the epoch permutation, and the
    post-restart stream equals the uninterrupted one.
    """

    def __init__(self, index: BitmapIndex, state: PipelineState,
                 batch: int, seq_len: int, n_shards: int = 1,
                 shard_id: int = 0):
        self.index = index
        self.state = state
        self.batch = batch
        self.seq_len = seq_len
        self.n_shards = n_shards
        self.shard_id = shard_id
        self.selection = index.query(state.query).to_array()
        assert self.selection.size > 0, f"empty selection: {state.query}"

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(self.state.seed + epoch * 1000003)
        return rng.permutation(self.selection)

    def doc_start_bitmap(self, tokens_meta: List[int]) -> RoaringBitmap:
        """Document-start token offsets as a roaring bitmap (feeds the
        doc-boundary attention mask)."""
        return RoaringBitmap.from_array(tokens_meta)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray, RoaringBitmap]:
        """Returns (tokens [B, S+1], loss_mask [B, S+1], doc_starts bitmap)."""
        B, S = self.batch, self.seq_len + 1
        out = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.float32)
        doc_starts: List[int] = []
        perm = self._perm(self.state.epoch)
        cursor = self.state.cursor + self.shard_id
        for b in range(B):
            fill = 0
            while fill < S:
                if cursor >= perm.size:
                    self.state.epoch += 1
                    perm = self._perm(self.state.epoch)
                    cursor = self.shard_id
                doc = int(perm[cursor])
                cursor += self.n_shards
                toks = self.index.corpus.tokens(doc, S - fill)
                doc_starts.append(b * S + fill)
                out[b, fill: fill + toks.size] = toks
                mask[b, fill: fill + toks.size] = 1.0
                fill += toks.size + 1          # EOS gap
        self.state.cursor = cursor - self.shard_id
        return out, mask, RoaringBitmap.from_array(doc_starts)
