"""Roaring block-mask algebra for sparse attention.

An attention pattern over S tokens with block size B is an (S/B) x (S/B)
boolean matrix; each *query-block row* is an integer set of active key-block
ids, stored as a paper-faithful RoaringBitmap. Pattern primitives (local
window, global stripes, causal, document-boundary) are built as Roaring
bitmaps and composed with the paper's AND/OR/ANDNOT — this is the framework's
host-side mask compiler, running the actual reproduction code.

``compile_mask`` extracts every row's packed block list (Algorithm 2) into
the (kv_idx, counts) arrays the Pallas kernel's scalar-prefetch grid
consumes. For a 500k-token sequence at block 128 there are 4096 block rows;
each row's set lives in exactly one Roaring container — arrays when sparse,
bitmap containers when a row attends broadly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import RoaringBitmap, union_many


def causal_mask(num_blocks: int) -> List[RoaringBitmap]:
    """Row r attends to blocks [0, r] — one run, built directly (2016
    paper's run containers; no per-block materialization)."""
    return [RoaringBitmap.from_range(0, r + 1) for r in range(num_blocks)]


def local_window_mask(num_blocks: int, window_blocks: int,
                      causal: bool = True) -> List[RoaringBitmap]:
    """Row r attends to its contiguous window — one run per row."""
    rows = []
    for r in range(num_blocks):
        lo = max(0, r - window_blocks + 1)
        hi = r + 1 if causal else min(num_blocks, r + window_blocks)
        rows.append(RoaringBitmap.from_range(lo, hi))
    return rows


def global_stripe_mask(num_blocks: int, stripe: Sequence[int],
                       causal: bool = True) -> List[RoaringBitmap]:
    """Every row attends to the given global block ids (and, symmetrically,
    stripe rows attend everywhere — the BigBird-style global pattern).
    Stripe rows are runs; scattered rows stay array containers."""
    stripe_arr = np.asarray(sorted(set(stripe)), dtype=np.int64)
    rows = []
    for r in range(num_blocks):
        s = stripe_arr[stripe_arr <= r] if causal else stripe_arr
        if r in stripe:
            rows.append(RoaringBitmap.from_range(
                0, r + 1 if causal else num_blocks))
        else:
            rb = RoaringBitmap.from_sorted_unique(s)
            rb.add(r)                      # always see own block
            rows.append(rb)
    return rows


def doc_boundary_mask(num_blocks: int, doc_starts_blocks: Sequence[int],
                      causal: bool = True) -> List[RoaringBitmap]:
    """Attention confined within document segments (from the data pipeline's
    bitmap index of document starts) — one run per row."""
    starts = sorted(set([0] + list(doc_starts_blocks)))
    bounds = starts + [num_blocks]
    rows = []
    for r in range(num_blocks):
        seg = max(i for i, s in enumerate(starts) if s <= r)
        lo, hi = bounds[seg], bounds[seg + 1]
        hi_eff = r + 1 if causal else hi
        rows.append(RoaringBitmap.from_range(lo, hi_eff))
    return rows


@dataclasses.dataclass
class MaskBuilder:
    """Composable mask: rows of RoaringBitmaps with paper set-algebra."""

    rows: List[RoaringBitmap]

    def union(self, other: "MaskBuilder") -> "MaskBuilder":
        return MaskBuilder([a | b for a, b in zip(self.rows, other.rows)])

    def union_many(self, others: Sequence["MaskBuilder"],
                   device: bool = True,
                   capacity: Optional[int] = None) -> "MaskBuilder":
        """Alg. 4 union across many patterns, row-wise.

        Default routes through the batched query engine: every pattern's
        rows become one batched slab (kind-preserving — window/causal/doc
        rows stay run rows), ``roaring.union_all``'s log-depth tree
        reduction merges all patterns vmapped over the row axis in one
        launch, and the result bridges back kind-for-kind via
        ``RoaringSlab.to_roaring``. ``capacity`` (containers per row) is
        derived from the largest block id present when not given.
        ``device=False`` keeps the host heap-union reference path; the two
        are bit-identical (tested in tests/test_wide_ops.py).
        """
        if not device or not others:
            return MaskBuilder([
                union_many([self.rows[i]] + [o.rows[i] for o in others])
                for i in range(len(self.rows))])
        from repro import roaring

        if capacity is None:
            capacity = 1 + max(
                (r.keys[-1] for b in (self, *others) for r in b.rows
                 if r.keys), default=0)
        stacks = [rows_to_slabs(b.rows, capacity) for b in (self, *others)]
        merged = roaring.union_all(stacks, capacity=capacity)
        return MaskBuilder([merged[r].to_roaring()
                            for r in range(len(self.rows))])

    def intersect(self, other: "MaskBuilder") -> "MaskBuilder":
        return MaskBuilder([a & b for a, b in zip(self.rows, other.rows)])

    def subtract(self, other: "MaskBuilder") -> "MaskBuilder":
        return MaskBuilder([a.andnot(b) for a, b in zip(self.rows, other.rows)])

    def density(self) -> float:
        n = len(self.rows)
        return sum(len(r) for r in self.rows) / float(n * n)

    def size_in_bytes(self) -> int:
        """Compressed mask footprint — the paper's metric, applied to masks."""
        return sum(r.size_in_bytes() for r in self.rows)


def compile_mask(builder: MaskBuilder, max_active: Optional[int] = None):
    """Extract packed block lists: (kv_idx i32[R, max_active], counts i32[R]).

    Row extraction is Algorithm 2 on each row's containers. ``max_active``
    defaults to the longest row (the kernel grid's K dimension).
    """
    rows = builder.rows
    counts = np.asarray([len(r) for r in rows], np.int32)
    if max_active is None:
        max_active = max(1, int(counts.max()))
    kv_idx = np.zeros((len(rows), max_active), np.int32)
    for i, r in enumerate(rows):
        vals = r.to_array()
        assert vals.size <= max_active, (i, vals.size, max_active)
        kv_idx[i, : vals.size] = vals
    return kv_idx, counts


def mask_density(kv_idx: np.ndarray, counts: np.ndarray) -> float:
    return float(counts.sum()) / (kv_idx.shape[0] ** 2)


# =============================================================================
# device-side mask algebra (jax_roaring hybrid dispatch)
# =============================================================================

def rows_to_slabs(rows: Sequence[RoaringBitmap], capacity: int = 2):
    """Stack mask rows into a batched ``roaring.RoaringSlab`` (leading axis
    = mask row).

    Block-id universes are small (< 2^16 for any practical block count), so
    each row is one container; the kind-preserving bridge keeps window /
    causal / doc rows as run rows (no per-block materialization), feeding
    the run pair classes of the batched object-API surfaces below. Rows are
    stacked raw (``align=False``): elementwise-batched ops re-align per row.
    """
    from repro import roaring

    return roaring.stack(
        [roaring.RoaringSlab.from_roaring(r, capacity) for r in rows],
        align=False)


def mask_overlap_cards(m1: "MaskBuilder", m2: "MaskBuilder",
                       capacity: int = 2) -> np.ndarray:
    """Per-row |row1 ∩ row2| without materializing intersection masks — the
    cardinality-only dispatch fast path, batched over rows. Useful for
    quantifying how much two attention patterns share (e.g. how redundant a
    global stripe is with the local window)."""
    s1 = rows_to_slabs(m1.rows, capacity)
    s2 = rows_to_slabs(m2.rows, capacity)
    return np.asarray(s1.and_card(s2))


def mask_jaccard(m1: "MaskBuilder", m2: "MaskBuilder",
                 capacity: int = 2) -> np.ndarray:
    """Per-row Jaccard similarity of two mask patterns (one dispatch pass)."""
    s1 = rows_to_slabs(m1.rows, capacity)
    s2 = rows_to_slabs(m2.rows, capacity)
    return np.asarray(s1.jaccard(s2))


def build_arch_mask(num_blocks: int, *, pattern: str, window_blocks: int = 8,
                    n_global: int = 4, causal: bool = True) -> MaskBuilder:
    """Standard long-context pattern: local window UNION global stripes —
    composed with the paper's set algebra."""
    local = MaskBuilder(local_window_mask(num_blocks, window_blocks, causal))
    if pattern == "local":
        return local
    stripe = list(range(n_global))
    glob = MaskBuilder(global_stripe_mask(num_blocks, stripe, causal))
    if pattern == "local_global":
        return local.union(glob)
    raise ValueError(pattern)
