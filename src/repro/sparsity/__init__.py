from .masks import (MaskBuilder, build_arch_mask, compile_mask,
                    local_window_mask, global_stripe_mask, causal_mask,
                    doc_boundary_mask, mask_density, rows_to_slabs,
                    mask_overlap_cards, mask_jaccard)

__all__ = ["MaskBuilder", "build_arch_mask", "compile_mask",
           "local_window_mask", "global_stripe_mask", "causal_mask",
           "doc_boundary_mask", "mask_density", "rows_to_slabs",
           "mask_overlap_cards", "mask_jaccard"]
