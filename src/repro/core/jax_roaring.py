"""TPU-native Roaring bitmap: the static-shape container slab.

The paper's dynamic two-level structure is re-thought for accelerator
execution (static shapes, no pointer chasing):

  * a ``RoaringSlab`` holds up to ``C`` containers. Row ``i`` of ``data``
    (u16[4096], 8 kB) is a packed sorted u16 array (first ``card[i]``
    entries), a 2^16-bit bitmap stored as 4096 16-bit words, *or* a packed
    run list — sorted ``(start, length-1)`` u16 pairs (the 2016 follow-up
    paper's run containers), padded with ``(0xFFFF, 0xFFFF)`` which can never
    be a valid run. The paper's 4096-element threshold is exactly the
    break-even where array and bitmap forms cost 8 kB, so a uniform slab row
    wastes nothing at the boundary; runs reuse the same row.
  * ``keys`` is the sorted first-level index (padded with ``KEY_SENTINEL``),
    ``card`` the per-container cardinality counters (paper S2), ``kind`` the
    container type tag (0 empty / 1 array / 2 bitmap / 3 run).

Set algebra runs the *kind-dispatch engine*: key-aligned container pairs are
classified by ``(kind_a, kind_b)`` against the declarative registry in
``repro.kernels.roaring.dispatch`` (one ``PairClass`` per grid cell naming
the row kernel and output semantic) and routed through the matching
algorithm — vectorized galloping for array x array, bit probes for
array x bitmap (no domain lift), fused word-op + popcount for
bitmap x bitmap, gallop-in-ranges for array x run, range-mask coverage for
run x bitmap, and a run-domain merge for run x run that never materializes
bits at all. On TPU the routing is a ``@pl.when``-tagged Pallas kernel
(``repro.kernels.roaring``) generated from the same table; the XLA reference
computes the same cheap paths cond-guarded per class. Output
canonicalization is *best-of-three* (``runOptimize``: array vs bitmap vs run
by serialized size) and *lazy*: only bitmap-domain rows whose canonical form
is packed (array or run) pay the O(2^16) extraction, and those passes are
``lax.cond``-guarded so array- and run-dominated workloads never touch the
2^16-element domain at runtime. Cardinality is maintained with
``lax.population_count`` (the popcnt the paper leans on) fused into the same
pass, mirroring Algorithm 1/3. See DESIGN.md for the dispatch table.

All functions are jit-/vmap-/pjit-compatible and allocation-free at trace
time; capacities are static Python ints.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.roaring import dispatch as _D

CHUNK_BITS = 16
CHUNK_SIZE = 1 << CHUNK_BITS
ARRAY_MAX = 4096                 # paper's array/bitmap threshold
ROW_WORDS = 4096                 # 4096 x u16 words = 2^16 bits = 8 kB
MAX_RUNS = ROW_WORDS // 2        # (start, length-1) pairs per run row
KEY_SENTINEL = jnp.int32(1 << 20)

KIND_EMPTY = _D.KIND_EMPTY       # 0
KIND_ARRAY = _D.KIND_ARRAY       # 1
KIND_BITMAP = _D.KIND_BITMAP     # 2
KIND_RUN = _D.KIND_RUN           # 3

# raw row *forms* flowing into the canonicalization engine (how a computed
# row is currently represented, before best-of-three picks its final kind)
FORM_ARRAY, FORM_BITS, FORM_RUNS = 0, 1, 2

# The public slab API. Every symbol listed here is documented in docs/API.md
# (tests/test_docs.py asserts the two stay in sync).
__all__ = [
    # layout constants
    "CHUNK_BITS", "CHUNK_SIZE", "ARRAY_MAX", "ROW_WORDS", "MAX_RUNS",
    "KEY_SENTINEL", "KIND_EMPTY", "KIND_ARRAY", "KIND_BITMAP", "KIND_RUN",
    # container slab + constructors / exporters
    "RoaringSlab", "empty", "from_indices", "from_dense_array",
    "from_roaring", "from_ranges", "to_roaring", "to_indices", "extract_row",
    "slab_run_optimize",
    # membership / rank / select
    "contains", "rank", "slab_select",
    # pairwise set algebra (kind-dispatch engine)
    "slab_and", "slab_or", "slab_xor", "slab_andnot",
    "slab_and_card", "slab_or_card", "slab_jaccard",
    # batched / wide ops
    "stack_slabs", "slab_and_many", "slab_and_card_many",
    "union_many_slabs",
    # legacy bitmap-domain A/B baselines
    "slab_and_bitmap_domain", "slab_or_bitmap_domain",
]


class RoaringSlab(NamedTuple):
    """Static-capacity Roaring bitmap. ``C = keys.shape[0]`` containers."""

    keys: jax.Array   # i32[C], sorted, inactive rows = KEY_SENTINEL
    card: jax.Array   # i32[C]
    kind: jax.Array   # i32[C] in {0,1,2}
    data: jax.Array   # u16[C, 4096]

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def n_containers(self) -> jax.Array:
        return jnp.sum(self.kind != KIND_EMPTY)

    @property
    def cardinality(self) -> jax.Array:
        """Sum of per-container counters (paper S2)."""
        return jnp.sum(self.card)

    def size_in_bytes(self) -> jax.Array:
        """Compressed serialized size (the paper's bits/item metric): 8-byte
        index header + 4 bytes/container header + per-kind payload — 2*card
        (array), 8192 (bitmap), 4*n_runs (run). Matches the oracle's
        ``RoaringBitmap.size_in_bytes`` accounting row for row."""
        nr = _rows_nruns(self.data, self.kind)
        payload = jnp.where(self.kind == KIND_ARRAY, 2 * self.card,
                            jnp.where(self.kind == KIND_BITMAP, 2 * ROW_WORDS,
                                      jnp.where(self.kind == KIND_RUN, 4 * nr,
                                                0)))
        live = (self.kind != KIND_EMPTY).astype(jnp.int32)
        return 8 + jnp.sum(live * (4 + payload))


def empty(capacity: int) -> RoaringSlab:
    """All-empty slab of static container capacity ``capacity``.

    Every row has ``kind == KIND_EMPTY``, ``card == 0``, key
    ``KEY_SENTINEL`` and zeroed payload — the identity element of
    ``slab_or`` / ``union_many_slabs``.
    """
    return RoaringSlab(
        keys=jnp.full((capacity,), KEY_SENTINEL, dtype=jnp.int32),
        card=jnp.zeros((capacity,), dtype=jnp.int32),
        kind=jnp.zeros((capacity,), dtype=jnp.int32),
        data=jnp.zeros((capacity, ROW_WORDS), dtype=jnp.uint16),
    )


# =============================================================================
# row-level helpers (one container)
# =============================================================================

def row_array_to_bits(row: jax.Array, card: jax.Array) -> jax.Array:
    """Packed sorted u16 array row -> 4096-word bitmap row.

    Distinct elements set distinct bits, so a scatter-add is an exact OR.
    """
    lo = row.astype(jnp.int32)
    valid = jnp.arange(row.shape[0]) < card
    word = jnp.where(valid, lo >> 4, ROW_WORDS)           # OOB index dropped
    bit = (lo & 15).astype(jnp.uint16)
    vals = jnp.where(valid, jnp.uint16(1) << bit, jnp.uint16(0))
    return jnp.zeros((ROW_WORDS,), jnp.uint16).at[word].add(
        vals, mode="drop")


def row_run_to_bits(row: jax.Array) -> jax.Array:
    """Packed run-pair row -> 4096-word coverage bitmap (the range-mask lift:
    difference-array scatter, O(n_runs + 4096) — never the 2^16 domain)."""
    return _D.coverage_by_scatter(row.reshape(_D.ROW_SHAPE),
                                  jnp.int32(MAX_RUNS)).reshape(ROW_WORDS)


def _row_run_parts(row: jax.Array):
    """(starts, length-1, valid) i32 views of a run row's 2048 pair slots.
    The ``(0xFFFF, 0xFFFF)`` padding fails ``start + length-1 < 2^16``, which
    every real run satisfies (a full-chunk run is ``(0, 0xFFFF)``)."""
    p = row.reshape(MAX_RUNS, 2).astype(jnp.int32)
    s, l = p[:, 0], p[:, 1]
    return s, l, (s + l) < CHUNK_SIZE


def row_nruns(row: jax.Array, kind: jax.Array) -> jax.Array:
    """Run count of a run row (0 for other kinds)."""
    _, _, valid = _row_run_parts(row)
    return jnp.where(kind == KIND_RUN, jnp.sum(valid.astype(jnp.int32)), 0)


def row_to_bits(row: jax.Array, card: jax.Array, kind: jax.Array) -> jax.Array:
    """Uniform bitmap-domain view of a container row (empty -> zeros).

    Kind-dispatching lift: arrays scatter their packed values, runs scatter
    their coverage (both O(4096)), bitmaps pass through.
    """
    as_bits = row_array_to_bits(row, card)
    lifted = jnp.where(kind == KIND_RUN, row_run_to_bits(row), as_bits)
    return jnp.where(kind == KIND_BITMAP, row, lifted) * (kind != KIND_EMPTY).astype(jnp.uint16)


def row_popcount(bits: jax.Array) -> jax.Array:
    """Container cardinality via popcnt (paper Alg. 1 line 7)."""
    return jnp.sum(lax_popcount(bits).astype(jnp.int32))


def lax_popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)


def row_bits_to_array(bits: jax.Array) -> jax.Array:
    """Vectorized Algorithm 2: bitmap row -> packed sorted u16 array row.

    Per-word popcounts -> exclusive cumsum gives each word's write offset;
    bit positions are scattered to offset + rank-within-word. O(2^16) fully
    data-parallel (the TPU replacement for the serial ``w & -w`` loop).
    """
    # bits: u16[4096] -> per-bit boolean [4096, 16]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, None] >> shifts[None, :]) & jnp.uint16(1)).astype(jnp.int32)
    flat = bitmat.reshape(-1)                               # [65536] in value order
    pos = jnp.arange(CHUNK_SIZE, dtype=jnp.int32)
    rank = jnp.cumsum(flat) - flat                          # exclusive cumsum
    idx = jnp.where(flat == 1, rank, CHUNK_SIZE)            # OOB dropped
    out = jnp.zeros((ROW_WORDS,), jnp.uint16).at[idx].add(
        pos.astype(jnp.uint16), mode="drop")
    return out


def _row_canonicalize_2kind(bits: jax.Array):
    """PR 1's array/bitmap-only canonicalization — retained verbatim for the
    ``slab_*_bitmap_domain`` A/B baseline (the pre-run architecture)."""
    card = row_popcount(bits)
    as_array = row_bits_to_array(bits)
    as_array = jnp.where(jnp.arange(ROW_WORDS) < card, as_array,
                         jnp.uint16(0xFFFF))
    is_bitmap = card > ARRAY_MAX
    data = jnp.where(is_bitmap, bits, as_array)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


def _row_edges(bits: jax.Array):
    """(rising, falling') edge bitmaps of a bitmap row: rising marks run
    starts (set bit, previous clear), falling' the position *after* each run
    end (clear bit, previous set). Word-carry chained, O(4096)."""
    prev = jnp.concatenate([jnp.zeros((1,), jnp.uint16), bits[:-1]])
    shifted = (bits << 1) | (prev >> 15)
    rising = bits & ~shifted
    falling = ~bits & shifted
    return rising, falling


def row_nruns_bits(bits: jax.Array) -> jax.Array:
    """# maximal runs of a bitmap row = popcount of its rising edges."""
    rising, _ = _row_edges(bits)
    return row_popcount(rising)


def _row_runs_from_bits(bits: jax.Array) -> jax.Array:
    """Bitmap row -> packed run-pair row.

    One Algorithm-2 extraction over ``rising | falling'`` yields the sorted
    interleaved sequence ``s0, e0+1, s1, e1+1, ...`` directly (the two edge
    sets are disjoint); a run ending at 65535 has no falling' bit, so its
    implicit end is 2^16. O(2^16) — callers guard with ``lax.cond``.
    """
    rising, falling = _row_edges(bits)
    edges = rising | falling
    pos = row_bits_to_array(edges)
    n_edges = row_popcount(edges)
    nr = row_popcount(rising)
    k = jnp.arange(MAX_RUNS, dtype=jnp.int32)
    s = jnp.take(pos, 2 * k).astype(jnp.int32)
    e1 = jnp.where(2 * k + 1 < n_edges,
                   jnp.take(pos, jnp.minimum(2 * k + 1, ROW_WORDS - 1)).astype(jnp.int32),
                   CHUNK_SIZE)
    lm1 = e1 - 1 - s
    live = k < nr
    return jnp.stack(
        [jnp.where(live, s, 0xFFFF), jnp.where(live, lm1, 0xFFFF)],
        axis=1).reshape(ROW_WORDS).astype(jnp.uint16)


def row_canonicalize(bits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """bitmap-domain row -> canonical (data, card, kind), best-of-three.

    The 2016 paper's ``runOptimize`` rule, applied per row: pick the kind
    whose serialized size is smallest — 2*card (array, card <= 4096), 8192
    (bitmap), 4*n_runs (run; strictly smaller only). Array rows are padded
    with 0xFFFF past ``card`` so the packed prefix plus padding stays
    globally sorted (binary-search friendly); run rows pad with the
    impossible pair (0xFFFF, 0xFFFF).
    """
    card = row_popcount(bits)
    nr = row_nruns_bits(bits)
    kind = _pick_kind(card, nr)
    as_array = row_bits_to_array(bits)
    as_array = jnp.where(jnp.arange(ROW_WORDS) < card, as_array,
                         jnp.uint16(0xFFFF))
    data = jnp.where(kind == KIND_BITMAP, bits,
                     jnp.where(kind == KIND_RUN, _row_runs_from_bits(bits),
                               as_array))
    return data, card, kind


def _pick_kind(card: jax.Array, nruns: jax.Array) -> jax.Array:
    """Strict best-of-three serialized-size rule (must match the oracle's
    ``py_roaring._canonical`` bit-for-bit): run iff 4*n_runs is strictly
    smaller than every alternative; array preferred at the 4096 tie."""
    other = jnp.where(card <= ARRAY_MAX,
                      jnp.minimum(2 * card, 2 * ARRAY_MAX), 2 * ARRAY_MAX)
    run_best = (4 * nruns < other) & (card > 0)
    return jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(run_best, KIND_RUN,
                               jnp.where(card <= ARRAY_MAX, KIND_ARRAY,
                                         KIND_BITMAP)))


# =============================================================================
# construction / export
# =============================================================================

def from_indices(idx: jax.Array, valid: jax.Array, capacity: int) -> RoaringSlab:
    """Build a slab from (padded) *sorted unique* int32/int64 indices.

    ``idx``: i64/i32[M] sorted ascending with invalid entries at the end
    (``valid`` false). Elements sharing high 16 bits land in one container.
    Works with or without x64 (int32 universes cover every in-framework use:
    per-leaf gradient coordinates, block ids, page ids).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    idx = idx.astype(idt)
    M = idx.shape[0]
    sentinel = jnp.asarray(int(KEY_SENTINEL), idt)
    hi = jnp.where(valid, idx >> CHUNK_BITS, sentinel)
    lo = (idx & (CHUNK_SIZE - 1)).astype(jnp.int32)

    first = jnp.concatenate([jnp.array([True]), hi[1:] != hi[:-1]]) & valid
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1           # container id per elem
    seg = jnp.where(valid, seg, capacity)                   # drop invalid
    counts = jnp.zeros((capacity,), jnp.int32).at[seg].add(1, mode="drop")

    # container keys: first element of each segment
    keys = jnp.full((capacity,), sentinel, dtype=idt)
    keys = keys.at[jnp.where(first, seg, capacity)].min(
        jnp.where(first, hi, sentinel), mode="drop")
    keys = jnp.where(counts > 0, keys, sentinel).astype(jnp.int32)

    # array representation: rank within segment
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    rank = jnp.arange(M, dtype=jnp.int32) - seg_start[jnp.minimum(seg, capacity - 1)]
    arr_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    arr_data = arr_data.at[seg, jnp.where(valid, rank, ROW_WORDS)].add(
        lo.astype(jnp.uint16), mode="drop")

    # bitmap representation (scatter-add of distinct power-of-two bits)
    bit_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    bit_data = bit_data.at[seg, jnp.where(valid, lo >> 4, ROW_WORDS)].add(
        jnp.where(valid, jnp.uint16(1) << (lo & 15).astype(jnp.uint16),
                  jnp.uint16(0)), mode="drop")

    is_bitmap = counts > ARRAY_MAX
    # pad array rows with 0xFFFF past card so binary search stays valid
    arr_data = jnp.where(jnp.arange(ROW_WORDS)[None, :] < counts[:, None],
                         arr_data, jnp.uint16(0xFFFF))
    data = jnp.where(is_bitmap[:, None], bit_data, arr_data)
    kind = jnp.where(counts == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return RoaringSlab(keys=keys, card=counts, kind=kind, data=data)


def from_dense_array(values: np.ndarray, capacity: int, max_elems: int) -> RoaringSlab:
    """Host-side convenience: numpy values -> slab (pads to max_elems)."""
    v = np.unique(np.asarray(values, dtype=np.int64))
    assert v.size <= max_elems, (v.size, max_elems)
    idx = np.full((max_elems,), 0, dtype=np.int64)
    idx[: v.size] = v
    valid = np.zeros((max_elems,), dtype=bool)
    valid[: v.size] = True
    # keep padded tail sorted-after-valid by setting it to the max value
    if v.size:
        idx[v.size:] = v[-1]
    return from_indices(jnp.asarray(idx), jnp.asarray(valid), capacity)


def from_roaring(rb, capacity: int) -> RoaringSlab:
    """Host-side bridge: a ``py_roaring.RoaringBitmap`` -> RoaringSlab with
    the container kinds preserved exactly — run containers land as run rows
    with no per-element or bitmap materialization (the run-shaped consumers'
    constructor: KV free/used pools, window/causal/doc mask rows)."""
    from repro.core import py_roaring as pr

    assert len(rb.keys) <= capacity, (len(rb.keys), capacity)
    keys = np.full((capacity,), int(KEY_SENTINEL), np.int32)
    card = np.zeros((capacity,), np.int32)
    kind = np.zeros((capacity,), np.int32)
    data = np.zeros((capacity, ROW_WORDS), np.uint16)
    for i, (k, c) in enumerate(zip(rb.keys, rb.containers)):
        keys[i] = k
        card[i] = c.cardinality
        if isinstance(c, pr.RunContainer):
            kind[i] = KIND_RUN
            row = np.full((ROW_WORDS,), 0xFFFF, np.uint16)
            row[0:2 * c.n_runs:2] = c.starts.astype(np.uint16)
            row[1:2 * c.n_runs:2] = c.lengths.astype(np.uint16)
            data[i] = row
        elif isinstance(c, pr.BitmapContainer):
            kind[i] = KIND_BITMAP
            data[i] = c.words.view(np.uint16)        # little-endian u64 -> u16
        else:
            kind[i] = KIND_ARRAY
            row = np.full((ROW_WORDS,), 0xFFFF, np.uint16)
            row[: c.arr.size] = c.arr
            data[i] = row
    return RoaringSlab(keys=jnp.asarray(keys), card=jnp.asarray(card),
                       kind=jnp.asarray(kind), data=jnp.asarray(data))


def from_ranges(ranges, capacity: int) -> RoaringSlab:
    """Host-side run-row constructor from half-open ``[start, end)`` integer
    ranges — builds run containers directly (no element materialization)."""
    from repro.core import py_roaring as pr

    return from_roaring(pr.RoaringBitmap.from_ranges(ranges), capacity)


def to_roaring(slab: RoaringSlab):
    """Host-side reverse bridge: RoaringSlab -> ``py_roaring.RoaringBitmap``,
    kind-preserving (the exact inverse of ``from_roaring``).

    Array rows become ``ArrayContainer`` (the packed ``card`` prefix), bitmap
    rows become ``BitmapContainer`` (u16 words reassembled to little-endian
    u64), run rows become ``RunContainer`` (the valid ``(start, len-1)``
    pairs). A canonical slab — any set-algebra or engine output — therefore
    round-trips bit-identically: same keys, same container kinds, same
    payloads.
    """
    from repro.core import py_roaring as pr

    keys = np.asarray(slab.keys)
    card = np.asarray(slab.card)
    kind = np.asarray(slab.kind)
    data = np.asarray(slab.data)
    rb = pr.RoaringBitmap()
    for i in range(keys.shape[0]):
        if kind[i] == KIND_EMPTY:
            continue
        if kind[i] == KIND_ARRAY:
            c = pr.ArrayContainer(data[i, : card[i]])
        elif kind[i] == KIND_BITMAP:
            c = pr.BitmapContainer(np.ascontiguousarray(data[i]).view(
                np.uint64), cardinality=int(card[i]))
        else:
            p = data[i].reshape(MAX_RUNS, 2).astype(np.int64)
            valid = (p[:, 0] + p[:, 1]) < CHUNK_SIZE
            c = pr.RunContainer(p[valid, 0], p[valid, 1])
        rb.keys.append(int(keys[i]))
        rb.containers.append(c)
    return rb


def slab_run_optimize(slab: RoaringSlab) -> RoaringSlab:
    """Device-side ``runOptimize``: re-canonicalize every row best-of-three
    through the engine (array rows runify via the O(4096) adjacency scatter,
    bitmap rows via the cond-guarded edge extraction)."""
    return _finalize_rows(slab.keys, slab.data, slab.card, slab.kind)


def to_indices(slab: RoaringSlab, max_out: int) -> tuple[jax.Array, jax.Array]:
    """Slab -> (sorted values int[max_out], valid bool[max_out]).

    Uniform path: every row is viewed in bitmap domain, all C*2^16 candidate
    bits are compacted by exclusive cumsum (global Algorithm 2).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    bits = jax.vmap(row_to_bits)(slab.data, slab.card, slab.kind)   # u16[C,4096]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, :, None] >> shifts[None, None, :]) & jnp.uint16(1))
    flat = bitmat.reshape(-1).astype(jnp.int32)             # [C*65536]
    # sentinel keys may wrap when shifted in int32 — harmless: their rows have
    # flat == 0 everywhere, so the wrapped values are multiplied away.
    base = (slab.keys.astype(idt) << CHUNK_BITS)
    vals = (base[:, None] + jnp.arange(CHUNK_SIZE, dtype=idt)[None, :]).reshape(-1)
    rank = jnp.cumsum(flat) - flat
    tgt = jnp.where(flat == 1, rank, max_out)
    out = jnp.zeros((max_out,), idt).at[tgt].add(vals * flat, mode="drop")
    total = jnp.sum(flat)
    valid = jnp.arange(max_out) < total
    return jnp.where(valid, out, 0), valid


def extract_row(slab: RoaringSlab, r, max_out: int = ARRAY_MAX):
    """Packed sorted values of container ``r`` (Alg. 2 on one row)."""
    bits = row_to_bits(slab.data[r], slab.card[r], slab.kind[r])
    arr = row_bits_to_array(bits)
    valid = jnp.arange(ROW_WORDS) < slab.card[r]
    return arr[:max_out], valid[:max_out]


# =============================================================================
# membership / rank
# =============================================================================

def contains(slab: RoaringSlab, queries: jax.Array) -> jax.Array:
    """Batched membership test (paper S3): first-level binary search, then
    array binary search or bitmap bit probe, selected by container kind.

    Bandwidth-lean: the bitmap path gathers only the one probed 16-bit word
    and the array path gathers one element per halving step (13 for a
    4096-wide window), instead of pulling the full 8 kB row per query into
    the vmap.
    """
    q = queries.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (q >> CHUNK_BITS).astype(jnp.int32)
    lo = (q & (CHUNK_SIZE - 1)).astype(jnp.int32)
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    key_hit = slab.keys[row_c] == hi

    def one(row_i, lo_i):
        card = slab.card[row_i]
        kind = slab.kind[row_i]
        # bitmap path: probe a single word
        word = slab.data[row_i, lo_i >> 4].astype(jnp.int32)
        bit_hit = ((word >> (lo_i & 15)) & 1) == 1
        # array path: binary search over the packed prefix, one gathered
        # element per step (log-bounded traffic; 0xFFFF padding keeps the
        # row globally sorted so the [0, card) window is safe). 13 steps:
        # lower_bound must shrink a window of up to 4096 to size 0, which
        # takes ceil(log2(4096)) + 1 halvings.
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            v = slab.data[row_i, jnp.clip(mid, 0, ROW_WORDS - 1)].astype(
                jnp.int32)
            go_right = v < lo_i
            return (jnp.where(go_right, mid + 1, l),
                    jnp.where(go_right, h, mid))

        l, _ = jax.lax.fori_loop(0, 13, body, (jnp.int32(0), card))
        probe = slab.data[row_i, jnp.clip(l, 0, ROW_WORDS - 1)].astype(
            jnp.int32)
        arr_hit = (l < card) & (probe == lo_i)
        # run path: binary search the <=2048 pair slots, two gathered u16s
        # per step. The comparator maps the (0xFFFF, 0xFFFF) padding past
        # the probe, so no run count is needed. 12 steps cover 2048 runs.
        # Deliberately not dispatch._run_covered — that searches a row tile
        # already resident (a full 8 kB gather here); this gathers only two
        # probed u16s per step, keeping membership log-bounded traffic.
        # Keep the window-guard/padding semantics in sync with
        # dispatch._run_upper_bound.
        def rbody(_, lh):
            l, h = lh
            open_ = l < h
            mid = (l + h) // 2
            mid_c = jnp.clip(2 * mid, 0, ROW_WORDS - 2)
            s = slab.data[row_i, mid_c].astype(jnp.int32)
            ln = slab.data[row_i, mid_c + 1].astype(jnp.int32)
            key = jnp.where(s + ln < CHUNK_SIZE, s, CHUNK_SIZE)
            go_right = open_ & (key <= lo_i)
            return (jnp.where(go_right, mid + 1, l),
                    jnp.where(open_ & ~go_right, mid, h))

        rl, _ = jax.lax.fori_loop(0, 12, rbody,
                                  (jnp.int32(0), jnp.int32(MAX_RUNS)))
        ri = jnp.clip(rl - 1, 0, MAX_RUNS - 1)
        rs = slab.data[row_i, 2 * ri].astype(jnp.int32)
        rln = slab.data[row_i, 2 * ri + 1].astype(jnp.int32)
        run_hit = (rl > 0) & (rs + rln < CHUNK_SIZE) & (lo_i <= rs + rln)
        return jnp.where(kind == KIND_BITMAP, bit_hit,
                         jnp.where(kind == KIND_ARRAY, arr_hit,
                                   jnp.where(kind == KIND_RUN, run_hit,
                                             False)))

    hits = jax.vmap(one)(row_c, lo)
    return hits & key_hit


def rank(slab: RoaringSlab, x: jax.Array) -> jax.Array:
    """# elements <= x: whole-container counters + one partial container."""
    x = x.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (x >> CHUNK_BITS).astype(jnp.int32)
    lo = (x & (CHUNK_SIZE - 1)).astype(jnp.int32)
    full = jnp.sum(jnp.where(slab.keys < hi, slab.card, 0))
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    hit = slab.keys[row_c] == hi
    bits = row_to_bits(slab.data[row_c], slab.card[row_c], slab.kind[row_c])
    word_idx = lo >> 4
    mask_full = (jnp.arange(ROW_WORDS) < word_idx)
    partial_words = jnp.sum(lax_popcount(jnp.where(mask_full, bits, 0)).astype(jnp.int32))
    last = bits[word_idx] & ((jnp.uint16(2) << (lo & 15).astype(jnp.uint16)) - 1).astype(jnp.uint16)
    in_row = partial_words + lax_popcount(last).astype(jnp.int32)
    return full + jnp.where(hit, in_row, 0)


def slab_select(slab: RoaringSlab, j: jax.Array) -> jax.Array:
    """Value of the j-th (0-based) smallest element — the slab counterpart
    of the oracle's ``select`` (paper S2 access operation, rank's inverse).

    First-level: binary search the per-container cardinality prefix sums;
    within the container, dispatch by kind — direct gather for arrays, a
    run-length prefix-sum search for run rows (log-bounded traffic, like
    ``contains``), and a one-row bit-rank cumsum for bitmaps. Returns -1 for
    out-of-range ``j``.
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    j = jnp.asarray(j, jnp.int32)
    csum = jnp.cumsum(slab.card)
    total = csum[-1] if slab.capacity else jnp.int32(0)
    row = jnp.searchsorted(csum, j, side="right")
    row_c = jnp.minimum(row, slab.capacity - 1)
    j_in = j - jnp.where(row_c > 0, csum[row_c - 1], 0)
    kind = slab.kind[row_c]
    drow = slab.data[row_c]

    # array: direct gather
    arr_val = drow[jnp.clip(j_in, 0, ROW_WORDS - 1)].astype(jnp.int32)
    # run: search the run-length prefix sums
    s, l, valid = _row_run_parts(drow)
    lens = jnp.where(valid, l + 1, 0)
    lcum = jnp.cumsum(lens)
    r = jnp.searchsorted(lcum, j_in, side="right")
    r_c = jnp.minimum(r, MAX_RUNS - 1)
    run_val = s[r_c] + j_in - (lcum[r_c] - lens[r_c])
    # bitmap: j_in-th set bit via bit-rank cumsum — the one O(2^16) pass,
    # cond-guarded so run/array selects keep their log bound
    def bit_rank(args):
        bits, j_in = args
        shifts = jnp.arange(16, dtype=jnp.uint16)
        flat = ((bits[:, None] >> shifts[None, :]) & jnp.uint16(1)).astype(
            jnp.int32).reshape(-1)
        return jnp.searchsorted(jnp.cumsum(flat), j_in + 1,
                                side="left").astype(jnp.int32)

    bit_pos = jax.lax.cond(kind == KIND_BITMAP, bit_rank,
                           lambda args: jnp.int32(0), (drow, j_in))
    lo_val = jnp.where(kind == KIND_ARRAY, arr_val,
                       jnp.where(kind == KIND_RUN, run_val,
                                 bit_pos.astype(jnp.int32)))
    val = (slab.keys[row_c].astype(idt) << CHUNK_BITS) + lo_val.astype(idt)
    ok = (j >= 0) & (j < total)
    return jnp.where(ok, val, -1)


# =============================================================================
# set algebra: hybrid per-kind dispatch (paper S4)
#
# Key-aligned container pairs are classified by (kind_a, kind_b) and routed
# through the matching algorithm via repro.kernels.roaring (Pallas @pl.when
# on TPU, XLA reference elsewhere). Canonicalization is lazy: only
# bitmap-domain output rows that land back under the 4096 threshold pay the
# O(2^16) extraction, and the pass is lax.cond-guarded so it is skipped at
# runtime when no row needs it. The pre-dispatch bitmap-domain formulation is
# kept below as slab_*_bitmap_domain for A/B benchmarking and cross-checks.
# =============================================================================

def _pad_keys(keys: jax.Array, capacity: int) -> jax.Array:
    n = keys.shape[0]
    if capacity <= n:
        return keys[:capacity]
    return jnp.concatenate(
        [keys, jnp.full((capacity - n,), KEY_SENTINEL, jnp.int32)])


def _merge_keys_many(key_cols: list[jax.Array], capacity: int) -> jax.Array:
    """Union of N sorted key columns, deduplicated (duplicates demoted to
    ``KEY_SENTINEL`` and re-sorted), padded/truncated to ``capacity`` — the
    single key-alignment idiom shared by the pairwise ops, the tree union,
    and ``index.stack_from_slabs``."""
    srt = jnp.sort(jnp.concatenate(key_cols))
    dup = jnp.concatenate([jnp.array([False]), srt[1:] == srt[:-1]])
    return _pad_keys(jnp.sort(jnp.where(dup, KEY_SENTINEL, srt)), capacity)


def _merge_keys(a: RoaringSlab, b: RoaringSlab, capacity: int) -> jax.Array:
    """Union of the two sorted key sets, deduplicated, padded with sentinel."""
    return _merge_keys_many([a.keys, b.keys], capacity)


def _intersect_keys(a: RoaringSlab, b: RoaringSlab, capacity: int) -> jax.Array:
    """Keys present in *both* slabs (the only rows an AND can populate), so
    the dispatch grid is |A.keys ∩ B.keys| rows instead of the union."""
    pos = jnp.searchsorted(b.keys, a.keys)
    pos_c = jnp.minimum(pos, b.capacity - 1)
    hit = (b.keys[pos_c] == a.keys) & (a.keys != KEY_SENTINEL)
    vals = jnp.sort(jnp.where(hit, a.keys, KEY_SENTINEL))
    return _pad_keys(vals, capacity)


def _gather_raw(s: RoaringSlab, keys: jax.Array):
    """Raw rows of ``s`` aligned to ``keys`` — native container form, no
    bitmap-domain lift. Absent keys get (card=0, kind=EMPTY)."""
    pos = jnp.searchsorted(s.keys, keys)
    pos_c = jnp.minimum(pos, s.capacity - 1)
    present = (s.keys[pos_c] == keys) & (keys != KEY_SENTINEL)
    data = s.data[pos_c]
    card = jnp.where(present, s.card[pos_c], 0)
    kind = jnp.where(present, s.kind[pos_c], KIND_EMPTY)
    return data, card, kind


def _compact_row(vals: jax.Array, hit: jax.Array) -> jax.Array:
    """Scatter the hit subset of a packed row into a fresh packed sorted row
    (0xFFFF padded). O(4096), never touches the 2^16-element domain."""
    h = hit.astype(jnp.int32)
    rank = jnp.cumsum(h) - h
    idx = jnp.where(hit, rank, ROW_WORDS)
    return jnp.full((ROW_WORDS,), 0xFFFF, jnp.uint16).at[idx].set(
        vals, mode="drop")


def _rows_bits_to_array_lazy(bits: jax.Array, need: jax.Array,
                             card: jax.Array) -> jax.Array:
    """Lazy Algorithm 2 over rows: the O(2^16) extraction runs only when at
    least one row actually crosses back under the 4096 threshold; otherwise
    lax.cond skips the whole pass at runtime."""
    masked = jnp.where(need[:, None], bits, jnp.uint16(0))
    arrs = jax.lax.cond(
        jnp.any(need),
        lambda m: jax.vmap(row_bits_to_array)(m),
        lambda m: jnp.zeros_like(m),
        masked)
    return jnp.where(jnp.arange(ROW_WORDS)[None, :] < card[:, None],
                     arrs, jnp.uint16(0xFFFF))


def _rows_nruns(data: jax.Array, kind: jax.Array) -> jax.Array:
    """Batched ``row_nruns``: per-row run counts (0 for non-run rows)."""
    p = data.reshape(data.shape[0], MAX_RUNS, 2).astype(jnp.int32)
    valid = (p[..., 0] + p[..., 1]) < CHUNK_SIZE
    return jnp.where(kind == KIND_RUN, jnp.sum(valid.astype(jnp.int32), -1), 0)


def _runs_from_array_rows(vals: jax.Array, card: jax.Array):
    """Packed sorted array rows -> packed run-pair rows + run counts.

    Adjacency-difference run detection + two O(4096) scatters per row —
    never the 2^16 domain.
    """
    C = vals.shape[0]
    v = vals.astype(jnp.int32)
    slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)[None, :]
    valid = slot < card[:, None]
    prev = jnp.concatenate([jnp.full((C, 1), -2, jnp.int32), v[:, :-1]], 1)
    nxt = jnp.concatenate([v[:, 1:], jnp.full((C, 1), -2, jnp.int32)], 1)
    isstart = valid & (v != prev + 1)
    isend = valid & ((slot + 1 >= card[:, None]) | (nxt != v + 1))
    rid = jnp.cumsum(isstart.astype(jnp.int32), axis=1) - 1
    rows = jnp.arange(C)[:, None]
    starts = jnp.zeros((C, MAX_RUNS), jnp.int32).at[
        rows, jnp.where(isstart, rid, MAX_RUNS)].add(v, mode="drop")
    pairs = jnp.full((C, ROW_WORDS), 0xFFFF, jnp.uint16)
    pairs = pairs.at[rows, jnp.where(isstart, 2 * rid, ROW_WORDS)].set(
        v.astype(jnp.uint16), mode="drop")
    lm1 = v - jnp.take_along_axis(starts, jnp.clip(rid, 0, MAX_RUNS - 1),
                                  axis=1)
    pairs = pairs.at[rows, jnp.where(isend, 2 * rid + 1, ROW_WORDS)].set(
        lm1.astype(jnp.uint16), mode="drop")
    return pairs, jnp.sum(isstart.astype(jnp.int32), axis=1)


def _arrays_from_runs_rows(pairs: jax.Array, card: jax.Array) -> jax.Array:
    """Packed run-pair rows -> packed sorted array rows (gather-only:
    per-slot binary search of the run-length prefix sums)."""
    C = pairs.shape[0]
    p = pairs.reshape(C, MAX_RUNS, 2).astype(jnp.int32)
    s, l = p[..., 0], p[..., 1]
    valid = (s + l) < CHUNK_SIZE
    lens = jnp.where(valid, l + 1, 0)
    cum = jnp.cumsum(lens, axis=1)
    k = jnp.arange(ROW_WORDS, dtype=jnp.int32)

    def one(cum_r, s_r, lens_r, card_r):
        r = jnp.searchsorted(cum_r, k, side="right")
        r_c = jnp.minimum(r, MAX_RUNS - 1)
        base = cum_r[r_c] - lens_r[r_c]
        val = s_r[r_c] + k - base
        return jnp.where(k < card_r, val, 0xFFFF).astype(jnp.uint16)

    return jax.vmap(one)(cum, s, lens, card)


def _runs_from_bits_rows_lazy(bits: jax.Array, need: jax.Array) -> jax.Array:
    """Lazy batched run extraction from bitmap rows: the O(2^16) edge pass
    runs only when some row's canonical kind is actually run."""
    masked = jnp.where(need[:, None], bits, jnp.uint16(0))
    return jax.lax.cond(
        jnp.any(need),
        lambda m: jax.vmap(_row_runs_from_bits)(m),
        lambda m: jnp.full_like(m, 0xFFFF),
        masked)


def _run_merge_row(da: jax.Array, db: jax.Array):
    """run x run intersection *in run domain* (the run-merge row kernel).

    Every output run closes at an input run end covered by the other side,
    so the <= na+nb output runs are enumerated by two lane-parallel searches
    (one per input end), deduped by a strict tie-break, and compacted with a
    single argsort — O(4096 log 4096), never the 2^16 domain. Returns
    (pairs_row, card, n_out); if ``n_out`` exceeds the 2048-pair row
    capacity (pathological alternating micro-runs) the caller falls back to
    the coverage-bits form.
    """
    sa, la, va = _row_run_parts(da)
    ea = sa + la
    sb, lb, vb = _row_run_parts(db)
    eb = sb + lb
    BIG = jnp.int32(1 << 17)
    sa_p = jnp.where(va, sa, BIG)
    sb_p = jnp.where(vb, sb, BIG)

    # candidates closing at a-ends: the b-run containing ea (ties included)
    j = jnp.searchsorted(sb_p, ea, side="right") - 1
    jc = jnp.clip(j, 0, MAX_RUNS - 1)
    av = va & (j >= 0) & (eb[jc] >= ea)
    a_start = jnp.maximum(sa, sb[jc])
    # candidates closing strictly inside a-runs at b-ends (tie-deduped)
    i = jnp.searchsorted(sa_p, eb, side="right") - 1
    ic = jnp.clip(i, 0, MAX_RUNS - 1)
    bv = vb & (i >= 0) & (ea[ic] > eb)
    b_start = jnp.maximum(sb, sa[ic])

    starts = jnp.concatenate([jnp.where(av, a_start, BIG),
                              jnp.where(bv, b_start, BIG)])
    ends = jnp.concatenate([jnp.where(av, ea, 0), jnp.where(bv, eb, 0)])
    card = (jnp.sum(jnp.where(av, ea - a_start + 1, 0))
            + jnp.sum(jnp.where(bv, eb - b_start + 1, 0)))
    n_out = jnp.sum(av.astype(jnp.int32)) + jnp.sum(bv.astype(jnp.int32))
    order = jnp.argsort(starts)
    ss = starts[order][:MAX_RUNS]
    ee = ends[order][:MAX_RUNS]
    live = jnp.arange(MAX_RUNS) < n_out
    pairs = jnp.stack([jnp.where(live, ss, 0xFFFF),
                       jnp.where(live, ee - ss, 0xFFFF)],
                      axis=1).reshape(ROW_WORDS).astype(jnp.uint16)
    return pairs, card, n_out


def _run_merge_rows_lazy(da, db, rr):
    """Cond-guarded batched run-merge over the rows classified run x run.
    Returns (pairs, card, n_out, bits_fallback) — the coverage-bits fallback
    is itself guarded and only materializes for overflowing rows."""
    C = da.shape[0]

    def merge(args):
        da, db = args
        m = rr[:, None]
        pairs, card, n_out = jax.vmap(_run_merge_row)(
            jnp.where(m, da, jnp.uint16(0xFFFF)),
            jnp.where(m, db, jnp.uint16(0xFFFF)))
        overflow = rr & (n_out > MAX_RUNS)

        def cov(args):
            da, db = args
            o = overflow[:, None]
            return jax.vmap(lambda x, y: row_run_to_bits(x) & row_run_to_bits(y))(
                jnp.where(o, da, jnp.uint16(0xFFFF)),
                jnp.where(o, db, jnp.uint16(0xFFFF)))

        bits = jax.lax.cond(jnp.any(overflow), cov,
                            lambda args: jnp.zeros((C, ROW_WORDS), jnp.uint16),
                            (da, db))
        return pairs, card, n_out, bits

    def skip(args):
        return (jnp.full((C, ROW_WORDS), 0xFFFF, jnp.uint16),
                jnp.zeros((C,), jnp.int32), jnp.zeros((C,), jnp.int32),
                jnp.zeros((C, ROW_WORDS), jnp.uint16))

    return jax.lax.cond(jnp.any(rr), merge, skip, (da, db))


def _finalize(keys, card, form, arr_rows, bits_rows, runs_rows, runs_nr):
    """The engine's canonicalization + assembly stage.

    Each computed row arrives in one of three *forms* (packed array /
    bitmap-domain words / packed run pairs); best-of-three picks the
    canonical kind per row and the required conversions run vectorized —
    cheap O(4096) passes unguarded, the two O(2^16) extractions
    (bits -> array, bits -> runs) ``lax.cond``-guarded. Dead rows are keyed
    out and rows re-sorted so live keys lead.
    """
    is_af = form == FORM_ARRAY
    is_bf = form == FORM_BITS
    is_rf = form == FORM_RUNS
    live_af = is_af & (card > 0)
    pairs_from_arr, nr_arr = jax.lax.cond(
        jnp.any(live_af),
        lambda a: _runs_from_array_rows(a, jnp.where(live_af, card, 0)),
        lambda a: (jnp.full_like(a, 0xFFFF), jnp.zeros_like(card)),
        arr_rows)
    bits_m = jnp.where(is_bf[:, None], bits_rows, jnp.uint16(0))
    nr_bits = jax.lax.cond(
        jnp.any(is_bf),
        lambda b: jax.vmap(row_nruns_bits)(b),
        lambda b: jnp.zeros_like(card), bits_m)
    nr = jnp.where(is_af, nr_arr, jnp.where(is_bf, nr_bits, runs_nr))
    kind = _pick_kind(card, nr)

    need_arr_bits = is_bf & (kind == KIND_ARRAY)
    arr_from_bits = _rows_bits_to_array_lazy(bits_rows, need_arr_bits, card)
    need_run_bits = is_bf & (kind == KIND_RUN)
    runs_from_bits = _runs_from_bits_rows_lazy(bits_rows, need_run_bits)
    need_arr_runs = is_rf & (kind == KIND_ARRAY)
    arr_from_runs = jax.lax.cond(
        jnp.any(need_arr_runs),
        lambda r: _arrays_from_runs_rows(r, jnp.where(need_arr_runs, card, 0)),
        lambda r: jnp.full_like(r, 0xFFFF), runs_rows)
    # a run-form row canonicalizes to bitmap only at the 4*nr == 8192 tie
    # (nr == 2048 with card > 4096), but the coverage lift must exist or the
    # bitmap branch below would read the caller's placeholder bits
    need_bits_runs = is_rf & (kind == KIND_BITMAP)
    bits_from_runs = jax.lax.cond(
        jnp.any(need_bits_runs),
        lambda r: jax.vmap(row_run_to_bits)(
            jnp.where(need_bits_runs[:, None], r, jnp.uint16(0xFFFF))),
        lambda r: jnp.zeros_like(r), runs_rows)

    arr_final = jnp.where(is_bf[:, None], arr_from_bits,
                          jnp.where(is_rf[:, None], arr_from_runs, arr_rows))
    run_final = jnp.where(is_af[:, None], pairs_from_arr,
                          jnp.where(is_bf[:, None], runs_from_bits, runs_rows))
    bits_final = jnp.where(is_rf[:, None], bits_from_runs, bits_rows)
    data = jnp.where((kind == KIND_BITMAP)[:, None], bits_final,
                     jnp.where((kind == KIND_RUN)[:, None], run_final,
                               arr_final))
    live = kind != KIND_EMPTY
    out_keys = jnp.where(live, keys, KEY_SENTINEL)
    order = jnp.argsort(out_keys)
    return RoaringSlab(keys=out_keys[order],
                       card=jnp.where(live, card, 0)[order],
                       kind=kind[order], data=data[order])


def _dispatch_meta(ka, kb, ca, cb, ra=None, rb=None) -> jax.Array:
    """Interleave (kind_a, kind_b, card_a, card_b, nruns_a, nruns_b) per row
    -> i32[6C] (the registry's scalar-prefetch contract)."""
    if ra is None:
        ra = jnp.zeros_like(ka)
    if rb is None:
        rb = jnp.zeros_like(kb)
    return jnp.stack([ka, kb, ca, cb, ra, rb], axis=1).reshape(-1).astype(
        jnp.int32)


# =============================================================================
# row-state algebra: deferred-canonicalization combines shared by the pairwise
# slab ops, the log-depth tree reduction, and the repro.index query engine.
#
# A *row state* is the triple (data u16[M, 4096], card i32[M], kind i32[M]) of
# key-aligned container rows — a RoaringSlab minus its keys, and minus the
# canonical-kind guarantee: intermediate states defer best-of-three
# (runOptimize) until a single `_finalize_rows` at the root of a combine tree,
# so an N-way reduction pays one canonicalization pass, not N-1.
# =============================================================================

def _finalize_rows(keys, data, card, kind) -> RoaringSlab:
    """Row state -> canonical RoaringSlab (single deferred best-of-three).

    Maps each row's kind tag to the engine form it is stored in (array rows
    are packed prefixes, bitmap rows word rows, run rows packed pairs) and
    runs ``_finalize``: cheap O(4096) conversions unguarded, the O(2^16)
    bits->array / bits->runs extractions ``lax.cond``-guarded, dead rows
    keyed out and re-sorted.
    """
    form = jnp.where(kind == KIND_BITMAP, FORM_BITS,
                     jnp.where(kind == KIND_RUN, FORM_RUNS, FORM_ARRAY))
    nr = _rows_nruns(data, kind)
    return _finalize(keys, card, form, data, data, data, nr)


def _or_rows(da, ca, ka, db, cb, kb, *, word_op=jnp.bitwise_or,
             xor: bool = False, defer_card: bool = False):
    """One OR/XOR combine step over key-aligned row pairs -> row state.

    Routed by the registry's union policy (``dispatch.union_route``): array
    pairs whose merged size provably stays under the 4096 threshold merge in
    array domain (sorted merge, O(8192 log)); every other live pair goes
    through the bitmap domain with the kind-dispatching lift — array rows
    scatter, run rows range-mask coverage, both O(4096) — and a fused
    popcount. Both passes are ``lax.cond``-guarded. Output kinds are the
    *deferred* {EMPTY, ARRAY, BITMAP}; no canonicalization happens here.

    ``defer_card=True`` is Algorithm 4's deferred-cardinality discipline for
    OR reduction trees: bitmap-path rows get the ``CHUNK_SIZE`` upper bound
    instead of a popcount. Sound mid-tree because no consumer reads a
    BITMAP row's card before the root — the union routing policy only
    inspects cards of array-ish rows (whose merge cards stay exact) — and
    the root recounts via ``_recount_bitmap_rows`` before finalization.
    """
    M = ka.shape[0]

    def merge_pass(args):
        da, ca, db, cb = args
        return jax.vmap(
            functools.partial(_row_merge_sparse, xor=xor))(da, ca, db, cb)

    def merge_skip(args):
        return (jnp.full((M, ROW_WORDS), 0xFFFF, jnp.uint16),
                jnp.zeros((M,), jnp.int32))

    def bitmap_pass(args):
        da, ca, ka, db, cb, kb = args
        out = word_op(_lift_rows(da, ca, ka), _lift_rows(db, cb, kb))
        if defer_card:
            return out, jnp.full((M,), CHUNK_SIZE, jnp.int32)
        return out, jax.vmap(row_popcount)(out)

    def bitmap_skip(args):
        return (jnp.zeros((M, ROW_WORDS), jnp.uint16),
                jnp.zeros((M,), jnp.int32))

    small, use_bitmap = _D.union_route(ka, kb, ca, cb, ARRAY_MAX)
    merge_rows, merge_card = jax.lax.cond(jnp.any(small), merge_pass,
                                          merge_skip, (da, ca, db, cb))
    bits, bcard = jax.lax.cond(jnp.any(use_bitmap), bitmap_pass, bitmap_skip,
                               (da, ca, ka, db, cb, kb))
    card = jnp.where(use_bitmap, bcard, merge_card)
    data = jnp.where(use_bitmap[:, None], bits, merge_rows)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(use_bitmap, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


_or_rows_deferred = functools.partial(_or_rows, defer_card=True)


def _recount_bitmap_rows(data, card, kind):
    """Exact cards for word rows at the root of a deferred-cardinality OR
    tree: ONE cond-guarded popcount pass (Algorithm 4 line 16's 'recount
    once at the end'), replacing the ``CHUNK_SIZE`` placeholders that
    ``_or_rows(defer_card=True)`` leaves on bitmap-path rows."""
    is_b = kind == KIND_BITMAP
    masked = jnp.where(is_b[:, None], data, jnp.uint16(0))
    cnt = jax.lax.cond(
        jnp.any(is_b),
        lambda m: jax.vmap(row_popcount)(m),
        lambda m: jnp.zeros((data.shape[0],), jnp.int32), masked)
    return jnp.where(is_b, cnt, card)


def _and_rows(da, ca, ka, db, cb, kb):
    """One AND combine step over key-aligned row pairs -> row state.

    The full 4x4 kind-dispatch grid through ``intersect_dispatch`` (Pallas on
    TPU, XLA reference elsewhere): mask-semantic cells compact the hit mask
    against the array side (output provably <= min(card) <= 4096, stays
    packed); bits-semantic cells — including run x run, which the kernel
    computes as the coverage AND — stay word rows with the fused-popcount
    cardinality. Deferred kinds {EMPTY, ARRAY, BITMAP}; the run-domain
    run-merge is a ``slab_and``-only specialization.
    """
    from repro.kernels.roaring import ops as _kops
    ra = _rows_nruns(da, ka)
    rb = _rows_nruns(db, kb)
    meta = _dispatch_meta(ka, kb, ca, cb, ra, rb)
    hits, card = _kops.intersect_dispatch(da, db, meta)
    bits_m = _D.out_mask("bits", ka, kb) | _D.route_mask("run_merge", ka, kb)
    src = jnp.where(_D.out_mask("mask_b", ka, kb)[:, None], db, da)
    arr_rows = jax.vmap(_compact_row)(src, (hits == 1) & ~bits_m[:, None])
    data = jnp.where(bits_m[:, None], hits, arr_rows)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(bits_m, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


def _andnot_rows(da, ca, ka, db, cb, kb):
    """One ANDNOT combine step (A \\ B per row pair) -> row state.

    Registry ``andnot_route``: array-A rows probe B in place whatever B's
    kind (binary search / bit probe / gallop-in-ranges — result provably
    <= card_a <= 4096, stays packed); bitmap- and run-A rows take the
    ``lax.cond``-guarded bitmap-domain pass with the cheap run lift.
    """
    M = ka.shape[0]
    slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)
    probe_a, lift_a = _D.andnot_route(ka, kb)

    def probe_row(dav, cav, dbv, cbv, kbv, rbv):
        pos = jnp.searchsorted(dbv, dav)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        arr_in = (dbv[pos_c] == dav) & (pos < cbv)
        v = dav.astype(jnp.int32)
        word = dbv[v >> 4].astype(jnp.int32)
        bit_in = ((word >> (v & 15)) & 1) == 1
        run_in = _D._run_covered(dbv.reshape(_D.ROW_SHAPE), rbv,
                                 v.reshape(_D.ROW_SHAPE)).reshape(ROW_WORDS)
        in_b = jnp.where(kbv == KIND_BITMAP, bit_in,
                         jnp.where(kbv == KIND_ARRAY, arr_in,
                                   jnp.where(kbv == KIND_RUN, run_in, False)))
        return (slot < cav) & ~in_b

    def bitmap_pass(args):
        da, ca, ka, db, cb, kb = args
        out = jnp.bitwise_and(_lift_rows(da, ca, ka),
                              ~_lift_rows(db, cb, kb))
        return out, jax.vmap(row_popcount)(out)

    def bitmap_skip(args):
        return (jnp.zeros((M, ROW_WORDS), jnp.uint16),
                jnp.zeros((M,), jnp.int32))

    rb = _rows_nruns(db, kb)
    keep = jax.vmap(probe_row)(da, ca, db, cb, kb, rb) & probe_a[:, None]
    arr_rows = jax.vmap(_compact_row)(da, keep)
    acard = jnp.sum(keep.astype(jnp.int32), axis=1)
    bits, bcard = jax.lax.cond(jnp.any(lift_a), bitmap_pass, bitmap_skip,
                               (da, ca, ka, db, cb, kb))
    card = jnp.where(lift_a, bcard, acard)
    data = jnp.where(lift_a[:, None], bits, arr_rows)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(lift_a, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


def _tree_reduce_rows(data, card, kind, combine=_or_rows):
    """Log-depth segmented reduction over the leading (slab) axis.

    ``data/card/kind``: stacked key-aligned row states ``[N, C, ...]``. Each
    level pairs adjacent slabs and runs one flattened ``combine`` over
    ``(N/2) * C`` rows — kind-dispatching at every level — carrying the odd
    tail unchanged; ceil(log2 N) levels total, no canonicalization inside
    (callers finish with ``_finalize_rows``).
    """
    C, W = data.shape[1], data.shape[2]
    while data.shape[0] > 1:
        n = data.shape[0]
        half = n // 2
        ev = slice(0, 2 * half, 2)
        od = slice(1, 2 * half, 2)
        d, c, k = combine(
            data[ev].reshape(half * C, W), card[ev].reshape(half * C),
            kind[ev].reshape(half * C),
            data[od].reshape(half * C, W), card[od].reshape(half * C),
            kind[od].reshape(half * C))
        d = d.reshape(half, C, W)
        c = c.reshape(half, C)
        k = k.reshape(half, C)
        if n % 2:
            d = jnp.concatenate([d, data[2 * half:]], axis=0)
            c = jnp.concatenate([c, card[2 * half:]], axis=0)
            k = jnp.concatenate([k, kind[2 * half:]], axis=0)
        data, card, kind = d, c, k
    return data[0], card[0], kind[0]


def slab_and(a: RoaringSlab, b: RoaringSlab,
             capacity: int | None = None) -> RoaringSlab:
    """Kind-dispatch intersection over the registry's 4x4 AND grid.

    array x array -> vectorized galloping; array x bitmap -> bit probes;
    bitmap x bitmap -> fused word-AND + popcount (Alg. 3); array x run ->
    gallop-in-ranges; run x bitmap -> range-mask coverage AND; run x run ->
    the run-domain merge (never touches bits at all). Mask-semantic outputs
    are provably <= min(card_a, card_b) <= 4096, so they compact straight to
    packed arrays; only bits-semantic rows whose canonical form is packed
    pay the (cond-guarded) extraction.
    """
    from repro.kernels.roaring import ops as _kops
    capacity = capacity or min(a.capacity, b.capacity)
    keys = _intersect_keys(a, b, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    ra = _rows_nruns(da, ka)
    rb = _rows_nruns(db, kb)
    rr = _D.route_mask("run_merge", ka, kb)
    # run x run rows are routed around the kernel (masked empty -> skipped)
    meta = _dispatch_meta(jnp.where(rr, KIND_EMPTY, ka),
                          jnp.where(rr, KIND_EMPTY, kb), ca, cb, ra, rb)
    hits, kcard = _kops.intersect_dispatch(da, db, meta)
    pairs_rr, card_rr, nr_rr, bits_rr = _run_merge_rows_lazy(da, db, rr)

    mask_m = _D.out_mask("mask_a", ka, kb) | _D.out_mask("mask_b", ka, kb)
    src = jnp.where(_D.out_mask("mask_b", ka, kb)[:, None], db, da)
    arr_rows = jax.vmap(_compact_row)(src, (hits == 1) & mask_m[:, None])
    card = jnp.where(rr, card_rr, kcard)
    overflow = rr & (nr_rr > MAX_RUNS)
    form = jnp.where(rr & ~overflow, FORM_RUNS,
                     jnp.where(_D.out_mask("bits", ka, kb) | overflow,
                               FORM_BITS, FORM_ARRAY))
    bits_rows = jnp.where(rr[:, None], bits_rr, hits)
    return _finalize(keys, card, form, arr_rows, bits_rows, pairs_rr, nr_rr)


def slab_and_card(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∩ B| without materializing a result slab (Alg. 3 line 5 for free:
    the dispatch kernel's fused popcount/hit-count is the entire answer —
    run x run rows use the in-kernel coverage-AND form, no merge pass)."""
    from repro.kernels.roaring import ops as _kops
    keys = _intersect_keys(a, b, min(a.capacity, b.capacity))
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    meta = _dispatch_meta(ka, kb, ca, cb, _rows_nruns(da, ka),
                          _rows_nruns(db, kb))
    _, card = _kops.intersect_dispatch(da, db, meta)
    return jnp.sum(card)


def slab_or_card(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∪ B| via inclusion-exclusion on the per-container counters."""
    return a.cardinality + b.cardinality - _slab_and_card(a, b)


def slab_jaccard(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∩ B| / |A ∪ B| in one dispatch pass (0 when both empty)."""
    inter = _slab_and_card(a, b)
    union = a.cardinality + b.cardinality - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0)


def stack_slabs(slabs: list[RoaringSlab]) -> RoaringSlab:
    """Stack same-capacity slabs into one batched (leading-axis) slab."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *slabs)


def slab_and_many(query: RoaringSlab, slabs: list[RoaringSlab],
                  unroll: bool = False) -> RoaringSlab:
    """Batched ``query ∩ slab_i`` over a fleet of same-capacity slabs.

    Default is one vmapped dispatch (single fused launch) — note that vmap
    lowers the lax.cond laziness guards to select, so the down-conversion
    pass runs for every batch element. ``unroll=True`` traces each pair
    separately (compile time grows with the fleet) but keeps the runtime
    laziness per slab — prefer it for large fleets of array-dominated slabs.
    """
    if unroll:
        return stack_slabs([_slab_and(query, s) for s in slabs])
    return jax.vmap(lambda s: _slab_and(query, s))(stack_slabs(slabs))


def slab_and_card_many(query: RoaringSlab,
                       slabs: list[RoaringSlab]) -> jax.Array:
    """Batched intersection cardinalities — the query-engine primitive
    (score many posting lists against one query without materializing).
    Cond-free, so vmap costs nothing extra."""
    stacked = stack_slabs(slabs)
    return jax.vmap(lambda s: _slab_and_card(query, s))(stacked)


def _lift_rows(data, card, kind):
    """Batched bitmap-domain view of raw rows, with a runtime fast path:
    when every live row is already a word row (tree-reduction levels past
    the first — union intermediates are bitmap-form by construction), the
    kind-dispatching lift (array scatter + run coverage + selects) is
    skipped wholesale by ``lax.cond`` and only the empty-row mask applies."""
    need = (kind != KIND_BITMAP) & (kind != KIND_EMPTY)

    def lift(args):
        data, card, kind = args
        return jax.vmap(row_to_bits)(data, card, kind)

    def passthrough(args):
        data, _, kind = args
        return data * (kind != KIND_EMPTY)[:, None].astype(jnp.uint16)

    return jax.lax.cond(jnp.any(need), lift, passthrough, (data, card, kind))


def _row_merge_sparse(da, ca, db, cb, *, xor: bool):
    """Array x array union/xor by sorted merge of the two packed prefixes —
    O(8192 log), stays entirely in array domain. Only meaningful when
    card_a + card_b <= 4096 (caller guarantees via the pair class)."""
    INVALID = jnp.int32(1) << 17
    slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)
    ia = jnp.where(slot < ca, da.astype(jnp.int32), INVALID)
    ib = jnp.where(slot < cb, db.astype(jnp.int32), INVALID)
    cat = jnp.sort(jnp.concatenate([ia, ib]))
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), cat[:-1]])
    nxt = jnp.concatenate([cat[1:], jnp.full((1,), -2, jnp.int32)])
    first = cat != prev
    keep = first & (cat < INVALID)
    if xor:
        keep = keep & (cat != nxt)
    h = keep.astype(jnp.int32)
    rank = jnp.cumsum(h) - h
    idx = jnp.where(keep, rank, 2 * ROW_WORDS)
    row = jnp.full((ROW_WORDS,), 0xFFFF, jnp.uint16).at[idx].set(
        cat.astype(jnp.uint16), mode="drop")
    return row, jnp.sum(h)


def _union_like(a: RoaringSlab, b: RoaringSlab, capacity: int,
                word_op, xor: bool) -> RoaringSlab:
    """Shared OR/XOR pipeline: merge the key sets, run one ``_or_rows``
    combine step (registry union policy — see its docstring), and finalize
    best-of-three so run-shaped outputs come back out as run rows."""
    keys = _merge_keys(a, b, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    data, card, kind = _or_rows(da, ca, ka, db, cb, kb, word_op=word_op,
                                xor=xor)
    return _finalize_rows(keys, data, card, kind)


def slab_or(a: RoaringSlab, b: RoaringSlab,
            capacity: int | None = None) -> RoaringSlab:
    """A ∪ B through the kind-dispatch engine (canonical output).

    Capacity defaults to ``a.capacity + b.capacity`` (the key sets may be
    disjoint); pass a tighter static ``capacity`` when the union's key count
    is known to fit.
    """
    return _union_like(a, b, capacity or (a.capacity + b.capacity),
                       jnp.bitwise_or, xor=False)


def slab_xor(a: RoaringSlab, b: RoaringSlab,
             capacity: int | None = None) -> RoaringSlab:
    """A ⊕ B (symmetric difference), same routing/canonical discipline as
    ``slab_or`` with the sorted-merge dropping equal pairs."""
    return _union_like(a, b, capacity or (a.capacity + b.capacity),
                       jnp.bitwise_xor, xor=True)


def slab_andnot(a: RoaringSlab, b: RoaringSlab,
                capacity: int | None = None) -> RoaringSlab:
    """A \\ B, routed by the registry's andnot policy: array-A rows probe B
    in place whatever B's kind — binary search for array B, bit probe for
    bitmap B, gallop-in-ranges for run B (result provably <= card_a <= 4096,
    stays packed); bitmap- and run-A rows go through the (cond-guarded)
    bitmap domain with the cheap run lift."""
    capacity = capacity or a.capacity
    keys = _pad_keys(a.keys, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    data, card, kind = _andnot_rows(da, ca, ka, db, cb, kb)
    return _finalize_rows(keys, data, card, kind)


# =============================================================================
# legacy bitmap-domain path (pre-dispatch) — A/B baseline + cross-check
# =============================================================================

def _gather_rows(s: RoaringSlab, keys: jax.Array):
    """Bitmap-domain rows of ``s`` aligned to ``keys`` (zeros when absent)."""
    pos = jnp.searchsorted(s.keys, keys)
    pos_c = jnp.minimum(pos, s.capacity - 1)
    present = (s.keys[pos_c] == keys) & (keys != KEY_SENTINEL)
    bits = jax.vmap(row_to_bits)(s.data[pos_c], s.card[pos_c], s.kind[pos_c])
    return bits * present[:, None].astype(jnp.uint16), present


def _binary_bits_op(a: RoaringSlab, b: RoaringSlab, word_op, capacity: int,
                    intersection: bool) -> RoaringSlab:
    """Pre-dispatch formulation: lift every row to the 2^16-bit domain,
    apply the word op, re-canonicalize every output row. Pays the full
    bitmap-domain tax regardless of container kinds — kept only so the
    benchmarks can measure what the dispatch path saves."""
    if capacity is None:
        capacity = a.capacity + b.capacity
    keys = _merge_keys(a, b, capacity)
    bits_a, pa = _gather_rows(a, keys)
    bits_b, pb = _gather_rows(b, keys)
    out_bits = word_op(bits_a, bits_b)
    data, card, kind = jax.vmap(_row_canonicalize_2kind)(out_bits)
    live = card > 0
    if intersection:
        live = live & pa & pb
        card = jnp.where(live, card, 0)
        kind = jnp.where(live, kind, KIND_EMPTY)
    keys = jnp.where(live, keys, KEY_SENTINEL)
    # compact: sort rows so live keys are sorted first (sentinel rows sink)
    order = jnp.argsort(keys)
    return RoaringSlab(keys=keys[order], card=card[order], kind=kind[order],
                       data=data[order])


def slab_and_bitmap_domain(a: RoaringSlab, b: RoaringSlab,
                           capacity: int | None = None) -> RoaringSlab:
    """A ∩ B through the pre-dispatch bitmap-domain path (A/B baseline).

    Same values/card as ``slab_and`` but 2-kind canonicalization only (no
    run outputs) and the full per-row O(2^16) tax — benchmark baseline, not
    a production path.
    """
    return _binary_bits_op(a, b, jnp.bitwise_and,
                           capacity or min(a.capacity, b.capacity) * 2,
                           intersection=True)


def slab_or_bitmap_domain(a: RoaringSlab, b: RoaringSlab,
                          capacity: int | None = None) -> RoaringSlab:
    """A ∪ B through the pre-dispatch bitmap-domain path (A/B baseline);
    see ``slab_and_bitmap_domain``."""
    return _binary_bits_op(a, b, jnp.bitwise_or,
                           capacity or (a.capacity + b.capacity),
                           intersection=False)


def union_many_slabs(slabs: list[RoaringSlab], capacity: int) -> RoaringSlab:
    """Algorithm 4, TPU form: log-depth tree reduction through the engine.

    The merged key set is computed once; every slab's rows are gathered
    key-aligned in *native* container form, and ``_tree_reduce_rows`` runs
    ceil(log2 N) ``_or_rows`` levels — kind-dispatching at every level:
    sparse array pairs merge in array domain, everything else goes through
    the bitmap domain with the O(4096) kind-aware lift (run rows range-mask,
    never the 2^16 element domain). Canonicalization is a *single* deferred
    best-of-three pass at the root, so run-shaped unions (e.g. the KV free
    pool) come back out as run rows. Replaces the PR 2 static unroll of N
    sequential bitmap-domain ORs; see ``benchmarks/kernel_bench.wide_ab``
    for the tree-vs-fold speedup gate.
    """
    if not slabs:
        return empty(capacity)
    keys = _merge_keys_many([s.keys for s in slabs], capacity)
    gathered = [_gather_raw(s, keys) for s in slabs]
    data = jnp.stack([g[0] for g in gathered])
    card = jnp.stack([g[1] for g in gathered])
    kind = jnp.stack([g[2] for g in gathered])
    data, card, kind = _tree_reduce_rows(data, card, kind, _or_rows_deferred)
    card = _recount_bitmap_rows(data, card, kind)   # Alg. 4: recount once
    return _finalize_rows(keys, data, card, kind)


# =============================================================================
# deprecation shims: the tuple-threading slab_* free functions are superseded
# by the repro.roaring object API. Each public slab_* name below is rebound to
# a shim that warns (DeprecationWarning, caller-attributed) and delegates; the
# original implementation stays reachable as _slab_<name> — the internal layer
# repro.roaring and this module's own helpers call. Warning cost is trace-time
# only: jitted callers never re-enter the shim.
# =============================================================================

_DEPRECATED = {
    "slab_and": "a & b (repro.roaring.RoaringSlab)",
    "slab_or": "a | b (repro.roaring.RoaringSlab)",
    "slab_xor": "a ^ b (repro.roaring.RoaringSlab)",
    "slab_andnot": "a - b (repro.roaring.RoaringSlab)",
    "slab_and_card": "a.and_card(b) (repro.roaring.RoaringSlab)",
    "slab_or_card": "a.or_card(b) (repro.roaring.RoaringSlab)",
    "slab_jaccard": "a.jaccard(b) (repro.roaring.RoaringSlab)",
    "slab_select": "a.select(j) (repro.roaring.RoaringSlab)",
    "slab_run_optimize": "a.run_optimize() (repro.roaring.RoaringSlab)",
    "slab_and_many": "stacked & query (repro.roaring, batched broadcast)",
    "slab_and_card_many": "stacked.and_card(query) (repro.roaring)",
    "slab_and_bitmap_domain":
        "repro.roaring set algebra (this A/B baseline stays for benchmarks)",
    "slab_or_bitmap_domain":
        "repro.roaring set algebra (this A/B baseline stays for benchmarks)",
}


def _install_deprecation_shims() -> None:
    g = globals()
    for name, repl in _DEPRECATED.items():
        impl = g[name]
        g["_" + name] = impl

        def _make(impl=impl, name=name, repl=repl):
            @functools.wraps(impl)
            def shim(*args, **kwargs):
                warnings.warn(
                    f"repro.core.jax_roaring.{name} is deprecated; "
                    f"use {repl}", DeprecationWarning, stacklevel=2)
                return impl(*args, **kwargs)

            shim.__wrapped__ = impl
            return shim

        g[name] = _make()


_install_deprecation_shims()
