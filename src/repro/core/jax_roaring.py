"""TPU-native Roaring bitmap: the static-shape container slab.

The paper's dynamic two-level structure is re-thought for accelerator
execution (static shapes, no pointer chasing):

  * a ``RoaringSlab`` holds up to ``C`` containers. Row ``i`` of ``data``
    (u16[4096], 8 kB) is *either* a packed sorted u16 array (first ``card[i]``
    entries) *or* a 2^16-bit bitmap stored as 4096 16-bit words. The paper's
    4096-element threshold is exactly the break-even where both forms cost
    8 kB, so a uniform slab row wastes nothing at the boundary.
  * ``keys`` is the sorted first-level index (padded with ``KEY_SENTINEL``),
    ``card`` the per-container cardinality counters (paper S2), ``kind`` the
    container type tag (0 empty / 1 array / 2 bitmap).

XLA-path set operations run in *bitmap domain* (uniform, maskable); the
paper's hybrid per-type dispatch — which skips work instead of masking it —
lives in the Pallas kernels (``repro.kernels.roaring``), where ``@pl.when``
on container-type tags skips whole 8 kB tiles. Cardinality is maintained with
``lax.population_count`` (the popcnt the paper leans on) fused into the same
pass, mirroring Algorithm 1/3.

All functions are jit-/vmap-/pjit-compatible and allocation-free at trace
time; capacities are static Python ints.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK_BITS = 16
CHUNK_SIZE = 1 << CHUNK_BITS
ARRAY_MAX = 4096                 # paper's array/bitmap threshold
ROW_WORDS = 4096                 # 4096 x u16 words = 2^16 bits = 8 kB
KEY_SENTINEL = jnp.int32(1 << 20)

KIND_EMPTY, KIND_ARRAY, KIND_BITMAP = 0, 1, 2


class RoaringSlab(NamedTuple):
    """Static-capacity Roaring bitmap. ``C = keys.shape[0]`` containers."""

    keys: jax.Array   # i32[C], sorted, inactive rows = KEY_SENTINEL
    card: jax.Array   # i32[C]
    kind: jax.Array   # i32[C] in {0,1,2}
    data: jax.Array   # u16[C, 4096]

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def n_containers(self) -> jax.Array:
        return jnp.sum(self.kind != KIND_EMPTY)

    @property
    def cardinality(self) -> jax.Array:
        """Sum of per-container counters (paper S2)."""
        return jnp.sum(self.card)


def empty(capacity: int) -> RoaringSlab:
    return RoaringSlab(
        keys=jnp.full((capacity,), KEY_SENTINEL, dtype=jnp.int32),
        card=jnp.zeros((capacity,), dtype=jnp.int32),
        kind=jnp.zeros((capacity,), dtype=jnp.int32),
        data=jnp.zeros((capacity, ROW_WORDS), dtype=jnp.uint16),
    )


# =============================================================================
# row-level helpers (one container)
# =============================================================================

def row_array_to_bits(row: jax.Array, card: jax.Array) -> jax.Array:
    """Packed sorted u16 array row -> 4096-word bitmap row.

    Distinct elements set distinct bits, so a scatter-add is an exact OR.
    """
    lo = row.astype(jnp.int32)
    valid = jnp.arange(row.shape[0]) < card
    word = jnp.where(valid, lo >> 4, ROW_WORDS)           # OOB index dropped
    bit = (lo & 15).astype(jnp.uint16)
    vals = jnp.where(valid, jnp.uint16(1) << bit, jnp.uint16(0))
    return jnp.zeros((ROW_WORDS,), jnp.uint16).at[word].add(
        vals, mode="drop")


def row_to_bits(row: jax.Array, card: jax.Array, kind: jax.Array) -> jax.Array:
    """Uniform bitmap-domain view of a container row (empty -> zeros)."""
    as_bits = row_array_to_bits(row, card)
    return jnp.where(kind == KIND_BITMAP, row, as_bits) * (kind != KIND_EMPTY).astype(jnp.uint16)


def row_popcount(bits: jax.Array) -> jax.Array:
    """Container cardinality via popcnt (paper Alg. 1 line 7)."""
    return jnp.sum(lax_popcount(bits).astype(jnp.int32))


def lax_popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)


def row_bits_to_array(bits: jax.Array) -> jax.Array:
    """Vectorized Algorithm 2: bitmap row -> packed sorted u16 array row.

    Per-word popcounts -> exclusive cumsum gives each word's write offset;
    bit positions are scattered to offset + rank-within-word. O(2^16) fully
    data-parallel (the TPU replacement for the serial ``w & -w`` loop).
    """
    # bits: u16[4096] -> per-bit boolean [4096, 16]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, None] >> shifts[None, :]) & jnp.uint16(1)).astype(jnp.int32)
    flat = bitmat.reshape(-1)                               # [65536] in value order
    pos = jnp.arange(CHUNK_SIZE, dtype=jnp.int32)
    rank = jnp.cumsum(flat) - flat                          # exclusive cumsum
    idx = jnp.where(flat == 1, rank, CHUNK_SIZE)            # OOB dropped
    out = jnp.zeros((ROW_WORDS,), jnp.uint16).at[idx].add(
        pos.astype(jnp.uint16), mode="drop")
    return out


def row_canonicalize(bits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """bitmap-domain row -> canonical (data, card, kind) per the 4096 rule.

    Array rows are padded with 0xFFFF past ``card`` so the packed prefix plus
    padding stays globally sorted (binary-search friendly).
    """
    card = row_popcount(bits)
    as_array = row_bits_to_array(bits)
    as_array = jnp.where(jnp.arange(ROW_WORDS) < card, as_array,
                         jnp.uint16(0xFFFF))
    is_bitmap = card > ARRAY_MAX
    data = jnp.where(is_bitmap, bits, as_array)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


# =============================================================================
# construction / export
# =============================================================================

def from_indices(idx: jax.Array, valid: jax.Array, capacity: int) -> RoaringSlab:
    """Build a slab from (padded) *sorted unique* int32/int64 indices.

    ``idx``: i64/i32[M] sorted ascending with invalid entries at the end
    (``valid`` false). Elements sharing high 16 bits land in one container.
    Works with or without x64 (int32 universes cover every in-framework use:
    per-leaf gradient coordinates, block ids, page ids).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    idx = idx.astype(idt)
    M = idx.shape[0]
    sentinel = jnp.asarray(int(KEY_SENTINEL), idt)
    hi = jnp.where(valid, idx >> CHUNK_BITS, sentinel)
    lo = (idx & (CHUNK_SIZE - 1)).astype(jnp.int32)

    first = jnp.concatenate([jnp.array([True]), hi[1:] != hi[:-1]]) & valid
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1           # container id per elem
    seg = jnp.where(valid, seg, capacity)                   # drop invalid
    counts = jnp.zeros((capacity,), jnp.int32).at[seg].add(1, mode="drop")

    # container keys: first element of each segment
    keys = jnp.full((capacity,), sentinel, dtype=idt)
    keys = keys.at[jnp.where(first, seg, capacity)].min(
        jnp.where(first, hi, sentinel), mode="drop")
    keys = jnp.where(counts > 0, keys, sentinel).astype(jnp.int32)

    # array representation: rank within segment
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    rank = jnp.arange(M, dtype=jnp.int32) - seg_start[jnp.minimum(seg, capacity - 1)]
    arr_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    arr_data = arr_data.at[seg, jnp.where(valid, rank, ROW_WORDS)].add(
        lo.astype(jnp.uint16), mode="drop")

    # bitmap representation (scatter-add of distinct power-of-two bits)
    bit_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    bit_data = bit_data.at[seg, jnp.where(valid, lo >> 4, ROW_WORDS)].add(
        jnp.where(valid, jnp.uint16(1) << (lo & 15).astype(jnp.uint16),
                  jnp.uint16(0)), mode="drop")

    is_bitmap = counts > ARRAY_MAX
    # pad array rows with 0xFFFF past card so binary search stays valid
    arr_data = jnp.where(jnp.arange(ROW_WORDS)[None, :] < counts[:, None],
                         arr_data, jnp.uint16(0xFFFF))
    data = jnp.where(is_bitmap[:, None], bit_data, arr_data)
    kind = jnp.where(counts == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return RoaringSlab(keys=keys, card=counts, kind=kind, data=data)


def from_dense_array(values: np.ndarray, capacity: int, max_elems: int) -> RoaringSlab:
    """Host-side convenience: numpy values -> slab (pads to max_elems)."""
    v = np.unique(np.asarray(values, dtype=np.int64))
    assert v.size <= max_elems, (v.size, max_elems)
    idx = np.full((max_elems,), 0, dtype=np.int64)
    idx[: v.size] = v
    valid = np.zeros((max_elems,), dtype=bool)
    valid[: v.size] = True
    # keep padded tail sorted-after-valid by setting it to the max value
    if v.size:
        idx[v.size:] = v[-1]
    return from_indices(jnp.asarray(idx), jnp.asarray(valid), capacity)


def to_indices(slab: RoaringSlab, max_out: int) -> tuple[jax.Array, jax.Array]:
    """Slab -> (sorted values int[max_out], valid bool[max_out]).

    Uniform path: every row is viewed in bitmap domain, all C*2^16 candidate
    bits are compacted by exclusive cumsum (global Algorithm 2).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    bits = jax.vmap(row_to_bits)(slab.data, slab.card, slab.kind)   # u16[C,4096]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, :, None] >> shifts[None, None, :]) & jnp.uint16(1))
    flat = bitmat.reshape(-1).astype(jnp.int32)             # [C*65536]
    # sentinel keys may wrap when shifted in int32 — harmless: their rows have
    # flat == 0 everywhere, so the wrapped values are multiplied away.
    base = (slab.keys.astype(idt) << CHUNK_BITS)
    vals = (base[:, None] + jnp.arange(CHUNK_SIZE, dtype=idt)[None, :]).reshape(-1)
    rank = jnp.cumsum(flat) - flat
    tgt = jnp.where(flat == 1, rank, max_out)
    out = jnp.zeros((max_out,), idt).at[tgt].add(vals * flat, mode="drop")
    total = jnp.sum(flat)
    valid = jnp.arange(max_out) < total
    return jnp.where(valid, out, 0), valid


def extract_row(slab: RoaringSlab, r, max_out: int = ARRAY_MAX):
    """Packed sorted values of container ``r`` (Alg. 2 on one row)."""
    bits = row_to_bits(slab.data[r], slab.card[r], slab.kind[r])
    arr = row_bits_to_array(bits)
    valid = jnp.arange(ROW_WORDS) < slab.card[r]
    return arr[:max_out], valid[:max_out]


# =============================================================================
# membership / rank
# =============================================================================

def contains(slab: RoaringSlab, queries: jax.Array) -> jax.Array:
    """Batched membership test (paper S3): first-level binary search, then
    array binary search or bitmap bit probe, selected by container kind."""
    q = queries.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (q >> CHUNK_BITS).astype(jnp.int32)
    lo = (q & (CHUNK_SIZE - 1)).astype(jnp.int32)
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    key_hit = slab.keys[row_c] == hi

    def one(row_i, lo_i):
        data = slab.data[row_i]
        card = slab.card[row_i]
        kind = slab.kind[row_i]
        # array path: binary search in packed sorted prefix
        pos = jnp.searchsorted(data, lo_i.astype(jnp.uint16))
        arr_hit = (pos < card) & (data[jnp.minimum(pos, ROW_WORDS - 1)]
                                  == lo_i.astype(jnp.uint16))
        # bitmap path: probe bit
        word = data[lo_i >> 4]
        bit_hit = ((word >> (lo_i & 15).astype(jnp.uint16)) & jnp.uint16(1)) == 1
        return jnp.where(kind == KIND_BITMAP, bit_hit,
                         jnp.where(kind == KIND_ARRAY, arr_hit, False))

    hits = jax.vmap(one)(row_c, lo)
    return hits & key_hit


def rank(slab: RoaringSlab, x: jax.Array) -> jax.Array:
    """# elements <= x: whole-container counters + one partial container."""
    x = x.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (x >> CHUNK_BITS).astype(jnp.int32)
    lo = (x & (CHUNK_SIZE - 1)).astype(jnp.int32)
    full = jnp.sum(jnp.where(slab.keys < hi, slab.card, 0))
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    hit = slab.keys[row_c] == hi
    bits = row_to_bits(slab.data[row_c], slab.card[row_c], slab.kind[row_c])
    word_idx = lo >> 4
    mask_full = (jnp.arange(ROW_WORDS) < word_idx)
    partial_words = jnp.sum(lax_popcount(jnp.where(mask_full, bits, 0)).astype(jnp.int32))
    last = bits[word_idx] & ((jnp.uint16(2) << (lo & 15).astype(jnp.uint16)) - 1).astype(jnp.uint16)
    in_row = partial_words + lax_popcount(last).astype(jnp.int32)
    return full + jnp.where(hit, in_row, 0)


# =============================================================================
# set algebra (XLA bitmap-domain path; hybrid dispatch is in the Pallas kernel)
# =============================================================================

def _merge_keys(a: RoaringSlab, b: RoaringSlab, capacity: int) -> jax.Array:
    """Union of the two sorted key sets, deduplicated, padded with sentinel."""
    cat = jnp.concatenate([a.keys, b.keys])
    srt = jnp.sort(cat)
    dup = jnp.concatenate([jnp.array([False]), srt[1:] == srt[:-1]])
    vals = jnp.where(dup, KEY_SENTINEL, srt)
    vals = jnp.sort(vals)
    return vals[:capacity]


def _gather_rows(s: RoaringSlab, keys: jax.Array):
    """Bitmap-domain rows of ``s`` aligned to ``keys`` (zeros when absent)."""
    pos = jnp.searchsorted(s.keys, keys)
    pos_c = jnp.minimum(pos, s.capacity - 1)
    present = (s.keys[pos_c] == keys) & (keys != KEY_SENTINEL)
    bits = jax.vmap(row_to_bits)(s.data[pos_c], s.card[pos_c], s.kind[pos_c])
    return bits * present[:, None].astype(jnp.uint16), present


def _binary_bits_op(a: RoaringSlab, b: RoaringSlab, word_op, capacity: int,
                    intersection: bool) -> RoaringSlab:
    if capacity is None:
        capacity = a.capacity + b.capacity
    keys = _merge_keys(a, b, capacity)
    bits_a, pa = _gather_rows(a, keys)
    bits_b, pb = _gather_rows(b, keys)
    out_bits = word_op(bits_a, bits_b)
    data, card, kind = jax.vmap(row_canonicalize)(out_bits)
    live = card > 0
    if intersection:
        live = live & pa & pb
        card = jnp.where(live, card, 0)
        kind = jnp.where(live, kind, KIND_EMPTY)
    keys = jnp.where(live, keys, KEY_SENTINEL)
    # compact: sort rows so live keys are sorted first (sentinel rows sink)
    order = jnp.argsort(keys)
    return RoaringSlab(keys=keys[order], card=card[order], kind=kind[order],
                       data=data[order])


def slab_and(a: RoaringSlab, b: RoaringSlab, capacity: int | None = None) -> RoaringSlab:
    return _binary_bits_op(a, b, jnp.bitwise_and,
                           capacity or min(a.capacity, b.capacity) * 2,
                           intersection=True)


def slab_or(a: RoaringSlab, b: RoaringSlab, capacity: int | None = None) -> RoaringSlab:
    return _binary_bits_op(a, b, jnp.bitwise_or,
                           capacity or (a.capacity + b.capacity),
                           intersection=False)


def slab_xor(a: RoaringSlab, b: RoaringSlab, capacity: int | None = None) -> RoaringSlab:
    return _binary_bits_op(a, b, jnp.bitwise_xor,
                           capacity or (a.capacity + b.capacity),
                           intersection=False)


def slab_andnot(a: RoaringSlab, b: RoaringSlab, capacity: int | None = None) -> RoaringSlab:
    out = _binary_bits_op(a, b, lambda x, y: jnp.bitwise_and(x, ~y),
                          capacity or a.capacity, intersection=False)
    # keys only present in A survive; rows from B alone are already zeroed by
    # the AND-NOT word op (x=0 there), and canonicalize marks them empty.
    return out


def union_many_slabs(slabs: list[RoaringSlab], capacity: int) -> RoaringSlab:
    """Algorithm 4, TPU form: key-aligned segmented OR-reduction in bitmap
    domain with cardinality computed once at the end (deferred popcount)."""
    all_keys = jnp.concatenate([s.keys for s in slabs])
    srt = jnp.sort(all_keys)
    dup = jnp.concatenate([jnp.array([False]), srt[1:] == srt[:-1]])
    keys = jnp.sort(jnp.where(dup, KEY_SENTINEL, srt))[:capacity]
    acc = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    for s in slabs:                                   # static unroll (fleet size)
        bits, _ = _gather_rows(s, keys)
        acc = jnp.bitwise_or(acc, bits)               # deferred cardinality
    data, card, kind = jax.vmap(row_canonicalize)(acc)
    keys = jnp.where(card > 0, keys, KEY_SENTINEL)
    order = jnp.argsort(keys)
    return RoaringSlab(keys[order], card[order], kind[order], data[order])
