"""TPU-native Roaring bitmap: the static-shape container slab.

The paper's dynamic two-level structure is re-thought for accelerator
execution (static shapes, no pointer chasing):

  * a ``RoaringSlab`` holds up to ``C`` containers. Row ``i`` of ``data``
    (u16[4096], 8 kB) is *either* a packed sorted u16 array (first ``card[i]``
    entries) *or* a 2^16-bit bitmap stored as 4096 16-bit words. The paper's
    4096-element threshold is exactly the break-even where both forms cost
    8 kB, so a uniform slab row wastes nothing at the boundary.
  * ``keys`` is the sorted first-level index (padded with ``KEY_SENTINEL``),
    ``card`` the per-container cardinality counters (paper S2), ``kind`` the
    container type tag (0 empty / 1 array / 2 bitmap).

Set algebra runs the paper's *hybrid per-kind dispatch* (S4): key-aligned
container pairs are classified by ``(kind_a, kind_b)`` and routed through the
matching algorithm — vectorized galloping for array x array, bit probes for
array x bitmap (no domain lift), fused word-op + popcount for
bitmap x bitmap. On TPU the routing is a ``@pl.when``-tagged Pallas kernel
(``repro.kernels.roaring``) that *skips* the mismatched work per 8 kB tile;
the XLA reference computes the same three cheap paths masked. Output
canonicalization is *lazy*: only bitmap-domain rows that cross back under the
4096 threshold pay the O(2^16) ``row_bits_to_array`` extraction, and that
whole pass is ``lax.cond``-guarded so array-dominated workloads never touch
the 2^16-element domain at runtime. Cardinality is maintained with
``lax.population_count`` (the popcnt the paper leans on) fused into the same
pass, mirroring Algorithm 1/3. See DESIGN.md for the dispatch table.

All functions are jit-/vmap-/pjit-compatible and allocation-free at trace
time; capacities are static Python ints.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK_BITS = 16
CHUNK_SIZE = 1 << CHUNK_BITS
ARRAY_MAX = 4096                 # paper's array/bitmap threshold
ROW_WORDS = 4096                 # 4096 x u16 words = 2^16 bits = 8 kB
KEY_SENTINEL = jnp.int32(1 << 20)

KIND_EMPTY, KIND_ARRAY, KIND_BITMAP = 0, 1, 2


class RoaringSlab(NamedTuple):
    """Static-capacity Roaring bitmap. ``C = keys.shape[0]`` containers."""

    keys: jax.Array   # i32[C], sorted, inactive rows = KEY_SENTINEL
    card: jax.Array   # i32[C]
    kind: jax.Array   # i32[C] in {0,1,2}
    data: jax.Array   # u16[C, 4096]

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def n_containers(self) -> jax.Array:
        return jnp.sum(self.kind != KIND_EMPTY)

    @property
    def cardinality(self) -> jax.Array:
        """Sum of per-container counters (paper S2)."""
        return jnp.sum(self.card)


def empty(capacity: int) -> RoaringSlab:
    return RoaringSlab(
        keys=jnp.full((capacity,), KEY_SENTINEL, dtype=jnp.int32),
        card=jnp.zeros((capacity,), dtype=jnp.int32),
        kind=jnp.zeros((capacity,), dtype=jnp.int32),
        data=jnp.zeros((capacity, ROW_WORDS), dtype=jnp.uint16),
    )


# =============================================================================
# row-level helpers (one container)
# =============================================================================

def row_array_to_bits(row: jax.Array, card: jax.Array) -> jax.Array:
    """Packed sorted u16 array row -> 4096-word bitmap row.

    Distinct elements set distinct bits, so a scatter-add is an exact OR.
    """
    lo = row.astype(jnp.int32)
    valid = jnp.arange(row.shape[0]) < card
    word = jnp.where(valid, lo >> 4, ROW_WORDS)           # OOB index dropped
    bit = (lo & 15).astype(jnp.uint16)
    vals = jnp.where(valid, jnp.uint16(1) << bit, jnp.uint16(0))
    return jnp.zeros((ROW_WORDS,), jnp.uint16).at[word].add(
        vals, mode="drop")


def row_to_bits(row: jax.Array, card: jax.Array, kind: jax.Array) -> jax.Array:
    """Uniform bitmap-domain view of a container row (empty -> zeros)."""
    as_bits = row_array_to_bits(row, card)
    return jnp.where(kind == KIND_BITMAP, row, as_bits) * (kind != KIND_EMPTY).astype(jnp.uint16)


def row_popcount(bits: jax.Array) -> jax.Array:
    """Container cardinality via popcnt (paper Alg. 1 line 7)."""
    return jnp.sum(lax_popcount(bits).astype(jnp.int32))


def lax_popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)


def row_bits_to_array(bits: jax.Array) -> jax.Array:
    """Vectorized Algorithm 2: bitmap row -> packed sorted u16 array row.

    Per-word popcounts -> exclusive cumsum gives each word's write offset;
    bit positions are scattered to offset + rank-within-word. O(2^16) fully
    data-parallel (the TPU replacement for the serial ``w & -w`` loop).
    """
    # bits: u16[4096] -> per-bit boolean [4096, 16]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, None] >> shifts[None, :]) & jnp.uint16(1)).astype(jnp.int32)
    flat = bitmat.reshape(-1)                               # [65536] in value order
    pos = jnp.arange(CHUNK_SIZE, dtype=jnp.int32)
    rank = jnp.cumsum(flat) - flat                          # exclusive cumsum
    idx = jnp.where(flat == 1, rank, CHUNK_SIZE)            # OOB dropped
    out = jnp.zeros((ROW_WORDS,), jnp.uint16).at[idx].add(
        pos.astype(jnp.uint16), mode="drop")
    return out


def row_canonicalize(bits: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """bitmap-domain row -> canonical (data, card, kind) per the 4096 rule.

    Array rows are padded with 0xFFFF past ``card`` so the packed prefix plus
    padding stays globally sorted (binary-search friendly).
    """
    card = row_popcount(bits)
    as_array = row_bits_to_array(bits)
    as_array = jnp.where(jnp.arange(ROW_WORDS) < card, as_array,
                         jnp.uint16(0xFFFF))
    is_bitmap = card > ARRAY_MAX
    data = jnp.where(is_bitmap, bits, as_array)
    kind = jnp.where(card == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return data, card, kind


# =============================================================================
# construction / export
# =============================================================================

def from_indices(idx: jax.Array, valid: jax.Array, capacity: int) -> RoaringSlab:
    """Build a slab from (padded) *sorted unique* int32/int64 indices.

    ``idx``: i64/i32[M] sorted ascending with invalid entries at the end
    (``valid`` false). Elements sharing high 16 bits land in one container.
    Works with or without x64 (int32 universes cover every in-framework use:
    per-leaf gradient coordinates, block ids, page ids).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    idx = idx.astype(idt)
    M = idx.shape[0]
    sentinel = jnp.asarray(int(KEY_SENTINEL), idt)
    hi = jnp.where(valid, idx >> CHUNK_BITS, sentinel)
    lo = (idx & (CHUNK_SIZE - 1)).astype(jnp.int32)

    first = jnp.concatenate([jnp.array([True]), hi[1:] != hi[:-1]]) & valid
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1           # container id per elem
    seg = jnp.where(valid, seg, capacity)                   # drop invalid
    counts = jnp.zeros((capacity,), jnp.int32).at[seg].add(1, mode="drop")

    # container keys: first element of each segment
    keys = jnp.full((capacity,), sentinel, dtype=idt)
    keys = keys.at[jnp.where(first, seg, capacity)].min(
        jnp.where(first, hi, sentinel), mode="drop")
    keys = jnp.where(counts > 0, keys, sentinel).astype(jnp.int32)

    # array representation: rank within segment
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    rank = jnp.arange(M, dtype=jnp.int32) - seg_start[jnp.minimum(seg, capacity - 1)]
    arr_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    arr_data = arr_data.at[seg, jnp.where(valid, rank, ROW_WORDS)].add(
        lo.astype(jnp.uint16), mode="drop")

    # bitmap representation (scatter-add of distinct power-of-two bits)
    bit_data = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    bit_data = bit_data.at[seg, jnp.where(valid, lo >> 4, ROW_WORDS)].add(
        jnp.where(valid, jnp.uint16(1) << (lo & 15).astype(jnp.uint16),
                  jnp.uint16(0)), mode="drop")

    is_bitmap = counts > ARRAY_MAX
    # pad array rows with 0xFFFF past card so binary search stays valid
    arr_data = jnp.where(jnp.arange(ROW_WORDS)[None, :] < counts[:, None],
                         arr_data, jnp.uint16(0xFFFF))
    data = jnp.where(is_bitmap[:, None], bit_data, arr_data)
    kind = jnp.where(counts == 0, KIND_EMPTY,
                     jnp.where(is_bitmap, KIND_BITMAP, KIND_ARRAY))
    return RoaringSlab(keys=keys, card=counts, kind=kind, data=data)


def from_dense_array(values: np.ndarray, capacity: int, max_elems: int) -> RoaringSlab:
    """Host-side convenience: numpy values -> slab (pads to max_elems)."""
    v = np.unique(np.asarray(values, dtype=np.int64))
    assert v.size <= max_elems, (v.size, max_elems)
    idx = np.full((max_elems,), 0, dtype=np.int64)
    idx[: v.size] = v
    valid = np.zeros((max_elems,), dtype=bool)
    valid[: v.size] = True
    # keep padded tail sorted-after-valid by setting it to the max value
    if v.size:
        idx[v.size:] = v[-1]
    return from_indices(jnp.asarray(idx), jnp.asarray(valid), capacity)


def to_indices(slab: RoaringSlab, max_out: int) -> tuple[jax.Array, jax.Array]:
    """Slab -> (sorted values int[max_out], valid bool[max_out]).

    Uniform path: every row is viewed in bitmap domain, all C*2^16 candidate
    bits are compacted by exclusive cumsum (global Algorithm 2).
    """
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    bits = jax.vmap(row_to_bits)(slab.data, slab.card, slab.kind)   # u16[C,4096]
    shifts = jnp.arange(16, dtype=jnp.uint16)
    bitmat = ((bits[:, :, None] >> shifts[None, None, :]) & jnp.uint16(1))
    flat = bitmat.reshape(-1).astype(jnp.int32)             # [C*65536]
    # sentinel keys may wrap when shifted in int32 — harmless: their rows have
    # flat == 0 everywhere, so the wrapped values are multiplied away.
    base = (slab.keys.astype(idt) << CHUNK_BITS)
    vals = (base[:, None] + jnp.arange(CHUNK_SIZE, dtype=idt)[None, :]).reshape(-1)
    rank = jnp.cumsum(flat) - flat
    tgt = jnp.where(flat == 1, rank, max_out)
    out = jnp.zeros((max_out,), idt).at[tgt].add(vals * flat, mode="drop")
    total = jnp.sum(flat)
    valid = jnp.arange(max_out) < total
    return jnp.where(valid, out, 0), valid


def extract_row(slab: RoaringSlab, r, max_out: int = ARRAY_MAX):
    """Packed sorted values of container ``r`` (Alg. 2 on one row)."""
    bits = row_to_bits(slab.data[r], slab.card[r], slab.kind[r])
    arr = row_bits_to_array(bits)
    valid = jnp.arange(ROW_WORDS) < slab.card[r]
    return arr[:max_out], valid[:max_out]


# =============================================================================
# membership / rank
# =============================================================================

def contains(slab: RoaringSlab, queries: jax.Array) -> jax.Array:
    """Batched membership test (paper S3): first-level binary search, then
    array binary search or bitmap bit probe, selected by container kind.

    Bandwidth-lean: the bitmap path gathers only the one probed 16-bit word
    and the array path gathers one element per halving step (13 for a
    4096-wide window), instead of pulling the full 8 kB row per query into
    the vmap.
    """
    q = queries.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (q >> CHUNK_BITS).astype(jnp.int32)
    lo = (q & (CHUNK_SIZE - 1)).astype(jnp.int32)
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    key_hit = slab.keys[row_c] == hi

    def one(row_i, lo_i):
        card = slab.card[row_i]
        kind = slab.kind[row_i]
        # bitmap path: probe a single word
        word = slab.data[row_i, lo_i >> 4].astype(jnp.int32)
        bit_hit = ((word >> (lo_i & 15)) & 1) == 1
        # array path: binary search over the packed prefix, one gathered
        # element per step (log-bounded traffic; 0xFFFF padding keeps the
        # row globally sorted so the [0, card) window is safe). 13 steps:
        # lower_bound must shrink a window of up to 4096 to size 0, which
        # takes ceil(log2(4096)) + 1 halvings.
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            v = slab.data[row_i, jnp.clip(mid, 0, ROW_WORDS - 1)].astype(
                jnp.int32)
            go_right = v < lo_i
            return (jnp.where(go_right, mid + 1, l),
                    jnp.where(go_right, h, mid))

        l, _ = jax.lax.fori_loop(0, 13, body, (jnp.int32(0), card))
        probe = slab.data[row_i, jnp.clip(l, 0, ROW_WORDS - 1)].astype(
            jnp.int32)
        arr_hit = (l < card) & (probe == lo_i)
        return jnp.where(kind == KIND_BITMAP, bit_hit,
                         jnp.where(kind == KIND_ARRAY, arr_hit, False))

    hits = jax.vmap(one)(row_c, lo)
    return hits & key_hit


def rank(slab: RoaringSlab, x: jax.Array) -> jax.Array:
    """# elements <= x: whole-container counters + one partial container."""
    x = x.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    hi = (x >> CHUNK_BITS).astype(jnp.int32)
    lo = (x & (CHUNK_SIZE - 1)).astype(jnp.int32)
    full = jnp.sum(jnp.where(slab.keys < hi, slab.card, 0))
    row = jnp.searchsorted(slab.keys, hi)
    row_c = jnp.minimum(row, slab.capacity - 1)
    hit = slab.keys[row_c] == hi
    bits = row_to_bits(slab.data[row_c], slab.card[row_c], slab.kind[row_c])
    word_idx = lo >> 4
    mask_full = (jnp.arange(ROW_WORDS) < word_idx)
    partial_words = jnp.sum(lax_popcount(jnp.where(mask_full, bits, 0)).astype(jnp.int32))
    last = bits[word_idx] & ((jnp.uint16(2) << (lo & 15).astype(jnp.uint16)) - 1).astype(jnp.uint16)
    in_row = partial_words + lax_popcount(last).astype(jnp.int32)
    return full + jnp.where(hit, in_row, 0)


# =============================================================================
# set algebra: hybrid per-kind dispatch (paper S4)
#
# Key-aligned container pairs are classified by (kind_a, kind_b) and routed
# through the matching algorithm via repro.kernels.roaring (Pallas @pl.when
# on TPU, XLA reference elsewhere). Canonicalization is lazy: only
# bitmap-domain output rows that land back under the 4096 threshold pay the
# O(2^16) extraction, and the pass is lax.cond-guarded so it is skipped at
# runtime when no row needs it. The pre-dispatch bitmap-domain formulation is
# kept below as slab_*_bitmap_domain for A/B benchmarking and cross-checks.
# =============================================================================

def _pad_keys(keys: jax.Array, capacity: int) -> jax.Array:
    n = keys.shape[0]
    if capacity <= n:
        return keys[:capacity]
    return jnp.concatenate(
        [keys, jnp.full((capacity - n,), KEY_SENTINEL, jnp.int32)])


def _merge_keys(a: RoaringSlab, b: RoaringSlab, capacity: int) -> jax.Array:
    """Union of the two sorted key sets, deduplicated, padded with sentinel."""
    cat = jnp.concatenate([a.keys, b.keys])
    srt = jnp.sort(cat)
    dup = jnp.concatenate([jnp.array([False]), srt[1:] == srt[:-1]])
    vals = jnp.where(dup, KEY_SENTINEL, srt)
    vals = jnp.sort(vals)
    return _pad_keys(vals, capacity)


def _intersect_keys(a: RoaringSlab, b: RoaringSlab, capacity: int) -> jax.Array:
    """Keys present in *both* slabs (the only rows an AND can populate), so
    the dispatch grid is |A.keys ∩ B.keys| rows instead of the union."""
    pos = jnp.searchsorted(b.keys, a.keys)
    pos_c = jnp.minimum(pos, b.capacity - 1)
    hit = (b.keys[pos_c] == a.keys) & (a.keys != KEY_SENTINEL)
    vals = jnp.sort(jnp.where(hit, a.keys, KEY_SENTINEL))
    return _pad_keys(vals, capacity)


def _gather_raw(s: RoaringSlab, keys: jax.Array):
    """Raw rows of ``s`` aligned to ``keys`` — native container form, no
    bitmap-domain lift. Absent keys get (card=0, kind=EMPTY)."""
    pos = jnp.searchsorted(s.keys, keys)
    pos_c = jnp.minimum(pos, s.capacity - 1)
    present = (s.keys[pos_c] == keys) & (keys != KEY_SENTINEL)
    data = s.data[pos_c]
    card = jnp.where(present, s.card[pos_c], 0)
    kind = jnp.where(present, s.kind[pos_c], KIND_EMPTY)
    return data, card, kind


def _compact_row(vals: jax.Array, hit: jax.Array) -> jax.Array:
    """Scatter the hit subset of a packed row into a fresh packed sorted row
    (0xFFFF padded). O(4096), never touches the 2^16-element domain."""
    h = hit.astype(jnp.int32)
    rank = jnp.cumsum(h) - h
    idx = jnp.where(hit, rank, ROW_WORDS)
    return jnp.full((ROW_WORDS,), 0xFFFF, jnp.uint16).at[idx].set(
        vals, mode="drop")


def _rows_bits_to_array_lazy(bits: jax.Array, need: jax.Array,
                             card: jax.Array) -> jax.Array:
    """Lazy Algorithm 2 over rows: the O(2^16) extraction runs only when at
    least one row actually crosses back under the 4096 threshold; otherwise
    lax.cond skips the whole pass at runtime."""
    masked = jnp.where(need[:, None], bits, jnp.uint16(0))
    arrs = jax.lax.cond(
        jnp.any(need),
        lambda m: jax.vmap(row_bits_to_array)(m),
        lambda m: jnp.zeros_like(m),
        masked)
    return jnp.where(jnp.arange(ROW_WORDS)[None, :] < card[:, None],
                     arrs, jnp.uint16(0xFFFF))


def _assemble(keys, data, card):
    """Final slab assembly: kind from the 4096 rule, dead rows keyed out,
    rows re-sorted so live keys lead."""
    live = card > 0
    is_big = card > ARRAY_MAX
    kind = jnp.where(~live, KIND_EMPTY,
                     jnp.where(is_big, KIND_BITMAP, KIND_ARRAY))
    out_keys = jnp.where(live, keys, KEY_SENTINEL)
    order = jnp.argsort(out_keys)
    return RoaringSlab(keys=out_keys[order], card=jnp.where(live, card, 0)[order],
                       kind=kind[order], data=data[order])


def _dispatch_meta(ka, kb, ca, cb) -> jax.Array:
    """Interleave (kind_a, kind_b, card_a, card_b) per row -> i32[4C]."""
    return jnp.stack([ka, kb, ca, cb], axis=1).reshape(-1).astype(jnp.int32)


def slab_and(a: RoaringSlab, b: RoaringSlab,
             capacity: int | None = None) -> RoaringSlab:
    """Hybrid-dispatch intersection (paper S4 AND table).

    array x array -> vectorized galloping; array x bitmap -> bit probes;
    bitmap x bitmap -> fused word-AND + popcount (Alg. 3). Array-side outputs
    are provably <= min(card_a, card_b) <= 4096, so they compact straight to
    packed arrays — no bitmap round trip; only bitmap x bitmap rows that land
    under the threshold pay the (cond-guarded) Algorithm 2 extraction.
    """
    from repro.kernels.roaring import ops as _kops
    capacity = capacity or min(a.capacity, b.capacity)
    keys = _intersect_keys(a, b, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    hits, card = _kops.intersect_dispatch(da, db, _dispatch_meta(ka, kb, ca, cb))
    bb = (ka == KIND_BITMAP) & (kb == KIND_BITMAP)
    ba = (ka == KIND_BITMAP) & (kb == KIND_ARRAY)
    src = jnp.where(ba[:, None], db, da)          # hits index the array side
    arr_rows = jax.vmap(_compact_row)(src, (hits == 1) & ~bb[:, None])
    need_dc = bb & (card > 0) & (card <= ARRAY_MAX)
    dc_rows = _rows_bits_to_array_lazy(hits, need_dc, card)
    data = jnp.where((card > ARRAY_MAX)[:, None], hits,
                     jnp.where(need_dc[:, None], dc_rows, arr_rows))
    return _assemble(keys, data, card)


def slab_and_card(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∩ B| without materializing a result slab (Alg. 3 line 5 for free:
    the dispatch kernel's fused popcount/hit-count is the entire answer)."""
    from repro.kernels.roaring import ops as _kops
    keys = _intersect_keys(a, b, min(a.capacity, b.capacity))
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    _, card = _kops.intersect_dispatch(da, db, _dispatch_meta(ka, kb, ca, cb))
    return jnp.sum(card)


def slab_or_card(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∪ B| via inclusion-exclusion on the per-container counters."""
    return a.cardinality + b.cardinality - slab_and_card(a, b)


def slab_jaccard(a: RoaringSlab, b: RoaringSlab) -> jax.Array:
    """|A ∩ B| / |A ∪ B| in one dispatch pass (0 when both empty)."""
    inter = slab_and_card(a, b)
    union = a.cardinality + b.cardinality - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0)


def stack_slabs(slabs: list[RoaringSlab]) -> RoaringSlab:
    """Stack same-capacity slabs into one batched (leading-axis) slab."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *slabs)


def slab_and_many(query: RoaringSlab, slabs: list[RoaringSlab],
                  unroll: bool = False) -> RoaringSlab:
    """Batched ``query ∩ slab_i`` over a fleet of same-capacity slabs.

    Default is one vmapped dispatch (single fused launch) — note that vmap
    lowers the lax.cond laziness guards to select, so the down-conversion
    pass runs for every batch element. ``unroll=True`` traces each pair
    separately (compile time grows with the fleet) but keeps the runtime
    laziness per slab — prefer it for large fleets of array-dominated slabs.
    """
    if unroll:
        return stack_slabs([slab_and(query, s) for s in slabs])
    return jax.vmap(lambda s: slab_and(query, s))(stack_slabs(slabs))


def slab_and_card_many(query: RoaringSlab,
                       slabs: list[RoaringSlab]) -> jax.Array:
    """Batched intersection cardinalities — the query-engine primitive
    (score many posting lists against one query without materializing).
    Cond-free, so vmap costs nothing extra."""
    stacked = stack_slabs(slabs)
    return jax.vmap(lambda s: slab_and_card(query, s))(stacked)


def _lift_rows(data, card, kind):
    return jax.vmap(row_to_bits)(data, card, kind)


def _row_merge_sparse(da, ca, db, cb, *, xor: bool):
    """Array x array union/xor by sorted merge of the two packed prefixes —
    O(8192 log), stays entirely in array domain. Only meaningful when
    card_a + card_b <= 4096 (caller guarantees via the pair class)."""
    INVALID = jnp.int32(1) << 17
    slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)
    ia = jnp.where(slot < ca, da.astype(jnp.int32), INVALID)
    ib = jnp.where(slot < cb, db.astype(jnp.int32), INVALID)
    cat = jnp.sort(jnp.concatenate([ia, ib]))
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), cat[:-1]])
    nxt = jnp.concatenate([cat[1:], jnp.full((1,), -2, jnp.int32)])
    first = cat != prev
    keep = first & (cat < INVALID)
    if xor:
        keep = keep & (cat != nxt)
    h = keep.astype(jnp.int32)
    rank = jnp.cumsum(h) - h
    idx = jnp.where(keep, rank, 2 * ROW_WORDS)
    row = jnp.full((ROW_WORDS,), 0xFFFF, jnp.uint16).at[idx].set(
        cat.astype(jnp.uint16), mode="drop")
    return row, jnp.sum(h)


def _union_like(a: RoaringSlab, b: RoaringSlab, capacity: int,
                word_op, xor: bool) -> RoaringSlab:
    """Shared OR/XOR pipeline: sparse array pairs merge in array domain,
    everything else goes through the bitmap domain. Both passes (and the
    down-conversion) are lax.cond-guarded symmetrically, so an all-array
    workload never lifts and an all-bitmap workload never sorts."""
    keys = _merge_keys(a, b, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    arrayish = (ka != KIND_BITMAP) & (kb != KIND_BITMAP)
    small = arrayish & (ca + cb <= ARRAY_MAX)
    use_bitmap = ~small & ((ka != KIND_EMPTY) | (kb != KIND_EMPTY))

    def merge_pass(args):
        da, ca, db, cb = args
        return jax.vmap(
            functools.partial(_row_merge_sparse, xor=xor))(da, ca, db, cb)

    def merge_skip(args):
        return (jnp.full((keys.shape[0], ROW_WORDS), 0xFFFF, jnp.uint16),
                jnp.zeros((keys.shape[0],), jnp.int32))

    merge_rows, merge_card = jax.lax.cond(jnp.any(small), merge_pass,
                                          merge_skip, (da, ca, db, cb))

    def bitmap_pass(args):
        da, ca, ka, db, cb, kb = args
        out = word_op(_lift_rows(da, ca, ka), _lift_rows(db, cb, kb))
        return out, jax.vmap(row_popcount)(out)

    def skip(args):
        return (jnp.zeros((keys.shape[0], ROW_WORDS), jnp.uint16),
                jnp.zeros((keys.shape[0],), jnp.int32))

    bits, bcard = jax.lax.cond(jnp.any(use_bitmap), bitmap_pass, skip,
                               (da, ca, ka, db, cb, kb))
    card = jnp.where(use_bitmap, bcard, merge_card)
    need_dc = use_bitmap & (card > 0) & (card <= ARRAY_MAX)
    dc_rows = _rows_bits_to_array_lazy(bits, need_dc, card)
    data = jnp.where((card > ARRAY_MAX)[:, None], bits,
                     jnp.where(need_dc[:, None], dc_rows, merge_rows))
    return _assemble(keys, data, card)


def slab_or(a: RoaringSlab, b: RoaringSlab,
            capacity: int | None = None) -> RoaringSlab:
    return _union_like(a, b, capacity or (a.capacity + b.capacity),
                       jnp.bitwise_or, xor=False)


def slab_xor(a: RoaringSlab, b: RoaringSlab,
             capacity: int | None = None) -> RoaringSlab:
    return _union_like(a, b, capacity or (a.capacity + b.capacity),
                       jnp.bitwise_xor, xor=True)


def slab_andnot(a: RoaringSlab, b: RoaringSlab,
                capacity: int | None = None) -> RoaringSlab:
    """A \\ B with per-kind dispatch: array-A rows probe B directly (result
    provably <= card_a <= 4096, stays array); only bitmap-A rows go through
    the (cond-guarded) bitmap domain."""
    capacity = capacity or a.capacity
    keys = _pad_keys(a.keys, capacity)
    da, ca, ka = _gather_raw(a, keys)
    db, cb, kb = _gather_raw(b, keys)
    slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)

    def probe_row(dav, cav, dbv, cbv, kbv):
        pos = jnp.searchsorted(dbv, dav)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        arr_in = (dbv[pos_c] == dav) & (pos < cbv)
        v = dav.astype(jnp.int32)
        word = dbv[v >> 4].astype(jnp.int32)
        bit_in = ((word >> (v & 15)) & 1) == 1
        in_b = jnp.where(kbv == KIND_BITMAP, bit_in,
                         jnp.where(kbv == KIND_ARRAY, arr_in, False))
        return (slot < cav) & ~in_b

    keep = jax.vmap(probe_row)(da, ca, db, cb, kb) & (ka == KIND_ARRAY)[:, None]
    arr_rows = jax.vmap(_compact_row)(da, keep)
    acard = jnp.sum(keep.astype(jnp.int32), axis=1)
    a_bmp = ka == KIND_BITMAP

    def bitmap_pass(args):
        da, ca, ka, db, cb, kb = args
        out = jnp.bitwise_and(_lift_rows(da, ca, ka),
                              ~_lift_rows(db, cb, kb))
        return out, jax.vmap(row_popcount)(out)

    def skip(args):
        return (jnp.zeros((keys.shape[0], ROW_WORDS), jnp.uint16),
                jnp.zeros((keys.shape[0],), jnp.int32))

    bits, bcard = jax.lax.cond(jnp.any(a_bmp), bitmap_pass, skip,
                               (da, ca, ka, db, cb, kb))
    card = jnp.where(a_bmp, bcard, acard)
    need_dc = a_bmp & (card > 0) & (card <= ARRAY_MAX)
    dc_rows = _rows_bits_to_array_lazy(bits, need_dc, card)
    data = jnp.where((card > ARRAY_MAX)[:, None], bits,
                     jnp.where(need_dc[:, None], dc_rows, arr_rows))
    return _assemble(keys, data, card)


# =============================================================================
# legacy bitmap-domain path (pre-dispatch) — A/B baseline + cross-check
# =============================================================================

def _gather_rows(s: RoaringSlab, keys: jax.Array):
    """Bitmap-domain rows of ``s`` aligned to ``keys`` (zeros when absent)."""
    pos = jnp.searchsorted(s.keys, keys)
    pos_c = jnp.minimum(pos, s.capacity - 1)
    present = (s.keys[pos_c] == keys) & (keys != KEY_SENTINEL)
    bits = jax.vmap(row_to_bits)(s.data[pos_c], s.card[pos_c], s.kind[pos_c])
    return bits * present[:, None].astype(jnp.uint16), present


def _binary_bits_op(a: RoaringSlab, b: RoaringSlab, word_op, capacity: int,
                    intersection: bool) -> RoaringSlab:
    """Pre-dispatch formulation: lift every row to the 2^16-bit domain,
    apply the word op, re-canonicalize every output row. Pays the full
    bitmap-domain tax regardless of container kinds — kept only so the
    benchmarks can measure what the dispatch path saves."""
    if capacity is None:
        capacity = a.capacity + b.capacity
    keys = _merge_keys(a, b, capacity)
    bits_a, pa = _gather_rows(a, keys)
    bits_b, pb = _gather_rows(b, keys)
    out_bits = word_op(bits_a, bits_b)
    data, card, kind = jax.vmap(row_canonicalize)(out_bits)
    live = card > 0
    if intersection:
        live = live & pa & pb
        card = jnp.where(live, card, 0)
        kind = jnp.where(live, kind, KIND_EMPTY)
    keys = jnp.where(live, keys, KEY_SENTINEL)
    # compact: sort rows so live keys are sorted first (sentinel rows sink)
    order = jnp.argsort(keys)
    return RoaringSlab(keys=keys[order], card=card[order], kind=kind[order],
                       data=data[order])


def slab_and_bitmap_domain(a: RoaringSlab, b: RoaringSlab,
                           capacity: int | None = None) -> RoaringSlab:
    return _binary_bits_op(a, b, jnp.bitwise_and,
                           capacity or min(a.capacity, b.capacity) * 2,
                           intersection=True)


def slab_or_bitmap_domain(a: RoaringSlab, b: RoaringSlab,
                          capacity: int | None = None) -> RoaringSlab:
    return _binary_bits_op(a, b, jnp.bitwise_or,
                           capacity or (a.capacity + b.capacity),
                           intersection=False)


def union_many_slabs(slabs: list[RoaringSlab], capacity: int) -> RoaringSlab:
    """Algorithm 4, TPU form: key-aligned segmented OR-reduction in bitmap
    domain with cardinality computed once at the end (deferred popcount).
    The final array extraction is the cond-guarded lazy pass."""
    all_keys = jnp.concatenate([s.keys for s in slabs])
    srt = jnp.sort(all_keys)
    dup = jnp.concatenate([jnp.array([False]), srt[1:] == srt[:-1]])
    keys = _pad_keys(jnp.sort(jnp.where(dup, KEY_SENTINEL, srt)), capacity)
    acc = jnp.zeros((capacity, ROW_WORDS), jnp.uint16)
    for s in slabs:                                   # static unroll (fleet size)
        bits, _ = _gather_rows(s, keys)
        acc = jnp.bitwise_or(acc, bits)               # deferred cardinality
    card = jax.vmap(row_popcount)(acc)
    need_dc = (card > 0) & (card <= ARRAY_MAX)
    arr_rows = _rows_bits_to_array_lazy(acc, need_dc, card)
    data = jnp.where((card > ARRAY_MAX)[:, None], acc, arr_rows)
    return _assemble(keys, data, card)
