"""Paper-faithful Roaring bitmap (Chambi, Lemire, Kaser, Godin 2014).

This module is the *reproduction floor*: a CPU implementation that follows the
paper's data layout and Algorithms 1-4 exactly:

  * two-level index: sorted 16-bit keys -> containers of the low 16 bits;
  * array containers (sorted packed u16, card <= 4096) vs bitmap containers
    (2^16-bit bitmap as 1024 x u64, card > 4096);
  * per-container cardinality counters;
  * hybrid AND/OR per container-type pair, including the cardinality-first
    bitmap AND (Alg. 3), fused popcount union (Alg. 1), galloping array
    intersection with the 64x ratio rule, and the union-through-bitmap rule;
  * Alg. 2 set-bit extraction (both the faithful ``w & -w`` loop and a
    vectorized equivalent);
  * Alg. 4 many-way union with a key min-heap and deferred cardinality.

NumPy stands in for 64-bit words + popcnt (``np.bitwise_count``), mirroring
how the paper's Java implementation leans on ``Long.bitCount``.

The TPU-native static-shape port lives in ``jax_roaring.py``; kernels in
``repro.kernels.roaring``.
"""

from __future__ import annotations

import heapq
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

# --- constants from the paper ------------------------------------------------
CHUNK_BITS = 16
CHUNK_SIZE = 1 << CHUNK_BITS              # 2^16 integers per chunk
ARRAY_MAX = 4096                          # array container max cardinality
BITMAP_WORDS = CHUNK_SIZE // 64           # 1024 x u64 words per bitmap container
GALLOP_RATIO = 64                         # merge vs galloping threshold (S4)

_U16 = np.uint16
_U64 = np.uint64


# =============================================================================
# Word-level primitives (Algorithm 2 and friends)
# =============================================================================

def popcount_words(words: np.ndarray) -> int:
    """Hamming weight of a word array — the paper's popcnt/Long.bitCount."""
    return int(np.bitwise_count(words).sum())


def extract_set_bits_faithful(w: int, base: int, out: List[int]) -> None:
    """Algorithm 2, verbatim: emit positions of set bits in one 64-bit word.

    Uses two's-complement tricks ``t = w & -w`` (isolate lowest bit) and
    ``w &= w - 1`` (clear lowest bit); cf. Warren, Hacker's Delight.
    """
    w &= (1 << 64) - 1
    while w != 0:
        t = w & (-w & ((1 << 64) - 1))
        out.append(base + int(t - 1).bit_count())
        w &= w - 1


def bitmap_to_array_faithful(words: np.ndarray) -> np.ndarray:
    """Convert bitmap words to a sorted u16 array via Algorithm 2 (loop form)."""
    out: List[int] = []
    for i, w in enumerate(words.tolist()):
        if w:
            extract_set_bits_faithful(int(w), i * 64, out)
    return np.asarray(out, dtype=_U16)


def bitmap_to_array(words: np.ndarray) -> np.ndarray:
    """Vectorized Algorithm 2: positions of all set bits, ascending.

    Equivalent output to the faithful loop; uses byte unpacking + nonzero,
    which is the numpy analogue of extracting with popcount offsets.
    """
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0].astype(_U16)


def array_to_bitmap(arr: np.ndarray) -> np.ndarray:
    """Set the bits of a sorted u16 array in a fresh 1024-word bitmap."""
    words = np.zeros(BITMAP_WORDS, dtype=_U64)
    a = arr.astype(np.int64)
    np.bitwise_or.at(words, a >> 6, (_U64(1) << (a & 63).astype(_U64)))
    return words


# =============================================================================
# Containers
# =============================================================================

class ArrayContainer:
    """Sorted packed array of 16-bit integers, cardinality <= 4096."""

    __slots__ = ("arr",)

    def __init__(self, arr: Optional[np.ndarray] = None):
        self.arr = (
            np.empty(0, dtype=_U16) if arr is None else np.asarray(arr, dtype=_U16)
        )

    @property
    def cardinality(self) -> int:
        return int(self.arr.size)

    def size_in_bytes(self) -> int:
        return 2 * self.arr.size  # 16 bits per integer

    def contains(self, x: int) -> bool:
        i = int(np.searchsorted(self.arr, _U16(x)))
        return i < self.arr.size and int(self.arr[i]) == x

    def clone(self) -> "ArrayContainer":
        return ArrayContainer(self.arr.copy())

    def add(self, x: int) -> "Container":
        """Binary search + linear-time insertion; convert at >4096 (S3)."""
        i = int(np.searchsorted(self.arr, _U16(x)))
        if i < self.arr.size and int(self.arr[i]) == x:
            return self
        self.arr = np.insert(self.arr, i, _U16(x))
        if self.arr.size > ARRAY_MAX:
            return BitmapContainer(array_to_bitmap(self.arr), self.arr.size)
        return self

    def remove(self, x: int) -> "Container":
        i = int(np.searchsorted(self.arr, _U16(x)))
        if i < self.arr.size and int(self.arr[i]) == x:
            self.arr = np.delete(self.arr, i)
        return self

    def to_array(self) -> np.ndarray:
        return self.arr

    def iter_values(self) -> Iterator[int]:
        return iter(self.arr.tolist())


class BitmapContainer:
    """2^16-bit bitmap (1024 x u64) with a tracked cardinality counter."""

    __slots__ = ("words", "cardinality")

    def __init__(self, words: Optional[np.ndarray] = None, cardinality: int = -1):
        self.words = (
            np.zeros(BITMAP_WORDS, dtype=_U64)
            if words is None
            else np.asarray(words, dtype=_U64)
        )
        self.cardinality = (
            popcount_words(self.words) if cardinality < 0 else int(cardinality)
        )

    def size_in_bytes(self) -> int:
        return 8 * BITMAP_WORDS  # always 8 kB

    def contains(self, x: int) -> bool:
        return bool((int(self.words[x >> 6]) >> (x & 63)) & 1)

    def clone(self) -> "BitmapContainer":
        return BitmapContainer(self.words.copy(), self.cardinality)

    def add(self, x: int) -> "Container":
        w = int(self.words[x >> 6])
        bit = 1 << (x & 63)
        if not (w & bit):
            self.words[x >> 6] = _U64(w | bit)
            self.cardinality += 1
        return self

    def remove(self, x: int) -> "Container":
        """Clear a bit; convert to array when cardinality reaches 4096 (S3)."""
        w = int(self.words[x >> 6])
        bit = 1 << (x & 63)
        if w & bit:
            self.words[x >> 6] = _U64(w & ~bit)
            self.cardinality -= 1
            if self.cardinality <= ARRAY_MAX:
                return ArrayContainer(bitmap_to_array(self.words))
        return self

    def to_array(self) -> np.ndarray:
        return bitmap_to_array(self.words)

    def iter_values(self) -> Iterator[int]:
        return iter(self.to_array().tolist())


Container = Union[ArrayContainer, BitmapContainer]


def _maybe_to_array(c: BitmapContainer) -> Container:
    if c.cardinality <= ARRAY_MAX:
        return ArrayContainer(bitmap_to_array(c.words))
    return c


# =============================================================================
# Container-pair logical operations (paper S4)
# =============================================================================

def union_bitmap_bitmap(a: BitmapContainer, b: BitmapContainer) -> BitmapContainer:
    """Algorithm 1: 1024 ORs with fused popcount; result stays a bitmap
    (cardinality >= max(|A|,|B|) > 4096)."""
    words = np.bitwise_or(a.words, b.words)
    return BitmapContainer(words, popcount_words(words))


def union_bitmap_bitmap_inplace(a: BitmapContainer, b: BitmapContainer) -> BitmapContainer:
    """In-place variant (S4): overwrite A, skip cardinality until asked."""
    np.bitwise_or(a.words, b.words, out=a.words)
    a.cardinality = popcount_words(a.words)
    return a


def intersect_bitmap_bitmap(a: BitmapContainer, b: BitmapContainer) -> Container:
    """Algorithm 3: compute cardinality first with 1024 ANDs + popcount, then
    materialize a bitmap (card > 4096) or extract an array (Alg. 2)."""
    anded = np.bitwise_and(a.words, b.words)
    c = popcount_words(anded)
    if c > ARRAY_MAX:
        return BitmapContainer(anded, c)
    return ArrayContainer(bitmap_to_array(anded))


def union_array_bitmap(a: ArrayContainer, b: BitmapContainer) -> BitmapContainer:
    """Clone the bitmap and set the array's bits (S4 Bitmap vs Array)."""
    out = b.clone()
    idx = a.arr.astype(np.int64)
    words = out.words
    # cardinality update by counting newly-set bits (paper: check whether the
    # word value was modified); array elements are unique, so the number of
    # new bits is the number of elements not already present.
    present = (words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
    np.bitwise_or.at(words, idx >> 6, (_U64(1) << (idx & 63).astype(_U64)))
    out.cardinality = b.cardinality + int(idx.size - int(present.sum()))
    return out


def intersect_array_bitmap(a: ArrayContainer, b: BitmapContainer) -> ArrayContainer:
    """Probe each array element against the bitmap (S4); output is an array
    (cannot exceed |A| <= 4096)."""
    idx = a.arr.astype(np.int64)
    hits = (b.words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
    return ArrayContainer(a.arr[hits.astype(bool)])


def _merge_intersect(small: np.ndarray, large: np.ndarray) -> np.ndarray:
    """Vectorized sorted-merge intersection (the paper's merge path)."""
    pos = np.searchsorted(large, small)
    pos_clipped = np.minimum(pos, large.size - 1)
    mask = (pos < large.size) & (large[pos_clipped] == small)
    return small[mask]


def galloping_intersect_faithful(r: np.ndarray, f: np.ndarray) -> np.ndarray:
    """Faithful galloping (S4): for each r_i, exponential search in f then
    binary search — skips comparisons when |r| << |f|."""
    out: List[int] = []
    j = 0
    fl = f.tolist()
    n = len(fl)
    for ri in r.tolist():
        # exponential (galloping) phase
        step = 1
        lo = j
        hi = j + 1
        while hi < n and fl[hi] < ri:
            lo = hi
            hi = min(n, hi + step)
            step <<= 1
        # binary search phase in (lo, hi]
        hi = min(hi, n - 1)
        import bisect

        j = bisect.bisect_left(fl, ri, lo, min(hi + 1, n))
        if j < n and fl[j] == ri:
            out.append(ri)
    return np.asarray(out, dtype=_U16)


def intersect_array_array(a: ArrayContainer, b: ArrayContainer) -> ArrayContainer:
    """Merge when cardinalities within 64x, galloping otherwise (S4).

    Production path uses vectorized binary search for both regimes (numpy's
    searchsorted); `galloping_intersect_faithful` preserves the paper's exact
    control flow for validation.
    """
    small, large = (a.arr, b.arr) if a.arr.size <= b.arr.size else (b.arr, a.arr)
    if small.size == 0:
        return ArrayContainer()
    return ArrayContainer(_merge_intersect(small, large))


def union_array_array(a: ArrayContainer, b: ArrayContainer) -> Container:
    """S4 Array vs Array union: merge when sum <= 4096; otherwise set bits in
    a bitmap, popcount, and convert back down if the true card <= 4096."""
    total = a.arr.size + b.arr.size
    if total <= ARRAY_MAX:
        return ArrayContainer(np.union1d(a.arr, b.arr).astype(_U16))
    words = array_to_bitmap(a.arr)
    idx = b.arr.astype(np.int64)
    np.bitwise_or.at(words, idx >> 6, (_U64(1) << (idx & 63).astype(_U64)))
    c = popcount_words(words)
    if c <= ARRAY_MAX:
        return ArrayContainer(bitmap_to_array(words))
    return BitmapContainer(words, c)


def container_or(a: Container, b: Container) -> Container:
    if isinstance(a, BitmapContainer):
        if isinstance(b, BitmapContainer):
            return union_bitmap_bitmap(a, b)
        return union_array_bitmap(b, a)
    if isinstance(b, BitmapContainer):
        return union_array_bitmap(a, b)
    return union_array_array(a, b)


def container_and(a: Container, b: Container) -> Container:
    if isinstance(a, BitmapContainer):
        if isinstance(b, BitmapContainer):
            return intersect_bitmap_bitmap(a, b)
        return intersect_array_bitmap(b, a)
    if isinstance(b, BitmapContainer):
        return intersect_array_bitmap(a, b)
    return intersect_array_array(a, b)


def container_xor(a: Container, b: Container) -> Container:
    """XOR (extension — the paper focuses on AND/OR; needed by the framework
    for mask algebra). Same dense/sparse materialization discipline."""
    wa = a.words if isinstance(a, BitmapContainer) else array_to_bitmap(a.arr)
    wb = b.words if isinstance(b, BitmapContainer) else array_to_bitmap(b.arr)
    words = np.bitwise_xor(wa, wb)
    c = popcount_words(words)
    if c > ARRAY_MAX:
        return BitmapContainer(words, c)
    return ArrayContainer(bitmap_to_array(words))


def container_andnot(a: Container, b: Container) -> Container:
    """A AND NOT B (extension; used for e.g. KV-page reclamation)."""
    if isinstance(a, ArrayContainer):
        if isinstance(b, BitmapContainer):
            idx = a.arr.astype(np.int64)
            hits = (b.words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
            return ArrayContainer(a.arr[~hits.astype(bool)])
        pos = np.searchsorted(b.arr, a.arr)
        pos_c = np.minimum(pos, max(b.arr.size - 1, 0))
        if b.arr.size == 0:
            return ArrayContainer(a.arr.copy())
        mask = (pos < b.arr.size) & (b.arr[pos_c] == a.arr)
        return ArrayContainer(a.arr[~mask])
    wb = b.words if isinstance(b, BitmapContainer) else array_to_bitmap(b.arr)
    words = np.bitwise_and(a.words, np.bitwise_not(wb))
    c = popcount_words(words)
    if c > ARRAY_MAX:
        return BitmapContainer(words, c)
    return ArrayContainer(bitmap_to_array(words))


# =============================================================================
# RoaringBitmap: the two-level index (paper S2-S4)
# =============================================================================

class RoaringBitmap:
    """Sorted first-level key array + containers, per the paper.

    Functional-style constructors (`from_array`) plus the mutating single-
    element `add`/`remove` used by the paper's Fig. 2e/2f benchmarks.
    """

    __slots__ = ("keys", "containers")

    def __init__(self, keys: Optional[List[int]] = None,
                 containers: Optional[List[Container]] = None):
        self.keys: List[int] = keys if keys is not None else []
        self.containers: List[Container] = containers if containers is not None else []

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_array(cls, values: Iterable[int]) -> "RoaringBitmap":
        v = np.asarray(sorted(set(int(x) for x in values)), dtype=np.int64)
        return cls.from_sorted_unique(v)

    @classmethod
    def from_sorted_unique(cls, v: np.ndarray) -> "RoaringBitmap":
        """Bulk build: segment by high 16 bits, choose container type by the
        4096 rule."""
        rb = cls()
        if v.size == 0:
            return rb
        v = np.asarray(v, dtype=np.int64)
        hi = v >> CHUNK_BITS
        lo = (v & (CHUNK_SIZE - 1)).astype(_U16)
        boundaries = np.nonzero(np.diff(hi))[0] + 1
        starts = np.concatenate(([0], boundaries))
        ends = np.concatenate((boundaries, [v.size]))
        for s, e in zip(starts.tolist(), ends.tolist()):
            key = int(hi[s])
            chunk = lo[s:e]
            if chunk.size > ARRAY_MAX:
                rb.keys.append(key)
                rb.containers.append(
                    BitmapContainer(array_to_bitmap(chunk), chunk.size))
            else:
                rb.keys.append(key)
                rb.containers.append(ArrayContainer(chunk.copy()))
        return rb

    # -- access operations (paper S3) ------------------------------------------
    def _find_key(self, key: int) -> int:
        """Binary search the first-level index; returns position or -pos-1."""
        import bisect

        i = bisect.bisect_left(self.keys, key)
        if i < len(self.keys) and self.keys[i] == key:
            return i
        return -i - 1

    def contains(self, x: int) -> bool:
        i = self._find_key(x >> CHUNK_BITS)
        if i < 0:
            return False
        return self.containers[i].contains(x & (CHUNK_SIZE - 1))

    __contains__ = contains

    def add(self, x: int) -> None:
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        i = self._find_key(key)
        if i >= 0:
            self.containers[i] = self.containers[i].add(low)
        else:
            pos = -i - 1
            self.keys.insert(pos, key)
            self.containers.insert(pos, ArrayContainer(np.asarray([low], dtype=_U16)))

    def remove(self, x: int) -> None:
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        i = self._find_key(key)
        if i < 0:
            return
        c = self.containers[i].remove(low)
        if c.cardinality == 0:
            del self.keys[i]
            del self.containers[i]
        else:
            self.containers[i] = c

    # -- aggregate queries (paper S2) -------------------------------------------
    @property
    def cardinality(self) -> int:
        """Sum of at most ceil(n / 2^16) per-container counters."""
        return sum(c.cardinality for c in self.containers)

    def __len__(self) -> int:
        return self.cardinality

    def rank(self, x: int) -> int:
        """# of set entries <= x: whole-container counters + one partial."""
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        total = 0
        for k, c in zip(self.keys, self.containers):
            if k < key:
                total += c.cardinality
            elif k == key:
                if isinstance(c, ArrayContainer):
                    total += int(np.searchsorted(c.arr, _U16(low), side="right"))
                else:
                    full_words = low >> 6
                    total += popcount_words(c.words[:full_words])
                    rem = (low & 63) + 1
                    total += int(int(c.words[full_words]) & ((1 << rem) - 1)).bit_count()
            else:
                break
        return total

    def select(self, j: int) -> int:
        """Value of the j-th (0-based) smallest element."""
        if j < 0 or j >= self.cardinality:
            raise IndexError(j)
        for k, c in zip(self.keys, self.containers):
            if j < c.cardinality:
                if isinstance(c, ArrayContainer):
                    return (k << CHUNK_BITS) | int(c.arr[j])
                return (k << CHUNK_BITS) | int(c.to_array()[j])
            j -= c.cardinality
        raise AssertionError("unreachable")

    # -- binary logical operations (paper S4 first-level merge) -----------------
    #
    # The paper merges the two sorted first-level arrays in O(n1 + n2) integer
    # comparisons; in numpy the same merge is done with vectorized sorted-set
    # routines so that per-container *python* overhead is only paid for keys
    # that actually produce work (all keys for OR, matching keys for AND).
    def _binary_op(self, other: "RoaringBitmap", op, union_keys: bool) -> "RoaringBitmap":
        out = RoaringBitmap()
        ka = np.asarray(self.keys, dtype=np.int64)
        kb = np.asarray(other.keys, dtype=np.int64)
        if not union_keys:
            common, ia, ib = np.intersect1d(ka, kb, assume_unique=True,
                                            return_indices=True)
            for k, i, j in zip(common.tolist(), ia.tolist(), ib.tolist()):
                c = op(self.containers[i], other.containers[j])
                if c.cardinality > 0:
                    out.keys.append(k)
                    out.containers.append(c)
            return out
        union = np.union1d(ka, kb)
        pa = np.searchsorted(ka, union)
        pb = np.searchsorted(kb, union)
        in_a = (pa < ka.size) & (ka[np.minimum(pa, max(ka.size - 1, 0))] == union) \
            if ka.size else np.zeros(union.size, dtype=bool)
        in_b = (pb < kb.size) & (kb[np.minimum(pb, max(kb.size - 1, 0))] == union) \
            if kb.size else np.zeros(union.size, dtype=bool)
        for k, i, j, a_has, b_has in zip(union.tolist(), pa.tolist(), pb.tolist(),
                                         in_a.tolist(), in_b.tolist()):
            if a_has and b_has:
                c = op(self.containers[i], other.containers[j])
            elif a_has:
                c = self.containers[i].clone()
            else:
                c = other.containers[j].clone()
            if c.cardinality > 0:
                out.keys.append(k)
                out.containers.append(c)
        return out

    def __and__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_and, union_keys=False)

    def __or__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_or, union_keys=True)

    def __xor__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_xor, union_keys=True)

    def andnot(self, other: "RoaringBitmap") -> "RoaringBitmap":
        out = RoaringBitmap()
        j = 0
        for k, c in zip(self.keys, self.containers):
            i = other._find_key(k)
            if i < 0:
                out.keys.append(k)
                out.containers.append(c.clone())
            else:
                r = container_andnot(c, other.containers[i])
                if r.cardinality > 0:
                    out.keys.append(k)
                    out.containers.append(r)
        return out

    # -- in-place union (S4 in-place variants) ----------------------------------
    def ior(self, other: "RoaringBitmap") -> "RoaringBitmap":
        """Self |= other, modifying bitmap containers in place when possible."""
        i = j = 0
        n2 = len(other.keys)
        while j < n2:
            k2 = other.keys[j]
            if i >= len(self.keys) or self.keys[i] > k2:
                self.keys.insert(i, k2)
                self.containers.insert(i, other.containers[j].clone())
                i += 1
                j += 1
            elif self.keys[i] < k2:
                i += 1
            else:
                a, b = self.containers[i], other.containers[j]
                if isinstance(a, BitmapContainer) and isinstance(b, BitmapContainer):
                    self.containers[i] = union_bitmap_bitmap_inplace(a, b)
                else:
                    self.containers[i] = container_or(a, b)
                i += 1
                j += 1
        return self

    # -- export -----------------------------------------------------------------
    def to_array(self) -> np.ndarray:
        parts = []
        for k, c in zip(self.keys, self.containers):
            parts.append((k << CHUNK_BITS) + c.to_array().astype(np.int64))
        if not parts:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(parts)

    def iter_values(self) -> Iterator[int]:
        for k, c in zip(self.keys, self.containers):
            base = k << CHUNK_BITS
            for v in c.iter_values():
                yield base + v

    # -- size accounting (bits/item experiments) ---------------------------------
    def size_in_bytes(self) -> int:
        """Serialized size: 4 bytes/container header (16-bit key + 16-bit
        cardinality) + container payloads + 8-byte index header."""
        total = 8 + 4 * len(self.containers)
        for c in self.containers:
            total += c.size_in_bytes()
        return total

    def container_stats(self) -> Tuple[int, int]:
        n_arr = sum(1 for c in self.containers if isinstance(c, ArrayContainer))
        return n_arr, len(self.containers) - n_arr

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, RoaringBitmap):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())

    def __repr__(self) -> str:
        na, nb = self.container_stats()
        return (f"RoaringBitmap(card={self.cardinality}, containers={na} array"
                f" + {nb} bitmap)")


# =============================================================================
# Algorithm 4: optimized many-way union
# =============================================================================

def union_many(bitmaps: Sequence[RoaringBitmap]) -> RoaringBitmap:
    """Paper Algorithm 4: min-heap of (key, container); for each key group,
    clone the max-cardinality container, OR the rest in place *without*
    cardinality maintenance, and recount once at the end."""
    heap: List[Tuple[int, int, int]] = []  # (key, bitmap_idx, container_idx)
    for bi, rb in enumerate(bitmaps):
        for ci, k in enumerate(rb.keys):
            heapq.heappush(heap, (k, bi, ci))
    out = RoaringBitmap()
    while heap:
        key = heap[0][0]
        group: List[Container] = []
        while heap and heap[0][0] == key:
            _, bi, ci = heapq.heappop(heap)
            group.append(bitmaps[bi].containers[ci])
        group.sort(key=lambda c: -c.cardinality)
        a = group[0].clone()
        if len(group) == 1:
            out.keys.append(key)
            out.containers.append(a)
            continue
        if isinstance(a, ArrayContainer):
            # array mode: Alg. 4 line 13 — merge until it upgrades to bitmap
            for qi, q in enumerate(group[1:]):
                a = container_or(a, q)
                if isinstance(a, BitmapContainer):
                    break
        if isinstance(a, BitmapContainer):
            # bitmap mode: in-place ORs with deferred cardinality (lines 10-11);
            # re-ORing containers already merged during array mode is a no-op
            # (idempotent), so we simply sweep the whole group.
            for q in group[1:]:
                wq = q.words if isinstance(q, BitmapContainer) else array_to_bitmap(q.arr)
                np.bitwise_or(a.words, wq, out=a.words)
            a.cardinality = popcount_words(a.words)  # line 14: once at the end
        out.keys.append(key)
        out.containers.append(a)
    return out
