"""Paper-faithful Roaring bitmap (Chambi, Lemire, Kaser, Godin 2014).

This module is the *reproduction floor*: a CPU implementation that follows the
paper's data layout and Algorithms 1-4 exactly:

  * two-level index: sorted 16-bit keys -> containers of the low 16 bits;
  * array containers (sorted packed u16, card <= 4096) vs bitmap containers
    (2^16-bit bitmap as 1024 x u64, card > 4096);
  * run containers (sorted ``(start, length-1)`` u16 pairs, per the follow-up
    paper *Consistently faster and smaller compressed bitmaps with Roaring*,
    Lemire, Ssi-Yan-Kai & Kaser 2016), chosen by the ``runOptimize``
    best-of-three serialized-size rule;
  * per-container cardinality counters;
  * hybrid AND/OR per container-type pair, including the cardinality-first
    bitmap AND (Alg. 3), fused popcount union (Alg. 1), galloping array
    intersection with the 64x ratio rule, and the union-through-bitmap rule;
  * full cross-kind algebra over the 3x3 container-type grid via the
    declarative ``_AND/_OR/_XOR/_ANDNOT`` pair-dispatch tables (the oracle
    mirror of the slab layer's kind-dispatch engine);
  * Alg. 2 set-bit extraction (both the faithful ``w & -w`` loop and a
    vectorized equivalent);
  * Alg. 4 many-way union with a key min-heap and deferred cardinality.

Canonical discipline: ``RoaringBitmap`` *set-algebra outputs* are always
best-of-three canonical (array vs bitmap vs run by serialized size — the 2016
paper's ``runOptimize`` applied eagerly), which is what makes this module the
bit-identical kind reference for ``jax_roaring``. Bulk constructors
(`from_sorted_unique`) and the 2014 add/remove dynamics keep the original
2-kind behavior; runs enter via ``from_ranges`` / ``run_optimize`` / op
outputs.

NumPy stands in for 64-bit words + popcnt (``np.bitwise_count``), mirroring
how the paper's Java implementation leans on ``Long.bitCount``.

The TPU-native static-shape port lives in ``jax_roaring.py``; kernels in
``repro.kernels.roaring``.
"""

from __future__ import annotations

import heapq
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

# --- constants from the paper ------------------------------------------------
CHUNK_BITS = 16
CHUNK_SIZE = 1 << CHUNK_BITS              # 2^16 integers per chunk
ARRAY_MAX = 4096                          # array container max cardinality
BITMAP_WORDS = CHUNK_SIZE // 64           # 1024 x u64 words per bitmap container
GALLOP_RATIO = 64                         # merge vs galloping threshold (S4)

_U16 = np.uint16
_U64 = np.uint64


# =============================================================================
# Word-level primitives (Algorithm 2 and friends)
# =============================================================================

def popcount_words(words: np.ndarray) -> int:
    """Hamming weight of a word array — the paper's popcnt/Long.bitCount."""
    return int(np.bitwise_count(words).sum())


def extract_set_bits_faithful(w: int, base: int, out: List[int]) -> None:
    """Algorithm 2, verbatim: emit positions of set bits in one 64-bit word.

    Uses two's-complement tricks ``t = w & -w`` (isolate lowest bit) and
    ``w &= w - 1`` (clear lowest bit); cf. Warren, Hacker's Delight.
    """
    w &= (1 << 64) - 1
    while w != 0:
        t = w & (-w & ((1 << 64) - 1))
        out.append(base + int(t - 1).bit_count())
        w &= w - 1


def bitmap_to_array_faithful(words: np.ndarray) -> np.ndarray:
    """Convert bitmap words to a sorted u16 array via Algorithm 2 (loop form)."""
    out: List[int] = []
    for i, w in enumerate(words.tolist()):
        if w:
            extract_set_bits_faithful(int(w), i * 64, out)
    return np.asarray(out, dtype=_U16)


def bitmap_to_array(words: np.ndarray) -> np.ndarray:
    """Vectorized Algorithm 2: positions of all set bits, ascending.

    Equivalent output to the faithful loop; uses byte unpacking + nonzero,
    which is the numpy analogue of extracting with popcount offsets.
    """
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0].astype(_U16)


def array_to_bitmap(arr: np.ndarray) -> np.ndarray:
    """Set the bits of a sorted u16 array in a fresh 1024-word bitmap."""
    words = np.zeros(BITMAP_WORDS, dtype=_U64)
    a = arr.astype(np.int64)
    np.bitwise_or.at(words, a >> 6, (_U64(1) << (a & 63).astype(_U64)))
    return words


# =============================================================================
# Containers
# =============================================================================

class ArrayContainer:
    """Sorted packed array of 16-bit integers, cardinality <= 4096."""

    __slots__ = ("arr",)

    def __init__(self, arr: Optional[np.ndarray] = None):
        self.arr = (
            np.empty(0, dtype=_U16) if arr is None else np.asarray(arr, dtype=_U16)
        )

    @property
    def cardinality(self) -> int:
        return int(self.arr.size)

    def size_in_bytes(self) -> int:
        return 2 * self.arr.size  # 16 bits per integer

    def contains(self, x: int) -> bool:
        i = int(np.searchsorted(self.arr, _U16(x)))
        return i < self.arr.size and int(self.arr[i]) == x

    def clone(self) -> "ArrayContainer":
        return ArrayContainer(self.arr.copy())

    def add(self, x: int) -> "Container":
        """Binary search + linear-time insertion; convert at >4096 (S3)."""
        i = int(np.searchsorted(self.arr, _U16(x)))
        if i < self.arr.size and int(self.arr[i]) == x:
            return self
        self.arr = np.insert(self.arr, i, _U16(x))
        if self.arr.size > ARRAY_MAX:
            return BitmapContainer(array_to_bitmap(self.arr), self.arr.size)
        return self

    def remove(self, x: int) -> "Container":
        i = int(np.searchsorted(self.arr, _U16(x)))
        if i < self.arr.size and int(self.arr[i]) == x:
            self.arr = np.delete(self.arr, i)
        return self

    def to_array(self) -> np.ndarray:
        return self.arr

    def iter_values(self) -> Iterator[int]:
        return iter(self.arr.tolist())


class BitmapContainer:
    """2^16-bit bitmap (1024 x u64) with a tracked cardinality counter."""

    __slots__ = ("words", "cardinality")

    def __init__(self, words: Optional[np.ndarray] = None, cardinality: int = -1):
        self.words = (
            np.zeros(BITMAP_WORDS, dtype=_U64)
            if words is None
            else np.asarray(words, dtype=_U64)
        )
        self.cardinality = (
            popcount_words(self.words) if cardinality < 0 else int(cardinality)
        )

    def size_in_bytes(self) -> int:
        return 8 * BITMAP_WORDS  # always 8 kB

    def contains(self, x: int) -> bool:
        return bool((int(self.words[x >> 6]) >> (x & 63)) & 1)

    def clone(self) -> "BitmapContainer":
        return BitmapContainer(self.words.copy(), self.cardinality)

    def add(self, x: int) -> "Container":
        w = int(self.words[x >> 6])
        bit = 1 << (x & 63)
        if not (w & bit):
            self.words[x >> 6] = _U64(w | bit)
            self.cardinality += 1
        return self

    def remove(self, x: int) -> "Container":
        """Clear a bit; convert to array when cardinality reaches 4096 (S3)."""
        w = int(self.words[x >> 6])
        bit = 1 << (x & 63)
        if w & bit:
            self.words[x >> 6] = _U64(w & ~bit)
            self.cardinality -= 1
            if self.cardinality <= ARRAY_MAX:
                return ArrayContainer(bitmap_to_array(self.words))
        return self

    def to_array(self) -> np.ndarray:
        return bitmap_to_array(self.words)

    def iter_values(self) -> Iterator[int]:
        return iter(self.to_array().tolist())


def runs_from_array(arr: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Sorted unique values -> (starts, lengths-1) of maximal runs."""
    a = np.asarray(arr, dtype=np.int64)
    if a.size == 0:
        return (np.empty(0, np.int64), np.empty(0, np.int64))
    brk = np.nonzero(np.diff(a) != 1)[0]
    starts = a[np.concatenate(([0], brk + 1))]
    ends = a[np.concatenate((brk, [a.size - 1]))]
    return starts, ends - starts


class RunContainer:
    """Sorted, disjoint, non-adjacent runs of consecutive 16-bit integers.

    The 2016 paper's third container kind: run ``i`` covers
    ``[starts[i], starts[i] + lengths[i]]`` (``lengths`` stores length-1, the
    serialized u16 format — a single run of all 2^16 values is
    ``(0, 0xFFFF)``). Serialized size is 4 bytes per run.
    """

    __slots__ = ("starts", "lengths")

    def __init__(self, starts: Optional[np.ndarray] = None,
                 lengths: Optional[np.ndarray] = None):
        self.starts = (np.empty(0, np.int64) if starts is None
                       else np.asarray(starts, dtype=np.int64))
        self.lengths = (np.empty(0, np.int64) if lengths is None
                        else np.asarray(lengths, dtype=np.int64))

    @property
    def n_runs(self) -> int:
        return int(self.starts.size)

    @property
    def cardinality(self) -> int:
        return int(self.lengths.sum() + self.starts.size)

    def size_in_bytes(self) -> int:
        return 4 * self.n_runs  # two u16 per run

    def contains(self, x: int) -> bool:
        i = int(np.searchsorted(self.starts, x, side="right")) - 1
        return i >= 0 and x <= int(self.starts[i] + self.lengths[i])

    def clone(self) -> "RunContainer":
        return RunContainer(self.starts.copy(), self.lengths.copy())

    def rank(self, low: int) -> int:
        """# of elements <= low (the run analogue of the partial popcount)."""
        i = int(np.searchsorted(self.starts, low, side="right"))
        full = int((self.lengths[:i] + 1).sum())
        if i > 0:
            e = int(self.starts[i - 1] + self.lengths[i - 1])
            full -= max(0, e - low)
        return full

    def add(self, x: int) -> "Container":
        """Insert one value: extend/merge runs; re-canonicalize by size."""
        if self.contains(x):
            return self
        i = int(np.searchsorted(self.starts, x, side="right")) - 1
        touch_prev = i >= 0 and int(self.starts[i] + self.lengths[i]) == x - 1
        touch_next = (i + 1 < self.n_runs and int(self.starts[i + 1]) == x + 1)
        if touch_prev and touch_next:
            self.lengths[i] += self.lengths[i + 1] + 2
            self.starts = np.delete(self.starts, i + 1)
            self.lengths = np.delete(self.lengths, i + 1)
        elif touch_prev:
            self.lengths[i] += 1
        elif touch_next:
            self.starts[i + 1] -= 1
            self.lengths[i + 1] += 1
        else:
            self.starts = np.insert(self.starts, i + 1, x)
            self.lengths = np.insert(self.lengths, i + 1, 0)
        return _canonical(self)

    def remove(self, x: int) -> "Container":
        """Delete one value: trim/split runs; re-canonicalize by size."""
        i = int(np.searchsorted(self.starts, x, side="right")) - 1
        if i < 0 or x > int(self.starts[i] + self.lengths[i]):
            return self
        s, e = int(self.starts[i]), int(self.starts[i] + self.lengths[i])
        if s == e:                                   # singleton run
            self.starts = np.delete(self.starts, i)
            self.lengths = np.delete(self.lengths, i)
        elif x == s:
            self.starts[i] += 1
            self.lengths[i] -= 1
        elif x == e:
            self.lengths[i] -= 1
        else:                                        # split
            self.starts = np.insert(self.starts, i + 1, x + 1)
            self.lengths = np.insert(self.lengths, i + 1, e - x - 1)
            self.lengths[i] = x - 1 - s
        return _canonical(self)

    def to_array(self) -> np.ndarray:
        if self.n_runs == 0:
            return np.empty(0, dtype=_U16)
        parts = [np.arange(s, s + l + 1)
                 for s, l in zip(self.starts.tolist(), self.lengths.tolist())]
        return np.concatenate(parts).astype(_U16)

    def to_bitmap_words(self) -> np.ndarray:
        """Run coverage as 1024 u64 words (the range-mask lift)."""
        flags = np.zeros(CHUNK_SIZE + 1, dtype=np.int8)
        np.add.at(flags, self.starts, 1)
        np.add.at(flags, self.starts + self.lengths + 1, -1)
        bits = np.cumsum(flags[:CHUNK_SIZE]) > 0
        return np.packbits(bits, bitorder="little").view(_U64)

    def iter_values(self) -> Iterator[int]:
        for s, l in zip(self.starts.tolist(), self.lengths.tolist()):
            yield from range(s, s + l + 1)


Container = Union[ArrayContainer, BitmapContainer, RunContainer]


def n_runs_of(c: Container) -> int:
    """Number of maximal runs a container's value set splits into."""
    if isinstance(c, RunContainer):
        return c.n_runs
    if isinstance(c, BitmapContainer):
        # rising-edge popcount: a run starts where a bit is set and its
        # predecessor is clear — O(1024 words), no value materialization
        w = c.words
        carry = np.concatenate(([_U64(0)], w[:-1] >> _U64(63)))
        rising = w & ~((w << _U64(1)) | carry)
        return int(np.bitwise_count(rising).sum())
    arr = c.arr
    if arr.size == 0:
        return 0
    return int(np.count_nonzero(np.diff(arr.astype(np.int64)) != 1)) + 1


def _canonical(c: Container) -> Container:
    """``runOptimize`` best-of-three: pick array vs bitmap vs run by strict
    serialized size (2*card vs 8192 vs 4*n_runs); run only when strictly
    smaller, array preferred at the 4096 tie (paper: > 4096 converts)."""
    card = c.cardinality
    if card == 0:
        return ArrayContainer()
    nr = n_runs_of(c)
    other = min(2 * card, 8 * BITMAP_WORDS) if card <= ARRAY_MAX \
        else 8 * BITMAP_WORDS
    if 4 * nr < other:
        if isinstance(c, RunContainer):
            return c
        arr = c.arr if isinstance(c, ArrayContainer) else c.to_array()
        return RunContainer(*runs_from_array(arr))
    if card <= ARRAY_MAX:
        if isinstance(c, ArrayContainer):
            return c
        return ArrayContainer(c.to_array())
    if isinstance(c, BitmapContainer):
        return c
    if isinstance(c, RunContainer):
        return BitmapContainer(c.to_bitmap_words(), card)
    return BitmapContainer(array_to_bitmap(c.arr), card)


def _maybe_to_array(c: BitmapContainer) -> Container:
    if c.cardinality <= ARRAY_MAX:
        return ArrayContainer(bitmap_to_array(c.words))
    return c


def _words_of(c: Container) -> np.ndarray:
    if isinstance(c, BitmapContainer):
        return c.words
    if isinstance(c, RunContainer):
        return c.to_bitmap_words()
    return array_to_bitmap(c.arr)


# =============================================================================
# Container-pair logical operations (paper S4)
# =============================================================================

def union_bitmap_bitmap(a: BitmapContainer, b: BitmapContainer) -> BitmapContainer:
    """Algorithm 1: 1024 ORs with fused popcount; result stays a bitmap
    (cardinality >= max(|A|,|B|) > 4096)."""
    words = np.bitwise_or(a.words, b.words)
    return BitmapContainer(words, popcount_words(words))


def union_bitmap_bitmap_inplace(a: BitmapContainer, b: BitmapContainer) -> BitmapContainer:
    """In-place variant (S4): overwrite A, skip cardinality until asked."""
    np.bitwise_or(a.words, b.words, out=a.words)
    a.cardinality = popcount_words(a.words)
    return a


def intersect_bitmap_bitmap(a: BitmapContainer, b: BitmapContainer) -> Container:
    """Algorithm 3: compute cardinality first with 1024 ANDs + popcount, then
    materialize a bitmap (card > 4096) or extract an array (Alg. 2)."""
    anded = np.bitwise_and(a.words, b.words)
    c = popcount_words(anded)
    if c > ARRAY_MAX:
        return BitmapContainer(anded, c)
    return ArrayContainer(bitmap_to_array(anded))


def union_array_bitmap(a: ArrayContainer, b: BitmapContainer) -> BitmapContainer:
    """Clone the bitmap and set the array's bits (S4 Bitmap vs Array)."""
    out = b.clone()
    idx = a.arr.astype(np.int64)
    words = out.words
    # cardinality update by counting newly-set bits (paper: check whether the
    # word value was modified); array elements are unique, so the number of
    # new bits is the number of elements not already present.
    present = (words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
    np.bitwise_or.at(words, idx >> 6, (_U64(1) << (idx & 63).astype(_U64)))
    out.cardinality = b.cardinality + int(idx.size - int(present.sum()))
    return out


def intersect_array_bitmap(a: ArrayContainer, b: BitmapContainer) -> ArrayContainer:
    """Probe each array element against the bitmap (S4); output is an array
    (cannot exceed |A| <= 4096)."""
    idx = a.arr.astype(np.int64)
    hits = (b.words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
    return ArrayContainer(a.arr[hits.astype(bool)])


def _merge_intersect(small: np.ndarray, large: np.ndarray) -> np.ndarray:
    """Vectorized sorted-merge intersection (the paper's merge path)."""
    pos = np.searchsorted(large, small)
    pos_clipped = np.minimum(pos, large.size - 1)
    mask = (pos < large.size) & (large[pos_clipped] == small)
    return small[mask]


def galloping_intersect_faithful(r: np.ndarray, f: np.ndarray) -> np.ndarray:
    """Faithful galloping (S4): for each r_i, exponential search in f then
    binary search — skips comparisons when |r| << |f|."""
    out: List[int] = []
    j = 0
    fl = f.tolist()
    n = len(fl)
    for ri in r.tolist():
        # exponential (galloping) phase
        step = 1
        lo = j
        hi = j + 1
        while hi < n and fl[hi] < ri:
            lo = hi
            hi = min(n, hi + step)
            step <<= 1
        # binary search phase in (lo, hi]
        hi = min(hi, n - 1)
        import bisect

        j = bisect.bisect_left(fl, ri, lo, min(hi + 1, n))
        if j < n and fl[j] == ri:
            out.append(ri)
    return np.asarray(out, dtype=_U16)


def intersect_array_array(a: ArrayContainer, b: ArrayContainer) -> ArrayContainer:
    """Merge when cardinalities within 64x, galloping otherwise (S4).

    Production path uses vectorized binary search for both regimes (numpy's
    searchsorted); `galloping_intersect_faithful` preserves the paper's exact
    control flow for validation.
    """
    small, large = (a.arr, b.arr) if a.arr.size <= b.arr.size else (b.arr, a.arr)
    if small.size == 0:
        return ArrayContainer()
    return ArrayContainer(_merge_intersect(small, large))


def union_array_array(a: ArrayContainer, b: ArrayContainer) -> Container:
    """S4 Array vs Array union: merge when sum <= 4096; otherwise set bits in
    a bitmap, popcount, and convert back down if the true card <= 4096."""
    total = a.arr.size + b.arr.size
    if total <= ARRAY_MAX:
        return ArrayContainer(np.union1d(a.arr, b.arr).astype(_U16))
    words = array_to_bitmap(a.arr)
    idx = b.arr.astype(np.int64)
    np.bitwise_or.at(words, idx >> 6, (_U64(1) << (idx & 63).astype(_U64)))
    c = popcount_words(words)
    if c <= ARRAY_MAX:
        return ArrayContainer(bitmap_to_array(words))
    return BitmapContainer(words, c)


def intersect_run_run(a: RunContainer, b: RunContainer) -> RunContainer:
    """Run-merge intersection (2016 paper): two-pointer sweep over the two
    sorted run lists; each output run is the overlap of one pair."""
    starts: List[int] = []
    lengths: List[int] = []
    i = j = 0
    na, nb = a.n_runs, b.n_runs
    while i < na and j < nb:
        sa, ea = int(a.starts[i]), int(a.starts[i] + a.lengths[i])
        sb, eb = int(b.starts[j]), int(b.starts[j] + b.lengths[j])
        s, e = max(sa, sb), min(ea, eb)
        if s <= e:
            starts.append(s)
            lengths.append(e - s)
        if ea <= eb:            # the run that closes first advances
            i += 1
        else:
            j += 1
    return RunContainer(np.asarray(starts, np.int64),
                        np.asarray(lengths, np.int64))


def union_run_run(a: RunContainer, b: RunContainer) -> RunContainer:
    """Run-merge union: merge the two sorted run lists, coalescing overlap
    and adjacency as we go."""
    starts: List[int] = []
    lengths: List[int] = []
    i = j = 0
    na, nb = a.n_runs, b.n_runs
    while i < na or j < nb:
        if j >= nb or (i < na and int(a.starts[i]) <= int(b.starts[j])):
            s, e = int(a.starts[i]), int(a.starts[i] + a.lengths[i])
            i += 1
        else:
            s, e = int(b.starts[j]), int(b.starts[j] + b.lengths[j])
            j += 1
        if starts and s <= int(starts[-1]) + int(lengths[-1]) + 1:
            lengths[-1] = max(lengths[-1], e - starts[-1])
        else:
            starts.append(s)
            lengths.append(e - s)
    return RunContainer(np.asarray(starts, np.int64),
                        np.asarray(lengths, np.int64))


def intersect_run_array(r: RunContainer, a: ArrayContainer) -> ArrayContainer:
    """Gallop-in-ranges: each array value binary-searches the run starts
    (S4's galloping adapted to interval endpoints)."""
    if a.arr.size == 0 or r.n_runs == 0:
        return ArrayContainer()
    v = a.arr.astype(np.int64)
    i = np.searchsorted(r.starts, v, side="right") - 1
    ic = np.maximum(i, 0)
    hit = (i >= 0) & (v <= r.starts[ic] + r.lengths[ic])
    return ArrayContainer(a.arr[hit])


def intersect_run_bitmap(r: RunContainer, b: BitmapContainer) -> Container:
    """Range-mask: AND the bitmap words with the run coverage (Alg. 3 with a
    synthesized operand), then materialize by the 4096 rule."""
    return _materialize_words(np.bitwise_and(r.to_bitmap_words(), b.words))


def _materialize_words(words: np.ndarray) -> Container:
    """Word-domain result -> container by the 4096 rule (Alg. 3 tail)."""
    c = popcount_words(words)
    if c > ARRAY_MAX:
        return BitmapContainer(words, c)
    return ArrayContainer(bitmap_to_array(words))


def _andnot_words(a: Container, b: Container) -> Container:
    return _materialize_words(
        np.bitwise_and(_words_of(a), np.bitwise_not(_words_of(b))))


def andnot_array_any(a: ArrayContainer, b: Container) -> ArrayContainer:
    """A \\ B with array A: probe each value of A in B (any B kind)."""
    if a.arr.size == 0:
        return ArrayContainer()
    if isinstance(b, ArrayContainer):
        if b.arr.size == 0:
            return ArrayContainer(a.arr.copy())
        pos = np.searchsorted(b.arr, a.arr)
        pos_c = np.minimum(pos, b.arr.size - 1)
        mask = (pos < b.arr.size) & (b.arr[pos_c] == a.arr)
        return ArrayContainer(a.arr[~mask])
    if isinstance(b, BitmapContainer):
        idx = a.arr.astype(np.int64)
        hits = (b.words[idx >> 6] >> (idx & 63).astype(_U64)) & _U64(1)
        return ArrayContainer(a.arr[~hits.astype(bool)])
    if b.n_runs == 0:
        return ArrayContainer(a.arr.copy())
    v = a.arr.astype(np.int64)
    i = np.searchsorted(b.starts, v, side="right") - 1
    ic = np.maximum(i, 0)
    keep = ~((i >= 0) & (v <= b.starts[ic] + b.lengths[ic]))
    return ArrayContainer(a.arr[keep])


def _xor_words(a: Container, b: Container) -> Container:
    return _materialize_words(np.bitwise_xor(_words_of(a), _words_of(b)))


def _or_words(a: Container, b: Container) -> Container:
    return _materialize_words(np.bitwise_or(_words_of(a), _words_of(b)))


# --- declarative pair-dispatch tables (the oracle mirror of the slab
# engine's kind-dispatch registry): keyed by (type_a, type_b); ``swap``-style
# symmetric entries are generated, so adding a 4th kind is new rows, not new
# branch chains. -------------------------------------------------------------

_A, _B, _R = ArrayContainer, BitmapContainer, RunContainer

_AND_TABLE = {
    (_A, _A): intersect_array_array,
    (_A, _B): intersect_array_bitmap,
    (_B, _A): lambda a, b: intersect_array_bitmap(b, a),
    (_B, _B): intersect_bitmap_bitmap,
    (_R, _R): intersect_run_run,
    (_R, _A): intersect_run_array,
    (_A, _R): lambda a, b: intersect_run_array(b, a),
    (_R, _B): intersect_run_bitmap,
    (_B, _R): lambda a, b: intersect_run_bitmap(b, a),
}

_OR_TABLE = {
    (_A, _A): union_array_array,
    (_A, _B): lambda a, b: union_array_bitmap(a, b),
    (_B, _A): lambda a, b: union_array_bitmap(b, a),
    (_B, _B): union_bitmap_bitmap,
    (_R, _R): union_run_run,
    (_R, _A): _or_words,
    (_A, _R): _or_words,
    (_R, _B): _or_words,
    (_B, _R): _or_words,
}

_ANDNOT_TABLE = {
    (_A, _A): andnot_array_any,
    (_A, _B): andnot_array_any,
    (_A, _R): andnot_array_any,
    (_B, _A): _andnot_words,
    (_B, _B): _andnot_words,
    (_B, _R): _andnot_words,
    (_R, _A): _andnot_words,
    (_R, _B): _andnot_words,
    (_R, _R): _andnot_words,
}


def container_or(a: Container, b: Container) -> Container:
    return _OR_TABLE[(type(a), type(b))](a, b)


def container_and(a: Container, b: Container) -> Container:
    return _AND_TABLE[(type(a), type(b))](a, b)


def container_xor(a: Container, b: Container) -> Container:
    """XOR (extension — the paper focuses on AND/OR; needed by the framework
    for mask algebra). Same dense/sparse materialization discipline."""
    return _xor_words(a, b)


def container_andnot(a: Container, b: Container) -> Container:
    """A AND NOT B (extension; used for e.g. KV-page reclamation)."""
    return _ANDNOT_TABLE[(type(a), type(b))](a, b)


# =============================================================================
# RoaringBitmap: the two-level index (paper S2-S4)
# =============================================================================

class RoaringBitmap:
    """Sorted first-level key array + containers, per the paper.

    Functional-style constructors (`from_array`) plus the mutating single-
    element `add`/`remove` used by the paper's Fig. 2e/2f benchmarks.
    """

    __slots__ = ("keys", "containers")

    def __init__(self, keys: Optional[List[int]] = None,
                 containers: Optional[List[Container]] = None):
        self.keys: List[int] = keys if keys is not None else []
        self.containers: List[Container] = containers if containers is not None else []

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_array(cls, values: Iterable[int]) -> "RoaringBitmap":
        v = np.asarray(sorted(set(int(x) for x in values)), dtype=np.int64)
        return cls.from_sorted_unique(v)

    @classmethod
    def from_sorted_unique(cls, v: np.ndarray) -> "RoaringBitmap":
        """Bulk build: segment by high 16 bits, choose container type by the
        4096 rule."""
        rb = cls()
        if v.size == 0:
            return rb
        v = np.asarray(v, dtype=np.int64)
        hi = v >> CHUNK_BITS
        lo = (v & (CHUNK_SIZE - 1)).astype(_U16)
        boundaries = np.nonzero(np.diff(hi))[0] + 1
        starts = np.concatenate(([0], boundaries))
        ends = np.concatenate((boundaries, [v.size]))
        for s, e in zip(starts.tolist(), ends.tolist()):
            key = int(hi[s])
            chunk = lo[s:e]
            if chunk.size > ARRAY_MAX:
                rb.keys.append(key)
                rb.containers.append(
                    BitmapContainer(array_to_bitmap(chunk), chunk.size))
            else:
                rb.keys.append(key)
                rb.containers.append(ArrayContainer(chunk.copy()))
        return rb

    @classmethod
    def from_ranges(cls, ranges: Sequence[Tuple[int, int]]) -> "RoaringBitmap":
        """Build run containers directly from half-open ``[start, end)``
        ranges — no per-element materialization (the run-shaped constructor
        the 2016 paper's workloads call for). Ranges may span chunks; they
        are split at 2^16 boundaries. Overlapping/adjacent ranges coalesce.
        Each container is best-of-three canonicalized."""
        spans = sorted((int(s), int(e)) for s, e in ranges if e > s)
        merged: List[List[int]] = []
        for s, e in spans:
            if merged and s <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], e)
            else:
                merged.append([s, e])
        per_key: dict = {}
        for s, e in merged:
            k = s >> CHUNK_BITS
            while s < e:
                chunk_end = min(e, (k + 1) << CHUNK_BITS)
                lo = s & (CHUNK_SIZE - 1)
                per_key.setdefault(k, ([], []))
                per_key[k][0].append(lo)
                per_key[k][1].append(chunk_end - s - 1)
                s = chunk_end
                k += 1
        rb = cls()
        for k in sorted(per_key):
            starts, lengths = per_key[k]
            rb.keys.append(k)
            rb.containers.append(_canonical(RunContainer(
                np.asarray(starts, np.int64), np.asarray(lengths, np.int64))))
        return rb

    @classmethod
    def from_range(cls, lo: int, hi: int) -> "RoaringBitmap":
        """Single contiguous ``[lo, hi)`` range (window/causal mask rows)."""
        return cls.from_ranges([(lo, hi)])

    def run_optimize(self) -> "RoaringBitmap":
        """The 2016 paper's ``runOptimize``: re-canonicalize every container
        best-of-three (array vs bitmap vs run by serialized size), in place."""
        self.containers = [_canonical(c) for c in self.containers]
        return self

    # -- access operations (paper S3) ------------------------------------------
    def _find_key(self, key: int) -> int:
        """Binary search the first-level index; returns position or -pos-1."""
        import bisect

        i = bisect.bisect_left(self.keys, key)
        if i < len(self.keys) and self.keys[i] == key:
            return i
        return -i - 1

    def contains(self, x: int) -> bool:
        i = self._find_key(x >> CHUNK_BITS)
        if i < 0:
            return False
        return self.containers[i].contains(x & (CHUNK_SIZE - 1))

    __contains__ = contains

    def add(self, x: int) -> None:
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        i = self._find_key(key)
        if i >= 0:
            self.containers[i] = self.containers[i].add(low)
        else:
            pos = -i - 1
            self.keys.insert(pos, key)
            self.containers.insert(pos, ArrayContainer(np.asarray([low], dtype=_U16)))

    def remove(self, x: int) -> None:
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        i = self._find_key(key)
        if i < 0:
            return
        c = self.containers[i].remove(low)
        if c.cardinality == 0:
            del self.keys[i]
            del self.containers[i]
        else:
            self.containers[i] = c

    # -- aggregate queries (paper S2) -------------------------------------------
    @property
    def cardinality(self) -> int:
        """Sum of at most ceil(n / 2^16) per-container counters."""
        return sum(c.cardinality for c in self.containers)

    def __len__(self) -> int:
        return self.cardinality

    def rank(self, x: int) -> int:
        """# of set entries <= x: whole-container counters + one partial."""
        key, low = x >> CHUNK_BITS, x & (CHUNK_SIZE - 1)
        total = 0
        for k, c in zip(self.keys, self.containers):
            if k < key:
                total += c.cardinality
            elif k == key:
                if isinstance(c, ArrayContainer):
                    total += int(np.searchsorted(c.arr, _U16(low), side="right"))
                elif isinstance(c, RunContainer):
                    total += c.rank(low)
                else:
                    full_words = low >> 6
                    total += popcount_words(c.words[:full_words])
                    rem = (low & 63) + 1
                    total += int(int(c.words[full_words]) & ((1 << rem) - 1)).bit_count()
            else:
                break
        return total

    def select(self, j: int) -> int:
        """Value of the j-th (0-based) smallest element."""
        if j < 0 or j >= self.cardinality:
            raise IndexError(j)
        for k, c in zip(self.keys, self.containers):
            if j < c.cardinality:
                if isinstance(c, ArrayContainer):
                    return (k << CHUNK_BITS) | int(c.arr[j])
                if isinstance(c, RunContainer):
                    # run-length prefix sums, O(log n_runs) — the KV
                    # allocator's free.select(0) pops from a run pool
                    cum = np.cumsum(c.lengths + 1)
                    r = int(np.searchsorted(cum, j, side="right"))
                    prev = int(cum[r - 1]) if r else 0
                    return (k << CHUNK_BITS) | int(c.starts[r] + j - prev)
                return (k << CHUNK_BITS) | int(c.to_array()[j])
            j -= c.cardinality
        raise AssertionError("unreachable")

    # -- binary logical operations (paper S4 first-level merge) -----------------
    #
    # The paper merges the two sorted first-level arrays in O(n1 + n2) integer
    # comparisons; in numpy the same merge is done with vectorized sorted-set
    # routines so that per-container *python* overhead is only paid for keys
    # that actually produce work (all keys for OR, matching keys for AND).
    def _binary_op(self, other: "RoaringBitmap", op, union_keys: bool) -> "RoaringBitmap":
        out = RoaringBitmap()
        ka = np.asarray(self.keys, dtype=np.int64)
        kb = np.asarray(other.keys, dtype=np.int64)
        if not union_keys:
            common, ia, ib = np.intersect1d(ka, kb, assume_unique=True,
                                            return_indices=True)
            for k, i, j in zip(common.tolist(), ia.tolist(), ib.tolist()):
                c = op(self.containers[i], other.containers[j])
                if c.cardinality > 0:
                    out.keys.append(k)
                    out.containers.append(_canonical(c))
            return out
        union = np.union1d(ka, kb)
        pa = np.searchsorted(ka, union)
        pb = np.searchsorted(kb, union)
        in_a = (pa < ka.size) & (ka[np.minimum(pa, max(ka.size - 1, 0))] == union) \
            if ka.size else np.zeros(union.size, dtype=bool)
        in_b = (pb < kb.size) & (kb[np.minimum(pb, max(kb.size - 1, 0))] == union) \
            if kb.size else np.zeros(union.size, dtype=bool)
        for k, i, j, a_has, b_has in zip(union.tolist(), pa.tolist(), pb.tolist(),
                                         in_a.tolist(), in_b.tolist()):
            if a_has and b_has:
                c = op(self.containers[i], other.containers[j])
            elif a_has:
                c = self.containers[i].clone()
            else:
                c = other.containers[j].clone()
            if c.cardinality > 0:
                out.keys.append(k)
                out.containers.append(_canonical(c))
        return out

    def __and__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_and, union_keys=False)

    def __or__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_or, union_keys=True)

    def __xor__(self, other: "RoaringBitmap") -> "RoaringBitmap":
        return self._binary_op(other, container_xor, union_keys=True)

    def andnot(self, other: "RoaringBitmap") -> "RoaringBitmap":
        out = RoaringBitmap()
        j = 0
        for k, c in zip(self.keys, self.containers):
            i = other._find_key(k)
            if i < 0:
                out.keys.append(k)
                out.containers.append(_canonical(c.clone()))
            else:
                r = container_andnot(c, other.containers[i])
                if r.cardinality > 0:
                    out.keys.append(k)
                    out.containers.append(_canonical(r))
        return out

    # -- in-place union (S4 in-place variants) ----------------------------------
    def ior(self, other: "RoaringBitmap") -> "RoaringBitmap":
        """Self |= other, modifying bitmap containers in place when possible."""
        i = j = 0
        n2 = len(other.keys)
        while j < n2:
            k2 = other.keys[j]
            if i >= len(self.keys) or self.keys[i] > k2:
                self.keys.insert(i, k2)
                self.containers.insert(
                    i, _canonical(other.containers[j].clone()))
                i += 1
                j += 1
            elif self.keys[i] < k2:
                i += 1
            else:
                a, b = self.containers[i], other.containers[j]
                if isinstance(a, BitmapContainer) and isinstance(b, BitmapContainer):
                    self.containers[i] = _canonical(
                        union_bitmap_bitmap_inplace(a, b))
                else:
                    self.containers[i] = _canonical(container_or(a, b))
                i += 1
                j += 1
        return self

    # -- export -----------------------------------------------------------------
    def to_array(self) -> np.ndarray:
        parts = []
        for k, c in zip(self.keys, self.containers):
            parts.append((k << CHUNK_BITS) + c.to_array().astype(np.int64))
        if not parts:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(parts)

    def iter_values(self) -> Iterator[int]:
        for k, c in zip(self.keys, self.containers):
            base = k << CHUNK_BITS
            for v in c.iter_values():
                yield base + v

    # -- size accounting (bits/item experiments) ---------------------------------
    def size_in_bytes(self) -> int:
        """Serialized size: 4 bytes/container header (16-bit key + 16-bit
        cardinality) + container payloads + 8-byte index header."""
        total = 8 + 4 * len(self.containers)
        for c in self.containers:
            total += c.size_in_bytes()
        return total

    def container_stats(self) -> Tuple[int, int]:
        n_arr = sum(1 for c in self.containers if isinstance(c, ArrayContainer))
        return n_arr, len(self.containers) - n_arr

    def kind_stats(self) -> Tuple[int, int, int]:
        """(n_array, n_bitmap, n_run) container counts."""
        na = sum(1 for c in self.containers if isinstance(c, ArrayContainer))
        nb = sum(1 for c in self.containers if isinstance(c, BitmapContainer))
        return na, nb, len(self.containers) - na - nb

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, RoaringBitmap):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())

    def __repr__(self) -> str:
        na, nb, nr = self.kind_stats()
        return (f"RoaringBitmap(card={self.cardinality}, containers={na} array"
                f" + {nb} bitmap + {nr} run)")


# =============================================================================
# Algorithm 4: optimized many-way union
# =============================================================================

def union_many(bitmaps: Sequence[RoaringBitmap]) -> RoaringBitmap:
    """Paper Algorithm 4: min-heap of (key, container); for each key group,
    clone the max-cardinality container, OR the rest in place *without*
    cardinality maintenance, and recount once at the end."""
    heap: List[Tuple[int, int, int]] = []  # (key, bitmap_idx, container_idx)
    for bi, rb in enumerate(bitmaps):
        for ci, k in enumerate(rb.keys):
            heapq.heappush(heap, (k, bi, ci))
    out = RoaringBitmap()
    while heap:
        key = heap[0][0]
        group: List[Container] = []
        while heap and heap[0][0] == key:
            _, bi, ci = heapq.heappop(heap)
            group.append(bitmaps[bi].containers[ci])
        group.sort(key=lambda c: -c.cardinality)
        a = group[0].clone()
        if len(group) == 1:
            out.keys.append(key)
            out.containers.append(_canonical(a))
            continue
        if not isinstance(a, BitmapContainer):
            # array/run mode: Alg. 4 line 13 — pair-merge (run-merge for run
            # operands) until the accumulator upgrades to bitmap
            for qi, q in enumerate(group[1:]):
                a = container_or(a, q)
                if isinstance(a, BitmapContainer):
                    break
        if isinstance(a, BitmapContainer):
            # bitmap mode: in-place ORs with deferred cardinality (lines 10-11);
            # re-ORing containers already merged during array mode is a no-op
            # (idempotent), so we simply sweep the whole group.
            for q in group[1:]:
                np.bitwise_or(a.words, _words_of(q), out=a.words)
            a.cardinality = popcount_words(a.words)  # line 14: once at the end
        out.keys.append(key)
        out.containers.append(_canonical(a))
    return out
