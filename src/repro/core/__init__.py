"""Core Roaring bitmap implementations (the paper's primary contribution).

- ``py_roaring``: paper-faithful CPU implementation (Algorithms 1-4).
- ``jax_roaring``: TPU-native static-shape container slab for use inside
  jit/pjit programs (masks, page tables, gradient index sets).
"""

from .py_roaring import (RoaringBitmap, ArrayContainer, BitmapContainer,
                         RunContainer, union_many, ARRAY_MAX, CHUNK_SIZE)

__all__ = [
    "RoaringBitmap", "ArrayContainer", "BitmapContainer", "RunContainer",
    "union_many", "ARRAY_MAX", "CHUNK_SIZE",
]
