"""Chunked sequence scan with per-chunk recomputation.

``lax.scan``'s backward stores the carried state for every step — for a
selective-SSM layer at 4k tokens that is seq_len x [B, d_inner, d_state]
floats (~68 GB/layer on jamba). Splitting the scan into checkpointed chunks
stores one carry per *chunk* and recomputes the inner steps in the backward
pass: memory drops by the chunk factor for ~2x scan FLOPs (the standard
recurrent-training trade).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_scan(f, init, xs, chunk_size: int = 256):
    """Drop-in for ``lax.scan(f, init, xs)`` over the leading time axis.

    Falls back to a plain scan when the sequence is short or indivisible.
    """
    leaves = jax.tree.leaves(xs)
    T = leaves[0].shape[0]
    if T <= chunk_size or T % chunk_size != 0:
        return jax.lax.scan(f, init, xs)
    n_chunks = T // chunk_size
    xs_c = jax.tree.map(
        lambda a: a.reshape(n_chunks, chunk_size, *a.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, xc):
        carry, ys = jax.lax.scan(f, carry, xc)
        return carry, ys

    carry, ys_c = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape(n_chunks * chunk_size, *a.shape[2:]), ys_c)
    return carry, ys
