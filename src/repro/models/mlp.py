"""Gated MLP (SwiGLU/GeGLU) and capacity-based MoE.

The MoE uses the TPU-idiomatic dispatch/combine-einsum formulation
(Mesh-TF/GShard style): tokens are routed to (expert, capacity-slot) pairs,
expert FFNs run as one batched einsum over the expert dimension (MXU-dense),
and results are combined with the routing weights. Dropped tokens (capacity
overflow) pass through the residual stream, as usual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .config import ModelConfig


def mlp_init(rng, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "wi": common.dense_init(ks[0], (d, f), dtype),
        "wo": common.dense_init(ks[2], (f, d), dtype),
    }
    if cfg.gated_mlp:
        p["wg"] = common.dense_init(ks[1], (d, f), dtype)
    return p
    # logical axes: wi/wg ("embed","mlp"), wo ("mlp","embed")


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
    if "wg" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))


def moe_init(rng, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": common.dense_init(ks[0], (d, e), jnp.float32),
        "wi": common.dense_init(ks[1], (e, d, f), dtype),
        "wg": common.dense_init(ks[2], (e, d, f), dtype),
        "wo": common.dense_init(ks[3], (e, f, d), dtype),
    }
    # logical axes: wi/wg ("expert","embed","mlp"), wo ("expert","mlp","embed")


def moe(params: dict, x: jax.Array, cfg: ModelConfig):
    """Returns (output, aux_loss). x: [B, S, d].

    Scatter-based dispatch with *per-data-shard grouping*: tokens are grouped
    by their data-parallel shard (dim 0 of the batch is batch-major, so
    groups align with shards), each group computes capacity slots with a
    group-local exclusive cumsum (no cross-shard sequential dependency), and
    expert buffers are [G, E, C_local, d] sharded (data, model, ., .) —
    dispatch stays shard-local, expert FFNs run expert-parallel over the
    model axis (one batched einsum, MXU-dense). Overflowing pairs are
    dropped (capacity-factor semantics) and ride the residual stream.
    """
    from repro.distributed import context as dctx

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    G = dctx.data_shard_count()
    if B % G != 0:                  # grouping must align with batch sharding
        G = 1
    NG = N // G
    C = max(1, int(np.ceil(cfg.capacity_factor * NG * K / E)))
    xf = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)       # [N, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                        # [E]
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)

    # group-local capacity slots: exclusive cumsum inside each data shard
    eg = gate_idx.reshape(G, NG * K)                    # expert id per pair
    onehot = jax.nn.one_hot(eg, E, dtype=jnp.int32)     # [G, NG*K, E]
    slot = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.sum(slot * onehot, axis=-1)              # [G, NG*K]
    keep = slot < C
    gates = (gate_vals.reshape(G, NG * K)
             * keep.astype(gate_vals.dtype))            # dropped -> 0
    xg = xf.reshape(G, NG, d)
    tok = jnp.repeat(jnp.arange(NG), K)                 # token id per pair

    def dispatch_one(xg_i, e_i, s_i, keep_i):
        e_idx = jnp.where(keep_i, e_i, E)               # OOB -> dropped
        s_idx = jnp.minimum(s_i, C - 1)
        return jnp.zeros((E, C, d), xg_i.dtype).at[e_idx, s_idx].add(
            xg_i[tok], mode="drop")

    expert_in = jax.vmap(dispatch_one)(xg, eg, slot, keep)   # [G, E, C, d]
    expert_in = dctx.constrain(expert_in, ("data", "model", None, None))

    import os as _os
    wi, wg, wo = (params["wi"].astype(xf.dtype),
                  params["wg"].astype(xf.dtype),
                  params["wo"].astype(xf.dtype))
    if _os.environ.get("REPRO_MOE_GATHER"):
        # explicit per-layer weight gather (bf16, once) so the expert
        # einsums run shard-local: without this, XLA resolves the
        # (G-on-data x f-on-data) einsum conflict by all-gathering the
        # [G,E,C,f] activations in f32 — 4 GB/layer/microstep on jamba
        # (EXPERIMENTS.md §Perf)
        wi = dctx.constrain(wi, ("model", None, None))
        wg = dctx.constrain(wg, ("model", None, None))
        wo = dctx.constrain(wo, ("model", None, None))

    h = jnp.einsum("gecd,edf->gecf", expert_in, wi)
    g = jnp.einsum("gecd,edf->gecf", expert_in, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * h
    expert_out = jnp.einsum("gecf,efd->gecd", h, wo)
    expert_out = dctx.constrain(expert_out, ("data", "model", None, None))

    def combine_one(eo_i, e_i, s_i, gates_i):
        per_pair = eo_i[jnp.minimum(e_i, E - 1), jnp.minimum(s_i, C - 1)]
        per_pair = per_pair * gates_i[:, None].astype(per_pair.dtype)
        return jnp.zeros((NG, d), per_pair.dtype).at[tok].add(per_pair)

    out = jax.vmap(combine_one)(expert_out, eg, slot, gates)  # [G, NG, d]
    return out.reshape(B, S, d), aux
