"""Unified LM: every assigned architecture is a layer-pattern over sub-blocks.

Layers are stacked per *super-block* and iterated with ``lax.scan`` so the
lowered HLO stays compact (an 80-layer model compiles as one while-loop over
10-40 super-blocks — essential for dry-running 80 cells on a CPU container).

Supports: dense GQA decoders, gemma2 local/global alternation with softcaps,
MoE (uniform or alternating), jamba's 7:1 mamba:attention hybrid with MoE,
RWKV6, whisper enc-dec (audio frontend stub), and qwen2-vl (vision stub,
M-RoPE). Decode paths expose per-layer caches (KV, conv/ssm state, rwkv
state) for the serving layer.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import context as dctx

from . import attention as attn_mod
from . import common, mlp as mlp_mod, rwkv as rwkv_mod, ssm as ssm_mod
from .config import ModelConfig


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


@jax.custom_vjp
def _residual_barrier(x):
    return jax.lax.optimization_barrier(x)


def _residual_barrier_fwd(x):
    return _residual_barrier(x), None


def _residual_barrier_bwd(_, g):
    return (g,)


# optimization_barrier has no differentiation rule; the barrier only shapes
# scheduling, so its VJP is the identity
_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


# =============================================================================
# init
# =============================================================================

def _sublayer_init(rng, kind: str, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    if kind == "rwkv":
        return {"ln1": common.rms_norm_init(d, jnp.float32),
                "tm": rwkv_mod.rwkv_init(ks[0], cfg, dtype),
                "ln2": common.rms_norm_init(d, jnp.float32)}
    p = {"ln1": common.rms_norm_init(d, jnp.float32),
         "ln2": common.rms_norm_init(d, jnp.float32)}
    if kind.startswith("attn"):
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    elif kind.startswith("mamba"):
        p["mamba"] = ssm_mod.mamba_init(ks[0], cfg, dtype)
    if kind.endswith("_moe"):
        p["moe"] = mlp_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_mod.mlp_init(ks[1], cfg, dtype)
    return p


def init_lm(rng, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg.param_dtype)
    kinds = cfg.block_kinds()
    n_sb = cfg.n_superblocks
    keys = jax.random.split(rng, len(kinds) + 4)
    params: dict[str, Any] = {
        "embed": common.embedding_init(keys[-1], cfg.vocab, cfg.d_model, dtype,
                                       vocab_padded=cfg.vocab_padded),
        "final_norm": common.rms_norm_init(cfg.d_model, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"table": common.dense_init(
            keys[-2], (cfg.vocab_padded, cfg.d_model), dtype)}

    def stack_init(rng, kind):
        def one(r):
            return _sublayer_init(r, kind, cfg, dtype)
        return jax.vmap(one)(jax.random.split(rng, n_sb))

    params["blocks"] = [stack_init(keys[j], kinds[j]) for j in range(len(kinds))]

    if cfg.layer_pattern == "encdec":
        enc_keys = jax.random.split(keys[-3], 2)
        def enc_one(r):
            return _sublayer_init(r, "attn_mlp", cfg, dtype)
        params["encoder"] = jax.vmap(enc_one)(
            jax.random.split(enc_keys[0], cfg.n_enc_layers))
        params["enc_norm"] = common.rms_norm_init(cfg.d_model, jnp.float32)
        def xattn_one(r):
            return {"ln": common.rms_norm_init(cfg.d_model, jnp.float32),
                    "xattn": attn_mod.attn_init(r, cfg, dtype)}
        params["cross"] = jax.vmap(xattn_one)(
            jax.random.split(enc_keys[1], n_sb))
    return params


# =============================================================================
# forward (training / prefill)
# =============================================================================

def _apply_sublayer(p, x, kind, cfg: ModelConfig, positions, block_lists):
    aux = jnp.float32(0.0)
    h = common.rms_norm(p["ln1"], x)
    if kind == "rwkv":
        x = x + rwkv_mod.rwkv_time_mix(p["tm"], h, cfg)
        h2 = common.rms_norm(p["ln2"], x)
        x = x + rwkv_mod.rwkv_channel_mix(p["tm"], h2, cfg)
        return x, aux
    if kind.startswith("attn"):
        x = x + attn_mod.attention(p["attn"], h, cfg, positions=positions,
                                   layer_kind=kind, block_lists=block_lists)
    elif kind.startswith("mamba"):
        x = x + ssm_mod.mamba(p["mamba"], h, cfg)
    h2 = common.rms_norm(p["ln2"], x)
    if kind.endswith("_moe"):
        out, aux = mlp_mod.moe(p["moe"], h2, cfg)
        x = x + out
    else:
        x = x + mlp_mod.mlp(p["mlp"], h2)
    return x, aux


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            block_lists=None, extra_embeds: Optional[jax.Array] = None,
            memory: Optional[jax.Array] = None, remat: str = "none"):
    """tokens: i32[B, S] -> (logits [B, S, V], aux_loss).

    ``extra_embeds``: precomputed modality embeddings ([B, S_m, d]) prepended
    to the token stream (vision/audio stubs). ``memory``: encoder output for
    enc-dec models. ``remat``: "none" | "full" | "dots" — checkpointing is
    applied at the *scan body* (per super-block), the only placement that
    keeps per-layer residuals out of the backward while-loop state.
    """
    cdt = _dtype(cfg.compute_dtype)
    x = common.embed(params["embed"], tokens).astype(cdt)
    if cfg.logit_softcap is not None:           # gemma-style sqrt(d) scaling
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kinds = cfg.block_kinds()

    def body(carry, layer_params):
        x, aux = carry
        # barrier: stop XLA from hoisting the first rms_norm's f32 upcast
        # into the scan's saved carry (bf16 residuals, not f32 — ~6 GB on
        # jamba train; see EXPERIMENTS.md §Perf)
        x = _residual_barrier(x)
        x = dctx.constrain_batch(x)             # anchor batch sharding
        if cfg.layer_pattern == "encdec":
            layer_params, cross_p = layer_params
        for j, kind in enumerate(kinds):
            x, a = _apply_sublayer(layer_params[j], x, kind, cfg,
                                   positions, block_lists)
            aux = aux + a
        if cfg.layer_pattern == "encdec" and memory is not None:
            h = common.rms_norm(cross_p["ln"], x)
            x = x + attn_mod.cross_attention(cross_p["xattn"], h, memory, cfg)
        return (dctx.constrain_batch(x), aux), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)

    xs = params["blocks"]
    if cfg.layer_pattern == "encdec":
        xs = (params["blocks"], params["cross"])
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    x = common.rms_norm(params["final_norm"], x)
    table = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = common.unembed(table, x, softcap=cfg.logit_softcap,
                            vocab=cfg.vocab)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:, :]
    return logits, aux


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    cdt = _dtype(cfg.compute_dtype)
    x = frames.astype(cdt)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, layer_params):
        h = common.rms_norm(layer_params["ln1"], x)
        x = x + attn_mod.attention(layer_params["attn"], h, cfg,
                                   positions=positions, causal=False)
        h2 = common.rms_norm(layer_params["ln2"], x)
        x = x + mlp_mod.mlp(layer_params["mlp"], h2)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return common.rms_norm(params["enc_norm"], x)


def lm_loss(params: dict, tokens: jax.Array, labels: jax.Array,
            cfg: ModelConfig, block_lists=None, extra_embeds=None,
            memory=None, aux_weight: float = 0.01):
    logits, aux = forward(params, tokens, cfg, block_lists=block_lists,
                          extra_embeds=extra_embeds, memory=memory)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll) + aux_weight * aux


# =============================================================================
# decode (single token)
# =============================================================================

def init_decode_caches(cfg: ModelConfig, batch: int, s_max: int) -> list:
    """Per-superblock-position stacked caches."""
    cdt = _dtype(cfg.compute_dtype)
    n_sb = cfg.n_superblocks
    d, hd, KVH = cfg.d_model, cfg.hd, cfg.n_kv_heads
    di, st = cfg.ssm_expand * d, cfg.ssm_state
    H_rwkv = d // rwkv_mod.HEAD_DIM
    caches = []
    for kind in cfg.block_kinds():
        if kind.startswith("attn"):
            caches.append({
                "k": jnp.zeros((n_sb, batch, s_max, KVH, hd), cdt),
                "v": jnp.zeros((n_sb, batch, s_max, KVH, hd), cdt)})
        elif kind.startswith("mamba"):
            caches.append({
                "conv": jnp.zeros((n_sb, batch, cfg.ssm_conv - 1, di), cdt),
                "h": jnp.zeros((n_sb, batch, di, st), jnp.float32)})
        elif kind == "rwkv":
            caches.append({
                "x_tm": jnp.zeros((n_sb, batch, d), cdt),
                "S": jnp.zeros((n_sb, batch, H_rwkv, rwkv_mod.HEAD_DIM,
                                rwkv_mod.HEAD_DIM), jnp.float32),
                "x_cm": jnp.zeros((n_sb, batch, d), cdt)})
        else:
            raise ValueError(kind)
    return caches


def decode_step(params: dict, caches: list, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig, memory: Optional[jax.Array] = None):
    """tokens: i32[B, 1]; pos: i32[B] -> (logits [B, 1, V], new caches)."""
    cdt = _dtype(cfg.compute_dtype)
    x = common.embed(params["embed"], tokens).astype(cdt)
    if cfg.logit_softcap is not None:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    kinds = cfg.block_kinds()

    def body(carry, scanned):
        x = carry
        if cfg.layer_pattern == "encdec":
            layer_params, cross_p, layer_caches = scanned
        else:
            (layer_params, layer_caches), cross_p = scanned, None
        new_caches = []
        for j, kind in enumerate(kinds):
            p, c = layer_params[j], layer_caches[j]
            h = common.rms_norm(p["ln1"], x)
            if kind.startswith("attn"):
                out, nk, nv = attn_mod.attention_decode(
                    p["attn"], h, cfg, cache_k=c["k"], cache_v=c["v"],
                    pos=pos, layer_kind=kind)
                x = x + out
                new_caches.append({"k": nk, "v": nv})
            elif kind.startswith("mamba"):
                out, (nc, nh) = ssm_mod.mamba_decode_step(
                    p["mamba"], h, (c["conv"], c["h"]), cfg)
                x = x + out
                new_caches.append({"conv": nc, "h": nh})
            elif kind == "rwkv":
                out, (x_tm, S, _) = rwkv_mod.rwkv_decode_step(
                    p["tm"], h, (c["x_tm"], c["S"], c["x_cm"]), cfg)
                x = x + out
                h2 = common.rms_norm(p["ln2"], x)
                cm_out, x_cm = rwkv_mod.rwkv_channel_mix_step(
                    p["tm"], h2, c["x_cm"], cfg)
                x = x + cm_out
                new_caches.append({"x_tm": x_tm, "S": S, "x_cm": x_cm})
                continue
            h2 = common.rms_norm(p["ln2"], x)
            if kind.endswith("_moe"):
                out, _ = mlp_mod.moe(p["moe"], h2, cfg)
                x = x + out
            else:
                x = x + mlp_mod.mlp(p["mlp"], h2)
        if cfg.layer_pattern == "encdec" and memory is not None:
            h = common.rms_norm(cross_p["ln"], x)
            x = x + attn_mod.cross_attention(cross_p["xattn"], h, memory, cfg)
        return x, new_caches

    if cfg.layer_pattern == "encdec":
        xs = (params["blocks"], params["cross"], caches)
    else:
        xs = (params["blocks"], caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    x = common.rms_norm(params["final_norm"], x)
    table = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = common.unembed(table, x, softcap=cfg.logit_softcap,
                            vocab=cfg.vocab)
    return logits, new_caches


# =============================================================================
# decode against the roaring-paged KV cache (serving path)
# =============================================================================

def init_paged_caches(cfg: ModelConfig, n_pages: int, page_size: int) -> list:
    """Per-superblock-position stacked page pools (attention kinds only)."""
    cdt = _dtype(cfg.compute_dtype)
    n_sb = cfg.n_superblocks
    hd, KVH = cfg.hd, cfg.n_kv_heads
    pools = []
    for kind in cfg.block_kinds():
        assert kind.startswith("attn"), (
            "paged decode supports attention-only patterns; use decode_step "
            f"for {cfg.layer_pattern}")
        pools.append({
            "k": jnp.zeros((n_sb, n_pages, page_size, KVH, hd), cdt),
            "v": jnp.zeros((n_sb, n_pages, page_size, KVH, hd), cdt)})
    return pools


def decode_step_paged(params: dict, pools: list, tokens: jax.Array,
                      pos: jax.Array, page_idx: jax.Array, counts: jax.Array,
                      lengths: jax.Array, cfg: ModelConfig,
                      use_pallas: bool = False):
    """Decode one token against roaring-paged KV pools.

    tokens: i32[B,1]; pos: i32[B]; page_idx: i32[B, max_pages] physical page
    list per sequence (from RoaringPageTable.gather_lists); counts/lengths:
    i32[B]. Returns (logits, new_pools).
    """
    from repro.kernels.sparse_attn import paged_decode

    cdt = _dtype(cfg.compute_dtype)
    x = common.embed(params["embed"], tokens).astype(cdt)
    if cfg.logit_softcap is not None:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    kinds = cfg.block_kinds()
    B = tokens.shape[0]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KVH
    page_size = pools[0]["k"].shape[2]
    # physical page + in-page offset where this token's KV lands
    logical = pos // page_size
    phys = jax.vmap(lambda pi, l: pi[l])(page_idx, logical)     # [B]
    offs = pos % page_size

    def body(x, scanned):
        layer_params, layer_pools = scanned
        new_pools = []
        for j, kind in enumerate(kinds):
            p, pool = layer_params[j], layer_pools[j]
            h = common.rms_norm(p["ln1"], x)
            q, k, v = attn_mod._project_qkv(p["attn"], h, cfg, pos[:, None])
            pk = pool["k"].at[phys, offs].set(k[:, 0].astype(pool["k"].dtype))
            pv = pool["v"].at[phys, offs].set(v[:, 0].astype(pool["v"].dtype))
            qg = q.reshape(B, KVH, G, hd)
            starts = (jnp.maximum(pos + 1 - cfg.window, 0)
                      if "local" in kind else jnp.zeros_like(pos))
            out = paged_decode(qg, pk, pv, page_idx, counts, lengths + 1,
                               starts, softcap=cfg.attn_softcap,
                               use_pallas=use_pallas)
            out = out.reshape(B, 1, H * hd).reshape(B, 1, H, hd)
            x = x + jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype),
                               p["attn"]["wo"].astype(x.dtype))
            new_pools.append({"k": pk, "v": pv})
            h2 = common.rms_norm(p["ln2"], x)
            if kind.endswith("_moe"):
                out2, _ = mlp_mod.moe(p["moe"], h2, cfg)
                x = x + out2
            else:
                x = x + mlp_mod.mlp(p["mlp"], h2)
        return x, new_pools

    x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
    x = common.rms_norm(params["final_norm"], x)
    table = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = common.unembed(table, x, softcap=cfg.logit_softcap,
                            vocab=cfg.vocab)
    return logits, new_pools
