"""Mamba-style selective SSM block (for the jamba hybrid).

Faithful-shape Mamba-1: in-projection to 2*d_inner (x, gate z), short causal
conv, data-dependent (Δ, B, C) selective scan over a d_state-wide latent, out
projection. The scan runs as ``lax.scan`` over time at train time (compact
HLO for the 500k-cell) and exposes a single-step form for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .config import ModelConfig
from .scan_utils import chunked_scan


def mamba_init(rng, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    ks = jax.random.split(rng, 6)
    # S4-style A initialization: -[1..st] per channel
    a_init = -jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32), (di, st))
    return {
        "in_proj": common.dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": common.dense_init(ks[1], (cfg.ssm_conv, di), dtype, scale=0.5),
        "x_proj": common.dense_init(ks[2], (di, 2 * st + 1), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32) + np.log(np.expm1(0.01)),
        "log_neg_a": jnp.log(-a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[3], (di, d), dtype),
    }
    # logical axes: in_proj ("embed","mlp"), out_proj ("mlp","embed"),
    # conv/x_proj/dt/A/D replicated or ("mlp",) sharded on model axis


def _ssm_scan(u, dt, B, Cm, A):
    """u: [Bt, L, di]; dt: [Bt, L, di]; B,Cm: [Bt, L, st]; A: [di, st]."""
    dA = jnp.exp(dt[..., None] * A)                       # [Bt,L,di,st]
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]  # [Bt,L,di,st]

    def step(h, xs):
        dA_t, dBu_t, C_t = xs
        h = h * dA_t + dBu_t                              # [Bt,di,st]
        y = jnp.sum(h * C_t[:, None, :], axis=-1)         # [Bt,di]
        return h, y

    Bt, L, di, st = dA.shape
    h0 = jnp.zeros((Bt, di, st), jnp.float32)
    xs = (dA.transpose(1, 0, 2, 3), dBu.transpose(1, 0, 2, 3),
          Cm.transpose(1, 0, 2))
    _, ys = chunked_scan(step, h0, xs)        # checkpointed chunks (memory)
    return ys.transpose(1, 0, 2)                          # [Bt, L, di]


def mamba(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: [B, L, d] -> [B, L, d]."""
    Bt, L, d = x.shape
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    u, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv (width ssm_conv)
    w = params["conv_w"].astype(x.dtype)                  # [K, di]
    upad = jnp.pad(u, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    u = sum(upad[:, i: i + L, :] * w[i] for i in range(cfg.ssm_conv))
    u = jax.nn.silu(u.astype(jnp.float32))
    proj = jnp.einsum("ble,ep->blp", u.astype(x.dtype),
                      params["x_proj"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(proj[..., 0:1] + params["dt_bias"])        # [B,L,di]
    Bm, Cm = proj[..., 1: 1 + st], proj[..., 1 + st:]
    A = -jnp.exp(params["log_neg_a"])                                # [di, st]
    y = _ssm_scan(u, dt, Bm, Cm, A)
    y = y + u * params["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("ble,ed->bld", y.astype(x.dtype),
                      params["out_proj"].astype(x.dtype))


def mamba_decode_step(params: dict, x: jax.Array, state, cfg: ModelConfig):
    """Single-token step. x: [B, 1, d]; state: (conv_buf [B,K-1,di], h [B,di,st])."""
    conv_buf, h = state
    Bt, _, d = x.shape
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    u, z = xz[..., :di], xz[..., di:]
    w = params["conv_w"].astype(x.dtype)
    hist = jnp.concatenate([conv_buf, u], axis=1)          # [B, K, di]
    u1 = jnp.einsum("bke,ke->be", hist, w)[:, None, :]
    new_conv = hist[:, 1:, :]
    u1 = jax.nn.silu(u1.astype(jnp.float32))
    proj = jnp.einsum("ble,ep->blp", u1.astype(x.dtype),
                      params["x_proj"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(proj[..., 0:1] + params["dt_bias"])
    Bm, Cm = proj[..., 1: 1 + st], proj[..., 1 + st:]
    A = -jnp.exp(params["log_neg_a"])
    dA = jnp.exp(dt[:, 0, :, None] * A)
    dBu = dt[:, 0, :, None] * Bm[:, 0, None, :] * u1[:, 0, :, None]
    h = h * dA + dBu
    y = jnp.sum(h * Cm[:, 0, None, :], axis=-1)[:, None, :]
    y = y + u1 * params["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("ble,ed->bld", y.astype(x.dtype),
                     params["out_proj"].astype(x.dtype))
    return out, (new_conv, h)
