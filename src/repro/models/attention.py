"""GQA attention with dense, sliding-window, and roaring block-sparse modes.

The roaring path consumes packed block lists produced by
``repro.sparsity.compile_mask`` — at train time through
``kernels.sparse_attn.sparse_attention`` (Pallas on TPU, reference math under
jit on CPU/dry-run), at decode time through the roaring-paged KV cache in
``repro.serve``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sparse_attn import sparse_attention
from . import common
from .config import ModelConfig

NEG_INF = -1e30


def attn_init(rng, cfg: ModelConfig, dtype) -> dict:
    d, hd, H, KVH = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    return {
        "wq": common.dense_init(ks[0], (d, H, hd), dtype),
        "wk": common.dense_init(ks[1], (d, KVH, hd), dtype),
        "wv": common.dense_init(ks[2], (d, KVH, hd), dtype),
        "wo": common.dense_init(ks[3], (H, hd, d), dtype),
    }
    # logical axes: wq/wk/wv ("embed","heads","head_dim"), wo ("heads","head_dim","embed")


def _project_qkv(params, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        q = common.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = common.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_mask(qi, kj, block, row_off, causal, window):
    rows = (qi * block + jnp.arange(block))[:, None] + row_off
    cols = (kj * block + jnp.arange(block))[None, :]
    mask = jnp.ones((block, block), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _block_scores(qb, kb, scale, softcap, qi, kj, block, row_off, causal,
                  window):
    """Returns (masked softcapped scores s, raw tanh t for bwd)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
    t = None
    if softcap is not None:
        t = jnp.tanh(s / softcap)
        s = softcap * t
    mask = _block_mask(qi, kj, block, row_off, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s, t


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, scale, softcap, causal, window, block):
    out, _ = _flash_fwd_impl(q, k, v, scale, softcap, causal, window, block)
    return out


def _flash_fwd_impl(q, k, v, scale, softcap, causal, window, block):
    """q: [B,S,KVH,G,hd] f32; k,v: [B,S_kv,KVH,hd] f32 -> (out, lse)."""
    B, S, KVH, G, hd = q.shape
    S_kv = k.shape[1]
    nq, nk = S // block, S_kv // block
    qr = q.reshape(B, nq, block, KVH, G, hd)
    kr = k.reshape(B, nk, block, KVH, hd)
    vr = v.reshape(B, nk, block, KVH, hd)
    row_off = S_kv - S

    def q_step(_, qi):
        qb = jax.lax.dynamic_index_in_dim(qr, qi, axis=1, keepdims=False)

        def kv_step(acc, kj):
            m, l, o = acc
            kb = jax.lax.dynamic_index_in_dim(kr, kj, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, kj, axis=1, keepdims=False)
            s, _ = _block_scores(qb, kb, scale, softcap, qi, kj, block,
                                 row_off, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            o = o * alpha + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
            return (m_new, l, o), None

        m0 = jnp.full((B, KVH, G, block, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block, 1), jnp.float32)
        o0 = jnp.zeros((B, KVH, G, block, hd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        l_safe = jnp.maximum(l, 1e-30)
        return None, (o / l_safe, m + jnp.log(l_safe))   # out, lse per row

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: [nq, B, KVH, G, block, hd] -> [B, S, KVH, G, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KVH, G, hd)
    lse = lses[..., 0].transpose(1, 0, 4, 2, 3).reshape(B, S, KVH, G)
    return out, lse


def _flash_vjp_fwd(q, k, v, scale, softcap, causal, window, block):
    out, lse = _flash_fwd_impl(q, k, v, scale, softcap, causal, window, block)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(scale, softcap, causal, window, block, res, do):
    """Flash backward with blockwise recompute: residuals are only (o, lse)
    per row — no [nq, nk, bq, bk] score tensors survive the forward."""
    q, k, v, out, lse = res
    B, S, KVH, G, hd = q.shape
    S_kv = k.shape[1]
    nq, nk = S // block, S_kv // block
    row_off = S_kv - S
    qr = q.reshape(B, nq, block, KVH, G, hd)
    kr = k.reshape(B, nk, block, KVH, hd)
    vr = v.reshape(B, nk, block, KVH, hd)
    dor = do.reshape(B, nq, block, KVH, G, hd).astype(jnp.float32)
    lser = lse.reshape(B, nq, block, KVH, G)
    # D_i = do_i . o_i  (per row)
    D = jnp.sum(do.astype(jnp.float32) * out, axis=-1) \
        .reshape(B, nq, block, KVH, G)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        qb = jax.lax.dynamic_index_in_dim(qr, qi, axis=1, keepdims=False)
        dob = jax.lax.dynamic_index_in_dim(dor, qi, axis=1, keepdims=False)
        lseb = jax.lax.dynamic_index_in_dim(lser, qi, axis=1, keepdims=False)
        Db = jax.lax.dynamic_index_in_dim(D, qi, axis=1, keepdims=False)
        # [B, block, KVH, G] -> [B, KVH, G, block]
        lse_t = lseb.transpose(0, 2, 3, 1)
        D_t = Db.transpose(0, 2, 3, 1)

        def kv_step(acc, kj):
            dq_b, dk_acc, dv_acc = acc
            kb = jax.lax.dynamic_index_in_dim(kr, kj, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, kj, axis=1, keepdims=False)
            s, t = _block_scores(qb, kb, scale, softcap, qi, kj, block,
                                 row_off, causal, window)
            p = jnp.exp(s - lse_t[..., None])            # [B,KVH,G,bq,bk]
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb)
            dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, dob)
            ds = p * (dp - D_t[..., None])
            if softcap is not None:
                ds = ds * (1.0 - t * t)
            ds = ds * scale
            dq_b = dq_b + jnp.einsum("bkgqs,bskd->bqkgd", ds, kb)
            dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qb)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(
                    dk_acc, kj * block, block, axis=1) + dk_blk,
                kj * block, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(
                    dv_acc, kj * block, block, axis=1) + dv_blk,
                kj * block, axis=1)
            return (dq_b, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, block, KVH, G, hd), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((B, S_kv, KVH, hd), jnp.float32)
    dv0 = jnp.zeros((B, S_kv, KVH, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KVH, G, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attn_jnp(q, k, v, cfg: ModelConfig, *, causal: bool,
                   window: Optional[int] = None, block: int = 512) -> jax.Array:
    """Blocked online-softmax attention in pure jnp (O(S) memory), with a
    flash-style custom VJP (blockwise recompute; residuals are (o, lse)).

    The reference formulation lowered by the dry-run for long sequences —
    same math as the Pallas kernel, expressed for XLA.
    q: [B,S,H,hd]; k,v: [B,S_kv,KVH,hd].
    """
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = hd ** -0.5
    qf = q.reshape(B, S, KVH, G, hd).astype(jnp.float32)
    out = _flash(qf, k.astype(jnp.float32), v.astype(jnp.float32), scale,
                 cfg.attn_softcap, causal, window, block)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _dense_attn(q, k, v, cfg: ModelConfig, *, causal: bool,
                window: Optional[int] = None) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,S_kv,KVH,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    S_kv, KVH = k.shape[1], k.shape[2]
    if S >= 2048 and S_kv >= 2048 and S % 512 == 0 and S_kv % 512 == 0:
        return flash_attn_jnp(q, k, v, cfg, causal=causal, window=window)
    group = H // KVH
    scale = hd ** -0.5
    qg = q.reshape(B, S, KVH, group, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    rows = jnp.arange(S)[:, None] + (S_kv - S)      # align ends (decode-friendly)
    cols = jnp.arange(S_kv)[None, :]
    mask = jnp.ones((S, S_kv), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention(params: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, layer_kind: str = "attn_mlp",
              block_lists=None, causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``block_lists``: optional (kv_idx, counts) roaring-extracted block lists;
    when provided and ``cfg.attn_impl == 'sparse'``, the block-sparse path is
    used (this is how long-context cells stay sub-quadratic).
    """
    q, k, v = _project_qkv(params, x, cfg, positions)
    local = "local" in layer_kind
    if cfg.attn_impl == "sparse" and block_lists is not None and not local:
        kv_idx, counts = block_lists
        out = sparse_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), kv_idx, counts,
            cfg.sparse_block, cfg.sparse_block, causal, cfg.attn_softcap,
            None, False)
        out = out.transpose(0, 2, 1, 3)
    else:
        out = _dense_attn(q, k, v, cfg, causal=causal,
                          window=cfg.window if local else None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def attention_decode(params: dict, x: jax.Array, cfg: ModelConfig, *,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, layer_kind: str = "attn_mlp"):
    """Single-token decode against a dense KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, KVH, hd]; pos: i32[B] current index.
    Returns (out [B,1,d], new_cache_k, new_cache_v). The roaring-paged cache
    variant lives in repro.serve (kernels.sparse_attn.paged_decode).
    """
    B, _, d = x.shape
    positions = pos[:, None]
    q, k, v = _project_qkv(params, x, cfg, positions)
    cache_k = jax.vmap(
        lambda c, kk, p: jax.lax.dynamic_update_slice_in_dim(c, kk, p, axis=0)
    )(cache_k, k.astype(cache_k.dtype), pos)
    cache_v = jax.vmap(lambda c, vv, p: jax.lax.dynamic_update_slice_in_dim(c, vv, p, axis=0)
                       )(cache_v, v.astype(cache_v.dtype), pos)
    S_max, KVH = cache_k.shape[1], cache_k.shape[2]
    H, hd = q.shape[2], q.shape[3]
    group = H // KVH
    scale = hd ** -0.5
    # sequence-parallel long-context decode: keep scores sharded along the
    # cache's sequence dim so softmax/PV combine shard-local partials with
    # tiny all-reduces instead of all-gathering the KV cache (11.5 GB/step
    # per device on qwen2-72b@524k before this constraint; see §Perf)
    seq_parallel = S_max >= (1 << 17)
    from repro.distributed import context as dctx
    qg = q.reshape(B, KVH, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * scale
    if seq_parallel:
        s = dctx.constrain(s, (None, None, None, "all"))
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    cols = jnp.arange(S_max)[None, :]
    live = cols <= pos[:, None]
    if "local" in layer_kind:
        live &= cols > (pos[:, None] - cfg.window)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if seq_parallel:
        p = dctx.constrain(p, (None, None, None, "all"))
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype)),
            cache_k, cache_v)


def cross_attention(params: dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Encoder-decoder cross attention (whisper): q from x, k/v from memory."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(x.dtype))
    out = _dense_attn(q, k, v, cfg, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
