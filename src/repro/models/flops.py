"""Analytic FLOP/byte model per (architecture x shape) cell.

Why analytic: XLA's HloCostAnalysis counts each while-loop body ONCE (no
trip-count multiplication — verified in tests/test_flops_model.py), so a
layer-scanned model under-reports by ~n_superblocks x. The roofline's compute
term therefore uses this analytic model, which is cross-validated against
``cost_analysis`` on fully-unrolled reduced configs (no loops -> HLO counts
are complete) in the same test.

Conventions: one MAC = 2 FLOPs; softmax/norms/elementwise included at their
op counts; backward = 2x forward matmul FLOPs (param + activation grads).
"""

from __future__ import annotations

import dataclasses

from .config import ModelConfig


@dataclasses.dataclass
class FlopCount:
    matmul: float = 0.0
    attention: float = 0.0        # score + pv matmuls (separate: masks change it)
    elementwise: float = 0.0

    @property
    def total(self) -> float:
        return self.matmul + self.attention + self.elementwise

    def scaled(self, f: float) -> "FlopCount":
        return FlopCount(self.matmul * f, self.attention * f,
                         self.elementwise * f)

    def __add__(self, o: "FlopCount") -> "FlopCount":
        return FlopCount(self.matmul + o.matmul, self.attention + o.attention,
                         self.elementwise + o.elementwise)


def _attn_visible(S_q: int, S_kv: int, causal: bool, window) -> float:
    """Average visible kv positions per query row."""
    if not causal:
        vis = S_kv
    else:
        # rows aligned at the end: row i sees (S_kv - S_q + i + 1)
        vis = S_kv - S_q / 2 + 0.5
    if window is not None:
        vis = min(vis, window)
    return max(vis, 1.0)


def layer_flops(cfg: ModelConfig, kind: str, B: int, S_q: int, S_kv: int,
                decode: bool = False) -> FlopCount:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    T = B * S_q                               # tokens processed
    fc = FlopCount()
    if kind.startswith("attn"):
        fc.matmul += 2 * T * d * hd * (H + 2 * KVH)       # qkv proj
        fc.matmul += 2 * T * H * hd * d                   # out proj
        causal = True
        window = cfg.window if "local" in kind else None
        vis = _attn_visible(S_q, S_kv, causal and not decode, window)
        if decode:
            vis = min(S_kv, window) if window else S_kv
        fc.attention += 2 * 2 * B * H * S_q * vis * hd    # scores + pv
        fc.elementwise += 6 * B * H * S_q * vis           # softmax/softcap
    elif kind.startswith("mamba"):
        di, st = cfg.ssm_expand * d, cfg.ssm_state
        fc.matmul += 2 * T * d * 2 * di                   # in proj
        fc.matmul += 2 * T * di * cfg.ssm_conv            # conv
        fc.matmul += 2 * T * di * (2 * st + 1)            # x proj
        fc.matmul += 2 * T * di * d                       # out proj
        fc.elementwise += 8 * T * di * st                 # selective scan
    elif kind == "rwkv":
        fc.matmul += 2 * T * d * d * 6                    # r,k,v,g,decay,out
        fc.elementwise += 6 * T * d * 64                  # wkv state update/read
        fc.matmul += 2 * T * d * f * 2                    # channel mix
        return fc                                         # no separate MLP
    n_mats = 3 if cfg.gated_mlp else 2
    if kind.endswith("_moe"):
        fc.matmul += 2 * T * d * cfg.n_experts            # router
        fc.matmul += 2 * T * cfg.top_k * d * f * n_mats   # routed experts
    elif kind.endswith("_mlp"):
        fc.matmul += 2 * T * d * f * n_mats
    return fc


def cell_flops(cfg: ModelConfig, *, kind: str, seq_len: int,
               global_batch: int) -> FlopCount:
    """kind: 'train' | 'prefill' | 'decode' (one new token, cache=seq_len)."""
    decode = kind == "decode"
    B = global_batch
    S_q = 1 if decode else seq_len
    S_kv = seq_len
    fc = FlopCount()
    for bk in [k for _ in range(cfg.n_superblocks) for k in cfg.block_kinds()]:
        fc = fc + layer_flops(cfg, bk, B, S_q, S_kv, decode=decode)
    if cfg.layer_pattern == "encdec":
        enc_S = 256                                      # stub frame count
        for _ in range(cfg.n_enc_layers):
            fc = fc + layer_flops(cfg, "attn_mlp", B, enc_S, enc_S)
        for _ in range(cfg.n_layers):                    # cross attention
            fc = fc + layer_flops(cfg, "attn", B, S_q, enc_S, decode=decode)
    # unembed + loss
    T = B * S_q
    fc.matmul += 2 * T * cfg.d_model * cfg.vocab
    fc.elementwise += 5 * T * cfg.vocab
    if kind == "train":
        fc = fc.scaled(3.0)                              # fwd + bwd(2x)
    return fc


def model_flops_reference(cfg: ModelConfig, *, kind: str, seq_len: int,
                          global_batch: int) -> float:
    """The standard 6·N·D (train) / 2·N_active·D (inference) reference."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch                         # decode: D = B tokens


def cell_hbm_bytes(cfg: ModelConfig, *, kind: str, seq_len: int,
                   global_batch: int, optimizer: str = "adamw") -> float:
    """First-order HBM traffic: params once (+grad/opt for train), KV cache
    for decode, activations for train/prefill."""
    bp = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    n = cfg.param_count()
    B = global_batch
    if kind == "decode":
        kv = (2 * sum(1 for _ in range(cfg.n_superblocks)
                      for k in cfg.block_kinds() if k.startswith("attn"))
              * cfg.n_kv_heads * cfg.hd * seq_len * B * 2)
        return n * bp + kv
    act = B * seq_len * cfg.d_model * 2 * (cfg.n_layers + 2)
    if kind == "train":
        opt_b = 8.0 if optimizer.startswith("adamw") else 0.1  # factored
        return n * (bp + 4 + opt_b) + act                # + grad f32 + opt
    return n * bp + act
