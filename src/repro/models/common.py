"""Shared model components: norms, embeddings, RoPE/M-RoPE, initializers.

Plain-pytree style: params are nested dicts of jax.Arrays; every component is
an ``init(rng, ...) -> params`` plus a pure ``apply(params, x) -> y``.
Logical sharding axes are attached via ``repro.distributed.sharding`` at
pjit time (names documented per initializer).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(rng, shape, scale, dtype):
    stddev = scale / max(1.0, np.sqrt(shape[0] if len(shape) > 1 else 1.0))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def rms_norm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def layer_norm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = ((x - mu) * jax.lax.rsqrt(var + eps)
           * params["scale"].astype(jnp.float32)
           + params["bias"].astype(jnp.float32))
    return out.astype(dt)


# ---------------------------------------------------------------- rotary embeddings

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)      # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs    # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Sequence[int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head_dim/2 frequency slots are split into
    (t, h, w) sections, each rotated by its own position stream.

    x: [..., S, H, hd]; positions: [..., S, 3] (text-only inputs pass the same
    value in all three streams, recovering 1-D RoPE exactly).
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)      # [hd/2]
    sec = np.asarray(sections)
    assert sec.sum() == hd // 2, (sections, hd)
    stream_id = jnp.asarray(np.repeat(np.arange(3), sec), jnp.int32)  # [hd/2]
    pos = jnp.take(positions.astype(jnp.float32), stream_id, axis=-1)  # [..., S, hd/2]
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embeddings

def embedding_init(rng, vocab: int, d: int, dtype,
                   vocab_padded: Optional[int] = None) -> dict:
    vp = vocab_padded or vocab
    return {"table": truncated_normal_init(rng, (vp, d), 1.0, dtype)}
    # logical axes: ("vocab"->model, "embed")


def embed(params: dict, tokens: jax.Array, scale_by_sqrt_dim: bool = False) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    if scale_by_sqrt_dim:
        out = out * np.sqrt(out.shape[-1]).astype(out.dtype)
    return out


def unembed(params: dict, x: jax.Array, softcap: Optional[float] = None,
            vocab: Optional[int] = None) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, params["table"])
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    vp = params["table"].shape[0]
    if vocab is not None and vocab < vp:
        # padded vocab slots never win the softmax
        pad = jnp.arange(vp) >= vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def dense_init(rng, shape, dtype, scale: float = 1.0) -> jax.Array:
    return truncated_normal_init(rng, shape, scale, dtype)
