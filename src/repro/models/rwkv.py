"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Attention-free: per-head state S in R^{hd x hd} evolves as
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,     y_t = (r_t S_t)
with w_t a *data-dependent* decay (the Finch novelty) and a bonus term u for
the current token. Train path scans over time; decode is a single-step
recurrence (O(1) per token — this is why the rwkv6 cell runs ``long_500k``
natively).

Simplifications vs the reference implementation (documented in DESIGN.md):
token-shift uses a plain previous-token mix (no LoRA on the mix coefficients)
and the decay LoRA is a single dense layer. Shapes and dataflow match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .config import ModelConfig
from .scan_utils import chunked_scan

HEAD_DIM = 64


def rwkv_init(rng, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = d // HEAD_DIM
    f = cfg.d_ff
    ks = jax.random.split(rng, 10)
    return {
        "wr": common.dense_init(ks[0], (d, d), dtype),
        "wk": common.dense_init(ks[1], (d, d), dtype),
        "wv": common.dense_init(ks[2], (d, d), dtype),
        "wg": common.dense_init(ks[3], (d, d), dtype),
        "wo": common.dense_init(ks[4], (d, d), dtype),
        "w_decay": common.dense_init(ks[5], (d, d), dtype, scale=0.1),
        "decay_bias": jnp.full((d,), -6.0, jnp.float32),
        "bonus_u": jnp.zeros((H, HEAD_DIM), jnp.float32),
        "mix": jnp.full((5, d), 0.5, jnp.float32),       # r,k,v,g,w token-shift
        "ln_x": common.layer_norm_init(d, jnp.float32),
        "cwi": common.dense_init(ks[6], (d, f), dtype),
        "cwo": common.dense_init(ks[7], (f, d), dtype),
        "cmix": jnp.full((1, d), 0.5, jnp.float32),
    }


def _time_shift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _mix(x, xprev, coeff):
    return x * coeff + xprev * (1.0 - coeff)


def rwkv_time_mix(params, x, cfg: ModelConfig):
    B, L, d = x.shape
    H = d // HEAD_DIM
    xp = _time_shift(x)
    mr, mk, mv, mg, mw = [params["mix"][i].astype(x.dtype) for i in range(5)]
    r = jnp.einsum("bld,de->ble", _mix(x, xp, mr), params["wr"].astype(x.dtype))
    k = jnp.einsum("bld,de->ble", _mix(x, xp, mk), params["wk"].astype(x.dtype))
    v = jnp.einsum("bld,de->ble", _mix(x, xp, mv), params["wv"].astype(x.dtype))
    g = jnp.einsum("bld,de->ble", _mix(x, xp, mg), params["wg"].astype(x.dtype))
    wdec = jnp.einsum("bld,de->ble", _mix(x, xp, mw),
                      params["w_decay"].astype(x.dtype))
    # data-dependent decay in (0,1): exp(-exp(bias + lora))
    w = jnp.exp(-jnp.exp(params["decay_bias"] + wdec.astype(jnp.float32)))

    r = r.reshape(B, L, H, HEAD_DIM).astype(jnp.float32)
    k = k.reshape(B, L, H, HEAD_DIM).astype(jnp.float32)
    v = v.reshape(B, L, H, HEAD_DIM).astype(jnp.float32)
    w = w.reshape(B, L, H, HEAD_DIM)
    u = params["bonus_u"]

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                      # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]   # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    S0 = jnp.zeros((B, H, HEAD_DIM, HEAD_DIM), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    _, ys = chunked_scan(step, S0, xs)        # checkpointed chunks (memory)
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, d)
    y = common.layer_norm(params["ln_x"], y)
    y = y * jax.nn.silu(g.astype(jnp.float32)).reshape(B, L, d)
    return jnp.einsum("bld,de->ble", y.astype(x.dtype),
                      params["wo"].astype(x.dtype))


def rwkv_channel_mix(params, x, cfg: ModelConfig):
    xp = _time_shift(x)
    xm = _mix(x, xp, params["cmix"][0].astype(x.dtype))
    h = jnp.einsum("bld,df->blf", xm, params["cwi"].astype(x.dtype))
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("blf,fd->bld", h, params["cwo"].astype(x.dtype))


def rwkv_decode_step(params, x, state, cfg: ModelConfig):
    """x: [B,1,d]; state: (x_prev_tm [B,d], S [B,H,hd,hd], x_prev_cm [B,d])."""
    B, _, d = x.shape
    H = d // HEAD_DIM
    x_tm, S, x_cm = state
    xp = x_tm[:, None, :]
    mr, mk, mv, mg, mw = [params["mix"][i].astype(x.dtype) for i in range(5)]
    r = jnp.einsum("bld,de->ble", _mix(x, xp, mr), params["wr"].astype(x.dtype))
    k = jnp.einsum("bld,de->ble", _mix(x, xp, mk), params["wk"].astype(x.dtype))
    v = jnp.einsum("bld,de->ble", _mix(x, xp, mv), params["wv"].astype(x.dtype))
    g = jnp.einsum("bld,de->ble", _mix(x, xp, mg), params["wg"].astype(x.dtype))
    wdec = jnp.einsum("bld,de->ble", _mix(x, xp, mw),
                      params["w_decay"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(params["decay_bias"] + wdec.astype(jnp.float32)))
    r = r.reshape(B, H, HEAD_DIM).astype(jnp.float32)
    k = k.reshape(B, H, HEAD_DIM).astype(jnp.float32)
    v = v.reshape(B, H, HEAD_DIM).astype(jnp.float32)
    w = w.reshape(B, H, HEAD_DIM)
    u = params["bonus_u"]
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S = w[..., :, None] * S + kv
    y = common.layer_norm(params["ln_x"], y.reshape(B, 1, d))
    y = y * jax.nn.silu(g.astype(jnp.float32)).reshape(B, 1, d)
    out_tm = jnp.einsum("bld,de->ble", y.astype(x.dtype),
                        params["wo"].astype(x.dtype))
    return out_tm, (x[:, 0, :], S, x_cm)


def rwkv_channel_mix_step(params, x, x_prev, cfg: ModelConfig):
    xm = _mix(x, x_prev[:, None, :], params["cmix"][0].astype(x.dtype))
    h = jnp.einsum("bld,df->blf", xm, params["cwi"].astype(x.dtype))
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("blf,fd->bld", h, params["cwo"].astype(x.dtype)), x[:, 0, :]
