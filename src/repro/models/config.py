"""ModelConfig: a single dataclass describing every assigned architecture.

``layer_pattern`` selects the super-block structure the layer scan uses:
  * "dense"        — uniform decoder blocks (attention + MLP)
  * "local_global" — period 2: sliding-window attn / global attn (gemma2)
  * "moe"          — uniform decoder blocks with MoE MLP (dbrx)
  * "moe_alt"      — period 2: dense MLP / MoE MLP (llama4-maverick)
  * "jamba"        — period 8: 7 mamba blocks + 1 attention block, MoE on
                     even in-block positions (jamba 1:7 interleave)
  * "rwkv"         — RWKV6 time-mix + channel-mix blocks (attention-free)
  * "encdec"       — whisper-style encoder-decoder
``frontend`` marks modality stubs ("audio", "vision", None): the launch-time
``input_specs`` provides precomputed frame/patch embeddings for these.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    layer_pattern: str = "dense"
    # attention
    rope_theta: float = 10_000.0
    window: int = 4096                        # sliding window (local layers)
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba) / RWKV
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # enc-dec
    n_enc_layers: int = 0
    frontend: Optional[str] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = True
    gated_mlp: bool = True                    # False: GPT-style 2-matrix MLP
    # training-time attention implementation: "dense" | "sparse" (roaring)
    attn_impl: str = "dense"
    sparse_block: int = 128

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so logits shard cleanly on the model axis
        (standard vocab padding); padded slots are masked at the LM head."""
        return (self.vocab + 255) // 256 * 256

    @property
    def superblock(self) -> int:
        return {"dense": 1, "moe": 1, "rwkv": 1, "local_global": 2,
                "moe_alt": 2, "jamba": 8, "encdec": 1}[self.layer_pattern]

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.superblock == 0, (
            self.name, self.n_layers, self.superblock)
        return self.n_layers // self.superblock

    def block_kinds(self) -> list[str]:
        """Per-layer kind inside one super-block."""
        p = self.layer_pattern
        if p in ("dense", "encdec"):
            return ["attn_mlp"]
        if p == "moe":
            return ["attn_moe"]
        if p == "local_global":
            return ["attn_local_mlp", "attn_mlp"]
        if p == "moe_alt":
            return ["attn_mlp", "attn_moe"]
        if p == "jamba":
            # 7 mamba + 1 attn per super-block; MoE on even in-block positions
            # (0,2,4,6) -> 36 MoE layers at 72L, matching jamba-1.5's 398B
            kinds = []
            for i in range(7):
                kinds.append("mamba_moe" if i % 2 == 0 else "mamba_mlp")
            kinds.append("attn_mlp")
            return kinds
        if p == "rwkv":
            return ["rwkv"]
        raise ValueError(p)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline math."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, H, KVH = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (H + 2 * KVH) + H * hd * d
        n_mats = 3 if self.gated_mlp else 2
        mlp = n_mats * d * f
        moe = self.n_experts * n_mats * d * f
        d_in = self.ssm_expand * d
        mamba = (d * 2 * d_in                          # in_proj (x, z)
                 + d_in * self.ssm_conv                # conv
                 + d_in * (2 * self.ssm_state + 1)     # B, C, dt proj (approx)
                 + d_in * d)                           # out proj
        rwkv = 6 * d * d + 2 * d * f                   # time-mix + channel-mix
        total = v * d + (0 if self.tie_embeddings else v * d)
        for kind in [k for _ in range(self.n_superblocks) for k in self.block_kinds()]:
            if kind.startswith("attn"):
                total += attn
            if kind.startswith("mamba"):
                total += mamba
            if kind == "rwkv":
                total += rwkv
            if kind.endswith("_moe"):
                total += moe
            elif kind.endswith("_mlp") or kind == "attn_mlp":
                total += mlp
        if self.layer_pattern == "encdec":
            # encoder blocks + decoder cross-attention
            total += self.n_enc_layers * (attn + mlp)
            total += self.n_layers * attn             # cross-attn per dec layer
        return total

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.gated_mlp else 2
        inactive = (self.n_experts - self.top_k) * n_mats * d * f
        n_moe = sum(1 for _ in range(self.n_superblocks)
                    for k in self.block_kinds() if k.endswith("_moe"))
        return self.param_count() - n_moe * inactive
