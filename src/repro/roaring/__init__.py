"""``repro.roaring`` — the stable v1 public Roaring surface.

One type, pytree-native: ``RoaringSlab`` carries the slab arrays as leaves
and the static capacity ``C`` as aux data, so it flows through ``jit`` /
``vmap`` / ``shard_map`` unchanged, with operator algebra (``&``, ``|``,
``^``, ``-``) bit-identical to the ``py_roaring`` oracle and a leading
batch axis replacing the old ``index.SlabStack``. ``RoaringFormatSpec`` is
the portable serialization codec behind ``RoaringSlab.serialize`` /
``deserialize``.

The old ``repro.core.jax_roaring.slab_*`` free functions still work but are
deprecated shims over the same engine — see ``docs/MIGRATION.md``.
"""

from repro.core.jax_roaring import (ARRAY_MAX, CHUNK_BITS, CHUNK_SIZE,
                                    KEY_SENTINEL, KIND_ARRAY, KIND_BITMAP,
                                    KIND_EMPTY, KIND_RUN, MAX_RUNS, ROW_WORDS)
from repro.roaring.format import RoaringFormatSpec
from repro.roaring.slab import (RoaringSlab, intersect_all, stack, union_all)

__all__ = [
    "RoaringSlab", "RoaringFormatSpec",
    "stack", "union_all", "intersect_all",
    # layout constants re-exported for consumers inspecting .kinds / .keys
    "CHUNK_BITS", "CHUNK_SIZE", "ARRAY_MAX", "ROW_WORDS", "MAX_RUNS",
    "KEY_SENTINEL", "KIND_EMPTY", "KIND_ARRAY", "KIND_BITMAP", "KIND_RUN",
]
