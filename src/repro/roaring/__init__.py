"""``repro.roaring`` — the stable v1 public Roaring surface.

One type, pytree-native: ``RoaringSlab`` carries the slab arrays as leaves
and the static capacity ``C`` as aux data, so it flows through ``jit`` /
``vmap`` / ``shard_map`` unchanged, with operator algebra (``&``, ``|``,
``^``, ``-``) bit-identical to the ``py_roaring`` oracle and a leading
batch axis replacing the old ``index.SlabStack``. ``RoaringFormatSpec`` is
the portable serialization codec behind ``RoaringSlab.serialize`` /
``deserialize``.

The old ``repro.core.jax_roaring.slab_*`` free functions still work but are
deprecated shims over the same engine — see ``docs/MIGRATION.md``.

``deserialize`` treats byte streams as untrusted: structural violations
raise ``RoaringFormatError`` (with byte-offset context) and ``DecodeLimits``
caps what a hostile stream may allocate. ``repro.roaring.validate`` is the
invariant auditor over host bitmaps, device slabs, and the serving page
table.
"""

from repro.core.jax_roaring import (ARRAY_MAX, CHUNK_BITS, CHUNK_SIZE,
                                    KEY_SENTINEL, KIND_ARRAY, KIND_BITMAP,
                                    KIND_EMPTY, KIND_RUN, MAX_RUNS, ROW_WORDS)
from repro.roaring import validate
from repro.roaring.format import (DecodeLimits, RoaringFormatError,
                                  RoaringFormatSpec)
from repro.roaring.slab import (RoaringSlab, intersect_all, stack, union_all)
from repro.roaring.validate import (AuditReport, InvariantViolation,
                                    Violation, audit_bitmap,
                                    audit_page_table, audit_slab)

__all__ = [
    "RoaringSlab", "RoaringFormatSpec",
    "stack", "union_all", "intersect_all",
    # robustness surface: hardened-codec errors + the invariant auditor
    "RoaringFormatError", "DecodeLimits", "validate",
    "AuditReport", "Violation", "InvariantViolation",
    "audit_bitmap", "audit_slab", "audit_page_table",
    # layout constants re-exported for consumers inspecting .kinds / .keys
    "CHUNK_BITS", "CHUNK_SIZE", "ARRAY_MAX", "ROW_WORDS", "MAX_RUNS",
    "KEY_SENTINEL", "KIND_EMPTY", "KIND_ARRAY", "KIND_BITMAP", "KIND_RUN",
]
