"""Structural invariant auditor for the Roaring data plane.

The paper's data structure is cheap to *verify*, not just to assume: every
container carries its kind, cardinality, and (for runs) run count, so a
linear host-side pass can confirm the whole two-level index is well formed —
sorted-unique keys, per-container cardinality agreeing with the payload
(array length / bitmap popcount / run-length sum), run pairs sorted,
non-overlapping and in-range, and (optionally) the strict best-of-three
canonical-kind rule that makes slab and oracle bit-identical.

Three subjects, one report shape:

* ``audit_bitmap`` — host ``py_roaring.RoaringBitmap``;
* ``audit_slab`` — device ``repro.roaring.RoaringSlab`` (single or stacked:
  a stacked slab audits every member; violations carry the member index);
* ``audit_page_table`` — the serving-side ``RoaringPageTable``: the free
  pool and the per-sequence page sets must exactly partition ``[0,
  n_pages)`` (no leaked pages, no double allocation), and the incremental
  free bitmap must itself audit clean.

Reports are machine-readable: an ``AuditReport`` holds per-container
``Violation`` records (code, container index, key, human detail). Nothing
here raises on bad data by itself — call ``raise_on_violation()`` (used by
``deserialize(check=True)`` / ``from_roaring(check=True)``) to escalate a
dirty report to ``InvariantViolation``, which subclasses
``RoaringFormatError`` so untrusted-input callers keep a single except arm.

``canonical=True`` additionally enforces the strict best-of-three kind rule
(run iff ``4*n_runs < min(2*card, 8192)``; array takes the 4096 tie) — true
for every set-algebra output, but deliberately *not* part of the structural
contract: bulk constructors (``from_sorted_unique``) are 2-kind by design
and foreign streams may legally ship non-canonical kinds.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core import py_roaring as pr
from repro.roaring.format import RoaringFormatError

__all__ = [
    "Violation", "AuditReport", "InvariantViolation",
    "audit_bitmap", "audit_slab", "audit_page_table",
]


class InvariantViolation(RoaringFormatError):
    """A structural audit failed (raised by ``AuditReport.raise_on_violation``
    and the ``check=True`` decode/bridge paths)."""


@dataclasses.dataclass(frozen=True)
class Violation:
    """One invariant breach, anchored to a container.

    ``code`` is a stable machine-readable slug (``key-order``,
    ``card-mismatch``, ``run-pairs``, ``kind-range``, ``canonical-kind``,
    ``page-leak``, ...); ``container`` is the container/row index within its
    bitmap (or ``-1`` for structure-level breaches), ``member`` the stacked-
    slab member (or ``-1``), ``key`` the 16-bit chunk key (or ``-1``)."""

    code: str
    container: int
    key: int
    detail: str
    member: int = -1


@dataclasses.dataclass(frozen=True)
class AuditReport:
    """Machine-readable audit result for one subject."""

    subject: str
    n_containers: int
    violations: Tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_on_violation(self) -> "AuditReport":
        """Escalate a dirty report to ``InvariantViolation``; returns self
        when clean so it chains off decode paths."""
        if self.violations:
            head = "; ".join(
                f"{v.code}@{v.container}: {v.detail}"
                for v in self.violations[:4])
            more = len(self.violations) - 4
            raise InvariantViolation(
                f"{self.subject}: {len(self.violations)} invariant "
                f"violation(s): {head}" + (f"; +{more} more" if more > 0
                                           else ""))
        return self

    def summary(self) -> str:
        return (f"{self.subject}: {self.n_containers} containers audited, "
                + ("clean" if self.ok
                   else f"{len(self.violations)} violation(s)"))


def _minimal_nruns_of_array(vals: np.ndarray) -> int:
    if vals.size == 0:
        return 0
    return int(np.count_nonzero(np.diff(vals.astype(np.int64)) != 1)) + 1


def _check_canonical_kind(out: List[Violation], kind_name: str, card: int,
                          min_nruns: int, i: int, key: int,
                          member: int = -1) -> None:
    """The strict best-of-three rule, mirroring ``jax_roaring._pick_kind``
    and ``py_roaring._canonical``: run wins iff 4*n_runs is strictly smaller
    than every alternative; array takes the 4096 tie against bitmap."""
    size_other = min(2 * card, 2 * pr.ARRAY_MAX) if card <= pr.ARRAY_MAX \
        else 2 * pr.ARRAY_MAX
    want = "run" if (card > 0 and 4 * min_nruns < size_other) else (
        "array" if card <= pr.ARRAY_MAX else "bitmap")
    if kind_name != want:
        out.append(Violation(
            "canonical-kind", i, key,
            f"kind {kind_name} but best-of-three picks {want} "
            f"(card {card}, minimal runs {min_nruns})", member))


def _audit_array(out: List[Violation], vals: np.ndarray, card: int, i: int,
                 key: int, member: int = -1) -> None:
    v = vals.astype(np.int64)
    if v.size != card:
        out.append(Violation(
            "card-mismatch", i, key,
            f"array payload holds {v.size} values, counter says {card}",
            member))
    if v.size and (int(v[0]) < 0 or int(v[-1]) > 0xFFFF):
        out.append(Violation(
            "value-range", i, key,
            f"array values outside [0, 65536): [{int(v[0])}, {int(v[-1])}]",
            member))
    if v.size > pr.ARRAY_MAX:
        out.append(Violation(
            "card-range", i, key,
            f"array container with {v.size} values exceeds the 4096 "
            "threshold", member))
    if v.size > 1 and not bool(np.all(np.diff(v) > 0)):
        out.append(Violation(
            "array-order", i, key, "array values not strictly increasing",
            member))


def _audit_runs(out: List[Violation], starts: np.ndarray, lengths: np.ndarray,
                card: int, i: int, key: int, member: int = -1) -> None:
    s = starts.astype(np.int64)
    l = lengths.astype(np.int64)            # stored as length-1
    if s.size == 0:
        out.append(Violation(
            "run-pairs", i, key, "run container with zero runs", member))
        return
    ends = s + l
    if int(s[0]) < 0 or bool(np.any(ends > 0xFFFF)):
        out.append(Violation(
            "run-range", i, key,
            "run exceeds the 16-bit chunk (start + length - 1 > 65535)",
            member))
    if s.size > 1 and not bool(np.all(s[1:] > ends[:-1])):
        out.append(Violation(
            "run-pairs", i, key, "runs out of order or overlapping", member))
    got = int((l + 1).sum())
    if got != card:
        out.append(Violation(
            "card-mismatch", i, key,
            f"run lengths sum to {got}, counter says {card}", member))


def audit_bitmap(rb: pr.RoaringBitmap, *,
                 canonical: bool = False) -> AuditReport:
    """Structurally audit a host ``RoaringBitmap``; see the module docstring
    for the invariant list. ``canonical=True`` also enforces the strict
    best-of-three kind rule (only guaranteed for set-algebra outputs)."""
    out: List[Violation] = []
    n = len(rb.keys)
    if len(rb.containers) != n:
        out.append(Violation(
            "structure", -1, -1,
            f"{n} keys but {len(rb.containers)} containers"))
        return AuditReport("RoaringBitmap", n, tuple(out))
    prev = -1
    for i, (k, c) in enumerate(zip(rb.keys, rb.containers)):
        k = int(k)
        if not 0 <= k <= 0xFFFF:
            out.append(Violation(
                "key-range", i, k, f"key {k} outside [0, 65536)"))
        if k <= prev:
            out.append(Violation(
                "key-order", i, k,
                f"key {k} not strictly greater than predecessor {prev}"))
        prev = k
        card = int(c.cardinality)
        if card == 0:
            out.append(Violation(
                "card-range", i, k, "empty container present in the index"))
            continue
        if isinstance(c, pr.RunContainer):
            _audit_runs(out, c.starts, c.lengths, card, i, k)
            if canonical:
                _check_canonical_kind(out, "run", card, c.n_runs, i, k)
        elif isinstance(c, pr.BitmapContainer):
            got = pr.popcount_words(c.words)
            if got != card:
                out.append(Violation(
                    "card-mismatch", i, k,
                    f"bitmap popcount {got}, counter says {card}"))
            elif canonical:
                _check_canonical_kind(
                    out, "bitmap", card,
                    _minimal_nruns_of_array(pr.bitmap_to_array(c.words)),
                    i, k)
        else:
            _audit_array(out, c.arr, card, i, k)
            if canonical:
                _check_canonical_kind(
                    out, "array", card, _minimal_nruns_of_array(c.arr), i, k)
    return AuditReport("RoaringBitmap", n, tuple(out))


def _audit_slab_member(out: List[Violation], keys, kinds, cards, nruns,
                       payload, member: int) -> int:
    from repro.core import jax_roaring as jr

    C = keys.shape[-1]
    live = 0
    prev = -1
    sentinel = int(jr.KEY_SENTINEL)
    for i in range(C):
        k, kind, card = int(keys[i]), int(kinds[i]), int(cards[i])
        nr = int(nruns[i])
        if kind not in (jr.KIND_EMPTY, jr.KIND_ARRAY, jr.KIND_BITMAP,
                        jr.KIND_RUN):
            out.append(Violation(
                "kind-range", i, k, f"unknown kind tag {kind}", member))
            continue
        if kind == jr.KIND_EMPTY:
            if k != sentinel:
                out.append(Violation(
                    "key-order", i, k,
                    "empty row carries a live key (not the sentinel)",
                    member))
            if card != 0:
                out.append(Violation(
                    "card-mismatch", i, k,
                    f"empty row with cardinality counter {card}", member))
            continue
        live += 1
        if not 0 <= k <= 0xFFFF:
            out.append(Violation(
                "key-range", i, k, f"key {k} outside [0, 65536)", member))
        if k <= prev:
            out.append(Violation(
                "key-order", i, k,
                f"key {k} not strictly greater than predecessor {prev}",
                member))
        prev = k
        if card <= 0:
            out.append(Violation(
                "card-range", i, k,
                f"live row with cardinality counter {card}", member))
            continue
        row = payload[i]
        if kind == jr.KIND_ARRAY:
            _audit_array(out, row[:card], card, i, k, member)
        elif kind == jr.KIND_BITMAP:
            got = pr.popcount_words(np.ascontiguousarray(row).view(
                np.uint64))
            if got != card:
                out.append(Violation(
                    "card-mismatch", i, k,
                    f"bitmap popcount {got}, counter says {card}", member))
        else:
            if not 0 < nr <= jr.MAX_RUNS:
                out.append(Violation(
                    "run-pairs", i, k,
                    f"run row with nruns counter {nr} outside (0, "
                    f"{jr.MAX_RUNS}]", member))
                continue
            allp = row.astype(np.int64).reshape(-1, 2)
            n_valid = int(np.count_nonzero(allp[:, 0] + allp[:, 1]
                                           < (1 << 16)))
            if n_valid != nr:
                out.append(Violation(
                    "nruns-mismatch", i, k,
                    f"payload holds {n_valid} in-range run pairs, nruns "
                    f"counter says {nr}", member))
            pairs = row[:2 * nr].astype(np.int64)
            _audit_runs(out, pairs[0::2], pairs[1::2], card, i, k, member)
    return live


def audit_slab(slab, *, canonical: bool = False) -> AuditReport:
    """Structurally audit a device ``repro.roaring.RoaringSlab`` (host-side
    pass over the transferred arrays). Stacked slabs audit every member;
    ``Violation.member`` carries the batch index. ``canonical=True`` checks
    the strict best-of-three kind rule per row (round-trips through
    ``to_roaring`` per live row — guaranteed only for engine outputs)."""
    keys = np.asarray(slab.keys)
    kinds = np.asarray(slab.kinds)
    cards = np.asarray(slab.cards)
    nruns = np.asarray(slab.nruns)
    payload = np.asarray(slab.payload)
    out: List[Violation] = []
    if keys.ndim == 1:
        members = [(keys, kinds, cards, nruns, payload, -1)]
    else:
        flat = keys.reshape(-1, keys.shape[-1]).shape[0]
        members = [
            (keys.reshape(flat, keys.shape[-1])[m],
             kinds.reshape(flat, keys.shape[-1])[m],
             cards.reshape(flat, keys.shape[-1])[m],
             nruns.reshape(flat, keys.shape[-1])[m],
             payload.reshape(flat, keys.shape[-1], payload.shape[-1])[m], m)
            for m in range(flat)]
    n_live = 0
    for mk, mkind, mcard, mnr, mpay, m in members:
        n_live += _audit_slab_member(out, mk, mkind, mcard, mnr, mpay, m)
        if canonical:
            for i in range(mk.shape[-1]):
                kind, card = int(mkind[i]), int(mcard[i])
                if kind == 0 or card <= 0:
                    continue
                row = mpay[i]
                if kind == 1:
                    mr = _minimal_nruns_of_array(row[:card])
                    _check_canonical_kind(out, "array", card, mr, i,
                                          int(mk[i]), m)
                elif kind == 2:
                    vals = pr.bitmap_to_array(
                        np.ascontiguousarray(row).view(np.uint64))
                    _check_canonical_kind(out, "bitmap", card,
                                          _minimal_nruns_of_array(vals), i,
                                          int(mk[i]), m)
                else:
                    nr = int(mnr[i])
                    _check_canonical_kind(out, "run", card, nr, i,
                                          int(mk[i]), m)
    return AuditReport("RoaringSlab", n_live, tuple(out))


def audit_page_table(table) -> AuditReport:
    """Audit a ``serve.kv_cache.RoaringPageTable``: the free pool plus the
    per-sequence page sets must exactly partition ``[0, n_pages)`` — a page
    in neither is *leaked*, a page in both (or in two sequences) is *double
    allocated* — and bookkeeping (``seq_len`` vs page count, list order vs
    set) must agree. The free bitmap is structurally audited too."""
    out: List[Violation] = []
    free = set(int(x) for x in table.free.to_array().tolist())
    seen: dict = {}
    for sid, pages in table.seq_pages.items():
        if len(set(pages)) != len(pages):
            out.append(Violation(
                "page-dup", -1, -1,
                f"sequence {sid} lists a page twice: {pages}"))
        for p in pages:
            if p in free:
                out.append(Violation(
                    "page-double-alloc", -1, -1,
                    f"page {p} of sequence {sid} is also in the free pool"))
            if p in seen:
                out.append(Violation(
                    "page-double-alloc", -1, -1,
                    f"page {p} allocated to sequences {seen[p]} and {sid}"))
            if not 0 <= p < table.n_pages:
                out.append(Violation(
                    "page-range", -1, -1,
                    f"page {p} of sequence {sid} outside [0, "
                    f"{table.n_pages})"))
            seen[p] = sid
        need = (table.seq_len.get(sid, 0) + table.page_size - 1) \
            // table.page_size
        if len(pages) < need:
            out.append(Violation(
                "page-accounting", -1, -1,
                f"sequence {sid} holds {len(pages)} pages for "
                f"{table.seq_len.get(sid, 0)} tokens (needs {need})"))
    missing = sorted(set(range(table.n_pages)) - free - set(seen))
    if missing:
        out.append(Violation(
            "page-leak", -1, -1,
            f"{len(missing)} page(s) neither free nor allocated: "
            f"{missing[:8]}" + ("..." if len(missing) > 8 else "")))
    for sid in table.seq_len:
        if sid not in table.seq_pages:
            out.append(Violation(
                "page-accounting", -1, -1,
                f"sequence {sid} has a length but no page list"))
    inner = audit_bitmap(table.free)
    out.extend(inner.violations)
    return AuditReport("RoaringPageTable", len(table.seq_pages), tuple(out))
