"""Portable Roaring serialization (``RoaringFormatSpec``).

The interchange format of the Roaring ecosystem (the layout CRoaring,
RoaringBitmap/Java, and pyroaring all read and write — see the 2017
implementation paper, S4 "Serialization"):

* little-endian ``u32`` cookie — ``12347`` (``SERIAL_COOKIE``, low 16 bits)
  when any run container is present, with ``n_containers - 1`` packed in the
  high 16 bits; plain ``12346`` (``SERIAL_COOKIE_NO_RUNCONTAINER``) followed
  by a ``u32`` container count otherwise;
* with runs: a bitset of ``ceil(n/8)`` bytes flagging which containers are
  run-encoded;
* the *descriptive header*: one ``(key u16, cardinality-1 u16)`` pair per
  container, in ascending key order;
* the *offset header* — one ``u32`` byte offset (from the start of the
  stream) per container — present when there are no runs, or when
  ``n_containers >= NO_OFFSET_THRESHOLD`` (4);
* container payloads in key order: arrays as ``card`` sorted ``u16`` values,
  bitmaps as 1024 little-endian ``u64`` words (8 kB), runs as a ``u16`` run
  count followed by ``(start u16, length-1 u16)`` pairs.

Kind round-trips exactly for every container the format can represent: a
non-run container is a bitmap iff ``cardinality > 4096``, which is precisely
the slab/oracle canonical rule (array takes the 4096 tie), so canonical
bitmaps — every set-algebra output — serialize and deserialize to identical
kinds, payloads, and bytes. The codec is host-side (bytes are not a device
type); the device entry points are ``RoaringSlab.serialize`` /
``RoaringSlab.deserialize``.
"""

from __future__ import annotations

import struct
from typing import List

import numpy as np

from repro.core import py_roaring as pr

__all__ = ["RoaringFormatSpec"]


class RoaringFormatSpec:
    """Codec constants + (de)serialization of host ``RoaringBitmap``s."""

    SERIAL_COOKIE: int = 12347
    SERIAL_COOKIE_NO_RUNCONTAINER: int = 12346
    NO_OFFSET_THRESHOLD: int = 4

    @classmethod
    def serialize(cls, rb: pr.RoaringBitmap) -> bytes:
        """``RoaringBitmap`` -> portable byte stream (format above)."""
        n = len(rb.keys)
        has_run = any(isinstance(c, pr.RunContainer) for c in rb.containers)
        buf = bytearray()
        if has_run:
            buf += struct.pack("<I", cls.SERIAL_COOKIE | ((n - 1) << 16))
            bitset = bytearray((n + 7) // 8)
            for i, c in enumerate(rb.containers):
                if isinstance(c, pr.RunContainer):
                    bitset[i >> 3] |= 1 << (i & 7)
            buf += bitset
        else:
            buf += struct.pack("<II", cls.SERIAL_COOKIE_NO_RUNCONTAINER, n)
        for k, c in zip(rb.keys, rb.containers):
            if not 0 <= k < (1 << 16):
                raise ValueError(f"container key {k} outside the 32-bit "
                                 "universe the portable format addresses")
            if c.cardinality == 0:
                raise ValueError(f"empty container at key {k} (the format "
                                 "has no empty-container encoding)")
            buf += struct.pack("<HH", k, c.cardinality - 1)
        with_offsets = (not has_run) or n >= cls.NO_OFFSET_THRESHOLD
        off_pos = len(buf)
        if with_offsets:
            buf += b"\x00" * (4 * n)
        offsets: List[int] = []
        for c in rb.containers:
            offsets.append(len(buf))
            if isinstance(c, pr.RunContainer):
                buf += struct.pack("<H", c.n_runs)
                pairs = np.empty(2 * c.n_runs, dtype="<u2")
                pairs[0::2] = c.starts
                pairs[1::2] = c.lengths          # stored as length-1 already
                buf += pairs.tobytes()
            elif isinstance(c, pr.BitmapContainer):
                buf += np.ascontiguousarray(c.words, dtype="<u8").tobytes()
            else:
                buf += np.ascontiguousarray(c.arr, dtype="<u2").tobytes()
        if with_offsets:
            buf[off_pos:off_pos + 4 * n] = struct.pack(f"<{n}I", *offsets)
        return bytes(buf)

    @classmethod
    def deserialize(cls, data: bytes) -> pr.RoaringBitmap:
        """Portable byte stream -> ``RoaringBitmap`` (kinds reconstructed:
        run containers from the flag bitset, bitmap iff card > 4096)."""
        if len(data) < 4:
            raise ValueError("truncated stream: missing cookie")
        (cookie,) = struct.unpack_from("<I", data, 0)
        pos = 4
        if cookie & 0xFFFF == cls.SERIAL_COOKIE:
            n = (cookie >> 16) + 1
            nbytes = (n + 7) // 8
            runbits = data[pos:pos + nbytes]
            pos += nbytes
            is_run = [(runbits[i >> 3] >> (i & 7)) & 1 == 1 for i in range(n)]
            with_offsets = n >= cls.NO_OFFSET_THRESHOLD
        elif cookie == cls.SERIAL_COOKIE_NO_RUNCONTAINER:
            (n,) = struct.unpack_from("<I", data, pos)
            pos += 4
            is_run = [False] * n
            with_offsets = True
        else:
            raise ValueError(f"not a portable roaring stream (cookie "
                             f"{cookie & 0xFFFF})")
        keys, cards = [], []
        for _ in range(n):
            k, cm1 = struct.unpack_from("<HH", data, pos)
            pos += 4
            keys.append(k)
            cards.append(cm1 + 1)
        if with_offsets:
            pos += 4 * n                          # derivable; not needed here
        rb = pr.RoaringBitmap()
        for i in range(n):
            if is_run[i]:
                (n_runs,) = struct.unpack_from("<H", data, pos)
                pos += 2
                pairs = np.frombuffer(data, dtype="<u2", count=2 * n_runs,
                                      offset=pos).astype(np.int64)
                pos += 4 * n_runs
                c: pr.Container = pr.RunContainer(pairs[0::2], pairs[1::2])
            elif cards[i] > pr.ARRAY_MAX:
                words = np.frombuffer(data, dtype="<u8", count=1024,
                                      offset=pos).astype(np.uint64)
                pos += 8192
                c = pr.BitmapContainer(words, cardinality=cards[i])
            else:
                arr = np.frombuffer(data, dtype="<u2", count=cards[i],
                                    offset=pos).astype(np.uint16)
                pos += 2 * cards[i]
                c = pr.ArrayContainer(arr)
            if c.cardinality != cards[i]:
                raise ValueError(f"container {i}: header cardinality "
                                 f"{cards[i]} != payload {c.cardinality}")
            rb.keys.append(keys[i])
            rb.containers.append(c)
        return rb
