"""Portable Roaring serialization (``RoaringFormatSpec``) — hardened codec.

The interchange format of the Roaring ecosystem (the layout CRoaring,
RoaringBitmap/Java, and pyroaring all read and write — see the 2017
implementation paper, S4 "Serialization"):

* little-endian ``u32`` cookie — ``12347`` (``SERIAL_COOKIE``, low 16 bits)
  when any run container is present, with ``n_containers - 1`` packed in the
  high 16 bits; plain ``12346`` (``SERIAL_COOKIE_NO_RUNCONTAINER``) followed
  by a ``u32`` container count otherwise;
* with runs: a bitset of ``ceil(n/8)`` bytes flagging which containers are
  run-encoded;
* the *descriptive header*: one ``(key u16, cardinality-1 u16)`` pair per
  container, in ascending key order;
* the *offset header* — one ``u32`` byte offset (from the start of the
  stream) per container — present when there are no runs, or when
  ``n_containers >= NO_OFFSET_THRESHOLD`` (4);
* container payloads in key order: arrays as ``card`` sorted ``u16`` values,
  bitmaps as 1024 little-endian ``u64`` words (8 kB), runs as a ``u16`` run
  count followed by ``(start u16, length-1 u16)`` pairs.

Kind round-trips exactly for every container the format can represent: a
non-run container is a bitmap iff ``cardinality > 4096``, which is precisely
the slab/oracle canonical rule (array takes the 4096 tie), so canonical
bitmaps — every set-algebra output — serialize and deserialize to identical
kinds, payloads, and bytes. The codec is host-side (bytes are not a device
type); the device entry points are ``RoaringSlab.serialize`` /
``RoaringSlab.deserialize``.

Threat model: ``deserialize`` treats its input as *untrusted* (a cookie from
a hostile client, a corrupted object-store blob). Every read is
bounds-checked before it happens, the offset header is verified against the
actual payload positions, keys must be sorted-unique, run pairs must be
sorted / non-overlapping / in-range, bitmap popcounts and array lengths must
match the declared cardinalities, and a ``DecodeLimits`` guard caps the
container count and stream size so a lying header cannot drive a large
allocation. Any violation raises a ``RoaringFormatError`` subclass carrying
the byte offset of the offending read — never a bare numpy/struct error, and
never a silently-wrong bitmap. An accepted stream re-serializes
byte-for-byte (the layout is fully determined by the parsed structure), so
``serialize(deserialize(data)) == data`` for every stream ``deserialize``
accepts.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List, Optional

import numpy as np

from repro.core import py_roaring as pr

__all__ = [
    "RoaringFormatSpec", "DecodeLimits",
    "RoaringFormatError", "TruncatedStreamError", "CookieError",
    "DescriptiveHeaderError", "OffsetHeaderError", "PayloadError",
    "TrailingDataError", "DecodeLimitError",
]

# hard structural ceilings of the format itself (u16 keys -> at most 2^16
# containers; a run payload row holds at most 2048 (start, len-1) pairs in
# the device slab layout)
_MAX_CONTAINERS = 1 << 16
_MAX_RUNS = 2048


class RoaringFormatError(ValueError):
    """A portable-format stream violated the format contract.

    Carries the byte ``offset`` of the offending read and, when the failure
    is container-scoped, the ``container`` index — so callers (and fuzz
    triage) can point at the exact corrupt byte. Subclasses name the stream
    region that failed; all of them are ``ValueError``s, so pre-hardening
    callers that caught ``ValueError`` still work.
    """

    def __init__(self, msg: str, *, offset: Optional[int] = None,
                 container: Optional[int] = None):
        self.offset = offset
        self.container = container
        ctx = []
        if container is not None:
            ctx.append(f"container {container}")
        if offset is not None:
            ctx.append(f"byte offset {offset}")
        super().__init__(msg + (f" [{', '.join(ctx)}]" if ctx else ""))


class TruncatedStreamError(RoaringFormatError):
    """The stream ends before a required read (cookie, header, payload)."""


class CookieError(RoaringFormatError):
    """The leading u32 is not a Roaring cookie, or lies about the stream
    (e.g. a run cookie whose run bitset flags no container)."""


class DescriptiveHeaderError(RoaringFormatError):
    """Keys out of order / duplicated in the descriptive header."""


class OffsetHeaderError(RoaringFormatError):
    """An offset-header entry disagrees with the actual payload position."""


class PayloadError(RoaringFormatError):
    """A container payload contradicts its header: bad run pairs, unsorted
    array values, or a bitmap popcount that differs from the declared
    cardinality."""


class TrailingDataError(RoaringFormatError):
    """Bytes remain after the last container payload."""


class DecodeLimitError(RoaringFormatError):
    """The stream exceeds the caller's ``DecodeLimits`` resource guard."""


@dataclasses.dataclass(frozen=True)
class DecodeLimits:
    """Resource guard for decoding untrusted streams.

    ``max_containers`` caps the container count *before* any per-container
    work happens (the format ceiling is 2^16; servers decoding hostile
    cookies should set this to their real schema bound), and
    ``max_stream_bytes`` rejects oversized blobs up front. Bounds checking
    already guarantees allocations never exceed the actual stream length —
    the limits exist so a hostile 256 MB cookie is refused in O(1) instead
    of parsed in O(n).
    """

    max_containers: int = _MAX_CONTAINERS
    max_stream_bytes: int = 1 << 28           # 256 MiB

    def __post_init__(self):
        if self.max_containers < 1 or self.max_stream_bytes < 8:
            raise ValueError("DecodeLimits must allow at least one "
                             "container and an 8-byte stream")


_DEFAULT_LIMITS = DecodeLimits()


def _raise_unless_sorted(arr: np.ndarray, i: int, payload_pos: int) -> None:
    """Exact-offset strictly-increasing check for one array payload."""
    if (arr[1:] > arr[:-1]).all():
        return
    bad = np.nonzero(arr[1:] <= arr[:-1])[0]
    j = int(bad[0])
    raise PayloadError(
        f"array values not sorted-unique: value[{j + 1}] = "
        f"{int(arr[j + 1])} after value[{j}] = {int(arr[j])}",
        offset=payload_pos + 2 * (j + 1), container=i)


class RoaringFormatSpec:
    """Codec constants + (de)serialization of host ``RoaringBitmap``s."""

    SERIAL_COOKIE: int = 12347
    SERIAL_COOKIE_NO_RUNCONTAINER: int = 12346
    NO_OFFSET_THRESHOLD: int = 4

    @classmethod
    def serialize(cls, rb: pr.RoaringBitmap) -> bytes:
        """``RoaringBitmap`` -> portable byte stream (format above)."""
        n = len(rb.keys)
        if n > _MAX_CONTAINERS:
            raise ValueError(f"{n} containers exceed the format's 2^16 "
                             "container ceiling")
        has_run = any(isinstance(c, pr.RunContainer) for c in rb.containers)
        buf = bytearray()
        if has_run:
            buf += struct.pack("<I", cls.SERIAL_COOKIE | ((n - 1) << 16))
            bitset = bytearray((n + 7) // 8)
            for i, c in enumerate(rb.containers):
                if isinstance(c, pr.RunContainer):
                    bitset[i >> 3] |= 1 << (i & 7)
            buf += bitset
        else:
            buf += struct.pack("<II", cls.SERIAL_COOKIE_NO_RUNCONTAINER, n)
        for k, c in zip(rb.keys, rb.containers):
            if not 0 <= k < (1 << 16):
                raise ValueError(f"container key {k} outside the 32-bit "
                                 "universe the portable format addresses")
            if c.cardinality == 0:
                raise ValueError(f"empty container at key {k} (the format "
                                 "has no empty-container encoding)")
            buf += struct.pack("<HH", k, c.cardinality - 1)
        with_offsets = (not has_run) or n >= cls.NO_OFFSET_THRESHOLD
        off_pos = len(buf)
        if with_offsets:
            buf += b"\x00" * (4 * n)
        offsets: List[int] = []
        for c in rb.containers:
            offsets.append(len(buf))
            if isinstance(c, pr.RunContainer):
                buf += struct.pack("<H", c.n_runs)
                pairs = np.empty(2 * c.n_runs, dtype="<u2")
                pairs[0::2] = c.starts
                pairs[1::2] = c.lengths          # stored as length-1 already
                buf += pairs.tobytes()
            elif isinstance(c, pr.BitmapContainer):
                buf += np.ascontiguousarray(c.words, dtype="<u8").tobytes()
            else:
                buf += np.ascontiguousarray(c.arr, dtype="<u2").tobytes()
        if with_offsets:
            buf[off_pos:off_pos + 4 * n] = struct.pack(f"<{n}I", *offsets)
        return bytes(buf)

    # -- hardened decode ------------------------------------------------------
    @classmethod
    def deserialize(cls, data: bytes, *,
                    limits: Optional[DecodeLimits] = None,
                    check: bool = False) -> pr.RoaringBitmap:
        """Untrusted portable byte stream -> ``RoaringBitmap``.

        Structural validation always runs (bounds, offsets, key order, run
        pairs, cardinality-vs-payload agreement); ``check=True`` additionally
        runs the full invariant auditor (``repro.roaring.validate``) on the
        result and raises ``InvariantViolation`` (a ``RoaringFormatError``)
        if it reports anything. ``limits`` defaults to ``DecodeLimits()``.
        """
        lim = limits if limits is not None else _DEFAULT_LIMITS
        ln = len(data)
        if ln > lim.max_stream_bytes:
            raise DecodeLimitError(
                f"stream of {ln} bytes exceeds max_stream_bytes "
                f"{lim.max_stream_bytes}", offset=0)

        def need(pos: int, k: int, what: str,
                 container: Optional[int] = None) -> None:
            if pos + k > ln:
                raise TruncatedStreamError(
                    f"truncated stream: {what} needs {k} bytes, "
                    f"{ln - pos} remain", offset=pos, container=container)

        need(0, 4, "cookie")
        (cookie,) = struct.unpack_from("<I", data, 0)
        pos = 4
        if cookie & 0xFFFF == cls.SERIAL_COOKIE:
            n = (cookie >> 16) + 1
            if n > lim.max_containers:
                raise DecodeLimitError(
                    f"cookie declares {n} containers, limit is "
                    f"{lim.max_containers}", offset=0)
            nbytes = (n + 7) // 8
            need(pos, nbytes, "run-flag bitset")
            runbits = data[pos:pos + nbytes]
            pos += nbytes
            is_run = [(runbits[i >> 3] >> (i & 7)) & 1 == 1 for i in range(n)]
            if not any(is_run):
                raise CookieError(
                    "run cookie (12347) but the run bitset flags no "
                    "container (the no-run encoding is cookie 12346)",
                    offset=4)
            with_offsets = n >= cls.NO_OFFSET_THRESHOLD
        elif cookie == cls.SERIAL_COOKIE_NO_RUNCONTAINER:
            need(pos, 4, "container count")
            (n,) = struct.unpack_from("<I", data, pos)
            pos += 4
            if n > _MAX_CONTAINERS:
                raise CookieError(
                    f"container count {n} exceeds the format's 2^16 "
                    "container ceiling", offset=4)
            if n > lim.max_containers:
                raise DecodeLimitError(
                    f"stream declares {n} containers, limit is "
                    f"{lim.max_containers}", offset=4)
            is_run = [False] * n
            with_offsets = True
        else:
            raise CookieError(
                f"not a portable roaring stream (cookie {cookie & 0xFFFF})",
                offset=0)

        desc_pos = pos
        need(pos, 4 * n, "descriptive header")
        # plain Python ints throughout the loop: per-container numpy scalar
        # extraction is the decode loop's biggest fixed cost. Below ~64
        # containers a single bulk struct.unpack_from beats the numpy
        # frombuffer/astype/tolist chain outright; above it, numpy wins.
        if n < 64:
            flat_desc = struct.unpack_from(f"<{2 * n}H", data, pos)
            key_list = flat_desc[0::2]
            card_list = [c + 1 for c in flat_desc[1::2]]
            pos += 4 * n
            for i in range(1, n):
                if key_list[i] <= key_list[i - 1]:
                    raise DescriptiveHeaderError(
                        f"keys not sorted-unique: key[{i}] = "
                        f"{key_list[i]} after key[{i - 1}] = "
                        f"{key_list[i - 1]}",
                        offset=desc_pos + 4 * i, container=i)
        else:
            desc = np.frombuffer(data, dtype="<u2", count=2 * n, offset=pos)
            keys = desc[0::2].astype(np.int64)
            pos += 4 * n
            if not (keys[1:] > keys[:-1]).all():
                bad = np.nonzero(keys[1:] <= keys[:-1])[0]
                i = int(bad[0])
                raise DescriptiveHeaderError(
                    f"keys not sorted-unique: key[{i + 1}] = "
                    f"{int(keys[i + 1])} after key[{i}] = {int(keys[i])}",
                    offset=desc_pos + 4 * (i + 1), container=i + 1)
            key_list = keys.tolist()
            card_list = (desc[1::2].astype(np.int64) + 1).tolist()

        off_pos = pos
        off_list: Optional[tuple] = None
        if with_offsets:
            need(pos, 4 * n, "offset header")
            off_list = (struct.unpack_from(f"<{n}I", data, pos) if n < 64
                        else tuple(np.frombuffer(data, dtype="<u4", count=n,
                                                 offset=pos).tolist()))
            pos += 4 * n

        rb = pr.RoaringBitmap()
        # bitmap popcount and array sortedness verification are deferred
        # and batched: ONE ufunc launch over every payload of the class
        # beats per-container launches ~4x at typical container counts
        # (tiny-array numpy calls are dominated by launch overhead)
        bitmap_checks: list = []             # (container, payload_pos)
        bitmap_words: list = []
        array_checks: list = []              # (container, payload_pos, arr)
        for i in range(n):
            if off_list is not None and off_list[i] != pos:
                raise OffsetHeaderError(
                    f"offset header says payload at {off_list[i]}, "
                    f"actual position is {pos}", offset=off_pos + 4 * i,
                    container=i)
            card_i = card_list[i]
            if is_run[i]:
                if pos + 2 > ln:
                    need(pos, 2, "run count", container=i)
                (n_runs,) = struct.unpack_from("<H", data, pos)
                run_pos = pos
                pos += 2
                if n_runs == 0:
                    raise PayloadError(
                        "run container with zero runs (cardinality is "
                        "at least 1)", offset=run_pos, container=i)
                if n_runs > _MAX_RUNS:
                    raise PayloadError(
                        f"{n_runs} runs exceed the 2048-run container "
                        "ceiling", offset=run_pos, container=i)
                if pos + 4 * n_runs > ln:
                    need(pos, 4 * n_runs, "run pairs", container=i)
                c: Optional[pr.Container] = None
                if n_runs >= 32:
                    # vectorized fast pass for long run lists; on any
                    # violation fall through to the Python walk, which
                    # pins the exact offending pair and byte offset
                    pv = np.frombuffer(data, dtype="<u2", count=2 * n_runs,
                                       offset=pos).astype(np.int64)
                    s_arr, l_arr = pv[0::2], pv[1::2]
                    e_arr = s_arr + l_arr                # inclusive ends
                    if ((e_arr <= 0xFFFF).all()
                            and (s_arr[1:] > e_arr[:-1]).all()
                            and int(l_arr.sum()) + n_runs == card_i):
                        c = pr.RunContainer(s_arr, l_arr)
                if c is None:
                    # pure-Python pair walk: for the short run lists real
                    # data produces, this beats five+ numpy ops on tiny
                    # arrays — and it is the exact-offset error path
                    flat = struct.unpack_from(f"<{2 * n_runs}H", data, pos)
                    prev_end, total = -1, 0
                    for j in range(n_runs):
                        s, l = flat[2 * j], flat[2 * j + 1]
                        e = s + l                        # inclusive end
                        if e > 0xFFFF:
                            raise PayloadError(
                                f"run {j} = (start {s}, len {l + 1}) "
                                "exceeds the 16-bit chunk (start + length "
                                "- 1 > 65535)",
                                offset=pos + 4 * j, container=i)
                        if s <= prev_end:
                            raise PayloadError(
                                f"runs {j - 1} and {j} out of order or "
                                f"overlapping: run {j - 1} ends at "
                                f"{prev_end}, run {j} starts at {s}",
                                offset=pos + 4 * j, container=i)
                        prev_end = e
                        total += l + 1
                    if total != card_i:
                        raise PayloadError(
                            f"header cardinality {card_i} != run payload "
                            f"cardinality {total}",
                            offset=desc_pos + 4 * i + 2, container=i)
                    c = pr.RunContainer(
                        np.asarray(flat[0::2], np.int64),
                        np.asarray(flat[1::2], np.int64))
                pos += 4 * n_runs
            elif card_i > pr.ARRAY_MAX:
                if pos + 8192 > ln:
                    need(pos, 8192, "bitmap payload", container=i)
                words = np.frombuffer(data, dtype="<u8", count=1024,
                                      offset=pos).astype(np.uint64)
                bitmap_checks.append((i, pos))
                bitmap_words.append(words)
                pos += 8192
                c = pr.BitmapContainer(words, cardinality=card_i)
            else:
                if pos + 2 * card_i > ln:
                    need(pos, 2 * card_i, "array payload", container=i)
                arr = np.frombuffer(data, dtype="<u2", count=card_i,
                                    offset=pos).astype(np.uint16)
                if card_i > 1:
                    array_checks.append((i, pos, arr))
                pos += 2 * card_i
                c = pr.ArrayContainer(arr)
            # card-vs-payload agreement is proven per branch: runs sum
            # their lengths, bitmaps popcount and arrays sorted-unique in
            # the batched epilogue below, arrays read exactly card_i values
            rb.keys.append(key_list[i])
            rb.containers.append(c)
        if pos != ln:
            raise TrailingDataError(
                f"{ln - pos} trailing bytes after the last container "
                "payload", offset=pos)
        cls._check_arrays_sorted(array_checks)
        if bitmap_checks:
            counts = np.bitwise_count(
                np.concatenate(bitmap_words)).reshape(
                    len(bitmap_words), 1024).sum(axis=1).tolist()
            for (i, payload_pos), got in zip(bitmap_checks, counts):
                if got != card_list[i]:
                    raise PayloadError(
                        f"bitmap popcount {got} != declared cardinality "
                        f"{card_list[i]}", offset=payload_pos, container=i)
        if check:
            from repro.roaring import validate as _v
            _v.audit_bitmap(rb).raise_on_violation()
        return rb

    @staticmethod
    def _check_arrays_sorted(array_checks: list) -> None:
        """Batched strictly-increasing check over every array payload.

        One pass over all payloads concatenated, entirely in uint16 (no
        widening): with wraparound steps ``e_j = (a[j+1] - a[j] - 1) mod
        2^16``, a segment of length m is strictly increasing iff
        ``sum(e) == last - first - (m - 1)`` — every non-increasing step
        adds exactly 2^16 to the sum, so the identity is exact, not a
        heuristic. Cross-segment boundary steps are zeroed and per-segment
        sums come from one ``np.add.reduceat``. On failure, the offending
        container is re-checked alone for an exact byte offset (error
        path, cost irrelevant).
        """
        if not array_checks:
            return
        if len(array_checks) <= 12:
            # few arrays: two small ufunc launches each beat the batched
            # pass's fixed cost (concat/reduceat/gather launches)
            for i, payload_pos, arr in array_checks:
                _raise_unless_sorted(arr, i, payload_pos)
            return
        lens = [a.shape[0] for (_, _, a) in array_checks]
        ends = np.cumsum(lens)
        combined = np.concatenate([a for (_, _, a) in array_checks])
        e = combined[1:] - combined[:-1]     # u16 wraparound, intentional
        e -= 1                               # equal step wraps to 65535
        e[ends[:-1] - 1] = 0                 # neutralize boundary steps
        starts = ends - np.asarray(lens)
        sums = np.add.reduceat(e, starts, dtype=np.int64)
        firsts = combined[starts].astype(np.int64)
        lasts = combined[ends - 1].astype(np.int64)
        expect = lasts - firsts - (np.asarray(lens, dtype=np.int64) - 1)
        if (sums == expect).all():
            return
        for i, payload_pos, arr in array_checks:       # locate (error path)
            _raise_unless_sorted(arr, i, payload_pos)

    # -- trusted-path baseline (A/B benchmark only) ---------------------------
    @classmethod
    def _deserialize_trusted(cls, data: bytes) -> pr.RoaringBitmap:
        """The pre-hardening decode loop, kept verbatim as the trusted-input
        baseline for the ``robust/*`` benchmark rows (validation overhead is
        gated at <= 1.3x this path). Never feed it untrusted bytes."""
        (cookie,) = struct.unpack_from("<I", data, 0)
        pos = 4
        if cookie & 0xFFFF == cls.SERIAL_COOKIE:
            n = (cookie >> 16) + 1
            nbytes = (n + 7) // 8
            runbits = data[pos:pos + nbytes]
            pos += nbytes
            is_run = [(runbits[i >> 3] >> (i & 7)) & 1 == 1 for i in range(n)]
            with_offsets = n >= cls.NO_OFFSET_THRESHOLD
        else:
            (n,) = struct.unpack_from("<I", data, pos)
            pos += 4
            is_run = [False] * n
            with_offsets = True
        keys, cards = [], []
        for _ in range(n):
            k, cm1 = struct.unpack_from("<HH", data, pos)
            pos += 4
            keys.append(k)
            cards.append(cm1 + 1)
        if with_offsets:
            pos += 4 * n
        rb = pr.RoaringBitmap()
        for i in range(n):
            if is_run[i]:
                (n_runs,) = struct.unpack_from("<H", data, pos)
                pos += 2
                pairs = np.frombuffer(data, dtype="<u2", count=2 * n_runs,
                                      offset=pos).astype(np.int64)
                pos += 4 * n_runs
                c: pr.Container = pr.RunContainer(pairs[0::2], pairs[1::2])
            elif cards[i] > pr.ARRAY_MAX:
                words = np.frombuffer(data, dtype="<u8", count=1024,
                                      offset=pos).astype(np.uint64)
                pos += 8192
                c = pr.BitmapContainer(words, cardinality=cards[i])
            else:
                arr = np.frombuffer(data, dtype="<u2", count=cards[i],
                                    offset=pos).astype(np.uint16)
                pos += 2 * cards[i]
                c = pr.ArrayContainer(arr)
            rb.keys.append(keys[i])
            rb.containers.append(c)
        return rb
