"""``RoaringSlab`` — the pytree-native Roaring container object.

The stable v1 object API over the kind-dispatch engine in
``repro.core.jax_roaring``: a frozen, pytree-registered dataclass whose
leaves are the slab arrays (``keys`` / ``kinds`` / ``cards`` / ``nruns`` /
``payload``) and whose static aux data is the container capacity ``C`` — so
a ``RoaringSlab`` flows through ``jit`` / ``vmap`` / ``shard_map`` natively
and ``jit`` caches by (shapes, C).

Batch axes are explicit and leading: a single slab has ``keys: i32[C]``
(``ndim == 1``); a *stacked* slab — N slabs key-aligned by ``stack()`` —
is the same type with ``keys: i32[N, C]`` (``ndim == 2``). Every operator
and method broadcasts over leading batch axes (vmapped internally), so the
expression ``a & b | c`` works identically on single and stacked slabs, and
``shard_map`` can shard the leading axis with one ``PartitionSpec``.

Set-algebra outputs keep the engine's canonical-kind invariant: per row the
serialized sizes 2·card (array) / 8192 (bitmap) / 4·n_runs (run) are
compared and the strict best-of-three wins, matching the ``py_roaring``
oracle kind-for-kind and payload-for-payload.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_roaring as jr
from repro.roaring.format import RoaringFormatSpec

__all__ = ["RoaringSlab", "stack", "union_all", "intersect_all"]

# values accepted wherever a slab operand is expected: the object API type
# or the internal row-state NamedTuple (coerced, never copied)
SlabLike = Union["RoaringSlab", jr.RoaringSlab]


def _to_internal(s: SlabLike) -> jr.RoaringSlab:
    """Object -> internal engine NamedTuple view (no copy). 1-D only."""
    if isinstance(s, RoaringSlab):
        return jr.RoaringSlab(keys=s.keys, card=s.cards, kind=s.kinds,
                              data=s.payload)
    return s


def _wrap(t: jr.RoaringSlab) -> "RoaringSlab":
    """Internal engine NamedTuple -> object (recomputes the nruns leaf)."""
    return RoaringSlab(keys=t.keys, kinds=t.kind, cards=t.card,
                       nruns=jr._rows_nruns(t.data, t.kind), payload=t.data,
                       C=t.keys.shape[-1])


def _as_object(s: SlabLike) -> "RoaringSlab":
    return s if isinstance(s, RoaringSlab) else _wrap(s)


def _batch_shape(s: SlabLike) -> Tuple[int, ...]:
    return tuple(s.keys.shape[:-1])


def _broadcast_map(f, operands: Sequence[SlabLike]):
    """Apply ``f`` (defined over 1-D object slabs) across leading batch axes.

    All batched operands must share one batch shape; unbatched operands are
    broadcast (``in_axes=None``). One ``jax.vmap`` level per batch axis.
    """
    shapes = {_batch_shape(s) for s in operands if _batch_shape(s)}
    if len(shapes) > 1:
        raise ValueError(f"mismatched slab batch shapes: {sorted(shapes)}")
    objs = [_as_object(s) for s in operands]
    if not shapes:
        return f(*objs)
    in_axes = tuple(0 if _batch_shape(s) else None for s in objs)
    g = f
    for _ in shapes.pop():
        g = jax.vmap(g, in_axes=in_axes)
    return g(*objs)


@dataclasses.dataclass(frozen=True, eq=False)
class RoaringSlab:
    """Static-capacity Roaring bitmap with ``C`` container rows.

    Leaves (pytree data fields; a leading batch axis makes a stacked slab):

    * ``keys    i32[..., C]``        sorted chunk keys, ``KEY_SENTINEL`` pad
    * ``kinds   i32[..., C]``        0 empty / 1 array / 2 bitmap / 3 run
    * ``cards   i32[..., C]``        per-container cardinality counters
    * ``nruns   i32[..., C]``        per-row run counts (0 for non-run rows)
    * ``payload u16[..., C, 4096]``  8 kB rows: packed arrays / bitmap words
      / ``(start, len-1)`` run pairs

    ``C`` is static aux data — it never enters tracing, so ``jit`` caches by
    shape and capacity.
    """

    keys: jax.Array
    kinds: jax.Array
    cards: jax.Array
    nruns: jax.Array
    payload: jax.Array
    C: int

    # -- static shape facts ---------------------------------------------------
    @property
    def capacity(self) -> int:
        """Static container capacity ``C``."""
        return self.C

    @property
    def ndim(self) -> int:
        """1 for a single slab, 2 for a stacked slab, higher when vmapped."""
        return self.keys.ndim

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return tuple(self.keys.shape[:-1])

    @property
    def n_slabs(self) -> int:
        """Leading-axis length of a stacked slab."""
        if self.ndim < 2:
            raise ValueError("n_slabs needs a stacked slab (ndim >= 2)")
        return self.keys.shape[0]

    def __getitem__(self, i) -> "RoaringSlab":
        """Slice the leading batch axis (stacked slab -> member slab)."""
        if self.ndim < 2:
            raise IndexError("cannot index a single slab (ndim == 1)")
        return RoaringSlab(keys=self.keys[i], kinds=self.kinds[i],
                           cards=self.cards[i], nruns=self.nruns[i],
                           payload=self.payload[i], C=self.C)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def empty(cls, capacity: int) -> "RoaringSlab":
        """All-empty slab — the identity of ``|`` and ``union_all``."""
        return _wrap(jr.empty(capacity))

    @classmethod
    def from_indices(cls, idx: jax.Array, valid: jax.Array,
                     capacity: int) -> "RoaringSlab":
        """Device-side: (padded) sorted unique integer indices -> slab."""
        return _wrap(jr.from_indices(idx, valid, capacity))

    @classmethod
    def from_values(cls, values: np.ndarray, capacity: int,
                    max_elems: int) -> "RoaringSlab":
        """Host-side: numpy integer values -> slab (pads to ``max_elems``)."""
        return _wrap(jr.from_dense_array(values, capacity, max_elems))

    @classmethod
    def from_roaring(cls, rb, capacity: int, *,
                     check: bool = False) -> "RoaringSlab":
        """Host ``py_roaring.RoaringBitmap`` -> slab, kind-preserving (run
        containers land as run rows, nothing materialized). ``check=True``
        audits the built slab (``repro.roaring.validate``) and raises
        ``InvariantViolation`` on any structural breach."""
        slab = _wrap(jr.from_roaring(rb, capacity))
        if check:
            from repro.roaring import validate as _v
            _v.audit_slab(slab).raise_on_violation()
        return slab

    @classmethod
    def from_ranges(cls, ranges: Iterable[Tuple[int, int]],
                    capacity: int) -> "RoaringSlab":
        """Half-open ``[start, end)`` integer ranges -> run-row slab."""
        return _wrap(jr.from_ranges(ranges, capacity))

    @classmethod
    def deserialize(cls, data: bytes, capacity: Optional[int] = None, *,
                    limits=None, check: bool = False) -> "RoaringSlab":
        """Untrusted portable Roaring byte stream -> slab (host-side; see
        ``RoaringFormatSpec``). ``capacity`` defaults to the container count
        in the stream. Structural stream validation always runs (any breach
        raises ``RoaringFormatError`` with byte-offset context; ``limits``
        caps container count / stream bytes); ``check=True`` additionally
        audits both the decoded host bitmap and the built device slab."""
        rb = RoaringFormatSpec.deserialize(data, limits=limits, check=check)
        if capacity is None:
            capacity = max(1, len(rb.keys))
        elif capacity < len(rb.keys):
            from repro.roaring.format import DecodeLimitError
            raise DecodeLimitError(
                f"stream holds {len(rb.keys)} containers, caller capacity "
                f"is {capacity}")
        return cls.from_roaring(rb, capacity, check=check)

    # -- exporters ------------------------------------------------------------
    def to_roaring(self):
        """Slab -> host ``RoaringBitmap``, kind-preserving (1-D only)."""
        self._require_single("to_roaring")
        return jr.to_roaring(_to_internal(self))

    def serialize(self) -> bytes:
        """Slab -> portable Roaring byte stream (host-side; byte-identical
        to ``RoaringFormatSpec.serialize`` of the same oracle bitmap)."""
        self._require_single("serialize")
        return RoaringFormatSpec.serialize(self.to_roaring())

    def to_indices(self, max_out: int) -> Tuple[jax.Array, jax.Array]:
        """Device-side: ``(sorted values, valid)`` padded to ``max_out``."""
        return _broadcast_map(
            lambda s: jr.to_indices(_to_internal(s), max_out), [self])

    def to_dense(self, universe: Optional[int] = None) -> np.ndarray:
        """Host-side dense ``bool[universe]`` membership vector (1-D only;
        ``universe`` defaults to the tightest chunk-aligned bound)."""
        self._require_single("to_dense")
        vals = self.to_roaring().to_array()
        if universe is None:
            hi = int(vals[-1]) + 1 if vals.size else 0
            universe = ((hi + jr.CHUNK_SIZE - 1) // jr.CHUNK_SIZE) \
                * jr.CHUNK_SIZE
        out = np.zeros((universe,), bool)
        out[vals[vals < universe]] = True
        return out

    # -- scalar accounting ----------------------------------------------------
    def card(self) -> jax.Array:
        """Total cardinality (sum of the per-container counters, paper S2);
        ``i32[]`` for a single slab, ``i32[N]`` per stacked member."""
        return jnp.sum(self.cards, axis=-1)

    def n_containers(self) -> jax.Array:
        """# live container rows."""
        return jnp.sum((self.kinds != jr.KIND_EMPTY).astype(jnp.int32),
                       axis=-1)

    def size_in_bytes(self) -> jax.Array:
        """Exact serialized-size accounting (the paper's bits/item metric):
        8-byte index header + 4 bytes/container + 2·card / 8192 / 4·n_runs
        payloads — equals the oracle's ``size_in_bytes`` byte-for-byte."""
        payload = jnp.where(self.kinds == jr.KIND_ARRAY, 2 * self.cards,
                            jnp.where(self.kinds == jr.KIND_BITMAP,
                                      2 * jr.ROW_WORDS,
                                      jnp.where(self.kinds == jr.KIND_RUN,
                                                4 * self.nruns, 0)))
        live = (self.kinds != jr.KIND_EMPTY).astype(jnp.int32)
        return 8 + jnp.sum(live * (4 + payload), axis=-1)

    # -- membership / rank / select -------------------------------------------
    def contains(self, queries: jax.Array) -> jax.Array:
        """Batched membership test — per-kind probes, log-bounded traffic."""
        return _broadcast_map(
            lambda s: jr.contains(_to_internal(s), queries), [self])

    def rank(self, x: jax.Array) -> jax.Array:
        """# elements <= x."""
        return _broadcast_map(lambda s: jr.rank(_to_internal(s), x), [self])

    def select(self, j: jax.Array) -> jax.Array:
        """Value of the j-th (0-based) smallest element; -1 out of range."""
        return _broadcast_map(
            lambda s: jr._slab_select(_to_internal(s), j), [self])

    def run_optimize(self) -> "RoaringSlab":
        """Device-side ``runOptimize``: re-canonicalize every row
        best-of-three through the engine."""
        return _broadcast_map(
            lambda s: _wrap(jr._slab_run_optimize(_to_internal(s))), [self])

    # -- set algebra (kind-dispatch engine; canonical outputs) ----------------
    def _binary(self, other: SlabLike, impl,
                capacity: Optional[int]) -> "RoaringSlab":
        return _broadcast_map(
            lambda a, b: _wrap(impl(_to_internal(a), _to_internal(b),
                                    capacity=capacity)),
            [self, other])

    def and_(self, other: SlabLike,
             capacity: Optional[int] = None) -> "RoaringSlab":
        """A ∩ B over the registry's 4x4 dispatch grid. Output capacity
        defaults to ``min(C_a, C_b)`` (provably sufficient)."""
        return self._binary(other, jr._slab_and, capacity)

    def or_(self, other: SlabLike,
            capacity: Optional[int] = None) -> "RoaringSlab":
        """A ∪ B. Output capacity defaults to ``C_a + C_b`` (the key sets
        may be disjoint); pass a tighter static ``capacity`` when known."""
        return self._binary(other, jr._slab_or, capacity)

    def xor(self, other: SlabLike,
            capacity: Optional[int] = None) -> "RoaringSlab":
        """A ⊕ B (symmetric difference)."""
        return self._binary(other, jr._slab_xor, capacity)

    def andnot(self, other: SlabLike,
               capacity: Optional[int] = None) -> "RoaringSlab":
        """A \\ B. Output capacity defaults to ``C_a``."""
        return self._binary(other, jr._slab_andnot, capacity)

    __and__ = and_
    __or__ = or_
    __xor__ = xor
    __sub__ = andnot

    def and_card(self, other: SlabLike) -> jax.Array:
        """|A ∩ B| with no result slab (the fused-popcount fast path)."""
        return _broadcast_map(
            lambda a, b: jr._slab_and_card(_to_internal(a), _to_internal(b)),
            [self, other])

    def or_card(self, other: SlabLike) -> jax.Array:
        """|A ∪ B| by inclusion-exclusion on the counters."""
        return _broadcast_map(
            lambda a, b: jr._slab_or_card(_to_internal(a), _to_internal(b)),
            [self, other])

    def jaccard(self, other: SlabLike) -> jax.Array:
        """|A∩B| / |A∪B| in one dispatch pass (0 when both empty)."""
        return _broadcast_map(
            lambda a, b: jr._slab_jaccard(_to_internal(a), _to_internal(b)),
            [self, other])

    # -- internals ------------------------------------------------------------
    def _require_single(self, what: str) -> None:
        if self.ndim != 1:
            raise ValueError(f"{what} needs a single slab (ndim == 1); "
                             f"index a stacked slab first, e.g. s[i]")

    def __repr__(self) -> str:
        batch = "x".join(str(b) for b in self.batch_shape)
        return (f"RoaringSlab(C={self.C}"
                + (f", batch=[{batch}]" if batch else "") + ")")


jax.tree_util.register_dataclass(
    RoaringSlab,
    data_fields=("keys", "kinds", "cards", "nruns", "payload"),
    meta_fields=("C",))


def stack(slabs: Sequence[SlabLike], capacity: Optional[int] = None,
          align: bool = True) -> RoaringSlab:
    """Stack N slabs into one batched ``RoaringSlab`` (leading axis N).

    ``align=True`` (the wide-query layout, absorbing the old
    ``index.SlabStack``): the merged key set over all N slabs is computed
    once and every slab's rows are gathered key-aligned in native container
    form, so wide combines are pure leading-axis reductions. ``capacity``
    must cover the merged distinct key count (defaults to the sum of input
    capacities). ``align=False`` stacks the raw arrays (same capacity
    required) for elementwise-batched ops, which re-align per member.
    """
    if not slabs:
        raise ValueError("stack needs at least one slab")
    objs = [_as_object(s) for s in slabs]
    if any(o.ndim != 1 for o in objs):
        raise ValueError("stack expects single (ndim == 1) slabs")
    if not align:
        if capacity is not None and any(o.C != capacity for o in objs):
            raise ValueError("align=False cannot change capacities")
        if len({o.C for o in objs}) > 1:
            raise ValueError("align=False needs equal-capacity slabs")
        return jax.tree.map(lambda *xs: jnp.stack(xs), *objs)
    if capacity is None:
        capacity = sum(o.C for o in objs)
    keys = jr._merge_keys_many([o.keys for o in objs], capacity)
    gathered = [jr._gather_raw(_to_internal(o), keys) for o in objs]
    data = jnp.stack([g[0] for g in gathered])
    card = jnp.stack([g[1] for g in gathered])
    kind = jnp.stack([g[2] for g in gathered])
    nruns = jnp.stack([jr._rows_nruns(g[0], g[2]) for g in gathered])
    return RoaringSlab(
        keys=jnp.broadcast_to(keys, (len(objs), capacity)),
        kinds=kind, cards=card, nruns=nruns, payload=data, C=capacity)


def _union_all_single(slabs: List[RoaringSlab],
                      capacity: Optional[int]) -> RoaringSlab:
    cap = capacity if capacity is not None else max(
        1, sum(s.C for s in slabs))
    return _wrap(jr.union_many_slabs([_to_internal(s) for s in slabs], cap))


def union_all(slabs: Sequence[SlabLike],
              capacity: Optional[int] = None) -> RoaringSlab:
    """N-way union (Algorithm 4): the engine's log-depth tree reduction with
    deferred cardinality and ONE canonicalization at the root.

    ``slabs`` may be single slabs (returns a single slab) or equal-batch
    stacked slabs (the reduction is vmapped over the batch axis — the mask
    compiler's shape; ``capacity`` is then required and static).
    """
    slabs = [_as_object(s) for s in slabs]
    if not slabs:
        return RoaringSlab.empty(capacity or 1)
    return _broadcast_map(
        lambda *ss: _union_all_single(list(ss), capacity), slabs)


def intersect_all(slabs: Sequence[SlabLike],
                  capacity: Optional[int] = None) -> RoaringSlab:
    """N-way intersection: log-depth tree of registry dispatch steps with a
    single deferred canonicalization (batched like ``union_all``).

    Alignment uses the *intersected* key set — only keys present in every
    operand can populate the result, and there are at most ``min(C_i)`` of
    them, so the default capacity is always sufficient (a union-key
    alignment could silently truncate shared keys past the capacity).
    """
    slabs = [_as_object(s) for s in slabs]
    if not slabs:
        raise ValueError("intersect_all needs at least one slab")

    def one(*ss: RoaringSlab) -> RoaringSlab:
        cap = capacity if capacity is not None else min(s.C for s in ss)
        keys = ss[0].keys
        for s in ss[1:]:
            pos = jnp.searchsorted(s.keys, keys)
            pos_c = jnp.minimum(pos, s.C - 1)
            hit = (s.keys[pos_c] == keys) & (keys != jr.KEY_SENTINEL)
            keys = jnp.sort(jnp.where(hit, keys, jr.KEY_SENTINEL))
        keys = jr._pad_keys(keys, cap)
        gathered = [jr._gather_raw(_to_internal(s), keys) for s in ss]
        data, card, kind = jr._tree_reduce_rows(
            jnp.stack([g[0] for g in gathered]),
            jnp.stack([g[1] for g in gathered]),
            jnp.stack([g[2] for g in gathered]), jr._and_rows)
        return _wrap(jr._finalize_rows(keys, data, card, kind))

    return _broadcast_map(one, slabs)
