"""Batched serving engine with continuous batching over the roaring-paged KV
cache.

Flow: requests enter a queue; each engine step (1) admits new requests into
free batch slots, allocating pages from the RoaringPageTable, (2) runs one
jit'd ``decode_step_paged`` over the active batch, (3) retires finished
sequences, returning their pages via Roaring OR into the free bitmap.

Prefill is chunk-free token-streaming through the same decode path (adequate
for the test scale; the 32k-prefill *shape* cells lower the one-shot
``forward`` path instead — see launch.dryrun).

Admission backpressure (PR 6): page-pool exhaustion during prefill or decode
does not crash the engine. The starved request is evicted — its pages
(including any partial allocation) go back to the pool via
``RoaringPageTable.release`` — and requeued at the head of the queue to be
re-admitted once a resident sequence retires (``requeues`` counts these).
Only when *no other sequence holds pages* (the request alone cannot ever
fit) does the original ``MemoryError`` propagate. ``table.audit()`` proves
no page leaks on any path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.models import transformer as T
from repro.models.config import ModelConfig

from .kv_cache import RoaringPageTable


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                       # i32[prompt_len]
    max_new_tokens: int = 16
    eos_id: int = -1                         # -1: never stop early
    generated: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 n_pages: int = 256, page_size: int = 16,
                 max_pages_per_seq: int = 32, greedy: bool = True):
        assert all(k.startswith("attn") for k in cfg.block_kinds()), (
            "paged engine supports attention-pattern archs; ssm/hybrid decode "
            "uses state caches via T.decode_step")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.table = RoaringPageTable(n_pages, page_size)
        self.pools = T.init_paged_caches(cfg, n_pages, page_size)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.slots: List[Optional[int]] = [None] * max_batch
        self.pos: Dict[int, int] = {}
        self._step_fn = jax.jit(
            lambda params, pools, tok, pos, pidx, cnt, lens: T.decode_step_paged(
                params, pools, tok, pos, pidx, cnt, lens, cfg))
        self.greedy = greedy
        self.steps_run = 0
        self.requeues = 0

    def submit(self, req: Request) -> None:
        req.generated = []
        self.queue.append(req)

    def _others_hold_pages(self, rid: int) -> bool:
        """True when any *other* sequence holds pages — i.e. eviction +
        retry can eventually succeed; False means the pool alone is too
        small for this request and requeueing would spin forever."""
        return any(s != rid and pages
                   for s, pages in self.table.seq_pages.items())

    def _evict_requeue(self, slot: int) -> None:
        """Backpressure: push the starved sequence out of its slot, return
        every page it holds (partial allocations included), and requeue it
        from scratch at the head of the queue."""
        rid = self.slots[slot]
        req = self.active.pop(rid)
        self.table.release(rid)
        self.slots[slot] = None
        self.pos.pop(rid, None)
        req.generated = []
        self.requeues += 1
        self.queue.insert(0, req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req.req_id
                self.active[req.req_id] = req
                self.pos[req.req_id] = 0
        # prefill admitted sequences token by token
        for i, rid in enumerate(self.slots):
            if rid is None:
                continue
            req = self.active[rid]
            try:
                while self.pos[rid] < len(req.prompt) - 1:
                    self._advance(i, int(req.prompt[self.pos[rid]]),
                                  sample=False)
            except MemoryError:
                if not self._others_hold_pages(rid):
                    raise          # can never fit: pool < one request
                self._evict_requeue(i)

    def _batch_arrays(self):
        B = self.max_batch
        page_idx = np.zeros((B, self.max_pages), np.int32)
        counts = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, rid in enumerate(self.slots):
            if rid is None:
                continue
            pi, cn, ln = self.table.gather_lists([rid], self.max_pages)
            page_idx[i], counts[i], lengths[i] = pi[0], cn[0], ln[0]
            pos[i] = self.pos[rid]
        return page_idx, counts, lengths, pos

    def _advance(self, slot: int, token: int, sample: bool) -> Optional[int]:
        """Feed `token` for the sequence in `slot`; optionally return the
        sampled next token. Other slots decode their own pending tokens too
        (continuous batching: one jit step serves the whole batch)."""
        rid = self.slots[slot]
        self.table.alloc(rid, 1)
        page_idx, counts, lengths, pos = self._batch_arrays()
        tok = np.zeros((self.max_batch, 1), np.int32)
        tok[slot, 0] = token
        lengths = np.maximum(lengths - 1, 0)     # decode adds the new token
        logits, self.pools = self._step_fn(
            self.params, self.pools, jnp.asarray(tok), jnp.asarray(pos),
            jnp.asarray(page_idx), jnp.asarray(counts), jnp.asarray(lengths))
        self.pos[rid] += 1
        self.steps_run += 1
        if sample:
            row = np.asarray(logits[slot, 0], np.float32)
            return int(np.argmax(row))
        return None

    def _publish_gauges(self) -> None:
        """Refresh the serving gauges (queue depth, page pool) on the
        ``repro.obs`` registry — called per step while telemetry is on."""
        reg = obs.registry()
        reg.gauge("serve.queue_depth").set(len(self.queue))
        reg.gauge("serve.active_seqs").set(len(self.active))
        reg.gauge("serve.page_pool.free_pages").set(len(self.table.free))
        reg.gauge("serve.page_pool.utilization").set(
            float(self.table.utilization()))
        reg.gauge("serve.requeues").set(self.requeues)
        reg.gauge("serve.steps").set(self.steps_run)

    def step(self) -> None:
        """One continuous-batching iteration: admit, decode, retire."""
        with obs.span("serve.step"):
            self._step()
        if obs.enabled():
            self._publish_gauges()

    def _step(self) -> None:
        self._admit()
        # batch one decode for every active sequence
        active_slots = [i for i, r in enumerate(self.slots) if r is not None]
        if not active_slots:
            return
        for i in active_slots:
            rid = self.slots[i]
            req = self.active[rid]
            nxt_in = (int(req.prompt[-1]) if not req.generated
                      else req.generated[-1])
            try:
                out = self._advance(i, nxt_in, sample=True)
            except MemoryError:
                if not self._others_hold_pages(rid):
                    raise          # can never fit: pool < one request
                self._evict_requeue(i)
                continue
            req.generated.append(out)
            if (len(req.generated) >= req.max_new_tokens
                    or out == req.eos_id):
                req.done = True
                self.table.release(rid)
                self.slots[i] = None
                del self.active[rid]
                del self.pos[rid]

    def run_until_done(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                return
            self.step()

    def utilization(self) -> float:
        return self.table.utilization()
