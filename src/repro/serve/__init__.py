from .kv_cache import RoaringPageTable, PagedKVCache
from .engine import ServeEngine, Request

__all__ = ["RoaringPageTable", "PagedKVCache", "ServeEngine", "Request"]
