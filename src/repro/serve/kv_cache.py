"""Roaring-paged KV cache.

The global page pool is a fixed tensor [P, page_size, KVH, hd] per layer.
Bookkeeping is pure paper machinery:

  * ``free``: RoaringBitmap of free physical pages — allocation pops from it,
    release is a Roaring OR; fragmentation never hurts because the bitmap is
    the allocator;
  * per-sequence page lists stay *ordered* (logical order = list order); the
    roaring set of pages in use per sequence supports O(containers) "how many
    pages" (cardinality counters) and batched reclamation via ANDNOT;
  * ``gather_lists`` packs the page ids into the scalar-prefetch arrays of
    ``kernels.sparse_attn.paged_decode``.

This is the serving-side mirror of what the paper's S3 access operations do
for integer sets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RoaringBitmap, union_many


class RoaringPageTable:
    """Host-side page allocator + per-sequence page lists."""

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        # the free pool starts as one maximal run [0, n_pages) — run
        # containers (2016 paper), not a materialized page-id array; pop /
        # release keep it best-of-three canonical
        self.free = RoaringBitmap.from_ranges([(0, n_pages)])
        self.seq_pages: Dict[int, List[int]] = {}
        self.seq_len: Dict[int, int] = {}

    # -- allocation ----------------------------------------------------------
    def alloc(self, seq_id: int, n_tokens: int) -> List[int]:
        """Ensure capacity for n_tokens more tokens; returns new page ids."""
        cur = self.seq_len.get(seq_id, 0)
        pages = self.seq_pages.setdefault(seq_id, [])
        need = (cur + n_tokens + self.page_size - 1) // self.page_size
        new = []
        while len(pages) < need:
            if len(self.free) == 0:
                raise MemoryError("KV page pool exhausted")
            p = self.free.select(0)            # paper S2 select: first free
            self.free.remove(p)
            pages.append(p)
            new.append(p)
        self.seq_len[seq_id] = cur + n_tokens
        return new

    def release(self, seq_id: int) -> None:
        """Return a sequence's pages to the pool (Roaring OR)."""
        pages = self.seq_pages.pop(seq_id, [])
        self.seq_len.pop(seq_id, None)
        if pages:
            self.free.ior(RoaringBitmap.from_array(pages))

    def used_bitmap(self) -> RoaringBitmap:
        """All pages in use = many-way union (Alg. 4) of per-seq sets."""
        sets = [RoaringBitmap.from_array(p) for p in self.seq_pages.values()]
        if not sets:
            return RoaringBitmap()
        return union_many(sets)

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages

    def audit(self):
        """Structural audit of the allocator (``repro.roaring.validate``):
        free/used partition exactly covers [0, n_pages) with no leaked,
        double-allocated, or duplicated pages, and per-sequence page counts
        cover ``seq_len``. Returns the machine-readable ``AuditReport``."""
        from repro.roaring import validate as _v
        return _v.audit_page_table(self)

    # -- device-side views (repro.roaring object API) --------------------------
    def _page_capacity(self) -> int:
        from repro import roaring
        return max(1, (self.n_pages + roaring.CHUNK_SIZE - 1)
                   // roaring.CHUNK_SIZE)

    def free_slab(self):
        """Free-page set as a device ``roaring.RoaringSlab`` (for jit-side
        allocators).

        Kind-preserving bridge: the free pool's run containers land as run
        rows directly — no per-page materialization, no bitmap round trip.
        """
        from repro.roaring import RoaringSlab
        return RoaringSlab.from_roaring(self.free, self._page_capacity())

    def _seq_slab(self, pages):
        """One page list as a device slab (empty list -> empty slab)."""
        from repro.roaring import RoaringSlab
        cap = self._page_capacity()
        if not pages:
            return RoaringSlab.empty(cap)
        return RoaringSlab.from_values(np.asarray(pages, np.int64), cap,
                                       len(pages))

    def _seq_slabs(self):
        """Per-sequence page sets as device slabs (skips empty sequences)."""
        return [self._seq_slab(p) for p in self.seq_pages.values() if p]

    def used_slab(self):
        """In-use pages as a device ``RoaringSlab`` — Alg. 4 as the query
        engine's log-depth tree reduction over per-sequence page slabs
        (kind-dispatching at every level, one deferred canonicalization);
        contiguously-allocated sequences union into run rows."""
        from repro import roaring
        cap = self._page_capacity()
        slabs = self._seq_slabs()
        if not slabs:
            return roaring.RoaringSlab.empty(cap)
        return roaring.union_all(slabs, capacity=cap)

    def rebuild_free_slab(self):
        """Recompute the free pool from scratch on device: the wide query
        ``all_pages ANDNOT (∪ per-seq pages)`` through the expression
        executor — a one-launch cross-check (and disaster-recovery rebuild)
        for the incrementally-maintained host ``free`` pool. Canonical
        output: the fresh-pool case comes back as run rows. The operands are
        attached as ``leaf(slab)`` nodes directly — no stack bookkeeping."""
        from repro import index
        from repro.roaring import RoaringSlab
        cap = self._page_capacity()
        full = RoaringSlab.from_ranges([(0, self.n_pages)], cap)
        slabs = self._seq_slabs()
        if not slabs:
            return full.run_optimize()
        expr = index.andnot(
            index.leaf(full),
            index.or_(*[index.leaf(s) for s in slabs]))
        return index.execute(expr, capacity=cap)

    def shared_pages_many(self, seq_id: int, others: List[int]) -> np.ndarray:
        """|pages(seq_id) ∩ pages(o)| for many candidate sequences in ONE
        stacked dispatch launch (prefix-cache scan: which resident sequences
        share the most physical pages with ``seq_id``)."""
        from repro import index, roaring
        if not others:
            return np.zeros((0,), np.int32)
        stack = roaring.stack(
            [self._seq_slab(self.seq_pages.get(o, [])) for o in others],
            capacity=self._page_capacity())
        return np.asarray(index.batched_and_card(
            stack, self._seq_slab(self.seq_pages.get(seq_id, []))))

    def shared_pages(self, seq_a: int, seq_b: int) -> int:
        """# physical pages two sequences share (prefix-cache diagnostics) via
        the cardinality-only dispatch fast path — no result set materialized."""
        from repro.roaring import RoaringSlab
        cap = self._page_capacity()
        sa = RoaringSlab.from_values(
            np.asarray(self.seq_pages.get(seq_a, []), np.int64), cap,
            self.n_pages)
        sb = RoaringSlab.from_values(
            np.asarray(self.seq_pages.get(seq_b, []), np.int64), cap,
            self.n_pages)
        return int(sa.and_card(sb))

    # -- kernel metadata -------------------------------------------------------
    def gather_lists(self, seq_ids: List[int], max_pages: int):
        """(page_idx i32[B, max_pages], counts i32[B], lengths i32[B])."""
        B = len(seq_ids)
        page_idx = np.zeros((B, max_pages), np.int32)
        counts = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        for i, s in enumerate(seq_ids):
            pages = self.seq_pages.get(s, [])
            assert len(pages) <= max_pages, (s, len(pages), max_pages)
            page_idx[i, : len(pages)] = pages
            counts[i] = len(pages)
            lengths[i] = self.seq_len.get(s, 0)
        return page_idx, counts, lengths


@dataclasses.dataclass
class PagedKVCache:
    """Device-side page pools for all layers: [L, P, page, KVH, hd] x (k, v)."""

    k: jax.Array
    v: jax.Array
    page_size: int

    @classmethod
    def create(cls, n_layers: int, n_pages: int, page_size: int, kvh: int,
               hd: int, dtype=jnp.bfloat16) -> "PagedKVCache":
        shape = (n_layers, n_pages, page_size, kvh, hd)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), page_size)

    def write_token(self, layer_slices_k, layer_slices_v, page_ids: jax.Array,
                    offsets: jax.Array):
        """Scatter one token's K/V ([L, B, KVH, hd]) into (page, offset)."""
        k = self.k.at[:, page_ids, offsets].set(
            layer_slices_k.astype(self.k.dtype))
        v = self.v.at[:, page_ids, offsets].set(
            layer_slices_v.astype(self.v.dtype))
        return PagedKVCache(k, v, self.page_size)
