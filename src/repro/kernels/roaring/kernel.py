"""Pallas TPU kernels for Roaring container operations.

Three kernels:

1. ``container_op``: the fused word-op + popcount of Algorithms 1/3. One grid
   step processes one 8 kB container-row pair, reshaped u16[32, 128] to match
   the VPU lane layout (last dim 128). The cardinality is accumulated in the
   same VMEM pass as the bitwise op — the TPU analogue of the paper's
   "popcount rides the superscalar pipeline alongside the OR" observation
   (S4, factors 1-3). Container-type tags arrive via scalar prefetch; fully
   empty pairs skip the VPU work with ``@pl.when`` *and* their payload DMA:
   the operand index_maps (``skip_dead_rows``) read the prefetched tags and
   redirect dead columns to block 0, which stays resident — so an empty
   column costs neither compute nor bandwidth (previously the copy still
   ran; see DESIGN.md).

2. ``array_intersect``: the galloping adaptation. Each lane binary-searches
   the other container's packed sorted array in 13 steps (lower_bound over a
   window of up to 4096 elements), so comparison count per lane matches
   galloping's log bound while the VPU amortizes it across 4096 lanes.

3. ``intersect_dispatch``: the hybrid per-type dispatch (paper S4, extended
   to the 2016 follow-up's run containers), fused. The kernel body is
   *generated from the declarative registry* (``dispatch.AND_TABLE``): one
   grid step reads the ``(kind, card, n_runs)`` tags from scalar prefetch and
   ``@pl.when``-branches into exactly one registry row kernel — vectorized
   galloping (array x array), bit probes (array x bitmap), word-AND + fused
   popcount (bitmap x bitmap), gallop-in-ranges (array x run), and the
   range-mask coverage forms (run x bitmap, run x run) whose run lift is the
   gather-only binary search (``dispatch.coverage_by_search``; Pallas cannot
   scatter). Work is *skipped*, not masked: a sparse pair never touches the
   2^16-bit domain. This is the kernel behind ``jax_roaring.slab_and``; the
   XLA mirror (same table, scatter-based run lift) lives in
   ``ref.intersect_dispatch_ref``.

Block shapes: container rows are (32, 128) u16 tiles = 8 kB — one row per
grid step keeps VMEM usage at ~3 tiles (a, b, out) plus scalars, far under
the ~16 MB VMEM budget, and the 128-wide minor dim is MXU/VPU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch as D

ROW_WORDS = 4096
ROW_SHAPE = (32, 128)          # u16[32,128] == one 8 kB container row
KIND_EMPTY = D.KIND_EMPTY
KIND_ARRAY = D.KIND_ARRAY
KIND_BITMAP = D.KIND_BITMAP
KIND_RUN = D.KIND_RUN

_OPS = {
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
    "andnot": lambda a, b: jnp.bitwise_and(a, ~b),
}


def skip_dead_rows(live):
    """Operand index_map factory for the zero-cost empty-column DMA skip.

    ``live(scalars, i)`` decides from the scalar-prefetch block whether grid
    column ``i`` has work; dead columns get their operand block redirected
    to column 0, which is already resident after the first fetch — so the
    per-column payload copy the ``@pl.when`` skip used to leave running
    becomes a no-op re-fetch. Safe because every kernel body writes its
    dead-column outputs without reading operand data (scalar-prefetch index
    maps may read the scalar block; see ``PrefetchScalarGridSpec``).
    """
    def index_map(i, scalars):
        return (jnp.where(live(scalars, i), i, 0), 0, 0)

    return index_map


def _pair_live(kinds, i):
    """Either side non-empty (interleaved i32[2C] kind tags)."""
    return jnp.logical_or(kinds[2 * i] != KIND_EMPTY,
                          kinds[2 * i + 1] != KIND_EMPTY)


def _pair_both_live(meta, i):
    """Both sides non-empty (i32[6C] dispatch meta) — an AND with an empty
    side is empty, so either-empty columns take the dead branch."""
    return jnp.logical_and(meta[D.META_FIELDS * i] != KIND_EMPTY,
                           meta[D.META_FIELDS * i + 1] != KIND_EMPTY)


def _container_op_kernel(kinds_ref, a_ref, b_ref, out_ref, card_ref, *, op: str):
    """One container-row pair per grid step; fused op + popcount."""
    i = pl.program_id(0)
    ka = kinds_ref[2 * i]
    kb = kinds_ref[2 * i + 1]
    both_empty = jnp.logical_and(ka == KIND_EMPTY, kb == KIND_EMPTY)

    @pl.when(jnp.logical_not(both_empty))
    def _compute():
        res = _OPS[op](a_ref[0], b_ref[0])
        out_ref[0] = res
        # Alg. 1 line 7 / Alg. 3 line 5: popcount fused into the same pass
        card_ref[0] = jnp.sum(
            jax.lax.population_count(res).astype(jnp.int32))

    @pl.when(both_empty)
    def _skip():
        out_ref[0] = jnp.zeros(ROW_SHAPE, jnp.uint16)
        card_ref[0] = 0


def container_op_pallas(a_bits: jax.Array, b_bits: jax.Array,
                        kinds: jax.Array, op: str,
                        interpret: bool = True):
    """Batched container op.

    a_bits, b_bits: u16[C, 4096] bitmap-domain rows (key-aligned).
    kinds: i32[2C] interleaved (kind_a0, kind_b0, kind_a1, ...) tags.
    Returns (out_bits u16[C, 4096], card i32[C]).
    """
    C = a_bits.shape[0]
    a3 = a_bits.reshape(C, *ROW_SHAPE)
    b3 = b_bits.reshape(C, *ROW_SHAPE)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), skip_dead_rows(_pair_live)),
            pl.BlockSpec((1, *ROW_SHAPE), skip_dead_rows(_pair_live)),
        ],
        out_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, k: (i,), memory_space=pltpu.SMEM),
        ],
    )
    out, card = pl.pallas_call(
        functools.partial(_container_op_kernel, op=op),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, *ROW_SHAPE), jnp.uint16),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        interpret=interpret,
    )(kinds, a3, b3)
    return out.reshape(C, ROW_WORDS), card


def _array_intersect_kernel(cards_ref, a_ref, b_ref, hit_ref, count_ref):
    """Vectorized binary search: every element of A (4096 lanes) searches the
    packed sorted array B in 13 halving steps (lower_bound over a window of
    up to 4096 needs ceil(log2(4096)) + 1) — galloping's log bound, SIMD."""
    i = pl.program_id(0)
    card_b = cards_ref[2 * i + 1]
    a = a_ref[0].astype(jnp.int32)                # (32,128) values (0xFFFF pad)
    b = b_ref[0].reshape(ROW_WORDS).astype(jnp.int32)

    lo = jnp.zeros(ROW_SHAPE, jnp.int32)
    hi = jnp.full(ROW_SHAPE, card_b, jnp.int32)   # search window [lo, hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        vals = jnp.take(b, jnp.clip(mid, 0, ROW_WORDS - 1))
        go_right = vals < a
        return (jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid))

    lo, hi = jax.lax.fori_loop(0, 13, body, (lo, hi))
    found = jnp.take(b, jnp.clip(lo, 0, ROW_WORDS - 1)) == a
    found = jnp.logical_and(found, lo < card_b)
    card_a = cards_ref[2 * i]
    flat_pos = (jax.lax.broadcasted_iota(jnp.int32, ROW_SHAPE, 0) * 128
                + jax.lax.broadcasted_iota(jnp.int32, ROW_SHAPE, 1))
    found = jnp.logical_and(found, flat_pos < card_a)
    hit_ref[0] = found.astype(jnp.uint16)
    count_ref[0] = jnp.sum(found.astype(jnp.int32))


def array_intersect_pallas(a_arr: jax.Array, b_arr: jax.Array,
                           cards: jax.Array, interpret: bool = True):
    """Intersect packed sorted array containers (0xFFFF-padded).

    a_arr, b_arr: u16[C, 4096]; cards: i32[2C] interleaved (card_a, card_b).
    Returns (hits u16[C, 4096] — 1 where a value of A is also in B — and
    count i32[C]). Compaction of hits to packed form stays in XLA (scatter).
    """
    C = a_arr.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, k: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, k: (i,), memory_space=pltpu.SMEM),
        ],
    )
    hits, count = pl.pallas_call(
        _array_intersect_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, *ROW_SHAPE), jnp.uint16),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        interpret=interpret,
    )(cards, a_arr.reshape(C, *ROW_SHAPE), b_arr.reshape(C, *ROW_SHAPE))
    return hits.reshape(C, ROW_WORDS), count


_PL_KERNELS = D.make_and_kernels(D.coverage_by_search)


def _intersect_dispatch_kernel(meta_ref, a_ref, b_ref, hits_ref, card_ref):
    """Hybrid per-kind dispatch, generated from ``dispatch.AND_TABLE``: one
    container pair per grid step, ``@pl.when`` selects exactly one registry
    row kernel by the pair's ``(kind_a, kind_b)`` cell.

    ``meta`` is i32[6C] interleaved (kind_a, kind_b, card_a, card_b,
    nruns_a, nruns_b). Output per row follows the cell's ``out`` semantic:
    a 0/1 mask over the array side's 4096 slots (``mask_a``/``mask_b``), or
    the word-op bitmap words (``bits`` — bitmap x bitmap and the
    coverage-lifted run forms). ``card`` is exact either way (fused popcount
    for the bits cases).
    """
    i = pl.program_id(0)
    ka, kb, ca, cb, ra, rb = D.unpack_meta(meta_ref, i)
    matched = jnp.zeros((), jnp.bool_)

    for cls in D.AND_TABLE:
        pred = D.class_predicate(cls, ka, kb)
        matched = jnp.logical_or(matched, pred)

        @pl.when(pred)
        def _cell(cls=cls):
            x, y, cx, cy, rx, ry = D.bind_args(cls, a_ref[0], b_ref[0],
                                               ca, cb, ra, rb)
            hits, card = _PL_KERNELS[cls.kernel](x, y, cx, cy, rx, ry)
            hits_ref[0] = hits
            card_ref[0] = card

    @pl.when(jnp.logical_not(matched))
    def _dead():
        hits_ref[0] = jnp.zeros(ROW_SHAPE, jnp.uint16)
        card_ref[0] = 0


def intersect_dispatch_pallas(a_data: jax.Array, b_data: jax.Array,
                              meta: jax.Array, interpret: bool = True):
    """Fused hybrid intersection over key-aligned container rows.

    a_data, b_data: u16[C, 4096] raw container rows (packed arrays, bitmap
    words, or run pairs, per their kind tag — *not* lifted to bitmap domain).
    meta: i32[6C] interleaved (kind_a, kind_b, card_a, card_b, nruns_a,
    nruns_b) per row. Returns (hits u16[C, 4096], card i32[C]); see the
    kernel docstring for the per-pair-class meaning of ``hits``.
    """
    C = a_data.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), skip_dead_rows(_pair_both_live)),
            pl.BlockSpec((1, *ROW_SHAPE), skip_dead_rows(_pair_both_live)),
        ],
        out_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, k: (i,), memory_space=pltpu.SMEM),
        ],
    )
    hits, card = pl.pallas_call(
        _intersect_dispatch_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, *ROW_SHAPE), jnp.uint16),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        interpret=interpret,
    )(meta, a_data.reshape(C, *ROW_SHAPE), b_data.reshape(C, *ROW_SHAPE))
    return hits.reshape(C, ROW_WORDS), card


def intersect_dispatch_stacked_pallas(a_data: jax.Array, b_data: jax.Array,
                                      meta: jax.Array, interpret: bool = True):
    """Batched-meta entry point for the dispatch kernel: a whole *slab stack*
    in one fused launch.

    a_data, b_data: u16[N, C, 4096] — N key-aligned slabs of C raw container
    rows each (the ``repro.index.SlabStack`` layout). meta: i32[N, 6C], the
    per-slab interleaved (kind_a, kind_b, card_a, card_b, nruns_a, nruns_b)
    scalar-prefetch block. The stack flattens to a single ``N*C`` grid — one
    kernel launch and one scalar-prefetch transfer for the whole wide query
    instead of N separate dispatches (vmap of the per-slab entry would also
    fuse, but this keeps the grid explicit and the meta contiguous for SMEM).
    Returns (hits u16[N, C, 4096], card i32[N, C]).
    """
    N, C = a_data.shape[0], a_data.shape[1]
    hits, card = intersect_dispatch_pallas(
        a_data.reshape(N * C, ROW_WORDS), b_data.reshape(N * C, ROW_WORDS),
        meta.reshape(-1), interpret=interpret)
    return hits.reshape(N, C, ROW_WORDS), card.reshape(N, C)
