"""Jit'd wrappers for the roaring container kernels.

``use_pallas=None`` auto-selects: the Pallas body targets TPU; on CPU (this
container) it runs in interpret mode inside tests, while jitted production
entry points fall back to the XLA reference formulation (same math).

Robustness hooks (PR 6): the public entry points are thin Python wrappers
over the jitted implementations, so two per-launch controls exist without
retracing —

* ``backend_scope("pallas" | "xla" | "auto")`` overrides the backend for
  every dispatch launched inside the ``with`` block whose caller did not
  pass an explicit ``use_pallas``/``interpret``; ``repro.index.execute``
  uses it to run its Pallas→XLA-ref degradation ladder without threading a
  flag through the whole row-state algebra;
* ``set_fault_hook(fn)`` installs a callable invoked with the resolved
  backend name (``"pallas"``/``"xla"``) before every kernel launch — the
  injectable-failure seam ``runtime.fault_tolerance.FaultPlan`` plugs into
  (raising there simulates a device/runtime failure at dispatch
  granularity). The hook fires at Python call time; inside an outer ``jit``
  trace that means once per trace, matching where a real lowering failure
  would surface;
* ``add_launch_hook(fn)`` / ``remove_launch_hook(fn)`` (PR 9) subscribe
  observers to every dispatch as a ``LaunchEvent(entry, backend)`` — the
  seam the ``repro.obs`` telemetry plane counts kernel launches through.
  Launch hooks fire *before* the fault hook, so a launch that the fault
  plan then fails is still accounted (matching real hardware, where the
  dispatch happened and then faulted). Launch hooks must not raise; any
  exception from one is swallowed.

Explicit ``use_pallas``/``interpret`` arguments always win over the scope
override, so tests pinning a backend stay pinned.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import fused as _f
from . import kernel as _k
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# -- per-launch controls ------------------------------------------------------
_BACKEND_OVERRIDE: Optional[str] = None       # None == "auto"
_FAULT_HOOK: Optional[Callable[[str], None]] = None
_LAUNCH_HOOKS: Tuple[Callable[["LaunchEvent"], None], ...] = ()


@dataclasses.dataclass(frozen=True)
class LaunchEvent:
    """One kernel dispatch: which public entry point and which backend the
    launch resolved to (``"pallas"`` / ``"xla"``)."""

    entry: str
    backend: str


def add_launch_hook(hook: Callable[[LaunchEvent], None]) -> None:
    """Subscribe an observer to every kernel dispatch. Idempotent."""
    global _LAUNCH_HOOKS
    if hook not in _LAUNCH_HOOKS:
        _LAUNCH_HOOKS = _LAUNCH_HOOKS + (hook,)


def remove_launch_hook(hook: Callable[[LaunchEvent], None]) -> None:
    """Unsubscribe a launch observer (no-op if absent)."""
    global _LAUNCH_HOOKS
    _LAUNCH_HOOKS = tuple(h for h in _LAUNCH_HOOKS if h != hook)


@contextlib.contextmanager
def backend_scope(backend: Optional[str]):
    """Scoped backend override for auto-selecting dispatch launches.

    ``"pallas"`` forces the Pallas body (interpret mode off-TPU), ``"xla"``
    forces the XLA reference, ``"auto"``/``None`` restores hardware
    auto-selection. Nests; restores the previous override on exit.
    """
    global _BACKEND_OVERRIDE
    if backend not in (None, "auto", "pallas", "xla"):
        raise ValueError(f"unknown roaring backend {backend!r} "
                         "(want 'pallas', 'xla', or 'auto')")
    prev = _BACKEND_OVERRIDE
    _BACKEND_OVERRIDE = None if backend == "auto" else backend
    try:
        yield
    finally:
        _BACKEND_OVERRIDE = prev


def current_backend() -> str:
    """The backend an auto-selecting launch would use right now."""
    if _BACKEND_OVERRIDE is not None:
        return _BACKEND_OVERRIDE
    return "pallas" if _on_tpu() else "xla"


def set_fault_hook(hook: Optional[Callable[[str], None]]):
    """Install (or clear, with ``None``) the per-launch fault hook; returns
    the previous hook so callers can restore it."""
    global _FAULT_HOOK
    prev = _FAULT_HOOK
    _FAULT_HOOK = hook
    return prev


def _resolve(use_pallas: Optional[bool], interpret: bool,
             entry: str = "dispatch") -> tuple:
    """Resolve (use_pallas, interpret) to concrete booleans: explicit args
    win, then the scope override, then hardware auto-selection — then fire
    the launch hooks (accounting) and the fault hook (injection) with the
    resolved backend name, in that order so faulted launches still count."""
    if use_pallas is None and not interpret:
        use_pallas = current_backend() == "pallas"
    elif use_pallas is None:
        use_pallas = _on_tpu()
    backend = "pallas" if (use_pallas or interpret) else "xla"
    if _LAUNCH_HOOKS:
        ev = LaunchEvent(entry, backend)
        for hook in _LAUNCH_HOOKS:
            try:
                hook(ev)
            except Exception:
                pass
    if _FAULT_HOOK is not None:
        _FAULT_HOOK(backend)
    return use_pallas, interpret


@functools.partial(jax.jit, static_argnames=("op", "use_pallas", "interpret"))
def _container_op(a_bits, b_bits, kinds, op, use_pallas, interpret):
    if use_pallas or interpret:
        return _k.container_op_pallas(a_bits, b_bits, kinds, op,
                                      interpret=not _on_tpu())
    return _ref.container_op_ref(a_bits, b_bits, kinds, op)


def container_op(a_bits, b_bits, kinds, op: str = "or",
                 use_pallas: bool | None = None, interpret: bool = False):
    """Batched fused container op + popcount over key-aligned rows."""
    use_pallas, interpret = _resolve(use_pallas, interpret, "container_op")
    return _container_op(a_bits, b_bits, kinds, op, use_pallas, interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _array_intersect(a_arr, b_arr, cards, use_pallas, interpret):
    if use_pallas or interpret:
        return _k.array_intersect_pallas(a_arr, b_arr, cards,
                                         interpret=not _on_tpu())
    return _ref.array_intersect_ref(a_arr, b_arr, cards)


def array_intersect(a_arr, b_arr, cards,
                    use_pallas: bool | None = None, interpret: bool = False):
    """Batched array-container intersection (vectorized galloping)."""
    use_pallas, interpret = _resolve(use_pallas, interpret, "array_intersect")
    return _array_intersect(a_arr, b_arr, cards, use_pallas, interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _intersect_dispatch(a_data, b_data, meta, use_pallas, interpret):
    if use_pallas or interpret:
        return _k.intersect_dispatch_pallas(a_data, b_data, meta,
                                            interpret=not _on_tpu())
    return _ref.intersect_dispatch_ref(a_data, b_data, meta)


def intersect_dispatch(a_data, b_data, meta,
                       use_pallas: bool | None = None,
                       interpret: bool = False):
    """Kind-dispatch container intersection over key-aligned rows, routed
    by the declarative registry (``dispatch.AND_TABLE`` — the 4x4 grid
    including run containers).

    meta: i32[6C] interleaved (kind_a, kind_b, card_a, card_b, nruns_a,
    nruns_b). Returns (hits u16[C, 4096], card i32[C]) — the slab layer
    compacts / lazily canonicalizes best-of-three on top of this. Pallas
    (``@pl.when`` skip) on TPU, XLA reference elsewhere.
    """
    use_pallas, interpret = _resolve(use_pallas, interpret,
                                     "intersect_dispatch")
    return _intersect_dispatch(a_data, b_data, meta, use_pallas, interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _intersect_dispatch_stacked(a_data, b_data, meta, use_pallas, interpret):
    if use_pallas or interpret:
        return _k.intersect_dispatch_stacked_pallas(a_data, b_data, meta,
                                                    interpret=not _on_tpu())
    N, C = a_data.shape[0], a_data.shape[1]
    hits, card = _ref.intersect_dispatch_ref(
        a_data.reshape(N * C, a_data.shape[2]),
        b_data.reshape(N * C, b_data.shape[2]), meta.reshape(-1))
    return hits.reshape(N, C, a_data.shape[2]), card.reshape(N, C)


@functools.partial(jax.jit, static_argnames=("plan", "use_pallas",
                                             "interpret"))
def _fused_tree(ops_data, meta, plan, use_pallas, interpret):
    if use_pallas or interpret:
        return _f.fused_eval_pallas(ops_data, meta, plan=plan,
                                    interpret=not _on_tpu())
    return _f.fused_eval_ref(ops_data, meta, plan=plan)


def fused_tree(ops_data, meta, plan,
               use_pallas: bool | None = None, interpret: bool = False):
    """Evaluate a whole compiled Boolean expression tree in ONE launch.

    ops_data: u16[N, C, 4096] raw container rows (one per distinct leaf, key
    aligned); meta: the ``fused.pack_lift_meta`` scalar-prefetch block
    (i32[3*N*C + C]); plan: a ``fused.FusedPlan`` (static — hash-consed per
    expression shape, so same-shape queries never retrace). Returns
    (bits u16[C, 4096] bitmap-domain root rows, card i32[C]); the caller
    runs the single best-of-three canonicalization. Pallas mega-kernel on
    TPU, tape-mirroring XLA evaluator elsewhere.
    """
    use_pallas, interpret = _resolve(use_pallas, interpret, "fused_tree")
    return _fused_tree(ops_data, meta, plan, use_pallas, interpret)


def intersect_dispatch_stacked(a_data, b_data, meta,
                               use_pallas: bool | None = None,
                               interpret: bool = False):
    """Stacked (batched-meta) kind-dispatch intersection: N key-aligned
    slabs of C rows each in one launch — the ``repro.index`` wide-query
    engine's inner kernel.

    a_data, b_data: u16[N, C, 4096] raw container rows; meta: i32[N, 6C]
    per-slab interleaved (kind, card, n_runs) x2. Returns
    (hits u16[N, C, 4096], card i32[N, C]) with the same per-pair-class
    semantics as ``intersect_dispatch``.
    """
    use_pallas, interpret = _resolve(use_pallas, interpret,
                                     "intersect_dispatch_stacked")
    return _intersect_dispatch_stacked(a_data, b_data, meta, use_pallas,
                                       interpret)
