"""Jit'd wrappers for the roaring container kernels.

``use_pallas=None`` auto-selects: the Pallas body targets TPU; on CPU (this
container) it runs in interpret mode inside tests, while jitted production
entry points fall back to the XLA reference formulation (same math).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("op", "use_pallas", "interpret"))
def container_op(a_bits, b_bits, kinds, op: str = "or",
                 use_pallas: bool | None = None, interpret: bool = False):
    """Batched fused container op + popcount over key-aligned rows."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _k.container_op_pallas(a_bits, b_bits, kinds, op,
                                      interpret=not _on_tpu())
    return _ref.container_op_ref(a_bits, b_bits, kinds, op)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def array_intersect(a_arr, b_arr, cards,
                    use_pallas: bool | None = None, interpret: bool = False):
    """Batched array-container intersection (vectorized galloping)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _k.array_intersect_pallas(a_arr, b_arr, cards,
                                         interpret=not _on_tpu())
    return _ref.array_intersect_ref(a_arr, b_arr, cards)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def intersect_dispatch(a_data, b_data, meta,
                       use_pallas: bool | None = None,
                       interpret: bool = False):
    """Kind-dispatch container intersection over key-aligned rows, routed
    by the declarative registry (``dispatch.AND_TABLE`` — the 4x4 grid
    including run containers).

    meta: i32[6C] interleaved (kind_a, kind_b, card_a, card_b, nruns_a,
    nruns_b). Returns (hits u16[C, 4096], card i32[C]) — the slab layer
    compacts / lazily canonicalizes best-of-three on top of this. Pallas
    (``@pl.when`` skip) on TPU, XLA reference elsewhere.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _k.intersect_dispatch_pallas(a_data, b_data, meta,
                                            interpret=not _on_tpu())
    return _ref.intersect_dispatch_ref(a_data, b_data, meta)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def intersect_dispatch_stacked(a_data, b_data, meta,
                               use_pallas: bool | None = None,
                               interpret: bool = False):
    """Stacked (batched-meta) kind-dispatch intersection: N key-aligned
    slabs of C rows each in one launch — the ``repro.index`` wide-query
    engine's inner kernel.

    a_data, b_data: u16[N, C, 4096] raw container rows; meta: i32[N, 6C]
    per-slab interleaved (kind, card, n_runs) x2. Returns
    (hits u16[N, C, 4096], card i32[N, C]) with the same per-pair-class
    semantics as ``intersect_dispatch``.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _k.intersect_dispatch_stacked_pallas(a_data, b_data, meta,
                                                    interpret=not _on_tpu())
    N, C = a_data.shape[0], a_data.shape[1]
    hits, card = _ref.intersect_dispatch_ref(
        a_data.reshape(N * C, a_data.shape[2]),
        b_data.reshape(N * C, b_data.shape[2]), meta.reshape(-1))
    return hits.reshape(N, C, a_data.shape[2]), card.reshape(N, C)
