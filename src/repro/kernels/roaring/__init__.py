from .ops import container_op, array_intersect, intersect_dispatch

__all__ = ["container_op", "array_intersect", "intersect_dispatch"]
