from .ops import container_op, array_intersect

__all__ = ["container_op", "array_intersect"]
