"""The kind-dispatch engine: one declarative registry of per-pair-class row
kernels, consumed by all three backends.

PR 1 hard-coded the paper's 3-kind hybrid dispatch as ``(kind_a, kind_b)``
branch chains in three places (``jax_roaring`` slab ops, the XLA reference,
the ``@pl.when`` Pallas kernel). Adding the 2016 follow-up paper's run
containers would have meant growing three hand-enumerated 3x3 grids to 4x4.
Instead the grid now lives *here once*:

  * ``AND_TABLE`` — one ``PairClass`` row per live ``(kind_a, kind_b)`` pair,
    naming the row kernel, the output semantic the slab layer must apply, and
    whether the kernel sees the operands swapped;
  * ``make_and_kernels(coverage)`` — the row-kernel implementations, written
    against gather-only jnp so the *same functions* run inside the Pallas
    kernel body (``@pl.when``-selected) and vmapped in the XLA reference
    (mask-selected). The only backend-specific piece is how a run row is
    lifted to its coverage bitmap: the XLA side scatters (cheap,
    O(n_runs + 4096)), the Pallas side binary-searches the run list per bit
    position (gather-only); both produce bit-identical coverage;
  * union / andnot routing policy (``union_route`` / ``andnot_route``) so the
    slab layer's OR/XOR/ANDNOT pipelines classify from the same table.

Row kernels all share one signature ``fn(x, y, cx, cy, rx, ry)`` over
``(32, 128)`` u16 tiles (one 8 kB container row), returning
``(hits_tile, card)``. ``swap`` in a table row means the kernel receives
``(b, a)`` — e.g. ``bitmap x array`` reuses the ``array x bitmap`` probe with
the roles reversed, and the slab layer compacts the hit mask against the
``b`` side (``out == 'mask_b'``).

Output semantics (``PairClass.out``):
  * ``'bits'``   — ``hits`` is a bitmap-domain row (word-op result);
  * ``'mask_a'`` — ``hits`` is a 0/1 mask over ``a``'s packed array slots;
  * ``'mask_b'`` — same, over ``b``'s slots.

``run x run`` is special-cased by the slab layer: the registry routes it to
the *run-merge* row kernel (``slab_route == 'run_merge'``), a scatter/argsort
formulation that stays entirely in run domain (``jax_roaring._run_merge_row``)
— the in-kernel ``run_cov_and`` (coverage AND + fused popcount) is the
Pallas/ref formulation of the same class, kept bit-identical for the
tri-backend tests and for TPU contexts where the kernel output is consumed
directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

ROW_WORDS = 4096
ROW_SHAPE = (32, 128)          # u16[32,128] == one 8 kB container row
MAX_RUNS = ROW_WORDS // 2      # (start, length-1) u16 pairs per row

KIND_EMPTY = 0
KIND_ARRAY = 1
KIND_BITMAP = 2
KIND_RUN = 3

# The registry's public surface (consumed by jax_roaring, ref.py, kernel.py,
# and the repro.index engine). Documented in docs/API.md; tests/test_docs.py
# asserts the two stay in sync.
__all__ = [
    "ROW_WORDS", "ROW_SHAPE", "MAX_RUNS",
    "KIND_EMPTY", "KIND_ARRAY", "KIND_BITMAP", "KIND_RUN",
    "PairClass", "AND_TABLE", "class_predicate", "out_mask", "route_mask",
    "union_route", "andnot_route",
    "coverage_by_search", "coverage_by_scatter", "make_and_kernels",
    "array_coverage_by_search", "array_coverage_by_scatter",
    "make_lift_kernels",
    "bind_args", "META_FIELDS", "unpack_meta",
]


@dataclasses.dataclass(frozen=True)
class PairClass:
    """One cell of the dispatch grid."""

    name: str
    kind_a: int
    kind_b: int
    kernel: str                # row-kernel id in make_and_kernels()
    out: str                   # 'bits' | 'mask_a' | 'mask_b'
    swap: bool = False         # kernel receives (b, a) instead of (a, b)
    slab_route: str = ""       # non-default slab-layer routing ('run_merge')


AND_TABLE: Tuple[PairClass, ...] = (
    PairClass("array_array", KIND_ARRAY, KIND_ARRAY, "gallop", "mask_a"),
    PairClass("array_bitmap", KIND_ARRAY, KIND_BITMAP, "probe", "mask_a"),
    PairClass("bitmap_array", KIND_BITMAP, KIND_ARRAY, "probe", "mask_b",
              swap=True),
    PairClass("bitmap_bitmap", KIND_BITMAP, KIND_BITMAP, "word_and", "bits"),
    PairClass("run_run", KIND_RUN, KIND_RUN, "run_cov_and", "bits",
              slab_route="run_merge"),
    PairClass("array_run", KIND_ARRAY, KIND_RUN, "run_gallop", "mask_a"),
    PairClass("run_array", KIND_RUN, KIND_ARRAY, "run_gallop", "mask_b",
              swap=True),
    PairClass("run_bitmap", KIND_RUN, KIND_BITMAP, "run_mask", "bits"),
    PairClass("bitmap_run", KIND_BITMAP, KIND_RUN, "run_mask", "bits",
              swap=True),
)


def class_predicate(cls: PairClass, ka, kb):
    """Row-selection predicate for one grid cell (jnp, scalar or batched)."""
    return jnp.logical_and(ka == cls.kind_a, kb == cls.kind_b)


def out_mask(out: str, ka, kb):
    """Batched predicate: rows whose AND output has the given semantic,
    honoring the slab-layer override for run x run."""
    acc = jnp.zeros_like(ka, dtype=bool)
    for cls in AND_TABLE:
        if cls.out == out and not cls.slab_route:
            acc = acc | class_predicate(cls, ka, kb)
    return acc


def route_mask(route: str, ka, kb):
    """Batched predicate: rows the slab layer routes specially."""
    acc = jnp.zeros_like(ka, dtype=bool)
    for cls in AND_TABLE:
        if cls.slab_route == route:
            acc = acc | class_predicate(cls, ka, kb)
    return acc


def union_route(ka, kb, ca, cb, array_max: int):
    """OR/XOR routing policy: packed sorted-merge only for array-ish pairs
    whose merged size provably stays under the threshold; every other live
    pair goes through the (kind-aware, run-lift-cheap) bitmap domain."""
    arrayish = (ka != KIND_BITMAP) & (ka != KIND_RUN) & \
               (kb != KIND_BITMAP) & (kb != KIND_RUN)
    small = arrayish & (ca + cb <= array_max)
    live = (ka != KIND_EMPTY) | (kb != KIND_EMPTY)
    return small, live & ~small


def andnot_route(ka, kb):
    """ANDNOT routing: array-A rows probe B in place (any B kind — the result
    is provably <= card_a); bitmap- or run-A rows go bitmap domain."""
    probe = ka == KIND_ARRAY
    lift = (ka == KIND_BITMAP) | (ka == KIND_RUN)
    return probe, lift


# =============================================================================
# shared row kernels (gather-only jnp: Pallas-body and vmap compatible)
# =============================================================================

def _flat_pos():
    return (jax.lax.broadcasted_iota(jnp.int32, ROW_SHAPE, 0) * ROW_SHAPE[1]
            + jax.lax.broadcasted_iota(jnp.int32, ROW_SHAPE, 1))


def _take_flat(row, idx):
    """Gather from a (32,128) tile by flat element index."""
    return jnp.take(row.reshape(ROW_WORDS), idx)


def _run_upper_bound(run_row, n_runs, p):
    """#run-starts <= p, searching the packed (start, len-1) pairs at even
    slots. 12 halvings resolve a window of up to 2048 runs."""
    lo = jnp.zeros_like(p)
    hi = jnp.full_like(p, n_runs)

    def body(_, lohi):
        lo, hi = lohi
        open_ = lo < hi                      # empty windows must not probe
        mid = (lo + hi) // 2
        s = _take_flat(run_row, jnp.clip(2 * mid, 0, ROW_WORDS - 2)).astype(
            jnp.int32)
        go_right = open_ & (s <= p)
        return (jnp.where(go_right, mid + 1, lo),
                jnp.where(open_ & ~go_right, mid, hi))

    lo, _ = jax.lax.fori_loop(0, 12, body, (lo, hi))
    return lo


def _run_covered(run_row, n_runs, p):
    """Is position ``p`` inside one of the row's runs? (binary search of the
    run list — the gallop-in-ranges probe.)"""
    idx = _run_upper_bound(run_row, n_runs, p) - 1
    idx_c = jnp.clip(idx, 0, MAX_RUNS - 1)
    s = _take_flat(run_row, 2 * idx_c).astype(jnp.int32)
    l = _take_flat(run_row, 2 * idx_c + 1).astype(jnp.int32)
    return (idx >= 0) & (p <= s + l)


def coverage_by_search(run_row, n_runs):
    """Run row -> coverage bitmap tile, gather-only (the Pallas-side lift):
    each of the 2^16 bit positions asks ``_run_covered`` via 16 lane-parallel
    passes over the (32,128) word tile."""
    word = _flat_pos()

    def bit_body(j, cov):
        covered = _run_covered(run_row, n_runs, word * 16 + j)
        return cov | (covered.astype(jnp.uint16) << j)

    return jax.lax.fori_loop(0, 16, bit_body,
                             jnp.zeros(ROW_SHAPE, jnp.uint16))


def coverage_by_scatter(run_row, n_runs):
    """Run row -> coverage bitmap tile via difference-array scatter,
    O(n_runs + ROW_WORDS) (the XLA-side lift). Bit-identical to
    ``coverage_by_search``; not Pallas-lowerable (scatter)."""
    flat = run_row.reshape(ROW_WORDS)
    pairs = flat.reshape(MAX_RUNS, 2).astype(jnp.int32)
    s, l = pairs[:, 0], pairs[:, 1]
    valid = (s + l) < (1 << 16)                  # 0xFFFF padding fails this
    e = s + l
    fw, lw = s >> 4, e >> 4
    mask_a = ((0xFFFF << (s & 15)) & 0xFFFF)
    mask_b = (0xFFFF >> (15 - (e & 15)))
    same = fw == lw
    m_first = jnp.where(same, mask_a & mask_b, mask_a)
    partial = jnp.zeros((ROW_WORDS,), jnp.int32)
    partial = partial.at[jnp.where(valid, fw, ROW_WORDS)].add(
        m_first, mode="drop")
    partial = partial.at[jnp.where(valid & ~same, lw, ROW_WORDS)].add(
        mask_b, mode="drop")
    span = valid & (lw > fw)
    diff = jnp.zeros((ROW_WORDS + 1,), jnp.int32)
    diff = diff.at[jnp.where(span, fw + 1, ROW_WORDS + 1)].add(1, mode="drop")
    diff = diff.at[jnp.where(span, lw, ROW_WORDS + 1)].add(-1, mode="drop")
    full = jnp.where(jnp.cumsum(diff)[:ROW_WORDS] > 0, 0xFFFF, 0)
    return (partial | full).astype(jnp.uint16).reshape(ROW_SHAPE)


def array_coverage_by_search(arr_row, card):
    """Packed sorted array row -> membership bitmap tile, gather-only (the
    Pallas-side lift for the fused tree evaluator): each of the 2^16 bit
    positions lower_bounds the array's packed prefix — 16 lane-parallel
    passes of 13 halvings over the (32,128) word tile. Bit-identical to
    ``array_coverage_by_scatter``."""
    word = _flat_pos()

    def contains(p):
        lo = jnp.zeros(ROW_SHAPE, jnp.int32)
        hi = jnp.full(ROW_SHAPE, card, jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            vals = _take_flat(arr_row, jnp.clip(mid, 0, ROW_WORDS - 1)).astype(
                jnp.int32)
            go_right = vals < p
            return (jnp.where(go_right, mid + 1, lo),
                    jnp.where(go_right, hi, mid))

        lo, _ = jax.lax.fori_loop(0, 13, body, (lo, hi))
        found = _take_flat(arr_row, jnp.clip(lo, 0, ROW_WORDS - 1)).astype(
            jnp.int32) == p
        return found & (lo < card)

    def bit_body(j, cov):
        return cov | (contains(word * 16 + j).astype(jnp.uint16) << j)

    return jax.lax.fori_loop(0, 16, bit_body,
                             jnp.zeros(ROW_SHAPE, jnp.uint16))


def array_coverage_by_scatter(arr_row, card):
    """Packed sorted array row -> membership bitmap tile via one-hot word
    scatter, O(4096) (the XLA-side lift). Values are distinct, so each bit
    is contributed exactly once; not Pallas-lowerable (scatter)."""
    flat = arr_row.reshape(ROW_WORDS).astype(jnp.int32)
    valid = jnp.arange(ROW_WORDS) < card
    words = jnp.zeros((ROW_WORDS,), jnp.int32)
    words = words.at[jnp.where(valid, flat >> 4, ROW_WORDS)].add(
        1 << (flat & 15), mode="drop")
    return words.astype(jnp.uint16).reshape(ROW_SHAPE)


def make_lift_kernels(coverage: Callable,
                      array_coverage: Callable) -> Dict[int, Callable]:
    """Bind the kind -> bitmap-domain lift table to backend-specific run /
    array coverage implementations.

    Every lift: ``fn(row, card, n_runs) -> bits u16[32,128]`` — the row's
    membership bitmap regardless of its stored kind. This is the leaf-load
    step of the fused tree evaluator: once every operand is in bitmap
    domain, the whole expression is word ops.
    """
    return {
        KIND_EMPTY: lambda row, c, r: jnp.zeros(ROW_SHAPE, jnp.uint16),
        KIND_ARRAY: lambda row, c, r: array_coverage(row, c),
        KIND_BITMAP: lambda row, c, r: row.astype(jnp.uint16),
        KIND_RUN: lambda row, c, r: coverage(row, r),
    }


def make_and_kernels(coverage: Callable) -> Dict[str, Callable]:
    """Bind the AND row kernels to a run-coverage lift implementation.

    Every kernel: ``fn(x, y, cx, cy, rx, ry) -> (hits u16[32,128], card)``
    where ``x``/``y`` are the (possibly swapped — see ``PairClass.swap``)
    container-row tiles and ``cx/cy/rx/ry`` their cardinalities / run counts.
    """

    def k_gallop(x, y, cx, cy, rx, ry):
        # vectorized galloping: every lane of x binary-searches y's packed
        # sorted prefix. 13 steps: lower_bound over a window of up to 4096
        # needs ceil(log2(4096)) + 1 halvings.
        a = x.astype(jnp.int32)
        lo = jnp.zeros(ROW_SHAPE, jnp.int32)
        hi = jnp.full(ROW_SHAPE, cy, jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            vals = _take_flat(y, jnp.clip(mid, 0, ROW_WORDS - 1)).astype(
                jnp.int32)
            go_right = vals < a
            return (jnp.where(go_right, mid + 1, lo),
                    jnp.where(go_right, hi, mid))

        lo, _ = jax.lax.fori_loop(0, 13, body, (lo, hi))
        found = _take_flat(y, jnp.clip(lo, 0, ROW_WORDS - 1)).astype(
            jnp.int32) == a
        found = found & (lo < cy) & (_flat_pos() < cx)
        return found.astype(jnp.uint16), jnp.sum(found.astype(jnp.int32))

    def k_probe(x, y, cx, cy, rx, ry):
        # bit probes: x's <=4096 packed values index y's bitmap words
        # directly — the 2^16-bit domain is never materialized
        arr = x.astype(jnp.int32)
        word = _take_flat(y, arr >> 4).astype(jnp.int32)
        hit = (((word >> (arr & 15)) & 1) == 1) & (_flat_pos() < cx)
        return hit.astype(jnp.uint16), jnp.sum(hit.astype(jnp.int32))

    def k_word_and(x, y, cx, cy, rx, ry):
        # Algorithm 3: word AND with the popcount fused into the same pass
        res = jnp.bitwise_and(x, y)
        return res, jnp.sum(jax.lax.population_count(res).astype(jnp.int32))

    def k_run_gallop(x, y, cx, cy, rx, ry):
        # gallop-in-ranges: x's packed values binary-search y's run list
        hit = _run_covered(y, ry, x.astype(jnp.int32)) & (_flat_pos() < cx)
        return hit.astype(jnp.uint16), jnp.sum(hit.astype(jnp.int32))

    def k_run_mask(x, y, cx, cy, rx, ry):
        # range-mask: lift x's runs to coverage words, AND with y's bitmap
        res = jnp.bitwise_and(coverage(x, rx), y)
        return res, jnp.sum(jax.lax.population_count(res).astype(jnp.int32))

    def k_run_cov_and(x, y, cx, cy, rx, ry):
        res = jnp.bitwise_and(coverage(x, rx), coverage(y, ry))
        return res, jnp.sum(jax.lax.population_count(res).astype(jnp.int32))

    return {
        "gallop": k_gallop,
        "probe": k_probe,
        "word_and": k_word_and,
        "run_gallop": k_run_gallop,
        "run_mask": k_run_mask,
        "run_cov_and": k_run_cov_and,
    }


def bind_args(cls: PairClass, da, db, ca, cb, ra, rb):
    """Operand roles for one grid cell (apply ``swap``)."""
    if cls.swap:
        return db, da, cb, ca, rb, ra
    return da, db, ca, cb, ra, rb


META_FIELDS = 6  # (kind_a, kind_b, card_a, card_b, nruns_a, nruns_b)


def unpack_meta(meta, i=None):
    """Interleaved i32[6C] meta -> per-row fields (scalar at ``i`` or
    batched slices)."""
    if i is None:
        return tuple(meta[j::META_FIELDS] for j in range(META_FIELDS))
    return tuple(meta[META_FIELDS * i + j] for j in range(META_FIELDS))
