"""Fused expression-tree evaluation: ONE kernel launch per whole Boolean tree.

The per-op executor (``index.engine._eval``) pays a separate dispatch launch
— and a full HBM round trip of intermediate row state — per combine step of
an AND/OR/ANDNOT tree. This module collapses the whole tree into a single
Pallas launch whose grid body evaluates every node for one container column:

  * **plan** (``plan_tape``): the static expression shape is topo-ordered
    into a *tape* — a left-fold post-order sequence of ``("load", operand,
    slot)`` leaf lifts and ``(op, a_slot, b_slot, dst_slot)`` word ops — with
    stack-machine scratch-slot assignment (an n-ary node folds in place, so
    slot pressure is the tree's operand depth, not its width). Plans are
    hash-consed per structural tree (``functools.lru_cache``), so the jitted
    evaluator retraces once per expression *shape*, never per query.
  * **load**: each distinct leaf row is streamed from HBM exactly once and
    lifted to its membership bitmap in VMEM scratch via the kind-dispatched
    lift table (``dispatch.make_lift_kernels``) — arrays and runs
    binary-search gather-only on the Pallas side, scatter on the XLA side;
    both bit-identical.
  * **ops**: every interior node is a pure 8 kB word op between scratch
    slots — intermediates never leave VMEM.
  * **root**: the root slot's popcount is fused into the same pass; the
    single best-of-three canonicalization happens once, outside, in
    ``jax_roaring._finalize_rows`` (same final pass as the per-op path, so
    results stay byte-identical to ``py_roaring``).

Columns where *every* leaf is empty skip their payload DMA entirely: the
meta block carries a per-column live flag and the operand index_map redirects
dead columns to block 0 (already resident — the same zero-cost-skip
mechanism ``kernel.py`` uses for empty pairs).

``fused_eval_ref`` is the XLA mirror (same tape, batched cond-guarded lifts,
same word ops) — the third backend of the bit-identity contract and the
fallback rung the ``index`` degradation ladder lands on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch as D

ROW_WORDS = D.ROW_WORDS
ROW_SHAPE = D.ROW_SHAPE

__all__ = [
    "FusedPlan", "plan_tape", "plan_cache_size", "plan_stats",
    "fused_eval_pallas", "fused_eval_ref",
    "LIFT_META_FIELDS", "pack_lift_meta",
]

# A tree is an operand index (leaf) or an (op, *subtrees) tuple.
Tree = Union[int, Tuple]

_WORD_OPS = {
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "andnot": lambda a, b: jnp.bitwise_and(a, ~b),
}


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """A compiled expression shape: the static op tape one fused launch
    replays per container column.

    ``tape`` steps are ``("load", operand_idx, dst_slot)`` — lift operand
    row into scratch — or ``(op, a_slot, b_slot, dst_slot)`` with ``op`` in
    ``{"and", "or", "andnot"}``. The result lands in slot 0. ``n_slots`` is
    the peak scratch height; ``n_operands`` the number of distinct leaf
    rows the kernel streams in.
    """

    tape: Tuple[Tuple, ...]
    n_slots: int
    n_operands: int

    @property
    def n_loads(self) -> int:
        return sum(1 for s in self.tape if s[0] == "load")

    @property
    def n_ops(self) -> int:
        return len(self.tape) - self.n_loads


def _emit(node: Tree, tape: list, height: int) -> int:
    """Post-order tape emission with stack-machine slot allocation: a node
    evaluates into slot ``height``; an n-ary node left-folds in place so
    only one extra slot is live per nesting level. Returns the peak slot
    count."""
    if isinstance(node, int):
        tape.append(("load", node, height))
        return height + 1
    op = node[0]
    if op not in _WORD_OPS:
        raise ValueError(f"unknown fused op {op!r}")
    children = node[1:]
    if op == "andnot" and len(children) != 2:
        raise ValueError("andnot is binary")
    if not children:
        raise ValueError(f"{op} node needs children")
    peak = _emit(children[0], tape, height)
    for ch in children[1:]:
        peak = max(peak, _emit(ch, tape, height + 1))
        tape.append((op, height, height + 1, height))
    return peak


@functools.lru_cache(maxsize=None)
def plan_tape(tree: Tree) -> FusedPlan:
    """Compile a structural expression tree (operand indices at the leaves,
    ``(op, *subtrees)`` tuples inside) into a ``FusedPlan``. Hash-consed:
    equal trees return the *same* plan object, so plans are free to use as
    jit static arguments without retraces."""
    tape: list = []
    n_slots = _emit(tree, tape, 0)
    operands = {s[1] for s in tape if s[0] == "load"}
    n_operands = (max(operands) + 1) if operands else 0
    return FusedPlan(tuple(tape), n_slots, n_operands)


def plan_cache_size() -> int:
    """Number of distinct expression shapes compiled so far (retrace-guard
    instrumentation)."""
    return plan_tape.cache_info().currsize


def plan_stats(plan: FusedPlan, n_containers: int) -> dict:
    """Launch-count / HBM-traffic model for one plan over ``n_containers``
    key-aligned columns — fused vs the per-op tree-reduce path.

    The fused launch reads each distinct operand row once and writes the
    root bits + card; the per-op path launches one dispatch per interior
    combine, each reading two row states from HBM and writing one back.
    (8 kB payload per container row; the i32 card adds 4 B.)
    """
    row = 2 * ROW_WORDS                       # u16[4096] payload bytes
    per_col_fused = plan.n_loads * row + row + 4
    per_col_per_op = plan.n_ops * (2 * row + row + 4) + plan.n_loads * 0
    return {
        "n_operands": plan.n_operands,
        "n_combines": plan.n_ops,
        "launches_fused": 1,
        "launches_per_op": max(plan.n_ops, 1),
        "hbm_bytes_fused": per_col_fused * n_containers,
        "hbm_bytes_per_op": per_col_per_op * n_containers,
    }


# =============================================================================
# meta packing (shared by both backends and the engine)
# =============================================================================

LIFT_META_FIELDS = 3  # (kind, card, n_runs) per (operand, column)


def pack_lift_meta(kind, card, nruns):
    """Pack per-operand row tags + the per-column live flags into the fused
    kernel's scalar-prefetch block.

    kind/card/nruns: i32[N, C]. Layout: interleaved (kind, card, n_runs) at
    flat index ``3 * (n * C + i)``, followed by C live flags (column ``i``
    is live iff any operand's row there is non-empty) that the operand
    index_map reads to skip dead columns' DMA.
    """
    fields = jnp.stack([kind, card, nruns], axis=2).reshape(-1)
    live = jnp.any(kind != D.KIND_EMPTY, axis=0)
    return jnp.concatenate([fields, live.astype(jnp.int32)]).astype(jnp.int32)


# =============================================================================
# Pallas fused evaluator
# =============================================================================

_PL_LIFTS = D.make_lift_kernels(D.coverage_by_search,
                                D.array_coverage_by_search)
_REF_LIFTS = D.make_lift_kernels(D.coverage_by_scatter,
                                 D.array_coverage_by_scatter)


def _fused_kernel(meta_ref, ops_ref, out_ref, card_ref, scratch_ref, *,
                  plan: FusedPlan, N: int, C: int):
    """One container column per grid step: replay the whole tape in VMEM.

    ``ops_ref`` is the (N, 1, 32, 128) column block — every operand's row
    for this column, streamed in once. ``scratch_ref`` holds the slot stack;
    no intermediate ever returns to HBM. Dead columns (live flag 0) write
    zeros without touching operand data — their blocks were redirected to
    column 0 by the index_map, so the DMA is a no-op re-fetch of a resident
    block.
    """
    i = pl.program_id(0)
    live = meta_ref[LIFT_META_FIELDS * N * C + i] != 0

    @pl.when(live)
    def _run():
        for step in plan.tape:
            if step[0] == "load":
                _, n, dst = step
                base = LIFT_META_FIELDS * (n * C) + LIFT_META_FIELDS * i
                kind = meta_ref[base]
                card = meta_ref[base + 1]
                nruns = meta_ref[base + 2]
                for k, lift in _PL_LIFTS.items():

                    @pl.when(kind == k)
                    def _load(lift=lift, dst=dst, n=n, card=card,
                              nruns=nruns):
                        scratch_ref[dst] = lift(ops_ref[n, 0], card, nruns)
            else:
                op, sa, sb, dst = step
                scratch_ref[dst] = _WORD_OPS[op](scratch_ref[sa],
                                                 scratch_ref[sb])
        res = scratch_ref[0]
        out_ref[0] = res
        card_ref[0] = jnp.sum(jax.lax.population_count(res).astype(jnp.int32))

    @pl.when(jnp.logical_not(live))
    def _skip():
        out_ref[0] = jnp.zeros(ROW_SHAPE, jnp.uint16)
        card_ref[0] = 0


def fused_eval_pallas(ops_data: jax.Array, meta: jax.Array, *,
                      plan: FusedPlan, interpret: bool = True):
    """Evaluate a whole Boolean tree in ONE Pallas launch.

    ops_data: u16[N, C, 4096] raw container rows (N distinct operands, key
    aligned). meta: the ``pack_lift_meta`` block (i32[3*N*C + C]). plan: the
    compiled tape (static). Returns (bits u16[C, 4096] bitmap-domain root
    rows, card i32[C]).
    """
    N, C = ops_data.shape[0], ops_data.shape[1]
    nc = LIFT_META_FIELDS * N * C

    def ops_map(i, m):
        # dead columns re-fetch block 0 (resident): zero-cost DMA skip
        return (0, jnp.where(m[nc + i] != 0, i, 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[pl.BlockSpec((N, 1, *ROW_SHAPE), ops_map)],
        out_specs=[
            pl.BlockSpec((1, *ROW_SHAPE), lambda i, m: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, m: (i,), memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[pltpu.VMEM((plan.n_slots, *ROW_SHAPE), jnp.uint16)],
    )
    bits, card = pl.pallas_call(
        functools.partial(_fused_kernel, plan=plan, N=N, C=C),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, *ROW_SHAPE), jnp.uint16),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        interpret=interpret,
    )(meta, ops_data.reshape(N, C, *ROW_SHAPE))
    return bits.reshape(C, ROW_WORDS), card


# =============================================================================
# XLA mirror (same tape, batched lifts)
# =============================================================================

def fused_eval_ref(ops_data: jax.Array, meta: jax.Array, *,
                   plan: FusedPlan):
    """XLA mirror of ``fused_eval_pallas``: identical tape, one batched
    cond-guarded lift pass per load (scatter-based coverage), the same word
    ops over whole [C, 32, 128] slot arrays. Bit-identical output."""
    N, C = ops_data.shape[0], ops_data.shape[1]
    fields = meta[:LIFT_META_FIELDS * N * C].reshape(N, C, LIFT_META_FIELDS)
    kind, card, nruns = fields[..., 0], fields[..., 1], fields[..., 2]
    live = meta[LIFT_META_FIELDS * N * C:] != 0
    rows = ops_data.reshape(N, C, *ROW_SHAPE)

    def load(n):
        bits = jnp.zeros((C, *ROW_SHAPE), jnp.uint16)
        # bitmap rows pass through; array / run rows lift via scatter only
        # when the class is present (cond-skipped wholesale otherwise)
        bits = jnp.where((kind[n] == D.KIND_BITMAP)[:, None, None],
                         rows[n], bits)
        for k in (D.KIND_ARRAY, D.KIND_RUN):
            pred = kind[n] == k
            lift = _REF_LIFTS[k]

            def run(b, n=n, pred=pred, lift=lift):
                lifted = jax.vmap(lift)(rows[n], card[n], nruns[n])
                return jnp.where(pred[:, None, None], lifted, b)

            bits = jax.lax.cond(jnp.any(pred), run, lambda b: b, bits)
        return bits

    slots = {}
    for step in plan.tape:
        if step[0] == "load":
            _, n, dst = step
            slots[dst] = load(n)
        else:
            op, sa, sb, dst = step
            slots[dst] = _WORD_OPS[op](slots[sa], slots[sb])
    res = slots[0] * live[:, None, None].astype(jnp.uint16)
    card_out = jnp.sum(jax.lax.population_count(res).astype(jnp.int32),
                       axis=(1, 2))
    return res.reshape(C, ROW_WORDS), card_out
