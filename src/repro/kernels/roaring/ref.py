"""Pure-jnp oracles for the roaring container kernels.

``intersect_dispatch_ref`` consumes the same declarative pair-class registry
(``dispatch.AND_TABLE``) as the Pallas kernel: one cond-guarded vmapped pass
per grid cell, selected by the cell's ``(kind_a, kind_b)`` predicate. XLA has
no per-row skip, so within a pass every row computes masked — but a class
with no matching rows is skipped wholesale at runtime by ``lax.cond``, and
none of the cheap paths touches the 2^16-element domain. The run-coverage
lift binds to the scatter formulation (O(n_runs + 4096) per row) instead of
the kernel's gather-only search; both are bit-identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dispatch as D

ROW_WORDS = D.ROW_WORDS
ROW_SHAPE = D.ROW_SHAPE
KIND_EMPTY = D.KIND_EMPTY
KIND_ARRAY = D.KIND_ARRAY
KIND_BITMAP = D.KIND_BITMAP
KIND_RUN = D.KIND_RUN

_OPS = {
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
    "andnot": lambda a, b: jnp.bitwise_and(a, ~b),
}


def container_op_ref(a_bits: jax.Array, b_bits: jax.Array,
                     kinds: jax.Array, op: str):
    """Word op + popcount, unfused XLA formulation."""
    res = _OPS[op](a_bits, b_bits)
    ka, kb = kinds[0::2], kinds[1::2]
    live = jnp.logical_or(ka != 0, kb != 0)
    res = res * live[:, None].astype(jnp.uint16)
    card = jnp.sum(jax.lax.population_count(res).astype(jnp.int32), axis=-1)
    return res, card


def array_intersect_ref(a_arr: jax.Array, b_arr: jax.Array, cards: jax.Array):
    """searchsorted-based oracle for the batched array intersection."""
    card_a, card_b = cards[0::2], cards[1::2]

    def one(a, b, ca, cb):
        pos = jnp.searchsorted(b, a)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        found = (b[pos_c] == a) & (pos < cb)
        found = found & (jnp.arange(ROW_WORDS) < ca)
        return found.astype(jnp.uint16), jnp.sum(found.astype(jnp.int32))

    return jax.vmap(one)(a_arr, b_arr, card_a, card_b)


_KERNELS = D.make_and_kernels(D.coverage_by_scatter)


def intersect_dispatch_ref(a_data: jax.Array, b_data: jax.Array,
                           meta: jax.Array):
    """XLA mirror of the fused hybrid dispatch kernel.

    Same contract as ``kernel.intersect_dispatch_pallas``: per row, ``hits``
    is a 0/1 mask over the array side's slots (``out == 'mask_*'`` classes),
    or the word-op result (``'bits'`` classes: bitmap x bitmap AND, and the
    coverage-lifted run x bitmap / run x run forms); ``card`` is the exact
    intersection cardinality either way. ``meta`` is i32[6C] interleaved
    (kind_a, kind_b, card_a, card_b, nruns_a, nruns_b).
    """
    ka, kb, ca, cb, ra, rb = D.unpack_meta(meta)
    C = a_data.shape[0]
    a3 = a_data.reshape(C, *ROW_SHAPE)
    b3 = b_data.reshape(C, *ROW_SHAPE)

    hits = jnp.zeros((C, *ROW_SHAPE), jnp.uint16)
    card = jnp.zeros((C,), jnp.int32)
    for cls in D.AND_TABLE:
        pred = D.class_predicate(cls, ka, kb)
        fn = _KERNELS[cls.kernel]

        def run_class(args, fn=fn, cls=cls, pred=pred):
            hits, card = args

            def one(da, db, ca_i, cb_i, ra_i, rb_i):
                return fn(*D.bind_args(cls, da, db, ca_i, cb_i, ra_i, rb_i))

            h, c = jax.vmap(one)(a3, b3, ca, cb, ra, rb)
            sel = pred[:, None, None]
            return (jnp.where(sel, h, hits), jnp.where(pred, c, card))

        hits, card = jax.lax.cond(jnp.any(pred), run_class,
                                  lambda args: args, (hits, card))
    return hits.reshape(C, ROW_WORDS), card
