"""Pure-jnp oracles for the roaring container kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

ROW_WORDS = 4096

_OPS = {
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
    "andnot": lambda a, b: jnp.bitwise_and(a, ~b),
}


def container_op_ref(a_bits: jax.Array, b_bits: jax.Array,
                     kinds: jax.Array, op: str):
    """Word op + popcount, unfused XLA formulation."""
    res = _OPS[op](a_bits, b_bits)
    ka, kb = kinds[0::2], kinds[1::2]
    live = jnp.logical_or(ka != 0, kb != 0)
    res = res * live[:, None].astype(jnp.uint16)
    card = jnp.sum(jax.lax.population_count(res).astype(jnp.int32), axis=-1)
    return res, card


def array_intersect_ref(a_arr: jax.Array, b_arr: jax.Array, cards: jax.Array):
    """searchsorted-based oracle for the batched array intersection."""
    card_a, card_b = cards[0::2], cards[1::2]

    def one(a, b, ca, cb):
        pos = jnp.searchsorted(b, a)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        found = (b[pos_c] == a) & (pos < cb)
        found = found & (jnp.arange(ROW_WORDS) < ca)
        return found.astype(jnp.uint16), jnp.sum(found.astype(jnp.int32))

    return jax.vmap(one)(a_arr, b_arr, card_a, card_b)
