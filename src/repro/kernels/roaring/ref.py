"""Pure-jnp oracles for the roaring container kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

ROW_WORDS = 4096

_OPS = {
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
    "andnot": lambda a, b: jnp.bitwise_and(a, ~b),
}


def container_op_ref(a_bits: jax.Array, b_bits: jax.Array,
                     kinds: jax.Array, op: str):
    """Word op + popcount, unfused XLA formulation."""
    res = _OPS[op](a_bits, b_bits)
    ka, kb = kinds[0::2], kinds[1::2]
    live = jnp.logical_or(ka != 0, kb != 0)
    res = res * live[:, None].astype(jnp.uint16)
    card = jnp.sum(jax.lax.population_count(res).astype(jnp.int32), axis=-1)
    return res, card


def array_intersect_ref(a_arr: jax.Array, b_arr: jax.Array, cards: jax.Array):
    """searchsorted-based oracle for the batched array intersection."""
    card_a, card_b = cards[0::2], cards[1::2]

    def one(a, b, ca, cb):
        pos = jnp.searchsorted(b, a)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        found = (b[pos_c] == a) & (pos < cb)
        found = found & (jnp.arange(ROW_WORDS) < ca)
        return found.astype(jnp.uint16), jnp.sum(found.astype(jnp.int32))

    return jax.vmap(one)(a_arr, b_arr, card_a, card_b)


KIND_EMPTY, KIND_ARRAY, KIND_BITMAP = 0, 1, 2


def intersect_dispatch_ref(a_data: jax.Array, b_data: jax.Array,
                           meta: jax.Array):
    """XLA mirror of the fused hybrid dispatch kernel.

    Same contract as ``kernel.intersect_dispatch_pallas``: per row, ``hits``
    is a 0/1 mask over the array side's slots (array x array and
    array x bitmap pairs) or the AND'd bitmap words (bitmap x bitmap);
    ``card`` is the exact intersection cardinality. All three algorithms are
    computed masked (XLA has no per-row skip) — the skip economics live in
    the Pallas path; this formulation is still cheap because nothing here
    touches the 2^16-element domain.
    """
    ka, kb = meta[0::4], meta[1::4]
    ca, cb = meta[2::4], meta[3::4]

    def one(da, db, ka, kb, ca, cb):
        live = (ka != KIND_EMPTY) & (kb != KIND_EMPTY)
        aa = live & (ka == KIND_ARRAY) & (kb == KIND_ARRAY)
        ab = live & (ka == KIND_ARRAY) & (kb == KIND_BITMAP)
        ba = live & (ka == KIND_BITMAP) & (kb == KIND_ARRAY)
        bb = live & (ka == KIND_BITMAP) & (kb == KIND_BITMAP)
        slot = jnp.arange(ROW_WORDS, dtype=jnp.int32)

        # array x array: vectorized galloping (searchsorted == binary search)
        pos = jnp.searchsorted(db, da)
        pos_c = jnp.clip(pos, 0, ROW_WORDS - 1)
        aa_hit = (db[pos_c] == da) & (pos < cb) & (slot < ca)

        # array x bitmap: bit probes, no domain lift
        arr = jnp.where(ab, da, db).astype(jnp.int32)
        bits = jnp.where(ab, db, da)
        word = bits[arr >> 4].astype(jnp.int32)
        probe_hit = (((word >> (arr & 15)) & 1) == 1) & \
            (slot < jnp.where(ab, ca, cb))

        # bitmap x bitmap: word AND + popcount (Algorithm 3)
        anded = jnp.bitwise_and(da, db)

        hits = jnp.where(
            bb, anded,
            jnp.where(aa, aa_hit.astype(jnp.uint16),
                      jnp.where(ab | ba, probe_hit.astype(jnp.uint16),
                                jnp.uint16(0))))
        card = jnp.where(
            bb, jnp.sum(jax.lax.population_count(anded).astype(jnp.int32)),
            jnp.where(aa, jnp.sum(aa_hit.astype(jnp.int32)),
                      jnp.where(ab | ba, jnp.sum(probe_hit.astype(jnp.int32)),
                                0)))
        return hits, card

    return jax.vmap(one)(a_data, b_data, ka, kb, ca, cb)
