from .ops import sparse_attention, paged_decode

__all__ = ["sparse_attention", "paged_decode"]
