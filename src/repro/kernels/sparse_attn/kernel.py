"""Roaring-driven block-sparse flash attention (splash-style, TPU Pallas).

The paper's two-level index becomes attention metadata: each query-block row
owns a Roaring set of active key-blocks; ``compile_mask`` (sparsity package)
extracts every row's packed block list via Algorithm 2. The kernel consumes
that list through *scalar prefetch*: the KV BlockSpec index map reads the
next physical block id from the prefetched list, so only active KV blocks are
ever DMA'd from HBM — the TPU equivalent of Roaring's "skip entire chunks of
the other bitmap" advantage over RLE formats (paper S1).

Kernels:
  * ``sparse_flash_attention``: training/prefill forward. Grid
    (B, H, num_q_blocks, max_active); online-softmax scratch in VMEM.
  * ``paged_decode_attention``: single-token decode against a paged KV cache
    whose per-sequence page lists come from a Roaring page table.

Block sizes default to (128, 128): the MXU-aligned sweet spot; one q-block
(128 x d_head) + one kv-block + softmax scratch stays well under VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# =============================================================================
# training / prefill forward
# =============================================================================

def _flash_kernel(counts_ref, kvidx_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, max_active: int, block_q: int, block_kv: int,
                  causal: bool, softcap: float | None):
    qb, j = pl.program_id(2), pl.program_id(3)
    count = counts_ref[qb]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(j < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kv_block = kvidx_ref[qb * max_active + j]
        if causal:
            row = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            col = kv_block * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(col <= row, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def sparse_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_idx: jax.Array, counts: jax.Array,
                           *, block_q: int = 128, block_kv: int = 128,
                           causal: bool = True, softcap: float | None = None,
                           scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """Block-sparse flash attention forward.

    q: [B, H, S, D]; k, v: [B, KVH, S_kv, D] (GQA: H a multiple of KVH).
    kv_idx: i32[num_q_blocks, max_active] packed active kv-block ids per
    query-block row (from Roaring extraction); counts: i32[num_q_blocks].
    """
    B, H, S, D = q.shape
    KVH, S_kv = k.shape[1], k.shape[2]
    group = H // KVH
    num_qb, max_active = kv_idx.shape
    assert S % block_q == 0 and S_kv % block_kv == 0
    assert num_qb == S // block_q
    if scale is None:
        scale = D ** -0.5

    flat_idx = kv_idx.reshape(-1)
    grid = (B, H, num_qb, max_active)

    def q_map(b, h, qb, j, counts, kvidx):
        return (b, h, qb, 0)

    def kv_map(b, h, qb, j, counts, kvidx):
        return (b, h // group, kvidx[qb * max_active + j], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_map),
            pl.BlockSpec((1, 1, block_kv, D), kv_map),
            pl.BlockSpec((1, 1, block_kv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )
    kern = functools.partial(
        _flash_kernel, scale=scale, max_active=max_active, block_q=block_q,
        block_kv=block_kv, causal=causal, softcap=softcap)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(counts, flat_idx, q, k, v)


# =============================================================================
# paged decode (one new token against a roaring-paged KV cache)
# =============================================================================

def _decode_kernel(counts_ref, pages_ref, lens_ref, starts_ref,
                   q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, max_pages: int,
                   page_size: int, softcap: float | None):
    b, j = pl.program_id(0), pl.program_id(2)
    count = counts_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(j < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # logical position of this page is j (page lists are order-preserving)
        pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        live = jnp.logical_and(pos < lens_ref[b], pos >= starts_ref[b])
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           page_idx: jax.Array, counts: jax.Array,
                           lengths: jax.Array, starts: jax.Array | None = None,
                           *, softcap: float | None = None,
                           scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """Decode attention for one new token per sequence.

    q: [B, KVH, G, D] (G = query heads per KV head).
    k_pages/v_pages: [P, page_size, KVH, D] global page pools.
    page_idx: i32[B, max_pages] physical page ids per sequence, packed from
    the Roaring page table; counts: i32[B] pages in use; lengths: i32[B]
    tokens in the KV cache per sequence; starts: i32[B] first visible
    position (sliding-window layers; default 0).
    """
    B, KVH, G, D = q.shape
    P, page_size = k_pages.shape[0], k_pages.shape[1]
    max_pages = page_idx.shape[1]
    if scale is None:
        scale = D ** -0.5
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)

    flat_pages = page_idx.reshape(-1)
    grid = (B, KVH, max_pages)

    def q_map(b, kvh, j, counts, pages, lens, starts):
        return (b, kvh, 0, 0)

    def kv_map(b, kvh, j, counts, pages, lens, starts):
        return (pages[b * max_pages + j], 0, kvh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), q_map),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kern = functools.partial(_decode_kernel, scale=scale, max_pages=max_pages,
                             page_size=page_size, softcap=softcap)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(counts, flat_pages, lengths, starts, q, k_pages, v_pages)
