"""Public entry points for roaring block-sparse attention.

``sparse_attention`` is differentiable: forward runs the Pallas kernel on TPU
(interpret-mode on CPU when requested); backward recomputes through the
reference formulation (flash-style recompute — no S x S residuals are saved).
The dry-run lowers the reference path (identical math; DESIGN.md S6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def sparse_attention(q, k, v, kv_idx, counts, block_q=128, block_kv=128,
                     causal=True, softcap=None, scale=None, use_pallas=False):
    if use_pallas:
        return _k.sparse_flash_attention(
            q, k, v, kv_idx, counts, block_q=block_q, block_kv=block_kv,
            causal=causal, softcap=softcap, scale=scale,
            interpret=not _on_tpu())
    return _ref.sparse_attention_ref(
        q, k, v, kv_idx, counts, block_q=block_q, block_kv=block_kv,
        causal=causal, softcap=softcap, scale=scale)


def _fwd(q, k, v, kv_idx, counts, block_q, block_kv, causal, softcap, scale,
         use_pallas):
    out = sparse_attention(q, k, v, kv_idx, counts, block_q, block_kv, causal,
                           softcap, scale, use_pallas)
    return out, (q, k, v, kv_idx, counts)


def _bwd(block_q, block_kv, causal, softcap, scale, use_pallas, res, g):
    q, k, v, kv_idx, counts = res

    def f(q, k, v):
        return _ref.sparse_attention_ref(
            q, k, v, kv_idx, counts, block_q=block_q, block_kv=block_kv,
            causal=causal, softcap=softcap, scale=scale)

    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


sparse_attention.defvjp(_fwd, _bwd)


def paged_decode(q, k_pages, v_pages, page_idx, counts, lengths, starts=None,
                 softcap=None, scale=None, use_pallas=False):
    """Decode attention (inference only — no vjp needed)."""
    if use_pallas:
        return _k.paged_decode_attention(
            q, k_pages, v_pages, page_idx, counts, lengths, starts,
            softcap=softcap, scale=scale, interpret=not _on_tpu())
    return _ref.paged_decode_ref(q, k_pages, v_pages, page_idx, counts,
                                 lengths, starts, softcap=softcap, scale=scale)
