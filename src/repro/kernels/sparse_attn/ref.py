"""Pure-jnp oracles for the sparse attention kernels.

These are also the formulations lowered by the multi-pod dry-run: identical
math and sparsity accounting, expressed as dense masked attention so XLA's
cost analysis reflects the same FLOPs/bytes the TPU kernel would do per
*active* block (inactive blocks are masked; the FLOP accounting for roofline
corrects for block sparsity via the mask density — see benchmarks.roofline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def block_mask_to_dense(kv_idx: jax.Array, counts: jax.Array,
                        num_kv_blocks: int) -> jax.Array:
    """[num_qb, max_active] packed block lists -> bool[num_qb, num_kv_blocks]."""
    num_qb, max_active = kv_idx.shape
    valid = jnp.arange(max_active)[None, :] < counts[:, None]
    dense = jnp.zeros((num_qb, num_kv_blocks), bool)
    rows = jnp.repeat(jnp.arange(num_qb), max_active).reshape(num_qb, max_active)
    dense = dense.at[rows, kv_idx].max(valid)
    return dense


def sparse_attention_ref(q, k, v, kv_idx, counts, *, block_q=128, block_kv=128,
                         causal=True, softcap=None, scale=None):
    """Dense masked attention oracle for the block-sparse flash kernel."""
    B, H, S, D = q.shape
    KVH, S_kv = k.shape[1], k.shape[2]
    group = H // KVH
    if scale is None:
        scale = D ** -0.5
    blockmask = block_mask_to_dense(kv_idx, counts, S_kv // block_kv)
    elem = jnp.repeat(jnp.repeat(blockmask, block_q, axis=0), block_kv, axis=1)
    if causal:
        elem = elem & (jnp.arange(S_kv)[None, :] <= jnp.arange(S)[:, None])
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(elem[None, None], s, NEG_INF)
    # fully-masked rows produce zeros (matches kernel's l=0 -> out=0)
    any_live = jnp.any(elem, axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    out = jnp.where(any_live[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, page_idx, counts, lengths,
                     starts=None, *, softcap=None, scale=None):
    """Gather-then-attend oracle for the paged decode kernel."""
    B, KVH, G, D = q.shape
    P, page_size = k_pages.shape[0], k_pages.shape[1]
    max_pages = page_idx.shape[1]
    if scale is None:
        scale = D ** -0.5
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)
    # gather logical KV streams: [B, max_pages*page_size, KVH, D]
    k_seq = k_pages[page_idx].reshape(B, max_pages * page_size, KVH, D)
    v_seq = v_pages[page_idx].reshape(B, max_pages * page_size, KVH, D)
    pos = jnp.arange(max_pages * page_size)
    live = (pos[None, :] < lengths[:, None]) & \
        (pos[None, :] >= starts[:, None])                       # [B, L]
    s = jnp.einsum("bkgd,blkd->bkgl", q.astype(jnp.float32),
                   k_seq.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", p, v_seq.astype(jnp.float32))
    return out.astype(q.dtype)
