"""Pallas TPU kernels for the paper's compute hot spots.

- ``roaring``: fused container word-op + popcount (Algorithms 1/3) and the
  vectorized array-intersection (the galloping adaptation).
- ``sparse_attn``: roaring-driven block-sparse flash attention (the framework
  integration that makes ``long_500k`` sub-quadratic) and paged decode.

Every kernel ships ``ops.py`` (jit'd wrapper with backend auto-detection) and
``ref.py`` (pure-jnp oracle used by tests and by the dry-run lowering).
"""
