"""WAH — Word Aligned Hybrid bitmap compression (Wu et al.), w = 32.

Format (paper S1): the bitmap is cut into 31-bit groups.
  * literal word: bit31 = 0, bits 0..30 = the heterogeneous group;
  * fill word:    bit31 = 1, bit30 = fill bit value, bits 0..29 = run length
    (number of consecutive homogeneous 31-bit groups, >= 1).

Sparse worst case: 2 words (64 bits) per set bit, as the paper notes.
"""

from __future__ import annotations

import numpy as np

from ._groups import (ALL_ONES, GROUP_BITS, classify, groups_to_indices,
                      indices_to_groups, pad_to, run_starts_and_lengths,
                      split_long_runs)

_FLAG = np.uint32(1) << np.uint32(31)
_FILL_ONE = np.uint32(1) << np.uint32(30)
_LEN_MASK = np.uint32((1 << 30) - 1)
RUN_CAP = (1 << 30) - 1


def encode_groups(payload: np.ndarray) -> np.ndarray:
    """Vectorized group-stream -> WAH words."""
    if payload.size == 0:
        return np.empty(0, dtype=np.uint32)
    cls = classify(payload)
    starts, lengths = run_starts_and_lengths(cls)
    cstart = cls[starts]
    starts, lengths, cstart = split_long_runs(starts, lengths, cstart, RUN_CAP)
    words = np.empty(starts.size, dtype=np.uint32)
    lit = cstart == 2
    words[lit] = payload[starts[lit]]
    fill = ~lit
    words[fill] = (_FLAG
                   | np.where(cstart[fill] == 1, _FILL_ONE, np.uint32(0))
                   | lengths[fill].astype(np.uint32))
    return words


def decode_groups(words: np.ndarray) -> np.ndarray:
    """Vectorized WAH words -> group stream."""
    if words.size == 0:
        return np.empty(0, dtype=np.uint32)
    is_fill = (words & _FLAG) != 0
    counts = np.where(is_fill, words & _LEN_MASK, 1).astype(np.int64)
    values = np.where(
        is_fill,
        np.where((words & _FILL_ONE) != 0, ALL_ONES, np.uint32(0)),
        words & _LEN_MASK | (words & (np.uint32(1) << np.uint32(30))),  # literal payload
    )
    # literal payload is simply bits 0..30:
    values = np.where(is_fill, values, words & np.uint32((1 << 31) - 1))
    return np.repeat(values, counts)


class WahBitmap:
    """WAH-compressed integer set."""

    __slots__ = ("words", "_max")

    def __init__(self, words: np.ndarray, max_value: int = -1):
        self.words = np.asarray(words, dtype=np.uint32)
        self._max = max_value

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_array(cls, values) -> "WahBitmap":
        idx = np.asarray(sorted(set(int(v) for v in values)), dtype=np.int64)
        return cls.from_sorted_unique(idx)

    @classmethod
    def from_sorted_unique(cls, idx: np.ndarray) -> "WahBitmap":
        payload = indices_to_groups(np.asarray(idx, dtype=np.int64))
        mx = int(idx[-1]) if len(idx) else -1
        return cls(encode_groups(payload), mx)

    # -- queries ---------------------------------------------------------------
    def to_array(self) -> np.ndarray:
        return groups_to_indices(decode_groups(self.words))

    @property
    def cardinality(self) -> int:
        payload = decode_groups(self.words)
        return int(np.bitwise_count(payload).sum())

    def size_in_bytes(self) -> int:
        return 4 * int(self.words.size)

    # -- logical ops -------------------------------------------------------------
    def _binary(self, other: "WahBitmap", op) -> "WahBitmap":
        ga, gb = decode_groups(self.words), decode_groups(other.words)
        n = max(ga.size, gb.size)
        out = op(pad_to(ga, n), pad_to(gb, n))
        return WahBitmap(encode_groups(out), max(self._max, other._max))

    def and_(self, other: "WahBitmap") -> "WahBitmap":
        return self._binary(other, np.bitwise_and)

    def or_(self, other: "WahBitmap") -> "WahBitmap":
        return self._binary(other, np.bitwise_or)

    def and_streaming(self, other: "WahBitmap"):
        return _streaming_op(self.words, other.words, "and")

    def or_streaming(self, other: "WahBitmap"):
        return _streaming_op(self.words, other.words, "or")

    # -- single-element updates (Fig. 2e/2f) --------------------------------------
    def append(self, x: int) -> None:
        """Add x > max(S): operate on the tail of the word stream only —
        the efficient-append case WAH supports."""
        assert x > self._max, "append requires x greater than all elements"
        gid, bit = x // GROUP_BITS, x % GROUP_BITS
        last_gid = self._max // GROUP_BITS if self._max >= 0 else -1
        words = self.words
        if gid == last_gid and words.size:
            w = int(words[-1])
            if w & int(_FLAG):  # trailing fill of ones cannot contain last group w/ gap
                # split: reduce run by one, emit literal for last group
                run = w & int(_LEN_MASK)
                fill_one = bool(w & int(_FILL_ONE))
                payload = int(ALL_ONES) if fill_one else 0
                payload |= 1 << bit
                if run == 1:
                    words = words[:-1]
                else:
                    words = words.copy()
                    words[-1] = np.uint32((w & ~int(_LEN_MASK)) | (run - 1))
                self.words = np.append(words, np.uint32(payload))
            else:
                words = words.copy()
                words[-1] = np.uint32(w | (1 << bit))
                self.words = words
        else:
            gap = gid - last_gid - 1
            new = []
            while gap > 0:
                take = min(gap, RUN_CAP)
                new.append(int(_FLAG) | take)
                gap -= take
            new.append(1 << bit)
            self.words = np.append(self.words, np.asarray(new, dtype=np.uint32))
        self._max = x

    def remove(self, x: int) -> None:
        """RLE formats have no efficient random remove: full pass (decode,
        clear, re-encode) — this is exactly what the paper's Fig. 2f shows."""
        payload = decode_groups(self.words)
        gid, bit = x // GROUP_BITS, x % GROUP_BITS
        if gid < payload.size:
            payload[gid] &= np.uint32(~(1 << bit) & 0xFFFFFFFF)
            self.words = encode_groups(payload)
            if x == self._max:
                idx = groups_to_indices(payload)
                self._max = int(idx[-1]) if idx.size else -1

    def __eq__(self, other) -> bool:
        if not isinstance(other, WahBitmap):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())


def _streaming_op(wa: np.ndarray, wb: np.ndarray, kind: str):
    """Faithful run-at-a-time WAH merge (word-level control flow of the real
    algorithm). Returns (result_words, words_touched)."""
    out: list[int] = []
    touched = 0

    def runs(words):
        for w in words.tolist():
            w = int(w)
            if w & int(_FLAG):
                yield (w & int(_LEN_MASK)), (int(ALL_ONES) if w & int(_FILL_ONE) else 0)
            else:
                yield 1, w

    ita, itb = runs(wa), runs(wb)
    la = lb = 0
    va = vb = 0
    op = (lambda x, y: x & y) if kind == "and" else (lambda x, y: x | y)
    while True:
        if la == 0:
            nxt = next(ita, None)
            if nxt is None:
                break
            la, va = nxt
            touched += 1
        if lb == 0:
            nxt = next(itb, None)
            if nxt is None:
                break
            lb, vb = nxt
            touched += 1
        take = min(la, lb) if (va in (0, int(ALL_ONES)) and vb in (0, int(ALL_ONES))) else 1
        v = op(va, vb)
        # append run to output (merge with previous run when homogeneous)
        if v in (0, int(ALL_ONES)) and out and (out[-1][1] == v):
            out[-1][0] += take
        else:
            out.append([take, v])
        la -= take
        lb -= take
    # drain: OR keeps the remainder, AND drops it (zeros)
    if kind == "or":
        for it, l, v in ((ita, la, va), (itb, lb, vb)):
            if l:
                if v in (0, int(ALL_ONES)) and out and out[-1][1] == v:
                    out[-1][0] += l
                else:
                    out.append([l, v])
            for l2, v2 in it:
                touched += 1
                if v2 in (0, int(ALL_ONES)) and out and out[-1][1] == v2:
                    out[-1][0] += l2
                else:
                    out.append([l2, v2])
    words = []
    for l, v in out:
        if v in (0, int(ALL_ONES)) and l >= 1:
            one = int(_FILL_ONE) if v == int(ALL_ONES) else 0
            while l > 0:
                take = min(l, RUN_CAP)
                words.append(int(_FLAG) | one | take)
                l -= take
        else:
            words.extend([v] * l)
    return WahBitmap(np.asarray(words, dtype=np.uint32)), touched
