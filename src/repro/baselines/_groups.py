"""31-bit group-stream helpers shared by the WAH and Concise codecs.

Both formats segment the logical bit sequence into groups of w-1 = 31 bits.
A "group stream" is a dense uint32 array of group payloads (bit 31 unused).
"""

from __future__ import annotations

import numpy as np

GROUP_BITS = 31
ALL_ONES = np.uint32((1 << GROUP_BITS) - 1)  # 0x7FFFFFFF


def indices_to_groups(idx: np.ndarray) -> np.ndarray:
    """Sorted unique int64 indices -> dense group payload stream (uint32)."""
    idx = np.asarray(idx, dtype=np.int64)
    if idx.size == 0:
        return np.empty(0, dtype=np.uint32)
    gid = idx // GROUP_BITS
    bit = (idx % GROUP_BITS).astype(np.uint32)
    n_groups = int(gid[-1]) + 1
    payload = np.zeros(n_groups, dtype=np.uint32)
    np.bitwise_or.at(payload, gid, np.uint32(1) << bit)
    return payload


def groups_to_indices(payload: np.ndarray) -> np.ndarray:
    """Dense group payload stream -> sorted int64 indices."""
    if payload.size == 0:
        return np.empty(0, dtype=np.int64)
    nz = np.nonzero(payload)[0]
    if nz.size == 0:
        return np.empty(0, dtype=np.int64)
    bits = np.unpackbits(
        payload[nz].astype("<u4").view(np.uint8).reshape(-1, 4),
        axis=1, bitorder="little")[:, :GROUP_BITS]
    g, b = np.nonzero(bits)
    return (nz[g] * GROUP_BITS + b).astype(np.int64)


def pad_to(payload: np.ndarray, n: int) -> np.ndarray:
    if payload.size >= n:
        return payload
    out = np.zeros(n, dtype=np.uint32)
    out[: payload.size] = payload
    return out


def classify(payload: np.ndarray) -> np.ndarray:
    """0 = zero-fill group, 1 = ones-fill group, 2 = literal."""
    cls = np.full(payload.size, 2, dtype=np.int8)
    cls[payload == 0] = 0
    cls[payload == ALL_ONES] = 1
    return cls


def run_starts_and_lengths(cls: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """RLE over the class stream where every literal group is its own run."""
    n = cls.size
    if n == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    change = np.empty(n, dtype=bool)
    change[0] = True
    change[1:] = cls[1:] != cls[:-1]
    starts = np.nonzero(change | (cls == 2))[0]
    lengths = np.diff(np.append(starts, n))
    return starts, lengths


def split_long_runs(starts: np.ndarray, lengths: np.ndarray, cls_at_start: np.ndarray,
                    cap: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split homogeneous runs longer than the format's run-length capacity."""
    too_long = (lengths > cap) & (cls_at_start != 2)
    if not too_long.any():
        return starts, lengths, cls_at_start
    s_out, l_out, c_out = [], [], []
    for s, l, c in zip(starts.tolist(), lengths.tolist(), cls_at_start.tolist()):
        if c != 2 and l > cap:
            while l > 0:
                take = min(l, cap)
                s_out.append(s)
                l_out.append(take)
                c_out.append(c)
                s += take
                l -= take
        else:
            s_out.append(s)
            l_out.append(l)
            c_out.append(c)
    return (np.asarray(s_out, dtype=np.int64), np.asarray(l_out, dtype=np.int64),
            np.asarray(c_out, dtype=np.int8))
