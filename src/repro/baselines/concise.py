"""Concise — Compressed 'n' Composable Integer Set (Colantonio & Di Pietro).

Format (paper S1, w = 32): like WAH but fill words sacrifice
ceil(log2 w) = 5 bits of the run length as *position bits*:

  * literal: bit31 = 0, bits 0..30 payload;
  * fill:    bit31 = 1, bit30 = fill bit, bits 25..29 = position p,
             bits 0..24 = run length r.
    p = 0  -> r+1 homogeneous 31-bit groups;
    p > 0  -> one group equal to the fill value with bit (p-1) flipped,
              followed by r homogeneous groups.

The mixed fill is what halves WAH's 64 bits/int worst case to 32 bits/int on
sets like {0, 62, 124, ...}.
"""

from __future__ import annotations

import numpy as np

from ._groups import (ALL_ONES, GROUP_BITS, classify, groups_to_indices,
                      indices_to_groups, pad_to, run_starts_and_lengths)

_FLAG = 1 << 31
_FILL_ONE = 1 << 30
_POS_SHIFT = 25
_POS_MASK = 0x1F
_LEN_MASK = (1 << 25) - 1
RUN_CAP = (1 << 25) - 1           # max r; one fill word covers r+1 groups
MAX_GROUPS_PER_WORD = RUN_CAP + 1


def _emit_pure_fill(out: list, fill_one: bool, n_groups: int) -> None:
    """Emit fill words covering n_groups homogeneous groups."""
    base = _FLAG | (_FILL_ONE if fill_one else 0)
    while n_groups > 0:
        take = min(n_groups, MAX_GROUPS_PER_WORD)
        out.append(base | (take - 1))
        n_groups -= take


def encode_groups(payload: np.ndarray) -> np.ndarray:
    """Group stream -> Concise words, merging single-flipped-bit literals into
    the following fill run (the format's signature optimization)."""
    if payload.size == 0:
        return np.empty(0, dtype=np.uint32)
    cls = classify(payload)
    starts, lengths = run_starts_and_lengths(cls)
    cstart = cls[starts].tolist()
    starts_l = starts.tolist()
    lengths_l = lengths.tolist()
    out: list[int] = []
    n = len(starts_l)
    i = 0
    while i < n:
        c, s, l = cstart[i], starts_l[i], lengths_l[i]
        if c == 2:  # literal group
            w = int(payload[s])
            pc = int(w).bit_count()
            merged = False
            if i + 1 < n and cstart[i + 1] in (0, 1):
                fill_one = cstart[i + 1] == 1
                nxt_len = lengths_l[i + 1]
                if (not fill_one and pc == 1) or (fill_one and pc == GROUP_BITS - 1):
                    if fill_one:
                        flipped = (~w) & int(ALL_ONES)
                    else:
                        flipped = w
                    p = int(flipped).bit_length()  # index of the single bit + 1
                    r = min(nxt_len, RUN_CAP)
                    out.append(_FLAG | (_FILL_ONE if fill_one else 0)
                               | (p << _POS_SHIFT) | r)
                    rest = nxt_len - r
                    if rest > 0:
                        _emit_pure_fill(out, fill_one, rest)
                    i += 2
                    merged = True
            if not merged:
                out.append(w)
                i += 1
        else:
            _emit_pure_fill(out, c == 1, l)
            i += 1
    return np.asarray(out, dtype=np.uint32)


def decode_groups(words: np.ndarray) -> np.ndarray:
    """Concise words -> dense group stream (vectorized)."""
    if words.size == 0:
        return np.empty(0, dtype=np.uint32)
    w = words.astype(np.int64)
    is_fill = (w & _FLAG) != 0
    fill_one = (w & _FILL_ONE) != 0
    pos = (w >> _POS_SHIFT) & _POS_MASK
    pos = np.where(is_fill, pos, 0)
    # every fill word covers r+1 groups: r fills preceded by one flipped word
    # when p > 0, or r+1 plain fills when p = 0 (paper S1).
    counts = np.where(is_fill, (w & _LEN_MASK) + 1, 1).astype(np.int64)
    values = np.where(is_fill,
                      np.where(fill_one, np.int64(int(ALL_ONES)), np.int64(0)),
                      w & int(ALL_ONES)).astype(np.int64)
    payload = np.repeat(values, counts).astype(np.uint32)
    # fix flipped first group of mixed fills
    mixed = is_fill & (pos > 0)
    if mixed.any():
        starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
        mstarts = starts[mixed]
        mbits = (pos[mixed] - 1).astype(np.uint32)
        payload[mstarts] ^= np.uint32(1) << mbits
    return payload


class ConciseBitmap:
    """Concise-compressed integer set."""

    __slots__ = ("words", "_max")

    def __init__(self, words: np.ndarray, max_value: int = -1):
        self.words = np.asarray(words, dtype=np.uint32)
        self._max = max_value

    @classmethod
    def from_array(cls, values) -> "ConciseBitmap":
        idx = np.asarray(sorted(set(int(v) for v in values)), dtype=np.int64)
        return cls.from_sorted_unique(idx)

    @classmethod
    def from_sorted_unique(cls, idx: np.ndarray) -> "ConciseBitmap":
        payload = indices_to_groups(np.asarray(idx, dtype=np.int64))
        mx = int(idx[-1]) if len(idx) else -1
        return cls(encode_groups(payload), mx)

    def to_array(self) -> np.ndarray:
        return groups_to_indices(decode_groups(self.words))

    @property
    def cardinality(self) -> int:
        return int(np.bitwise_count(decode_groups(self.words)).sum())

    def size_in_bytes(self) -> int:
        return 4 * int(self.words.size)

    def _binary(self, other: "ConciseBitmap", op) -> "ConciseBitmap":
        ga, gb = decode_groups(self.words), decode_groups(other.words)
        n = max(ga.size, gb.size)
        out = op(pad_to(ga, n), pad_to(gb, n))
        return ConciseBitmap(encode_groups(out), max(self._max, other._max))

    def and_(self, other: "ConciseBitmap") -> "ConciseBitmap":
        return self._binary(other, np.bitwise_and)

    def or_(self, other: "ConciseBitmap") -> "ConciseBitmap":
        return self._binary(other, np.bitwise_or)

    # -- single-element updates ---------------------------------------------------
    def append(self, x: int) -> None:
        """Add x > max(S), operating on the stream tail only."""
        assert x > self._max
        gid, bit = x // GROUP_BITS, x % GROUP_BITS
        last_gid = self._max // GROUP_BITS if self._max >= 0 else -1
        out = self.words.tolist()
        if gid == last_gid and out:
            w = int(out[-1])
            if not (w & _FLAG):
                out[-1] = w | (1 << bit)
            else:
                # tail is a fill covering this group: split its last group off
                payload = int(ALL_ONES) if (w & _FILL_ONE) else 0
                r = w & _LEN_MASK
                if r == 0 and not ((w >> _POS_SHIFT) & _POS_MASK):
                    out.pop()
                else:
                    out[-1] = w - 1 if r > 0 else w
                out.append(payload | (1 << bit))
        else:
            gap = gid - last_gid - 1
            if gap > 0:
                lit_is_single = out and not (int(out[-1]) & _FLAG) \
                    and int(out[-1]).bit_count() == 1
                if lit_is_single and gap - 1 <= RUN_CAP:
                    p = int(out[-1]).bit_length()
                    out[-1] = _FLAG | (p << _POS_SHIFT) | gap
                    # covers literal + gap groups: r = gap, total gap+1  ... but we
                    # need literal + gap zero groups = gap+1 groups -> r = gap. OK.
                else:
                    tmp: list[int] = []
                    _emit_pure_fill(tmp, False, gap)
                    out.extend(tmp)
            out.append(1 << bit)
        self.words = np.asarray(out, dtype=np.uint32)
        self._max = x

    def remove(self, x: int) -> None:
        """Full-pass decode/modify/encode — RLE formats lack random removal."""
        payload = decode_groups(self.words)
        gid, bit = x // GROUP_BITS, x % GROUP_BITS
        if gid < payload.size:
            payload[gid] &= np.uint32(~(1 << bit) & 0xFFFFFFFF)
            self.words = encode_groups(payload)
            if x == self._max:
                idx = groups_to_indices(payload)
                self._max = int(idx[-1]) if idx.size else -1

    def __eq__(self, other) -> bool:
        if not isinstance(other, ConciseBitmap):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())
