"""RLE-based compressed bitmap baselines the paper compares against.

WAH (Wu et al.) and Concise (Colantonio & Di Pietro) are implemented from the
format definitions in the paper's S1; BitSet mirrors java.util.BitSet's
doubling allocation. All three expose the same small API:

    from_array(values) / to_array()
    and_(other) / or_(other)          -> new object
    append(x)   (x > max, Fig. 2e)    remove(x)  (Fig. 2f)
    size_in_bytes()

Two op engines are provided for the RLE formats:
  * ``engine="expanded"`` (default): vectorized decode -> word-wise op ->
    re-encode. Favorable to WAH/Concise on modern hardware (numpy SIMD), so
    Roaring's measured advantage is conservative.
  * ``engine="streaming"``: the faithful run-at-a-time merge of the original
    algorithms, with a words-touched counter for machine-independent cost.
"""

from .wah import WahBitmap
from .concise import ConciseBitmap
from .bitset import BitSet

__all__ = ["WahBitmap", "ConciseBitmap", "BitSet"]
