"""Uncompressed bitmap mirroring java.util.BitSet.

Key behavioural detail reproduced from the paper's S5.1: BitSet *doubles* the
backing array whenever it grows, so the measured footprint of an incrementally
built set exceeds the trimmed size (visible in Fig. 2a/2b as BitSet sitting
slightly above 64/d even on dense data). Bulk construction allocates exactly;
`append` follows the doubling policy. Logical ops are in-place in Java, so the
benchmarked op includes a `clone`, as in the paper.
"""

from __future__ import annotations

import numpy as np


class BitSet:
    __slots__ = ("words", "words_in_use")

    def __init__(self, words: np.ndarray | None = None):
        self.words = words if words is not None else np.zeros(1, dtype=np.uint64)
        self.words_in_use = int(self.words.size)

    @classmethod
    def from_array(cls, values) -> "BitSet":
        idx = np.asarray(sorted(set(int(v) for v in values)), dtype=np.int64)
        return cls.from_sorted_unique(idx)

    @classmethod
    def from_sorted_unique(cls, idx: np.ndarray) -> "BitSet":
        idx = np.asarray(idx, dtype=np.int64)
        n_words = (int(idx[-1]) >> 6) + 1 if idx.size else 1
        words = np.zeros(n_words, dtype=np.uint64)
        np.bitwise_or.at(words, idx >> 6,
                         np.uint64(1) << (idx & 63).astype(np.uint64))
        return cls(words)

    def _ensure(self, n_words: int) -> None:
        if n_words > self.words.size:
            new_size = max(2 * self.words.size, n_words)  # java doubling policy
            grown = np.zeros(new_size, dtype=np.uint64)
            grown[: self.words.size] = self.words
            self.words = grown
        self.words_in_use = max(self.words_in_use, n_words)

    def add(self, x: int) -> None:
        self._ensure((x >> 6) + 1)
        self.words[x >> 6] |= np.uint64(1) << np.uint64(x & 63)

    append = add

    def remove(self, x: int) -> None:
        if (x >> 6) < self.words.size:
            self.words[x >> 6] &= ~(np.uint64(1) << np.uint64(x & 63))

    def contains(self, x: int) -> bool:
        w = x >> 6
        return w < self.words.size and bool((int(self.words[w]) >> (x & 63)) & 1)

    def clone(self) -> "BitSet":
        b = BitSet(self.words.copy())
        b.words_in_use = self.words_in_use
        return b

    def and_(self, other: "BitSet") -> "BitSet":
        """clone + in-place AND, matching the paper's measurement protocol."""
        out = self.clone()
        n = min(out.words.size, other.words.size)
        np.bitwise_and(out.words[:n], other.words[:n], out=out.words[:n])
        out.words[n:] = 0
        return out

    def or_(self, other: "BitSet") -> "BitSet":
        small, large = (self, other) if self.words.size <= other.words.size else (other, self)
        out = large.clone()
        n = small.words.size
        np.bitwise_or(out.words[:n], small.words[:n], out=out.words[:n])
        return out

    def to_array(self) -> np.ndarray:
        bits = np.unpackbits(self.words.view(np.uint8), bitorder="little")
        return np.nonzero(bits)[0].astype(np.int64)

    @property
    def cardinality(self) -> int:
        return int(np.bitwise_count(self.words).sum())

    def size_in_bytes(self) -> int:
        """Allocated footprint (doubling included), as measured in the paper."""
        return 8 * int(self.words.size)

    def trimmed_size_in_bytes(self) -> int:
        nz = np.nonzero(self.words)[0]
        return 8 * (int(nz[-1]) + 1) if nz.size else 8

    def __eq__(self, other) -> bool:
        if not isinstance(other, BitSet):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())
