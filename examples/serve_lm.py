"""End-to-end serving: batched requests, continuous batching, roaring-paged
KV cache (the paper's structure as the page allocator + per-seq page sets).

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    eng = ServeEngine(cfg, params, max_batch=4, n_pages=256, page_size=8,
                      max_pages_per_seq=32)

    rnp = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    prompt=rnp.integers(1, cfg.vocab, int(rnp.integers(3, 10))),
                    max_new_tokens=int(rnp.integers(4, 12)))
            for i in range(10)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    peak = 0.0
    while eng.queue or eng.active:
        eng.step()
        peak = max(peak, eng.utilization())
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tok} new tokens in {dt:.1f}s")
    print(f"peak page-pool utilization {peak:.1%}; all pages reclaimed: "
          f"{eng.utilization() == 0.0} (roaring OR back into the free set)")
    for r in reqs[:4]:
        print(f"  req {r.req_id}: {list(r.prompt)} -> {r.generated}")
    assert all(r.done for r in reqs)
    print("OK")


if __name__ == "__main__":
    main()
