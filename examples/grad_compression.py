"""Roaring top-k gradient compression on real LM gradients.

Demonstrates: compress -> exact top-k roundtrip -> roaring container stats
(scattered coordinates become array containers; hot embedding rows become
bitmap containers) -> wire-cost vs dense all-reduce.

    PYTHONPATH=src python examples/grad_compression.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.grad_comp import compress_leaf, compression_ratio, decompress_leaf
from repro.models import transformer as T


def main():
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    tokens = jax.random.randint(rng, (4, 129), 0, cfg.vocab)

    def loss(p):
        return T.lm_loss(p, tokens[:, :-1], tokens[:, 1:], cfg)

    grads = jax.grad(loss)(params)
    total_dense = 0
    total_comp = 0
    print(f"{'leaf':40s} {'n':>10s} {'k':>8s} {'ratio':>8s} {'containers'}")
    for path, g in jax.tree_util.tree_leaves_with_path(grads)[:8]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)[:40]
        k = max(64, g.size // 100)
        c = compress_leaf(g, k)
        back = decompress_leaf(c, g.shape, g.dtype)
        # contract: every kept coordinate restores exactly; nothing above
        # the kept-set magnitude was dropped (ties at the k-th magnitude may
        # resolve either way)
        flat = np.asarray(g, np.float32).reshape(-1)
        bflat = np.asarray(back, np.float32).reshape(-1)
        kept = np.nonzero(bflat)[0]
        assert kept.size <= k
        assert np.allclose(bflat[kept], flat[kept], rtol=1e-5)
        dropped_max = np.abs(np.where(bflat == 0, flat, 0)).max()
        kept_min = np.abs(flat[kept]).min() if kept.size else 0.0
        assert dropped_max <= kept_min + 1e-7
        r = compression_ratio(c, g.size)
        kinds = np.asarray(c.slab.kinds)
        total_dense += g.size * 4
        total_comp += r * g.size * 4
        print(f"{name:40s} {g.size:>10d} {k:>8d} {r:>8.3f} "
              f"{int((kinds == 1).sum())} array / {int((kinds == 2).sum())} bitmap")
    print(f"\nwire bytes per sync: dense {total_dense/1e6:.1f} MB -> "
          f"compressed {total_comp/1e6:.2f} MB "
          f"({total_dense/max(total_comp,1):.0f}x)")
    print("OK")


if __name__ == "__main__":
    main()
