"""Quickstart: the Roaring bitmap core, the paper's claims in 60 seconds —
plus the ``repro.roaring`` object API (pytree-native slabs with operator
algebra, portable serialization), the wide-query engine, and the columnar
``repro.store`` bitmap index with its predicate compiler.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.baselines import BitSet, ConciseBitmap, WahBitmap
from repro.core import RoaringBitmap, union_many


def main():
    # --- the paper's S1 example: {0, 62, 124, ...} --------------------------
    vals = np.arange(0, 62 * 10000, 62, dtype=np.int64)
    roar = RoaringBitmap.from_sorted_unique(vals)
    wah = WahBitmap.from_sorted_unique(vals)
    con = ConciseBitmap.from_sorted_unique(vals)
    bits = lambda o: o.size_in_bytes() * 8 / vals.size
    print("bits/integer on {0, 62, 124, ...}:")
    print(f"  roaring {bits(roar):6.1f}   (paper: ~16)")
    print(f"  concise {bits(con):6.1f}   (paper: 32)")
    print(f"  wah     {bits(wah):6.1f}   (paper: 64)")

    # --- hybrid containers -----------------------------------------------------
    rb = RoaringBitmap.from_array(
        list(range(0, 62_000, 62))                 # sparse chunk -> array
        + list(range(1 << 16, (1 << 16) + 100))    # small chunk  -> array
        + list(range(2 << 16, 3 << 16, 2)))        # dense chunk  -> bitmap
    na, nb = rb.container_stats()
    print(f"\nfig-1 bitmap: {na} array + {nb} bitmap containers, "
          f"cardinality {len(rb)} (counter sum)")

    # --- set algebra vs python sets ---------------------------------------------
    rng = np.random.default_rng(0)
    a = np.unique(rng.integers(0, 1 << 20, 50_000))
    b = np.unique(rng.integers(0, 1 << 20, 80_000))
    ra, rb2 = RoaringBitmap.from_sorted_unique(a), RoaringBitmap.from_sorted_unique(b)
    sa, sb = set(a.tolist()), set(b.tolist())
    assert set((ra & rb2).to_array().tolist()) == sa & sb
    assert set((ra | rb2).to_array().tolist()) == sa | sb
    print("\nAND/OR verified against python set algebra "
          f"(|A|={len(sa)}, |B|={len(sb)}, |A&B|={len(sa & sb)})")

    # --- Algorithm 4: many-way union ----------------------------------------------
    parts = [RoaringBitmap.from_sorted_unique(
        np.unique(rng.integers(0, 1 << 20, 20_000))) for _ in range(32)]
    u = union_many(parts)
    print(f"alg-4 union of 32 bitmaps: cardinality {len(u)}, "
          f"{u.size_in_bytes()/1024:.0f} kB")

    # --- rank/select ---------------------------------------------------------------
    print(f"rank(500000) = {ra.rank(500_000)}, select(1000) = {ra.select(1000)}")

    # --- the device slab (PR 5 object API): pytree-native, operator algebra --------
    import jax

    from repro import roaring
    from repro.roaring import RoaringSlab

    da = RoaringSlab.from_values(a, capacity=16, max_elems=1 << 17)
    db = RoaringSlab.from_values(b, capacity=16, max_elems=1 << 17)
    inter = da & db                              # kind-dispatch engine, canonical
    assert int(inter.card()) == len(sa & sb)
    runs = RoaringSlab.from_ranges([(0, 40_000)], capacity=4)  # run rows directly
    dense = RoaringSlab.from_values(np.arange(0, 40_000), capacity=4,
                                    max_elems=1 << 16)
    opt = dense.run_optimize()                   # best-of-three, on device
    print(f"\nslab [0, 40000): {int(dense.size_in_bytes())} B as "
          f"array/bitmap rows -> {int(opt.size_in_bytes())} B after "
          f".run_optimize() (== from_ranges: {int(runs.size_in_bytes())} B)")
    hits = opt.contains(np.asarray([39_999, 40_000]))
    assert bool(hits[0]) and not bool(hits[1])

    # portable serialization (the Roaring interchange format)
    blob = inter.serialize()
    back = RoaringSlab.deserialize(blob)
    assert back.serialize() == blob
    print(f"serialize round trip: {len(blob)} bytes, kind-exact")

    # jit / vmap flow: a RoaringSlab is a pytree (capacity is static aux)
    f = jax.jit(lambda x, y: (x & y).card())
    assert int(f(da, db)) == len(sa & sb)

    # --- the wide-query engine: Algorithm 4 at query-engine scale -------------------
    from repro import index

    posting = [RoaringSlab.from_values(
        np.unique(rng.integers(0, 1 << 18, 4_000)), 8, 1 << 14)
        for _ in range(8)]
    stack = roaring.stack(posting, capacity=8)   # stacked slab: ndim == 2
    u = index.wide_union(stack)                  # log-depth tree reduction
    expr = index.andnot(index.or_(index.leaf(0), index.leaf(1)),
                        index.leaf(2))
    n = int(index.execute_card(stack, expr))     # no result materialized
    # ... or attach slabs to the tree directly — no stack bookkeeping
    n2 = int(index.execute_card(index.andnot(
        index.or_(index.leaf(posting[0]), index.leaf(posting[1])),
        index.leaf(posting[2]))))
    assert n == n2
    scores, ids = index.topk_by_card(stack, posting[0], k=3)
    print(f"wide union of 8 slabs: |∪| = {int(u.card())}; "
          f"|(0 ∪ 1) \\ 2| = {n}; top-3 vs slab 0 = "
          f"{np.asarray(ids).tolist()} (scores {np.asarray(scores).tolist()})")

    # --- fused execution (PR 7): the whole tree in ONE kernel launch -----------------
    # per-op evaluation runs N-1 launches and round-trips every intermediate
    # through HBM; fused=True compiles the tree to a tape and evaluates it in
    # a single launch with intermediates in VMEM — byte-identical results
    wide = index.or_(*[index.leaf(i) for i in range(8)])
    filt = index.execute(stack, wide, fused=True)      # one launch, one finalize
    assert filt.serialize() == index.execute(stack, wide).serialize()
    nf = int(index.execute_card(stack, wide, fused=True))
    assert nf == int(u.card())                         # same ∪ as wide_union
    print(f"fused 8-way OR: |∪| = {nf} "
          f"(one launch; byte-identical to the per-op executor)")

    # --- the store (PR 8): columnar records -> bitmap index -> predicates ------------
    from repro import store

    n_rows = 5_000
    records = {
        "city": rng.integers(0, 8, n_rows).astype(np.int64),
        "kind": np.asarray(["a", "b", "c"])[rng.integers(0, 3, n_rows)],
        "age": np.clip(rng.normal(35, 12, n_rows), 0, 95).astype(np.int64),
    }
    s = store.BitmapStore.build(records, bsi=("age",))   # age: bit-sliced
    pred = store.and_(store.eq("kind", "b"),
                      store.not_(store.in_("city", [2, 5])),
                      store.range_("age", 30, 40))
    rows = s.query_indices(pred, fused=True)             # one kernel launch
    mask = ((records["kind"] == "b") & ~np.isin(records["city"], [2, 5])
            & (records["age"] >= 30) & (records["age"] <= 40))
    assert np.array_equal(rows, np.nonzero(mask)[0])     # == numpy row filter
    assert s.count(pred) == rows.size
    total = s.sum_("age", store.eq("kind", "b"))         # bit-sliced aggregate
    assert total == int(records["age"][records["kind"] == "b"].sum())
    blob = s.save()                                      # portable slab blobs
    assert store.BitmapStore.load(blob).save() == blob   # byte-exact reload
    print(f"store: {s!r}\n  |{pred.__class__.__name__}| = {rows.size} rows, "
          f"sum(age | kind=b) = {total}, saved {len(blob)} bytes")

    # --- telemetry (PR 9): trace a query, read the launch accounting ------------------
    # off by default; enable() turns on spans + the kernel launch hook, and
    # every store.query phase (compile, cached execute, eager fallback)
    # shows up as a span with the launch counters alongside
    import repro.obs as obs

    obs.enable()
    traced = store.and_(store.eq("city", 3), store.range_("age", 18, 65))
    s.query(traced, fused=True)              # cache miss: compile + execute
    s.query(traced, fused=True)              # cache hit: no retrace, no launch
    report = obs.collect()
    print("\n" + obs.render_text(report))
    assert obs.span_trees(), "traced query produced no span tree"
    assert obs.registry().total("roaring.launches") >= 1
    obs.disable()


if __name__ == "__main__":
    main()
