"""Quickstart: the Roaring bitmap core, the paper's claims in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.baselines import BitSet, ConciseBitmap, WahBitmap
from repro.core import RoaringBitmap, union_many


def main():
    # --- the paper's S1 example: {0, 62, 124, ...} --------------------------
    vals = np.arange(0, 62 * 10000, 62, dtype=np.int64)
    roar = RoaringBitmap.from_sorted_unique(vals)
    wah = WahBitmap.from_sorted_unique(vals)
    con = ConciseBitmap.from_sorted_unique(vals)
    bits = lambda o: o.size_in_bytes() * 8 / vals.size
    print("bits/integer on {0, 62, 124, ...}:")
    print(f"  roaring {bits(roar):6.1f}   (paper: ~16)")
    print(f"  concise {bits(con):6.1f}   (paper: 32)")
    print(f"  wah     {bits(wah):6.1f}   (paper: 64)")

    # --- hybrid containers -----------------------------------------------------
    rb = RoaringBitmap.from_array(
        list(range(0, 62_000, 62))                 # sparse chunk -> array
        + list(range(1 << 16, (1 << 16) + 100))    # small chunk  -> array
        + list(range(2 << 16, 3 << 16, 2)))        # dense chunk  -> bitmap
    na, nb = rb.container_stats()
    print(f"\nfig-1 bitmap: {na} array + {nb} bitmap containers, "
          f"cardinality {len(rb)} (counter sum)")

    # --- set algebra vs python sets ---------------------------------------------
    rng = np.random.default_rng(0)
    a = np.unique(rng.integers(0, 1 << 20, 50_000))
    b = np.unique(rng.integers(0, 1 << 20, 80_000))
    ra, rb2 = RoaringBitmap.from_sorted_unique(a), RoaringBitmap.from_sorted_unique(b)
    sa, sb = set(a.tolist()), set(b.tolist())
    assert set((ra & rb2).to_array().tolist()) == sa & sb
    assert set((ra | rb2).to_array().tolist()) == sa | sb
    print("\nAND/OR verified against python set algebra "
          f"(|A|={len(sa)}, |B|={len(sb)}, |A&B|={len(sa & sb)})")

    # --- Algorithm 4: many-way union ----------------------------------------------
    parts = [RoaringBitmap.from_sorted_unique(
        np.unique(rng.integers(0, 1 << 20, 20_000))) for _ in range(32)]
    u = union_many(parts)
    print(f"alg-4 union of 32 bitmaps: cardinality {len(u)}, "
          f"{u.size_in_bytes()/1024:.0f} kB")

    # --- rank/select ---------------------------------------------------------------
    print(f"rank(500000) = {ra.rank(500_000)}, select(1000) = {ra.select(1000)}")


if __name__ == "__main__":
    main()
