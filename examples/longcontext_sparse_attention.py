"""Roaring-driven block-sparse attention: mask algebra -> kernel metadata ->
attention output, verified against the dense oracle.

Shows the paper's structures doing framework work: the attention mask for a
long-context layer is built with Roaring unions (local window | global
stripes | doc-boundary), compiled to packed block lists (Algorithm 2
extraction), and consumed by the splash-style kernel in interpret mode.

    PYTHONPATH=src python examples/longcontext_sparse_attention.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.kernels.sparse_attn import kernel as K
from repro.kernels.sparse_attn import ref as R
from repro.sparsity import (MaskBuilder, build_arch_mask, compile_mask,
                            doc_boundary_mask, mask_density)


def main():
    S, block = 2048, 128
    nb = S // block

    # 1) mask algebra with roaring bitmaps
    base = build_arch_mask(nb, pattern="local_global", window_blocks=4,
                           n_global=2)
    docs = MaskBuilder(doc_boundary_mask(nb, doc_starts_blocks=[6, 11]))
    mask = base.intersect(docs)            # confine attention within docs
    kv_idx, counts = compile_mask(mask)
    print(f"{nb}x{nb} block mask: density {mask_density(kv_idx, counts):.3f} "
          f"(dense causal would be {(nb+1)/(2*nb):.3f})")
    print(f"roaring mask footprint: {mask.size_in_bytes()} bytes vs "
          f"{nb * nb // 8} bytes for a dense block-bool matrix")

    # 2) attention through the block lists (interpret-mode pallas kernel)
    rng = np.random.default_rng(0)
    B, H, D = 1, 2, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    out_kernel = K.sparse_flash_attention(
        q, k, v, jnp.asarray(kv_idx), jnp.asarray(counts),
        block_q=block, block_kv=block, causal=True, interpret=True)
    out_ref = R.sparse_attention_ref(
        q, k, v, jnp.asarray(kv_idx), jnp.asarray(counts),
        block_q=block, block_kv=block, causal=True)
    err = float(jnp.max(jnp.abs(out_kernel - out_ref)))
    print(f"kernel vs dense-masked oracle: max |err| = {err:.2e}")
    assert err < 2e-5
    print("OK")


if __name__ == "__main__":
    main()
