"""End-to-end training driver: bitmap-indexed data pipeline -> LM training
with fault tolerance, checkpoint/restart, and a mid-run mixture switch done
with Roaring query algebra.

Default runs a ~10M-param gemma2-family model for 120 steps on CPU; pass
--full-100m --steps 300 on a larger machine for the 100M-scale run.

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import BitmapIndex, DataPipeline, PipelineState, SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw, cosine_schedule
from repro.runtime import ResilientTrainer, simulate_failure
from repro.train import TrainState, make_train_step


def small_cfg(full_100m: bool) -> ModelConfig:
    if full_100m:
        return ModelConfig(
            name="gemma2-100m", n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=4, d_ff=2048, vocab=32_000, head_dim=64,
            layer_pattern="local_global", window=256,
            attn_softcap=50.0, logit_softcap=30.0)
    return ModelConfig(
        name="gemma2-10m", n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=1024, vocab=8_000, head_dim=64, layer_pattern="local_global",
        window=128, attn_softcap=50.0, logit_softcap=30.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the run mid-way and prove restart-equivalence")
    args = ap.parse_args()

    cfg = small_cfg(args.full_100m)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    corpus = SyntheticCorpus(n_docs=20_000, vocab=cfg.vocab, seed=0,
                             mean_len=args.seq // 2)
    index = BitmapIndex(corpus)
    # mixture queries, evaluated with roaring set algebra
    q_high = "quality>=3&!dedup_dup"
    q_all = "quality>=1&!dedup_dup"
    print(f"selection[{q_high}] = {len(index.query(q_high))} docs; "
          f"selection[{q_all}] = {len(index.query(q_all))} docs")

    pipes = {
        q: DataPipeline(index, PipelineState(query=q, seed=7),
                        batch=args.batch, seq_len=args.seq)
        for q in (q_high, q_all)}
    switch_at = args.steps // 2
    cache = {}

    def batch_at(step):
        if step not in cache:
            # curriculum: high-quality mixture first, then broaden (roaring
            # queries make the switch free)
            pipe = pipes[q_high] if step < switch_at else pipes[q_all]
            toks, mask, _ = pipe.next_batch()
            cache[step] = {"tokens": jnp.asarray(toks),
                           "mask": jnp.asarray(mask)}
        return cache[step]

    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps))
    state = TrainState(params, opt.init(params), 0)
    base_step = jax.jit(make_train_step(cfg, opt), donate_argnums=())

    losses = []

    def step_fn(state, batch):
        state, metrics = base_step(state, batch)
        losses.append(float(metrics["loss"]))
        s = int(np.asarray(state["step"]))
        if s % 10 == 0:
            print(f"  step {s:4d} loss {losses[-1]:.4f}")
        return state, metrics

    ckdir = tempfile.mkdtemp(prefix="repro_train_")
    failure = simulate_failure({args.steps // 3}) if args.inject_failure else None
    trainer = ResilientTrainer(step_fn, ckdir, ckpt_every=20,
                               failure_source=failure)
    state, _ = trainer.run(state, batch_at, n_steps=args.steps)

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"\nloss: {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"(restarts: {trainer.restarts})")
    assert last < first, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
