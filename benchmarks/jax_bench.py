"""Framework-layer benchmarks: grad compression wire cost, mask compilation,
data-pipeline query throughput — the paper's structures doing LM work."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # roaring grad compression: ratio + encode/decode time
    from repro.grad_comp import compress_leaf, compression_ratio, decompress_leaf
    n = 1 << 20
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    for pct in ([1] if quick else [1, 5]):
        k = n * pct // 100
        t0 = time.perf_counter()
        c = compress_leaf(g, k)
        jax.block_until_ready(c.values)
        enc_us = (time.perf_counter() - t0) * 1e6
        r = compression_ratio(c, n)
        rows.append((f"grad_comp/topk{pct}%/1M", round(enc_us, 1), round(r, 4)))

    # mask compilation throughput at long_500k geometry
    from repro.sparsity import build_arch_mask, compile_mask, mask_density
    nb = 512 if quick else 4096
    t0 = time.perf_counter()
    m = build_arch_mask(nb, pattern="local_global", window_blocks=8, n_global=4)
    kv_idx, counts = compile_mask(m)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"sparsity/compile_mask/{nb}rows", round(us, 1),
                 round(mask_density(kv_idx, counts), 4)))

    # bitmap-index query throughput
    from repro.data import BitmapIndex, SyntheticCorpus
    corpus = SyntheticCorpus(n_docs=100_000 if quick else 500_000, vocab=1000,
                             seed=1)
    idx = BitmapIndex(corpus)
    t0 = time.perf_counter()
    sel = idx.query("lang=1|lang=2&quality>=2&!dedup_dup")
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"data/bitmap_query/{corpus.n_docs}docs", round(us, 1),
                 len(sel)))

    # serving engine tokens/s (reduced model, CPU)
    if not quick:
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.serve import Request, ServeEngine
        cfg = get_config("stablelm-1.6b", reduced=True)
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, max_batch=2, n_pages=128, page_size=8,
                          max_pages_per_seq=16)
        reqs = [Request(req_id=i, prompt=np.asarray([3, 5, 7]),
                        max_new_tokens=8) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_done()
        dt = time.perf_counter() - t0
        ntok = sum(len(r.generated) for r in reqs)
        rows.append(("serve/paged_decode_tokens", round(dt * 1e6, 1),
                     round(ntok / dt, 2)))
    return rows
