"""Kernel-layer benchmarks: pallas (interpret) correctness-at-scale + the
XLA reference path throughput on CPU (wall numbers are CPU-only indicative;
the TPU story is the dry-run roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, repeats=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats * 1e6


def _rand_slab(jr, rng, n, universe, capacity):
    vals = np.unique(rng.integers(0, universe, n))
    return jr.from_dense_array(vals, capacity, 1 << 17)


def dispatch_ab(quick: bool = False):
    """A/B: hybrid per-kind dispatch vs the legacy bitmap-domain path.

    Three workload shapes: sparse (all array containers — the case the
    bitmap-domain path taxes hardest), mixed (array x bitmap), dense (all
    bitmap). Derived column = speedup of dispatch over bitmap-domain on the
    same jitted intersection.
    """
    import jax
    from repro.core import jax_roaring as jr

    rows = []
    rng = np.random.default_rng(7)
    C = 16
    workloads = {
        # (n_a, n_b, universe): universe/chunks chosen so per-chunk cards
        # land well under / around / over the 4096 threshold
        "sparse": (12000, 12000, C << 16),     # ~750/chunk -> arrays
        "mixed": (3000, 60000, 8 << 16),       # arrays vs ~7.5k/chunk bitmaps
        "dense": (100000, 100000, 8 << 16),    # ~12k/chunk -> bitmaps
    }
    repeats = 3 if quick else 5
    for name, (na, nb, universe) in workloads.items():
        sa = _rand_slab(jr, rng, na, universe, C)
        sb = _rand_slab(jr, rng, nb, universe, C)
        f_new = jax.jit(lambda x, y: jr._slab_and(x, y, capacity=C))
        f_old = jax.jit(lambda x, y: jr._slab_and_bitmap_domain(x, y, capacity=C))
        f_card = jax.jit(jr._slab_and_card)
        us_new = _t(lambda: f_new(sa, sb), repeats)
        us_old = _t(lambda: f_old(sa, sb), repeats)
        us_card = _t(lambda: f_card(sa, sb), repeats)
        speedup = us_old / max(us_new, 1e-9)
        rows.append((f"kernels/dispatch_ab/{name}/bitmap_domain",
                     round(us_old, 1), ""))
        rows.append((f"kernels/dispatch_ab/{name}/hybrid_dispatch",
                     round(us_new, 1), round(speedup, 2)))
        rows.append((f"kernels/dispatch_ab/{name}/and_card_only",
                     round(us_card, 1),
                     round(us_old / max(us_card, 1e-9), 2)))
    return rows


def run_ab(quick: bool = False):
    """A/B on the 2016 paper's run-friendly regime: run-container dispatch
    (run-merge for run x run, range-mask coverage for run x bitmap) vs the
    legacy bitmap-domain path on the same logical sets.

    The legacy path lifts every row and pays the unconditional O(2^16)
    re-canonicalization; the engine stays in run/word domain and only the
    rows whose canonical form needs a packed extraction pay it (guarded).
    """
    import jax
    from repro.core import RoaringBitmap, jax_roaring as jr
    from .synth import gen_run_ranges, gen_set

    rows = []
    repeats = 3 if quick else 5
    n = 100_000
    # run-heavy operands: ~2000 runs of mean length 50 at density 2^-2
    ra = RoaringBitmap.from_ranges(gen_run_ranges(0.25, 50.0, 1, n))
    rb = RoaringBitmap.from_ranges(gen_run_ranges(0.25, 50.0, 2, n))
    # scattered-dense operand over the same universe: bitmap containers
    # dense enough that run x bitmap outputs stay above the 4096 threshold
    # (the regime where the legacy path's unconditional O(2^16)
    # re-canonicalization is pure waste)
    vs = gen_set(0.5, "uniform", seed=3, n=2 * n)
    C = 8
    sa = jr.from_roaring(ra, C)
    sb = jr.from_roaring(rb, C)
    sc = jr.from_dense_array(vs, C, 1 << 18)
    workloads = {"run_run": (sa, sb), "run_bitmap": (sa, sc)}
    f_new = jax.jit(lambda x, y: jr._slab_and(x, y, capacity=C))
    f_old = jax.jit(lambda x, y: jr._slab_and_bitmap_domain(x, y, capacity=C))
    f_card = jax.jit(jr._slab_and_card)
    for name, (x, y) in workloads.items():
        assert int(f_new(x, y).cardinality) == int(f_old(x, y).cardinality)
        us_new = _t(lambda: f_new(x, y), repeats)
        us_old = _t(lambda: f_old(x, y), repeats)
        us_card = _t(lambda: f_card(x, y), repeats)
        rows.append((f"run/{name}/bitmap_domain", round(us_old, 1), ""))
        rows.append((f"run/{name}/hybrid_dispatch", round(us_new, 1),
                     round(us_old / max(us_new, 1e-9), 2)))
        rows.append((f"run/{name}/and_card_only", round(us_card, 1),
                     round(us_old / max(us_card, 1e-9), 2)))
    # compressed-size ratio of the same sets with vs without run containers
    plain = jr.from_dense_array(ra.to_array(), C, 1 << 17)
    rows.append(("run/size/run_rows", 0.0, int(sa.size_in_bytes())))
    rows.append(("run/size/two_kind_rows", 0.0, int(plain.size_in_bytes())))
    return rows


def wide_ab(quick: bool = False):
    """A/B on wide horizontal ops (paper Alg. 4 / the CRoaring aggregation
    layer): the query engine's log-depth tree reduction vs the sequential
    pairwise fold of N-1 canonicalizing slab ops, N = 16 slabs.

    The fold pays a full best-of-three canonicalization (and key re-sort)
    per step; the tree pays ceil(log2 N) kind-dispatching combine levels
    with deferred cardinality and ONE canonicalization + recount at the
    root. The derived column is the within-run fold/tree speedup
    (machine-independent; the union and card-only-scoring rows are gated
    >= 2x in benchmarks/compare.py --speedup-mode, the AND-tree row is
    informational — see the SPEEDUP_ROWS comment there).
    """
    import functools as _ft

    import jax
    from repro import index
    from repro.core import RoaringBitmap, jax_roaring as jr
    from .synth import gen_run_ranges

    rows = []
    rng = np.random.default_rng(11)
    N, C = 16, 8
    repeats = 3 if quick else 5

    # --- wide union: tree reduction vs pairwise slab_or fold -----------------
    # run-heavy operands — the consumer regime (KV free-pool rebuilds, mask
    # pattern merges) and the fold's worst case: every fold step's output
    # canonicalizes to run rows, so the fold pays the cond-guarded O(2^16)
    # bits->runs extraction N-1 times where the tree pays it once at the
    # root (plus N-1 re-lifts of those runs back to words on the next step).
    slabs = [jr.from_roaring(
        RoaringBitmap.from_ranges(gen_run_ranges(
            0.20, 40.0, 20 + i, int(0.20 * (C << 16)))), C)
        for i in range(N)]
    f_tree = jax.jit(lambda *ss: jr.union_many_slabs(list(ss), capacity=C))

    def fold(op, *ss):
        acc = ss[0]
        for s in ss[1:]:
            acc = op(acc, s, capacity=C)
        return acc

    f_fold = jax.jit(_ft.partial(fold, jr._slab_or))
    assert int(f_tree(*slabs).cardinality) == int(f_fold(*slabs).cardinality)
    us_tree = _t(lambda: f_tree(*slabs), repeats)
    us_fold = _t(lambda: f_fold(*slabs), repeats)
    rows.append((f"wide/union_n{N}/pairwise_fold", round(us_fold, 1), ""))
    rows.append((f"wide/union_n{N}/tree_reduce", round(us_tree, 1),
                 round(us_fold / max(us_tree, 1e-9), 2)))

    # --- wide AND: engine tree vs pairwise slab_and fold ---------------------
    # overlapping operands (each slab keeps ~97% of a shared base set), the
    # realistic wide-AND regime — N conjunctive filters that each pass most
    # rows. With independent random operands the fold degenerates (the first
    # AND empties the intermediate and the remaining N-2 steps are no-ops),
    # which benchmarks nothing.
    from repro import roaring
    base = np.unique(rng.integers(0, C << 16, 60_000))
    and_slabs = []
    for i in range(N):
        keep = rng.random(base.size) > 0.03
        and_slabs.append(jr.from_dense_array(base[keep], C, 1 << 17))
    stack = roaring.stack(and_slabs, capacity=C)
    f_wand = jax.jit(index.wide_intersect)
    f_fand = jax.jit(_ft.partial(fold, jr._slab_and))
    assert int(f_wand(stack).card()) == \
        int(f_fand(*and_slabs).cardinality)
    us_wand = _t(lambda: f_wand(stack), repeats)
    us_fand = _t(lambda: f_fand(*and_slabs), repeats)
    rows.append((f"wide/and_n{N}/pairwise_fold", round(us_fand, 1), ""))
    rows.append((f"wide/and_n{N}/tree_reduce", round(us_wand, 1),
                 round(us_fand / max(us_wand, 1e-9), 2)))

    # --- cardinality-only wide scoring (stacked batched-meta dispatch) -------
    q = and_slabs[0]
    f_score = jax.jit(index.batched_and_card)
    us_score = _t(lambda: f_score(stack, q), repeats)
    rows.append((f"wide/score_n{N}/batched_card", round(us_score, 1),
                 round(us_fand / max(us_score, 1e-9), 2)))
    return rows


def fused_ab(quick: bool = False):
    """A/B: the fused single-launch tree evaluator (PR 7) vs the per-op
    tree-reduce executor, on the same jitted ``index.execute`` queries.

    Wide AND trees use the overlapping-operand regime (each slab keeps ~97%
    of a shared base set — see ``wide_ab``); OR trees run the run-heavy
    consumer regime from ``synth``; the mixed shape is ANDNOT-of-OR over
    sparse operands. The derived column is per_op/fused (within one run on
    one machine); ``benchmarks/compare.py`` gates the floors: 1.5x at
    N >= 16 for the AND/ANDNOT regimes (the fused acceptance bar), 1.0x
    narrow, and no-regression parity (0.9) for the run-heavy union rows,
    where both paths are bound by the same per-leaf lifts and root
    finalize so the ~1.1-1.7x win sits inside timer noise of 1.0.
    """
    import functools as _ft

    import jax
    from repro import index, roaring
    from repro.core import RoaringBitmap, jax_roaring as jr
    from .synth import gen_run_ranges, gen_set

    rows = []
    rng = np.random.default_rng(23)
    C = 8
    repeats = 2 if quick else 4
    sizes = [4, 16] if quick else [4, 16, 64]

    def ab(name, stack, expr, repeats=repeats):
        f_po = jax.jit(_ft.partial(
            lambda s, e: index.execute(s, e), e=expr))
        f_fu = jax.jit(_ft.partial(
            lambda s, e: index.execute(s, e, fused=True), e=expr))
        assert int(f_po(stack).card()) == int(f_fu(stack).card())
        us_po = _t(lambda: f_po(stack), repeats)
        us_fu = _t(lambda: f_fu(stack), repeats)
        rows.append((f"fused/{name}/per_op", round(us_po, 1), ""))
        rows.append((f"fused/{name}/fused_tree", round(us_fu, 1),
                     round(us_po / max(us_fu, 1e-9), 2)))
        f_poc = jax.jit(_ft.partial(
            lambda s, e: index.execute_card(s, e), e=expr))
        f_fuc = jax.jit(_ft.partial(
            lambda s, e: index.execute_card(s, e, fused=True), e=expr))
        us_poc = _t(lambda: f_poc(stack), repeats)
        us_fuc = _t(lambda: f_fuc(stack), repeats)
        rows.append((f"fused/{name}/card_fused", round(us_fuc, 1),
                     round(us_poc / max(us_fuc, 1e-9), 2)))

    # --- AND-heavy: N conjunctive filters over a shared base set -------------
    base = np.unique(rng.integers(0, C << 16, 60_000))
    for N in sizes:
        slabs = [roaring.RoaringSlab.from_values(
            base[rng.random(base.size) > 0.03], C, 1 << 17)
            for _ in range(N)]
        stack = roaring.stack(slabs, capacity=C)
        ab(f"and_n{N}", stack,
           index.and_(*[index.leaf(i) for i in range(N)]))

    # --- OR-heavy: run-heavy operands (the union/consumer regime) ------------
    for N in sizes:
        slabs = [roaring.RoaringSlab.from_roaring(
            RoaringBitmap.from_ranges(gen_run_ranges(
                0.15, 40.0, 30 + i, int(0.15 * (C << 16)))), C)
            for i in range(N)]
        stack = roaring.stack(slabs, capacity=C)
        ab(f"or_runs_n{N}", stack,
           index.or_(*[index.leaf(i) for i in range(N)]))

    # --- mixed ANDNOT over sparse operands -----------------------------------
    # (or of N/2 sparse slabs) \ (or of N/2 sparse slabs): array containers
    # end to end, the regime where per-op compaction overhead dominates
    for N in [16] if quick else [16, 64]:
        slabs = [roaring.RoaringSlab.from_values(
            gen_set(2.0 ** -6, "uniform", seed=50 + i,
                    n=int(2.0 ** -6 * (C << 16))), C, 1 << 17)
            for i in range(N)]
        stack = roaring.stack(slabs, capacity=C)
        half = N // 2
        expr = index.andnot(
            index.or_(*[index.leaf(i) for i in range(half)]),
            index.or_(*[index.leaf(i) for i in range(half, N)]))
        ab(f"andnot_sparse_n{N}", stack, expr)
    return rows


def api_ab(quick: bool = False):
    """A/B: the ``repro.roaring`` object API vs the raw row-state path.

    ``RoaringSlab.__and__`` / ``.and_card`` wrap the exact same engine
    entry points the free functions call, plus the nruns-leaf refresh — the
    object layer must be (essentially) free under jit. The derived column is
    raw/object; ``benchmarks/compare.py`` gates it at >= 0.9x.
    """
    import jax
    from repro import roaring
    from repro.core import jax_roaring as jr

    rows = []
    rng = np.random.default_rng(13)
    C = 32
    repeats = 3 if quick else 5
    # 32 chunks, arrays (~600/chunk) vs mixed arrays/bitmaps (~7.5k/chunk):
    # big enough that both jitted programs run for milliseconds, so the
    # parity ratio measures the programs and not the timer
    va = np.unique(rng.integers(0, C << 16, 20000))
    vb = np.unique(rng.integers(0, C << 16, 250000))
    a_obj = roaring.RoaringSlab.from_values(va, C, 1 << 18)
    b_obj = roaring.RoaringSlab.from_values(vb, C, 1 << 18)
    a_raw = jr.from_dense_array(va, C, 1 << 18)
    b_raw = jr.from_dense_array(vb, C, 1 << 18)

    f_obj = jax.jit(lambda x, y: x.and_(y, capacity=C))
    f_raw = jax.jit(lambda x, y: jr._slab_and(x, y, capacity=C))
    assert int(f_obj(a_obj, b_obj).card()) == \
        int(f_raw(a_raw, b_raw).cardinality)
    f_objc = jax.jit(lambda x, y: x.and_card(y))
    f_rawc = jax.jit(jr._slab_and_card)

    # the two paths compile to the same computation (the object layer is a
    # trace-time veneer), so any measured delta is timer noise — which on a
    # shared CPU runner is easily +-10%. Each trial measures the two paths
    # back to back (alternating order to kill drift/thermal bias) and
    # contributes one raw/object ratio; the derived column is the MEDIAN of
    # the per-trial ratios, so a transient stall in any single measurement
    # cannot fake an overhead or a win.
    us_raw, us_obj, us_rawc, us_objc = [], [], [], []
    card_reps = 10 * repeats                 # fast op: drown the timer
    for trial in range(7):
        pairs = [(us_raw, lambda: f_raw(a_raw, b_raw), repeats),
                 (us_obj, lambda: f_obj(a_obj, b_obj), repeats),
                 (us_rawc, lambda: f_rawc(a_raw, b_raw), card_reps),
                 (us_objc, lambda: f_objc(a_obj, b_obj), card_reps)]
        if trial % 2:                        # kill ordering/thermal bias
            pairs.reverse()
        for acc, fn, reps in pairs:
            acc.append(_t(fn, reps))

    def med_ratio(raw, obj):
        return float(np.median(np.asarray(raw) / np.asarray(obj)))

    rows.append(("api/and/raw_rowstate", round(min(us_raw), 1), ""))
    rows.append(("api/and/object", round(min(us_obj), 1),
                 round(med_ratio(us_raw, us_obj), 2)))
    rows.append(("api/card/raw_rowstate", round(min(us_rawc), 1), ""))
    rows.append(("api/card/object", round(min(us_objc), 1),
                 round(med_ratio(us_rawc, us_objc), 2)))
    return rows


def run(quick: bool = False):
    rows = []
    from repro.core import jax_roaring as jr
    from repro.kernels.roaring import ref as kr_ref

    # batched container op (XLA ref path, jitted)
    rng = np.random.default_rng(0)
    for C in ([8] if quick else [8, 64]):
        a = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
        b = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
        kinds = jnp.asarray([2] * (2 * C), jnp.int32)
        f = jax.jit(lambda a, b: kr_ref.container_op_ref(a, b, kinds, "or"))
        us = _t(lambda: f(a, b))
        # fused op+popcount processes C*8kB with one pass
        rows.append((f"kernels/container_or_popcount/C={C}", round(us, 1),
                     round(C * 8192 / max(us, 1e-9), 1)))  # bytes/us

    # slab set ops end to end
    from repro.core.jax_roaring import from_dense_array, _slab_and as slab_and
    va = np.unique(rng.integers(0, 1 << 19, 30000))
    vb = np.unique(rng.integers(0, 1 << 19, 30000))
    sa = from_dense_array(va, 16, 1 << 15)
    sb = from_dense_array(vb, 16, 1 << 15)
    f = jax.jit(lambda x, y: slab_and(x, y, capacity=16).cardinality)
    us = _t(lambda: f(sa, sb))
    rows.append(("kernels/slab_and_30k", round(us, 1), int(f(sa, sb))))

    # hybrid dispatch vs bitmap-domain A/B
    rows.extend(dispatch_ab(quick=quick))

    # run-container dispatch vs bitmap-domain A/B (2016 follow-up regime)
    rows.extend(run_ab(quick=quick))

    # wide horizontal ops: tree reduction vs sequential pairwise fold
    rows.extend(wide_ab(quick=quick))

    # object-API overhead: repro.roaring vs the raw row-state path
    rows.extend(api_ab(quick=quick))

    # sparse attention ref vs flash ref at 2k
    from repro.models import attention as A
    from repro.configs import get_config
    cfg = get_config("stablelm-1.6b", reduced=True)
    B, S, H, hd = 1, 2048, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    f = jax.jit(lambda q, k, v: A.flash_attn_jnp(q, k, v, cfg, causal=True))
    us = _t(lambda: f(q, k, v))
    flops = 4 * B * H * S * S / 2 * hd
    rows.append(("kernels/flash_attn_2k", round(us, 1),
                 round(flops / max(us, 1e-9) / 1e6, 2)))  # GFLOP/s

    return rows
