"""Benchmark orchestrator. One section per paper table/figure, plus kernel and
roofline sections for the JAX framework layers.

Prints ``name,us_per_call,derived`` CSV rows and writes artifacts/bench.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced repeats")
    ap.add_argument("--sections", default="all",
                    help="comma list: fig2ab,fig2cd,fig2ef,tables,alg4,"
                         "dispatch,compressruns,kernels,fused,jax,robust,"
                         "store")
    args = ap.parse_args()

    from . import paper_figures as pf

    sections = args.sections.split(",") if args.sections != "all" else [
        "fig2ab", "fig2cd", "fig2ef", "tables", "alg4", "dispatch",
        "compressruns", "kernels", "fused", "jax", "robust", "store"]
    rows = []

    def run(name, fn):
        if name in sections:
            print(f"# --- {name} ---", file=sys.stderr, flush=True)
            rows.extend(fn())

    r = 2 if args.quick else 3
    run("fig2ab", lambda: pf.fig2ab_compression(repeats=r))
    run("fig2cd", lambda: pf.fig2cd_ops(repeats=2 if args.quick else 5))
    run("fig2cd", lambda: pf.fig2cd_streaming_crosscheck(repeats=r))
    run("fig2ef", lambda: pf.fig2ef_append_remove(n_updates=100 if args.quick else 200))
    run("tables", lambda: pf.tables_realdata(
        n_bitmaps=30 if args.quick else 60, n_pairs=15 if args.quick else 30))
    run("alg4", lambda: pf.alg4_many_way_union(repeats=r))
    run("dispatch", lambda: pf.dispatch_ab_sweep(repeats=r))
    run("compressruns", lambda: pf.run_compression())

    if "kernels" in sections:
        try:
            from . import kernel_bench
            print("# --- kernels ---", file=sys.stderr, flush=True)
            rows.extend(kernel_bench.run(quick=args.quick))
        except ImportError:
            print("# kernels section unavailable", file=sys.stderr)

    if "fused" in sections:
        try:
            from . import kernel_bench
            print("# --- fused ---", file=sys.stderr, flush=True)
            rows.extend(kernel_bench.fused_ab(quick=args.quick))
        except ImportError:
            print("# fused section unavailable", file=sys.stderr)

    if "jax" in sections:
        try:
            from . import jax_bench
            print("# --- jax ---", file=sys.stderr, flush=True)
            rows.extend(jax_bench.run(quick=args.quick))
        except ImportError:
            print("# jax section unavailable", file=sys.stderr)

    if "robust" in sections:
        try:
            from . import robust_bench
            print("# --- robust ---", file=sys.stderr, flush=True)
            rows.extend(robust_bench.run(quick=args.quick))
        except ImportError:
            print("# robust section unavailable", file=sys.stderr)

    if "store" in sections:
        try:
            from . import store_bench
            print("# --- store ---", file=sys.stderr, flush=True)
            rows.extend(store_bench.run(quick=args.quick))
        except ImportError:
            print("# store section unavailable", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, t, d in rows:
        print(f"{name},{t},{d}")

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench.json", "w") as f:
        json.dump([{"name": n, "us_per_call": t, "derived": d}
                   for n, t, d in rows], f, indent=1)


if __name__ == "__main__":
    main()
