"""Benchmark orchestrator. One section per paper table/figure, plus kernel and
roofline sections for the JAX framework layers.

Prints ``name,us_per_call,derived`` CSV rows and writes artifacts/bench.json.
Every run also writes artifacts/telemetry.json (``repro.obs`` report):
environment metadata (jax/jaxlib version, backend, host), per-section wall
times, and whatever counters/spans the instrumented layers emitted while
telemetry was on.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced repeats")
    ap.add_argument("--sections", default="all",
                    help="comma list: fig2ab,fig2cd,fig2ef,tables,alg4,"
                         "dispatch,compressruns,kernels,fused,jax,robust,"
                         "store,obs")
    args = ap.parse_args()

    from . import paper_figures as pf

    sections = args.sections.split(",") if args.sections != "all" else [
        "fig2ab", "fig2cd", "fig2ef", "tables", "alg4", "dispatch",
        "compressruns", "kernels", "fused", "jax", "robust", "store", "obs"]
    rows = []
    section_s = {}

    # telemetry on for the whole run: counters/spans from the instrumented
    # layers land in artifacts/telemetry.json. The obs overhead A/B opens
    # its own telemetry_scope(on=False) windows, so its disabled-path
    # timings are not contaminated by this.
    try:
        import repro.obs as obs
        obs.enable()
    except ImportError:
        obs = None

    def run(name, fn):
        if name in sections:
            print(f"# --- {name} ---", file=sys.stderr, flush=True)
            t0 = time.perf_counter()
            try:
                rows.extend(fn())
            except ImportError:
                print(f"# {name} section unavailable", file=sys.stderr)
                return
            dt = time.perf_counter() - t0
            section_s[name] = round(section_s.get(name, 0.0) + dt, 3)
            print(f"# --- {name} done in {dt:.1f}s ---", file=sys.stderr,
                  flush=True)

    r = 2 if args.quick else 3
    run("fig2ab", lambda: pf.fig2ab_compression(repeats=r))
    run("fig2cd", lambda: pf.fig2cd_ops(repeats=2 if args.quick else 5))
    run("fig2cd", lambda: pf.fig2cd_streaming_crosscheck(repeats=r))
    run("fig2ef", lambda: pf.fig2ef_append_remove(n_updates=100 if args.quick else 200))
    run("tables", lambda: pf.tables_realdata(
        n_bitmaps=30 if args.quick else 60, n_pairs=15 if args.quick else 30))
    run("alg4", lambda: pf.alg4_many_way_union(repeats=r))
    run("dispatch", lambda: pf.dispatch_ab_sweep(repeats=r))
    run("compressruns", lambda: pf.run_compression())

    def _kernels():
        from . import kernel_bench
        return kernel_bench.run(quick=args.quick)

    def _fused():
        from . import kernel_bench
        return kernel_bench.fused_ab(quick=args.quick)

    def _jax():
        from . import jax_bench
        return jax_bench.run(quick=args.quick)

    def _robust():
        from . import robust_bench
        return robust_bench.run(quick=args.quick)

    def _store():
        from . import store_bench
        return store_bench.run(quick=args.quick)

    def _obs():
        from . import obs_bench
        return obs_bench.run(quick=args.quick)

    run("kernels", _kernels)
    run("fused", _fused)
    run("jax", _jax)
    run("robust", _robust)
    run("store", _store)
    run("obs", _obs)

    print("name,us_per_call,derived")
    for name, t, d in rows:
        print(f"{name},{t},{d}")

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench.json", "w") as f:
        json.dump([{"name": n, "us_per_call": t, "derived": d}
                   for n, t, d in rows], f, indent=1)

    if obs is not None:
        from repro.obs import report as _report
        _report.write_report("artifacts/telemetry.json",
                             extra={"sections": section_s})
        obs.disable()
        print("# wrote artifacts/telemetry.json "
              f"(sections: {section_s})", file=sys.stderr)


if __name__ == "__main__":
    main()
