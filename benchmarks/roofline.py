"""Roofline report: artifacts/dryrun/*.json -> markdown tables + hillclimb
cell selection.

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str = "pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            recs.append(r)
    return recs


def fmt_s(x):
    if x >= 0.1:
        return f"{x:.3f}"
    if x >= 1e-4:
        return f"{x*1e3:.2f}m"
    return f"{x*1e6:.1f}u"


def table(recs):
    hdr = ("| cell | compute | memory | collective | dominant | useful "
           "(6ND/analytic) | HLO flops raw | HBM GB/dev | temp GB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        rf = r["roofline"]
        mem_gb = r["analytic"]["hbm_bytes"] / r["chips"] / 1e9
        temp_gb = r["memory_analysis"]["temp_size_in_bytes"] / 1e9
        lines.append(
            f"| {r['arch']}/{r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | {rf['useful_ratio']:.2f} | "
            f"{r['cost_analysis']['flops']:.2e} | {mem_gb:.2f} | "
            f"{temp_gb:.2f} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """Worst roofline fraction; most collective-bound; most paper-
    representative (long-context decode = roaring paged/sequence machinery)."""
    frac = [(r["roofline"]["roofline_fraction"], r["cell"]) for r in recs]
    coll = [(r["roofline"]["collective_s"]
             / max(sum([r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                        r["roofline"]["collective_s"]]), 1e-30), r["cell"])
            for r in recs]
    worst = min(frac)[1]
    most_coll = max(coll)[1]
    paper = [r["cell"] for r in recs
             if r["shape"] == "long_500k" and "qwen2" in r["arch"]]
    return worst, most_coll, (paper[0] if paper else recs[-1]["cell"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"## Roofline ({args.mesh}, {len(recs)} cells)\n")
    print(table(recs))
    w, c, p = pick_hillclimb(recs)
    print(f"\nhillclimb candidates: worst-fraction={w}  "
          f"most-collective={c}  paper-representative={p}")


if __name__ == "__main__":
    main()
