"""Roofline report: artifacts/dryrun/*.json -> markdown tables + hillclimb
cell selection, plus an analytic fused-vs-per-op launch/traffic model for
the PR 7 mega-kernel (``--fused`` section, no dryrun artifacts needed).

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str = "pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            recs.append(r)
    return recs


def fmt_s(x):
    if x >= 0.1:
        return f"{x:.3f}"
    if x >= 1e-4:
        return f"{x*1e3:.2f}m"
    return f"{x*1e6:.1f}u"


def table(recs):
    hdr = ("| cell | compute | memory | collective | dominant | useful "
           "(6ND/analytic) | HLO flops raw | HBM GB/dev | temp GB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        rf = r["roofline"]
        mem_gb = r["analytic"]["hbm_bytes"] / r["chips"] / 1e9
        temp_gb = r["memory_analysis"]["temp_size_in_bytes"] / 1e9
        lines.append(
            f"| {r['arch']}/{r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | {rf['useful_ratio']:.2f} | "
            f"{r['cost_analysis']['flops']:.2e} | {mem_gb:.2f} | "
            f"{temp_gb:.2f} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """Worst roofline fraction; most collective-bound; most paper-
    representative (long-context decode = roaring paged/sequence machinery)."""
    frac = [(r["roofline"]["roofline_fraction"], r["cell"]) for r in recs]
    coll = [(r["roofline"]["collective_s"]
             / max(sum([r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                        r["roofline"]["collective_s"]]), 1e-30), r["cell"])
            for r in recs]
    worst = min(frac)[1]
    most_coll = max(coll)[1]
    paper = [r["cell"] for r in recs
             if r["shape"] == "long_500k" and "qwen2" in r["arch"]]
    return worst, most_coll, (paper[0] if paper else recs[-1]["cell"])


def fused_model(n_containers: int = 64):
    """Analytic launch-count / HBM-traffic table for the fused tree
    evaluator vs the per-op pipeline, straight from ``fused.plan_stats``
    (the same model the scheduler uses). Per-op re-materialises every
    intermediate through HBM (2 reads + 1 write of an 8 kB row per
    combine); fused streams each operand row once and keeps intermediates
    in VMEM scratch, so its traffic is load-bound, not op-bound."""
    import sys
    sys.path.insert(0, "src")
    from repro.kernels.roaring import fused

    lines = ["| tree | N | launches per-op | launches fused | "
             "HBM MB per-op | HBM MB fused | traffic ratio |",
             "|" + "---|" * 7]
    for N in (4, 16, 64):
        plan = fused.plan_tape(("and",) + tuple(range(N)))
        st = fused.plan_stats(plan, n_containers)
        ratio = st["hbm_bytes_per_op"] / max(st["hbm_bytes_fused"], 1)
        lines.append(
            f"| and_n{N} | {N} | {st['launches_per_op']} | "
            f"{st['launches_fused']} | "
            f"{st['hbm_bytes_per_op'] / 1e6:.2f} | "
            f"{st['hbm_bytes_fused'] / 1e6:.2f} | {ratio:.2f}x |")
    return "\n".join(lines)


def measured_table(ns=(4, 16)):
    """Measured-vs-model launch counts: run each AND tree through the eager
    engine with telemetry on (``repro.obs.launch_crosscheck``) and put the
    measured kernel-launch counters next to the analytic model's. Small
    capacity — this exists to audit the *accounting*, not to time anything."""
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import repro.index as index
    import repro.obs as obs
    from repro import roaring

    C = 2
    rng = np.random.default_rng(7)
    slabs = [roaring.RoaringSlab.from_values(
        np.unique(rng.integers(0, C << 16, 3000)), C, 1 << 14)
        for _ in range(max(ns))]
    stack = roaring.stack(slabs, capacity=C)
    lines = ["| tree | fused measured | fused model | per-op measured | "
             "per-op model (dispatches) | per-op combines | match |",
             "|" + "---|" * 7]
    for N in ns:
        expr = index.and_(*[index.leaf(i) for i in range(N)])
        r = obs.launch_crosscheck(stack, expr)
        lines.append(
            f"| and_n{N} | {r['fused_measured']} | {r['fused_model']} | "
            f"{r['per_op_measured']} | {r['per_op_model']} | "
            f"{r['per_op_combines']} | {'yes' if r['match'] else 'NO'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--containers", type=int, default=64,
                    help="container columns for the fused traffic model")
    ap.add_argument("--measured", action="store_true",
                    help="also run the fused trees eagerly with telemetry "
                         "on and print measured vs modeled launch counts")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"## Roofline ({args.mesh}, {len(recs)} cells)\n")
    if recs:
        print(table(recs))
        w, c, p = pick_hillclimb(recs)
        print(f"\nhillclimb candidates: worst-fraction={w}  "
              f"most-collective={c}  paper-representative={p}")
    else:
        print("(no dryrun artifacts)")
    print(f"\n## Fused tree evaluator: modeled launches / HBM traffic "
          f"(C={args.containers})\n")
    print(fused_model(args.containers))
    if args.measured:
        print("\n## Measured vs modeled kernel launches (telemetry "
              "counters, eager engine)\n")
        print(measured_table())


if __name__ == "__main__":
    main()
