"""Robustness-tax benchmark: the hardened untrusted-input decode path vs the
trusted fast path.

Two A/B pairs over the same mixed 24-container stream:

* ``robust/deserialize/*`` — the data-plane deserialize the serving system
  actually runs on untrusted bytes: ``RoaringSlab.deserialize`` (hardened
  codec + slab build) vs ``_deserialize_trusted`` + ``from_roaring``. The
  derived column of ``robust/deserialize/validated`` is
  ``trusted_us / validated_us``, gated in CI at >= 0.77 (full structural
  validation may cost at most ~1.3x the trusted ingest).
* ``robust/codec/*`` — the host codec alone, recorded for transparency but
  not gated: the trusted decode is essentially one memcpy pass per payload,
  while validation necessarily adds a second full pass (bitmap popcount,
  array sortedness) plus reduce, so the codec-only ratio sits near ~0.5 at
  these container sizes no matter how the checks are batched. The absolute
  cost is a few microseconds per container — invisible once the payload
  reaches the slab/device path measured above.
"""

from __future__ import annotations

import gc
import time

import numpy as np


def _t(fn, repeats: int) -> float:
    """Best-of-N wall time: the minimum is the least contention-biased
    estimator for a deterministic CPU-bound function on a shared runner.
    GC is disabled during timing (as ``timeit`` does): collection cost
    scales with the whole process's live-object count, so in a long-lived
    bench process it taxes whichever side allocates more temporaries by an
    amount unrelated to the code under test."""
    fn()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6
    finally:
        if was_enabled:
            gc.enable()


def _workload_stream():
    """A realistic mixed stream: arrays, bitmaps, and runs across 24 chunks
    (large enough that decode cost dominates call overhead)."""
    from repro.core import py_roaring as pr
    from repro.roaring.format import RoaringFormatSpec

    rng = np.random.default_rng(42)
    vals = []
    for hi in range(24):
        mode = hi % 3
        if mode == 0:                      # array
            vals += [(hi << 16) + int(v)
                     for v in rng.choice(65536, 1500, replace=False)]
        elif mode == 1:                    # bitmap
            vals += sorted(set(
                (hi << 16 | rng.integers(0, 65536, 9000)).tolist()))
        else:                              # runs
            start = int(rng.integers(0, 30000))
            vals += [(hi << 16) + v for v in range(start, start + 8000)]
    rb = pr.RoaringBitmap.from_array(
        np.asarray(sorted(set(vals)), np.uint64)).run_optimize()
    return RoaringFormatSpec.serialize(rb)


def run(quick: bool = False):
    from repro.roaring import RoaringSlab
    from repro.roaring.format import RoaringFormatSpec as FS

    data = _workload_stream()
    cap = len(FS._deserialize_trusted(data).keys)
    repeats = 5 if quick else 12

    def ingest_trusted():
        return RoaringSlab.from_roaring(FS._deserialize_trusted(data),
                                        capacity=cap)

    def ingest_validated():
        return RoaringSlab.deserialize(data, capacity=cap)

    # each trial measures the A and B sides back to back (alternating order
    # to kill drift/allocator bias across a long-lived bench process) and
    # contributes one trusted/validated ratio; the derived column is the
    # MEDIAN of the per-trial ratios, so a transient stall in any single
    # measurement cannot fake (or hide) a robustness tax
    us_ing_t, us_ing_v, us_codec_t, us_codec_v = [], [], [], []
    codec_reps = repeats * 6                 # fast op: drown the timer
    for trial in range(7):
        pairs = [(us_ing_t, ingest_trusted, repeats),
                 (us_ing_v, ingest_validated, repeats),
                 (us_codec_t, lambda: FS._deserialize_trusted(data),
                  codec_reps),
                 (us_codec_v, lambda: FS.deserialize(data), codec_reps)]
        if trial % 2:
            pairs.reverse()
        for acc, fn, reps in pairs:
            acc.append(_t(fn, reps))

    def med_ratio(a, b):
        return float(np.median(np.asarray(a) / np.asarray(b)))

    return [
        ("robust/deserialize/trusted", round(min(us_ing_t), 1), ""),
        ("robust/deserialize/validated", round(min(us_ing_v), 1),
         round(med_ratio(us_ing_t, us_ing_v), 3)),
        ("robust/codec/trusted", round(min(us_codec_t), 1), ""),
        ("robust/codec/validated", round(min(us_codec_v), 1),
         round(med_ratio(us_codec_t, us_codec_v), 3)),
    ]


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
