"""Buffer hunt: compile one cell and rank the largest HLO tensors.

The dry-run profiling loop's microscope — finds which intermediate is
responsible for a temp-memory blowup and which computation (entry / layer
scan / inner scan) it lives in.

    PYTHONPATH=src python -m benchmarks.buffer_hunt --arch jamba-1.5-large-398b \
        --shape train_4k [--multi-pod] [--top 20]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=16)
    ap.add_argument("--min-mb", type=float, default=64.0)
    args = ap.parse_args()

    from repro.distributed.context import data_axes
    from repro.launch.hlo_analysis import (parse_computations,
                                           while_body_depths, _SHAPE_RE,
                                           _DTYPE_BYTES)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    daxes = [a for a in ("pod", "data") if a in mesh.shape]
    dcount = int(np.prod([mesh.shape[a] for a in daxes]))
    fn, sds, sh, donate, meta = build_cell(args.arch, args.shape, mesh)
    with mesh, data_axes(daxes, dcount):
        compiled = jax.jit(fn, in_shardings=sh,
                           donate_argnums=donate).lower(*sds).compile()
    m = compiled.memory_analysis()
    print(f"arg={m.argument_size_in_bytes/1e9:.2f}GB "
          f"out={m.output_size_in_bytes/1e9:.2f}GB "
          f"temp={m.temp_size_in_bytes/1e9:.2f}GB\n")
    hlo = compiled.as_text()
    comps = parse_computations(hlo)
    depths = while_body_depths(comps)
    seen = defaultdict(lambda: [0, 0, "", ""])
    for cname, lines in comps.items():
        for ln in lines:
            if "=" not in ln:
                continue
            lhs = ln.split("=", 1)[1]
            head = lhs.strip().split("(")[0]
            b = 0
            for dt, dims in _SHAPE_RE.findall(head):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in (dims.split(",") if dims else []):
                    n *= int(d)
                b += n * _DTYPE_BYTES[dt]
            if b < args.min_mb * 1e6:
                continue
            shape_key = head.strip()[:70]
            op = re.search(r"\)?\s*([a-z\-]+)\(", lhs)
            seen[shape_key][0] = b
            seen[shape_key][1] += 1
            seen[shape_key][2] = f"d{depths.get(cname, 0)}"
            seen[shape_key][3] = (op.group(1) if op else "?")
    rows = sorted(seen.items(), key=lambda kv: -kv[1][0])[: args.top]
    for shape_key, (b, cnt, depth, op) in rows:
        print(f"{b/1e9:8.2f}GB x{cnt:4d} {depth:3s} {op:18s} {shape_key}")


if __name__ == "__main__":
    main()
