"""Perf regression gate: diff a fresh bench run against a checked-in baseline.

Usage:
    PYTHONPATH=src python -m benchmarks.run --quick --sections dispatch,kernels
    python -m benchmarks.compare [--baseline benchmarks/baselines/seed_bench.json]
                                 [--fresh artifacts/bench.json]
                                 [--threshold 1.5]

Rows are matched by ``name``; a row is a regression when its us_per_call
exceeds ``threshold`` x the baseline. Rows present on only one side are
reported but never fail the gate (benchmarks grow over time). Exit code 1 on
any regression, so CI / future perf PRs get a hard signal.

Wall-clock numbers on shared CPU runners are noisy — the default threshold is
deliberately loose (1.5x); it is a tripwire for order-of-magnitude mistakes
(e.g. re-introducing the bitmap-domain tax), not a microbenchmark court.

Cross-machine comparison of absolute microseconds is meaningless, so CI uses
``--speedup-mode`` instead: it checks the *within-run* hybrid-vs-bitmap-domain
speedup columns (derived), which only depend on the ratio measured on a single
machine.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def load_derived(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for r in rows:
        try:
            out[r["name"]] = float(r["derived"])
        except (TypeError, ValueError):
            pass
    return out


# within-run speedup rows that must hold on any machine (sparse/mixed A/B,
# the 2016-paper run-container regime, and the wide-op rows: the union tree
# reduction and the card-only wide scoring, each vs the sequential pairwise
# fold). dense is excluded by construction — the two paths converge there —
# and wide/and_n16/tree_reduce is informational only: an AND tree runs the
# same N-1 combines as the fold (its win is one deferred canonicalization
# and log depth for parallel hardware, not less CPU work), so its CPU ratio
# hovers near 1x by design.
SPEEDUP_ROWS = (
    "kernels/dispatch_ab/sparse/hybrid_dispatch",
    "kernels/dispatch_ab/mixed/hybrid_dispatch",
    "dispatch_ab/d=2^-8/hybrid_dispatch",
    "dispatch_ab/d=2^-4/hybrid_dispatch",
    "run/run_run/hybrid_dispatch",
    "run/run_bitmap/hybrid_dispatch",
    "wide/union_n16/tree_reduce",
    "wide/score_n16/batched_card",
)

# api/* rows gate the repro.roaring object layer against the raw row-state
# path at near-parity (the object wrapper must be free under jit); the
# derived column is raw/object, so 1.0 means identical and the floor is a
# small-overhead allowance, not a speedup requirement.
API_ROWS = (
    "api/and/object",
    "api/card/object",
)
API_FLOOR = 0.9

# fused/* rows gate the single-launch fused tree evaluator against the
# per-op tree-reduce executor on the same jitted engine queries; the
# derived column is per_op/fused. The intersection and sparse-ANDNOT
# regimes are where per-op pays N-1 launches and HBM round-trips that
# the fused kernel folds into one pass: narrow trees must not regress
# (floor 1.0), and the wide rows (N >= 16) carry the PR 7 acceptance
# bar of >= 1.5x (measured 4-90x locally). The run-heavy union regime
# (or_runs_*) is different: both paths are dominated by the same
# per-leaf coverage lifts and (for materialize) the same dense root
# finalize, so its structural win is ~1.1-1.7x with run-to-run noise
# crossing 1.0 on the card rows — those rows get a no-regression
# parity floor (0.9, the API_ROWS treatment), not a speedup claim.
FUSED_ROWS = (
    "fused/and_n4/fused_tree",
    "fused/and_n4/card_fused",
)
FUSED_FLOOR = 1.0
FUSED_WIDE_ROWS = (
    "fused/and_n16/fused_tree",
    "fused/and_n64/fused_tree",
    "fused/andnot_sparse_n16/fused_tree",
    "fused/andnot_sparse_n64/fused_tree",
    "fused/and_n16/card_fused",
    "fused/and_n64/card_fused",
    "fused/andnot_sparse_n16/card_fused",
    "fused/andnot_sparse_n64/card_fused",
)
FUSED_WIDE_FLOOR = 1.5
FUSED_PARITY_ROWS = (
    "fused/or_runs_n4/fused_tree",
    "fused/or_runs_n4/card_fused",
    "fused/or_runs_n16/fused_tree",
    "fused/or_runs_n16/card_fused",
    "fused/or_runs_n64/fused_tree",
    "fused/or_runs_n64/card_fused",
)
FUSED_PARITY_FLOOR = 0.9

# robust/* rows gate the hardened untrusted-input deserialize (full
# structural validation + slab build) against the trusted fast path; the
# derived column is trusted/validated, so the 0.77 floor caps the
# robustness tax at ~1.3x on the data-plane ingest the serving system
# actually runs. robust/codec/validated (host codec alone, ratio ~0.5:
# validation is a second memory pass over what is otherwise one memcpy) is
# recorded in bench.json for transparency but deliberately not gated.
ROBUST_ROWS = (
    "robust/deserialize/validated",
)
ROBUST_FLOOR = 0.77

# store/* rows gate the PR 8 columnar bitmap-index store on census-like
# data — the paper's Table 3 / Figure 2 scenario end-to-end. The size
# rows' derived column is baseline_bytes / roaring_bytes and is
# DETERMINISTIC (seeded data, no timing), so the floors sit close to
# the measured ratios: Roaring beats WAH ~1.3x on shuffled rows and
# ~2.2x / ~1.7x (WAH / Concise) once rows are sorted and runs form —
# the paper-order ordering WAH < Concise < Roaring. Shuffled-vs-Concise
# is ~1.07x (both are array-like on high-entropy postings) and is
# recorded but not gated.
STORE_SIZE_ROWS = (
    "store/size/census/wah",
)
STORE_SIZE_FLOOR = 1.1
STORE_SIZE_SORTED_ROWS = (
    "store/size/census_sorted/wah",
)
STORE_SIZE_SORTED_FLOOR = 1.5
STORE_SIZE_SORTED_CONCISE_ROWS = (
    "store/size/census_sorted/concise",
)
STORE_SIZE_SORTED_CONCISE_FLOOR = 1.2
# query latency rows are wall-clock: loose tripwires only. fused's win
# grows with tree size — the 15-node BSI range tree is ~45x over per-op
# (and 3x over the WAH postings eval, the vs_wah derived column); the
# 8-leaf OR is ~2.8x. and2 (1 combine) and the trivial and2/or8 vs_wah
# ratios are dominated by the fixed jax dispatch floor on CPU and are
# recorded ungated.
STORE_QUERY_ROWS = (
    "store/query/range_and/fused",
)
STORE_QUERY_FLOOR = 5.0
STORE_QUERY_OR_ROWS = (
    "store/query/or8/fused",
)
STORE_QUERY_OR_FLOOR = 1.2
STORE_QUERY_WAH_ROWS = (
    "store/query/range_and/vs_wah",
)
STORE_QUERY_WAH_FLOOR = 1.2

# obs/* rows gate the PR 9 telemetry plane. The disabled row's derived
# column is raw/instrumented on the same warm jitted fused store query
# (median of alternating trials): telemetry off must stay within 5% of
# the pre-telemetry body, so the floor is 0.95 parity, not a speedup.
# obs/query/enabled is recorded ungated — tracing is allowed to cost.
OBS_ROWS = (
    "obs/query/disabled",
)
OBS_FLOOR = 0.95
# derived is exactly 1.0 when measured launch counters == the analytic
# model on every checked tree, 0.0 otherwise — a hard accounting gate.
OBS_CROSSCHECK_ROWS = (
    "obs/crosscheck/fused_launches",
)
OBS_CROSSCHECK_FLOOR = 1.0


def check_speedups(fresh_path: str, floor: float,
                   api_floor: float = API_FLOOR) -> int:
    """Machine-independent gate: each A/B row's derived column is the
    hybrid-vs-bitmap-domain speedup (or object-vs-raw / trusted-vs-
    validated ratio) measured *within one run on one machine*, so it is
    meaningful on any runner class."""
    derived = load_derived(fresh_path)
    bad, seen = [], 0
    for rows, row_floor in ((SPEEDUP_ROWS, floor), (API_ROWS, api_floor),
                            (ROBUST_ROWS, ROBUST_FLOOR),
                            (FUSED_ROWS, FUSED_FLOOR),
                            (FUSED_WIDE_ROWS, FUSED_WIDE_FLOOR),
                            (FUSED_PARITY_ROWS, FUSED_PARITY_FLOOR),
                            (STORE_SIZE_ROWS, STORE_SIZE_FLOOR),
                            (STORE_SIZE_SORTED_ROWS, STORE_SIZE_SORTED_FLOOR),
                            (STORE_SIZE_SORTED_CONCISE_ROWS,
                             STORE_SIZE_SORTED_CONCISE_FLOOR),
                            (STORE_QUERY_ROWS, STORE_QUERY_FLOOR),
                            (STORE_QUERY_OR_ROWS, STORE_QUERY_OR_FLOOR),
                            (STORE_QUERY_WAH_ROWS, STORE_QUERY_WAH_FLOOR),
                            (OBS_ROWS, OBS_FLOOR),
                            (OBS_CROSSCHECK_ROWS, OBS_CROSSCHECK_FLOOR)):
        for name in rows:
            if name not in derived:
                continue
            seen += 1
            ok = derived[name] >= row_floor
            print(f"{name:55s} speedup {derived[name]:6.2f}x "
                  f"(floor {row_floor:.1f}x) "
                  f"{'ok' if ok else '<-- BELOW FLOOR'}")
            if not ok:
                bad.append(name)
    if seen == 0:
        print("FAIL: no dispatch A/B rows in fresh run (wrong --sections?)",
              file=sys.stderr)
        return 1
    if bad:
        print(f"\nFAIL: {len(bad)} ratio(s) below floor", file=sys.stderr)
        return 1
    print(f"\nOK: {seen} within-run ratios at or above their floors")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baselines/seed_bench.json")
    ap.add_argument("--fresh", default="artifacts/bench.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when fresh > threshold * baseline")
    ap.add_argument("--speedup-mode", action="store_true",
                    help="machine-independent gate on the within-run "
                         "hybrid-vs-bitmap speedup columns (for CI, where "
                         "absolute wall-clock vs a dev-machine baseline is "
                         "meaningless)")
    ap.add_argument("--min-speedup", type=float, default=2.0)
    ap.add_argument("--min-api-ratio", type=float, default=API_FLOOR,
                    help="floor for the api/* object-vs-raw parity rows")
    args = ap.parse_args()

    if args.speedup_mode:
        return check_speedups(args.fresh, args.min_speedup,
                              args.min_api_ratio)

    base = load(args.baseline)
    fresh = load(args.fresh)
    common = sorted(set(base) & set(fresh))
    only_base = sorted(set(base) - set(fresh))
    only_fresh = sorted(set(fresh) - set(base))

    regressions = []
    print(f"{'name':60s} {'base_us':>12s} {'fresh_us':>12s} {'ratio':>7s}")
    for name in common:
        b, f = base[name], fresh[name]
        ratio = f / b if b > 0 else float("inf")
        flag = " <-- REGRESSION" if ratio > args.threshold else ""
        print(f"{name:60s} {b:12.1f} {f:12.1f} {ratio:7.2f}{flag}")
        if ratio > args.threshold:
            regressions.append((name, ratio))

    if only_base:
        print(f"\n# {len(only_base)} baseline-only rows (not run): "
              + ", ".join(only_base[:5]) + ("..." if len(only_base) > 5 else ""))
    if only_fresh:
        print(f"# {len(only_fresh)} new rows (no baseline): "
              + ", ".join(only_fresh[:5]) + ("..." if len(only_fresh) > 5 else ""))

    if regressions:
        print(f"\nFAIL: {len(regressions)} regression(s) over "
              f"{args.threshold:.2f}x:", file=sys.stderr)
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x", file=sys.stderr)
        return 1
    print(f"\nOK: {len(common)} rows within {args.threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
