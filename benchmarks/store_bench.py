"""Store benchmarks: the paper's bitmap-index database scenario end to end.

Two sections over census-like records (``synth.gen_census_like`` — the same
generator the differential suite uses):

* ``store/size/*`` — serialized index size for the SAME per-(column, value)
  postings under Roaring (what ``BitmapStore`` holds), WAH, and Concise.
  The wah/concise rows' derived column is ``baseline_bytes / roaring_bytes``
  — deterministic and machine-independent, gated in CI at paper order
  (Roaring strictly smaller). A sorted-rows variant (the arXiv:0901.3751
  reordering axis, where RLE formats close the gap) is recorded ungated
  for honesty, and the bit-sliced encoding of the integer column is
  compared against its one-slab-per-value encoding.
* ``store/query/*`` — predicate latency through the store: the compiled
  expression executed as one jitted whole-call (per-op and fused), vs the
  same queries evaluated over host WAH/Concise postings. ``vs_wah`` rows'
  derived column is ``wah_us / store_us``; the ``fused`` row's is
  ``per_op_us / fused_us`` (all within-run ratios).
"""

from __future__ import annotations

import gc
import time

import numpy as np

from .synth import gen_census_like

QUERY_COLS = ("cat0", "cat1", "cat2", "cat3", "int0")


def _t(fn, repeats: int) -> float:
    """Best-of-N wall time in us; device results are blocked on."""
    import jax

    jax.block_until_ready(fn())
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best * 1e6
    finally:
        if was_enabled:
            gc.enable()


def _eq_postings(records: dict, cols=QUERY_COLS) -> list:
    """The per-(column, value) posting lists a classic bitmap index holds."""
    out = []
    for name in cols:
        arr = np.asarray(records[name])
        for v in np.unique(arr):
            out.append(np.nonzero(arr == v)[0].astype(np.int64))
    return out


def _size_rows(n_rows: int) -> list:
    from repro import store
    from repro.baselines import ConciseBitmap, WahBitmap

    rows = []
    for variant, sort_rows in (("census", False), ("census_sorted", True)):
        records = gen_census_like(n_rows, seed=3, sort_rows=sort_rows)
        eq_records = {k: records[k] for k in QUERY_COLS}
        t0 = time.perf_counter()
        s = store.BitmapStore.build(eq_records)
        build_us = (time.perf_counter() - t0) * 1e6
        roar = s.index_size_in_bytes()
        postings = _eq_postings(records)
        wah = sum(WahBitmap.from_sorted_unique(p).size_in_bytes()
                  for p in postings)
        con = sum(ConciseBitmap.from_sorted_unique(p).size_in_bytes()
                  for p in postings)
        rows += [
            (f"store/size/{variant}/roaring", round(build_us, 1), roar),
            (f"store/size/{variant}/wah", round(build_us, 1),
             round(wah / roar, 3)),
            (f"store/size/{variant}/concise", round(build_us, 1),
             round(con / roar, 3)),
        ]
        if not sort_rows:
            # the O'Neil/Quass encoding win: bits slabs instead of one slab
            # per distinct value for the same integer column
            eq_bytes = store.BitmapStore.build(
                {"int0": records["int0"]}).index_size_in_bytes()
            bsi_bytes = store.BitmapStore.build(
                {"int0": records["int0"]},
                bsi=("int0",)).index_size_in_bytes()
            rows.append(("store/size/census/bsi_int0", 0.0,
                         round(eq_bytes / bsi_bytes, 3)))
    return rows


def _wah_eval(postings: dict, tree) -> object:
    """Evaluate a (op, args...) tuple-tree over host baseline bitmaps."""
    op = tree[0]
    if op == "leaf":
        return postings[tree[1]]
    kids = [_wah_eval(postings, t) for t in tree[1:]]
    acc = kids[0]
    for k in kids[1:]:
        acc = acc.and_(k) if op == "and" else acc.or_(k)
    return acc


def _query_rows(n_rows: int, repeats: int) -> list:
    import jax

    from repro import index as ix
    from repro import store
    from repro.baselines import WahBitmap

    records = gen_census_like(n_rows, seed=3)
    s = store.BitmapStore.build(
        {k: records[k] for k in QUERY_COLS}, bsi=("int0",))

    # host per-(column, value) WAH postings for the same records
    wah: dict = {}
    for name in ("cat0", "cat1", "cat2", "int0"):
        arr = np.asarray(records[name])
        for v in np.unique(arr):
            wah[(name, int(v))] = WahBitmap.from_sorted_unique(
                np.nonzero(arr == v)[0].astype(np.int64))

    int0_vals = sorted(set(np.asarray(records["int0"]).tolist()))
    queries = {
        # 2-way AND: the cheapest query, baseline-friendliest regime
        "and2": (
            store.and_(store.eq("cat0", 1), store.eq("cat1", 2)),
            ("and", ("leaf", ("cat0", 1)), ("leaf", ("cat1", 2)))),
        # 8-way OR: the wide-union regime
        "or8": (
            store.in_("cat2", list(range(8))),
            ("or", *(("leaf", ("cat2", v)) for v in range(8)))),
        # BSI range AND posting: the slice-comparison tree vs the OR-chain
        # a value-per-bitmap index must run for the same range
        "range_and": (
            store.and_(store.range_("int0", 25, 60), store.eq("cat0", 1)),
            ("and", ("or", *(("leaf", ("int0", v)) for v in int0_vals
                             if 25 <= v <= 60)),
             ("leaf", ("cat0", 1)))),
    }

    rows = []
    for qname, (pred, wah_tree) in queries.items():
        expr = s.compile(pred)
        stack = s._stack
        f_perop = jax.jit(lambda st, e=expr: ix.execute(st, e))
        f_fused = jax.jit(lambda st, e=expr: ix.execute(st, e, fused=True))
        us_perop = _t(lambda: f_perop(stack), repeats)
        us_fused = _t(lambda: f_fused(stack), repeats)
        us_wah = _t(lambda: _wah_eval(wah, wah_tree), repeats)
        rows += [
            (f"store/query/{qname}/per_op", round(us_perop, 1), ""),
            (f"store/query/{qname}/fused", round(us_fused, 1),
             round(us_perop / us_fused, 3)),
            (f"store/query/{qname}/vs_wah", round(us_wah, 1),
             round(us_wah / min(us_perop, us_fused), 3)),
        ]
    return rows


def run(quick: bool = False) -> list:
    n_rows = 20_000 if quick else 50_000
    repeats = 5 if quick else 12
    return _size_rows(n_rows) + _query_rows(n_rows, repeats)


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
