"""Benchmarks reproducing the paper's Figure 2 and Tables I-II.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``:
  * fig2ab: compression (derived = bits/int; us_per_call = build time)
  * fig2cd: AND/OR times (derived = speedup of roaring vs scheme)
  * fig2ef: append/remove times
  * tables: real-data surrogates (derived = expansion factor vs roaring)

Methodology notes:
  * WAH/Concise use the vectorized "expanded" op engine, which is *favorable*
    to them on numpy (Roaring's measured advantage is therefore conservative);
    a faithful streaming run is reported for one density as a cross-check.
  * All timings are averages over `repeats` runs after one warmup.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.baselines import BitSet, ConciseBitmap, WahBitmap
from repro.core import RoaringBitmap

from .synth import (REAL_SPECS, densities, gen_real_surrogate, gen_run_set,
                    gen_set)

SCHEMES = {
    "roaring": RoaringBitmap.from_sorted_unique,
    "concise": ConciseBitmap.from_sorted_unique,
    "wah": WahBitmap.from_sorted_unique,
    "bitset": BitSet.from_sorted_unique,
}


def _time_us(fn: Callable, repeats: int) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def fig2ab_compression(repeats: int = 3, dists=("uniform", "beta")) -> list:
    rows = []
    for dist in dists:
        for d in densities():
            vals = gen_set(d, dist, seed=int(1 / d))
            for name, ctor in SCHEMES.items():
                t = _time_us(lambda: ctor(vals), repeats)
                obj = ctor(vals)
                bits = obj.size_in_bytes() * 8 / vals.size
                rows.append((f"fig2ab/{dist}/d=2^{int(np.log2(d))}/{name}",
                             round(t, 1), round(bits, 2)))
    return rows


def fig2cd_ops(repeats: int = 5, dists=("uniform",)) -> list:
    rows = []
    for dist in dists:
        for d in densities():
            va = gen_set(d, dist, seed=11)
            vb = gen_set(d, dist, seed=22)
            objs = {n: c(va) for n, c in SCHEMES.items()}
            objs_b = {n: c(vb) for n, c in SCHEMES.items()}
            times = {}
            for op in ("and", "or"):
                for name in SCHEMES:
                    a, b = objs[name], objs_b[name]
                    if name == "roaring":
                        fn = (lambda: a & b) if op == "and" else (lambda: a | b)
                    else:
                        fn = (lambda: a.and_(b)) if op == "and" else (lambda: a.or_(b))
                    times[(op, name)] = _time_us(fn, repeats)
                for name in SCHEMES:
                    speedup = times[(op, name)] / times[(op, "roaring")]
                    rows.append((f"fig2cd/{dist}/d=2^{int(np.log2(d))}/{op}/{name}",
                                 round(times[(op, name)], 1), round(speedup, 2)))
    return rows


def fig2cd_streaming_crosscheck(repeats: int = 3) -> list:
    """Faithful word-at-a-time WAH ops at one density, for methodology."""
    rows = []
    d = 2.0 ** -6
    va, vb = gen_set(d, "uniform", 11), gen_set(d, "uniform", 22)
    wa, wb = WahBitmap.from_sorted_unique(va), WahBitmap.from_sorted_unique(vb)
    ra, rb = RoaringBitmap.from_sorted_unique(va), RoaringBitmap.from_sorted_unique(vb)
    t_stream = _time_us(lambda: wa.and_streaming(wb), repeats)
    t_roar = _time_us(lambda: ra & rb, repeats)
    _, touched = wa.and_streaming(wb)
    rows.append(("fig2cd/streaming/wah-and", round(t_stream, 1), touched))
    rows.append(("fig2cd/streaming/roaring-and", round(t_roar, 1),
                 round(t_stream / t_roar, 2)))
    return rows


def fig2ef_append_remove(n_updates: int = 200) -> list:
    rows = []
    d = 2.0 ** -7
    vals = gen_set(d, "uniform", 7)
    for name, ctor in SCHEMES.items():
        obj = ctor(vals)
        x = int(vals[-1])
        t0 = time.perf_counter()
        for i in range(n_updates):
            x += 37 + (i % 61)
            obj.append(x) if hasattr(obj, "append") else obj.add(x)
        t_app = (time.perf_counter() - t0) / n_updates * 1e6
        rows.append((f"fig2e/append/{name}", round(t_app, 2), n_updates))

        obj = ctor(vals)
        rng = np.random.default_rng(3)
        targets = rng.choice(vals, size=min(n_updates, vals.size), replace=False)
        t0 = time.perf_counter()
        for x in targets.tolist():
            obj.remove(int(x))
        t_rem = (time.perf_counter() - t0) / targets.size * 1e6
        rows.append((f"fig2f/remove/{name}", round(t_rem, 2), targets.size))
    return rows


def tables_realdata(n_bitmaps: int = 60, n_pairs: int = 30) -> list:
    """Tables I-II: size and AND/OR time expansion factors vs Roaring on the
    four real-data surrogates."""
    rows = []
    for ds in REAL_SPECS:
        bitmaps = gen_real_surrogate(ds, n_bitmaps, seed=hash(ds) % 2**31)
        rng = np.random.default_rng(1)
        # stratified-ish pairing: mix small & large cardinalities like S5.2
        order = np.argsort([b.size for b in bitmaps])
        pairs = [(int(order[i]), int(order[-1 - (i % (n_bitmaps // 2))]))
                 for i in range(n_pairs)]
        built = {n: [ctor(b) for b in bitmaps] for n, ctor in SCHEMES.items()}
        sizes = {n: sum(o.size_in_bytes() for o in objs) for n, objs in built.items()}
        bits_item = sizes["roaring"] * 8 / sum(b.size for b in bitmaps)
        rows.append((f"tableI/{ds}/roaring-bits-per-item", 0.0, round(bits_item, 2)))
        for n in SCHEMES:
            rows.append((f"tableIIa/{ds}/size-expansion/{n}", 0.0,
                         round(sizes[n] / sizes["roaring"], 2)))
        for op in ("and", "or"):
            t_by = {}
            for n, objs in built.items():
                t0 = time.perf_counter()
                for i, j in pairs:
                    a, b = objs[i], objs[j]
                    if n == "roaring":
                        _ = (a & b) if op == "and" else (a | b)
                    else:
                        _ = a.and_(b) if op == "and" else a.or_(b)
                t_by[n] = (time.perf_counter() - t0) / len(pairs) * 1e6
            for n in SCHEMES:
                rows.append((f"tableII{'b' if op == 'and' else 'c'}/{ds}/{op}/{n}",
                             round(t_by[n], 1), round(t_by[n] / t_by["roaring"], 2)))
    return rows


def run_compression(n: int = 100_000) -> list:
    """Compression-ratio table for run containers (2016 follow-up paper):
    serialized size of the same sets with the 2-kind (array/bitmap) layout
    vs best-of-three ``runOptimize``, across the uniform / beta (no run
    structure — ratio ~1x) and run-friendly workloads (the paper's "often
    2x better compression" claim; KV pools and window masks land here).
    Derived column = two-kind bytes / run-optimized bytes. The device slab's
    ``size_in_bytes`` accounting is cross-checked against the oracle's."""
    from repro.core import RoaringBitmap, jax_roaring as jr

    workloads = {
        "uniform/d=2^-4": gen_set(2.0 ** -4, "uniform", 11, n=n),
        "beta/d=2^-4": gen_set(2.0 ** -4, "beta", 12, n=n),
        "run/avg=16": gen_run_set(2.0 ** -2, 16.0, 13, n=n),
        "run/avg=64": gen_run_set(2.0 ** -2, 64.0, 14, n=n),
        "run/contig": np.arange(n, dtype=np.int64),
    }
    rows = []
    for name, vals in workloads.items():
        rb = RoaringBitmap.from_sorted_unique(vals)
        two_kind = rb.size_in_bytes()
        opt = rb.run_optimize().size_in_bytes()
        cap = len(rb.keys)
        slab = jr.from_roaring(rb, cap)
        assert int(slab.size_in_bytes()) == opt, (name, opt)
        rows.append((f"compressruns/{name}/two_kind_bytes", 0.0, two_kind))
        rows.append((f"compressruns/{name}/run_optimized_bytes", 0.0, opt))
        rows.append((f"compressruns/{name}/ratio", 0.0,
                     round(two_kind / max(opt, 1), 2)))
    return rows


def dispatch_ab_sweep(repeats: int = 3, n: int = 10_000) -> list:
    """Hybrid per-kind dispatch vs bitmap-domain slab AND across the paper's
    density axis (C&DP sets): sparse densities produce array containers (the
    workload the bitmap-domain path taxes ~linearly in 2^16), dense densities
    produce bitmap containers (where the two paths converge). Derived column
    = dispatch speedup; also cross-checks both paths against py_roaring."""
    import jax
    import jax.numpy as jnp
    from repro.core import jax_roaring as jr

    rows = []
    sparse = densities(sparse_only=True)        # 2^-10 .. 2^-4, array regime
    sweep = [sparse[2], sparse[-1], 2.0 ** -1]  # 2^-8, 2^-4, then the dense
    for d in sweep:                             # point where paths converge
        e = int(round(-np.log2(d)))
        va = gen_set(d, "uniform", seed=e, n=n)
        vb = gen_set(d, "uniform", seed=100 + e, n=n)
        cap = max(1, int(np.ceil(n / d / (1 << 16))) + 1)
        sa = jr.from_dense_array(va, cap, 1 << 16)
        sb = jr.from_dense_array(vb, cap, 1 << 16)
        f_new = jax.jit(lambda x, y: jr._slab_and(x, y))
        f_old = jax.jit(lambda x, y: jr._slab_and_bitmap_domain(x, y))
        us_new = _time_us(lambda: jax.block_until_ready(f_new(sa, sb)), repeats)
        us_old = _time_us(lambda: jax.block_until_ready(f_old(sa, sb)), repeats)
        want = len(RoaringBitmap.from_sorted_unique(va)
                   & RoaringBitmap.from_sorted_unique(vb))
        got_new = int(f_new(sa, sb).cardinality)
        got_old = int(f_old(sa, sb).cardinality)
        assert got_new == want and got_old == want, (got_new, got_old, want)
        rows.append((f"dispatch_ab/d=2^-{e}/bitmap_domain", round(us_old, 1), ""))
        rows.append((f"dispatch_ab/d=2^-{e}/hybrid_dispatch", round(us_new, 1),
                     round(us_old / max(us_new, 1e-9), 2)))
    return rows


def alg4_many_way_union(n_bitmaps: int = 64, repeats: int = 3) -> list:
    """Algorithm 4 vs naive left-fold union (paper S4 'aggregating many')."""
    from repro.core import union_many
    sets = [gen_set(2.0 ** -5, "uniform", 100 + i, n=20000) for i in range(n_bitmaps)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]

    def naive():
        acc = rbs[0]
        for r in rbs[1:]:
            acc = acc | r
        return acc

    t_heap = _time_us(lambda: union_many(rbs), repeats)
    t_naive = _time_us(naive, repeats)
    return [("alg4/union_many/heap", round(t_heap, 1), n_bitmaps),
            ("alg4/union_many/naive-fold", round(t_naive, 1),
             round(t_naive / t_heap, 2))]
