"""Synthetic data generation per Colantonio & Di Pietro, as used in paper S5.1.

Data sets of 10^5 integers at densities d in [2^-10, 0.5]:
  * uniform:  floor(y * max)   with y ~ U[0,1)
  * beta:     floor(y^2 * max) (discretized Beta(0.5, 1); C&DP call it Zipfian)
  * max = 10^5 / d.
"""

from __future__ import annotations

import numpy as np

N_INTS = 100_000


def gen_set(density: float, distribution: str, seed: int, n: int = N_INTS) -> np.ndarray:
    rng = np.random.default_rng(seed)
    max_val = int(n / density)
    y = rng.random(n)
    if distribution == "uniform":
        vals = np.floor(y * max_val)
    elif distribution == "beta":
        vals = np.floor(y * y * max_val)
    else:
        raise ValueError(distribution)
    return np.unique(vals.astype(np.int64))


def densities(sparse_only: bool = False):
    """d = 2^-10 .. 2^-1, the paper's sweep.

    ``sparse_only`` restricts to the array-container regime d <= 2^-4 (the
    densities whose 10^5-element sets stay under ~4096 per chunk), for
    benchmarks that only probe the sparse dispatch paths.
    """
    exps = range(10, 3, -1) if sparse_only else range(10, 0, -1)
    return [2.0 ** -e for e in exps]


def gen_run_ranges(density: float, avg_run: float, seed: int,
                   n: int = N_INTS) -> list:
    """Run-friendly sets (the 2016 paper's regime) as [start, end) ranges:
    ~n integers as maximal runs of geometric mean length ``avg_run`` over a
    universe of n/density, for the run-row constructors (no element
    materialization). KV free/used pools and window/causal attention masks
    look like this."""
    rng = np.random.default_rng(seed)
    max_val = int(n / density)
    n_runs = max(1, int(round(n / avg_run)))
    starts = np.sort(rng.integers(0, max_val, n_runs))
    lengths = rng.geometric(1.0 / avg_run, size=n_runs)
    return [(int(s), int(min(s + l, max_val)))
            for s, l in zip(starts.tolist(), lengths.tolist())]


def gen_run_set(density: float, avg_run: float, seed: int,
                n: int = N_INTS) -> np.ndarray:
    """``gen_run_ranges`` materialized to sorted unique integers — the same
    distribution by construction."""
    ranges = gen_run_ranges(density, avg_run, seed, n)
    return np.unique(np.concatenate([np.arange(s, e) for s, e in ranges]))


def gen_census_like(n_rows: int, seed: int, *, n_cat: int = 4,
                    n_int: int = 2, sort_rows: bool = False) -> dict:
    """Census-like columnar records: correlated low-cardinality categorical
    columns plus non-negative integer columns — the store/benchmark workload
    shared by ``tests/test_store.py`` and ``benchmarks/store_bench.py``
    (replacing ad-hoc per-file data setup).

    A latent "region" drives every column (census attributes correlate:
    geography predicts income predicts occupation), so AND queries have
    non-trivial selectivity and posting bitmaps cluster. Cardinalities
    follow the census pattern (a few values dominate each column).
    ``sort_rows=True`` lexicographically sorts the rows (the
    arXiv:0901.3751 reordering axis): sorted rows form long runs, which is
    where RLE formats close the gap — the honest-fight variant.
    """
    rng = np.random.default_rng(seed)
    latent = rng.integers(0, 8, n_rows)
    records: dict = {}
    for i in range(n_cat):
        card = (2, 8, 16, 32, 64, 128)[i % 6]
        noise = rng.integers(0, max(2, card // 4), n_rows)
        records[f"cat{i}"] = ((latent * (card // 8 + 1) + noise) % card
                              ).astype(np.int64)
    for i in range(n_int):
        if i % 2 == 0:       # age-like: clipped normal, correlated
            vals = rng.normal(30 + 5 * latent, 12, n_rows)
            records[f"int{i}"] = np.clip(vals, 0, 95).astype(np.int64)
        else:                # income-like: lognormal, long tail
            vals = rng.lognormal(9 + 0.15 * latent, 0.7, n_rows)
            records[f"int{i}"] = np.minimum(vals, 500_000).astype(np.int64)
    if sort_rows and n_rows:
        order = np.lexsort(tuple(reversed(list(records.values()))))
        records = {k: v[order] for k, v in records.items()}
    return records


# ---------------------------------------------------------------------------
# Real-data surrogates for Tables I-II.
#
# The four datasets (CENSUS1881, CENSUSINCOME, WIKILEAKS, WEATHER) are not
# redistributable inside this offline container, so we synthesize surrogate
# bitmap indexes matched to the published per-dataset statistics (rows,
# density) and the structural property the paper identifies as decisive:
#   * CENSUS1881: huge cardinality skew  -> sparse x dense intersections
#   * CENSUSINCOME: dense bitmaps (d=0.17)
#   * WIKILEAKS: long runs of ones (RLE-friendly; roaring loses on size)
#   * WEATHER: moderately dense
# ---------------------------------------------------------------------------

REAL_SPECS = {
    "census1881": dict(rows=4_277_807, density=1.2e-3, kind="skewed"),
    "censusincome": dict(rows=199_523, density=1.7e-1, kind="dense"),
    "wikileaks": dict(rows=1_178_559, density=1.3e-3, kind="runs"),
    "weather": dict(rows=1_015_367, density=6.4e-2, kind="dense"),
}


def gen_real_surrogate(name: str, n_bitmaps: int, seed: int) -> list[np.ndarray]:
    """Generate `n_bitmaps` attribute bitmaps over the dataset's row universe."""
    spec = REAL_SPECS[name]
    rows, density, kind = spec["rows"], spec["density"], spec["kind"]
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_bitmaps):
        if kind == "skewed":
            # zipf-like attribute cardinalities: a few huge, most tiny
            card = int(np.clip(rows * density * 50 / (1 + (i % 40)) ** 1.5, 16, rows // 3))
            vals = np.unique(rng.integers(0, rows, size=card))
        elif kind == "dense":
            card = int(rows * density * rng.uniform(0.5, 2.0))
            card = min(card, rows - 1)
            vals = np.unique(rng.integers(0, rows, size=card))
        elif kind == "runs":
            # sorted/clustered data: geometric run lengths of consecutive rows
            # (mean ~24), plus scattered singletons — mirrors WIKILEAKS where
            # RLE formats compress ~30% better than Roaring (paper S5.2)
            target = int(rows * density * rng.uniform(0.5, 2.0))
            starts = np.sort(rng.integers(0, rows, size=max(4, target // 16)))
            runs = rng.geometric(1 / 24.0, size=starts.size)
            pieces = [np.arange(s, min(s + l, rows)) for s, l in zip(starts, runs)]
            lone = rng.integers(0, rows, size=max(4, target // 10))
            vals = np.unique(np.concatenate(pieces + [lone]))
        else:
            raise ValueError(kind)
        out.append(vals.astype(np.int64))
    return out
