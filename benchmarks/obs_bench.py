"""Telemetry-plane benchmarks: the off-by-default cost contract and the
measured-vs-analytic launch-accounting cross-check.

``obs/query/*`` rows measure the same warm jitted fused
``BitmapStore.query`` three ways — the pre-telemetry query body inlined
(predicate compile + cache lookup + jitted call, no obs code at all),
the instrumented ``query()`` with telemetry disabled (the state every
non-observing user runs), and with telemetry enabled. The disabled row's
derived column is the median of per-trial raw/instrumented ratios with
alternating measurement order (the ``api_ab`` methodology — a transient
stall in one measurement cannot fake an overhead), and ``compare.py``
gates it at >= 0.95x: telemetry off must cost under 5% on the hot path.
The enabled row is recorded ungated — spans, launch events, and gauge
refreshes are allowed to cost real time when someone is watching.

``obs/crosscheck/fused_launches`` runs ``obs.launch_crosscheck`` on fused
N=4 and N=16 AND trees; derived is 1.0 only when the measured launch
counters equal the analytic model on every tree, gated at 1.0 — an
accounting bug fails CI, not just a unit test.
"""

from __future__ import annotations

import sys
import time

import jax
import numpy as np


def _t(fn, repeats=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats * 1e6


def overhead_ab(quick: bool = False):
    import repro.obs as obs
    from repro.store import BitmapStore
    from repro.store import predicate as P

    rng = np.random.default_rng(42)
    n = 20_000 if quick else 50_000
    recs = {"city": rng.integers(0, 8, n), "sex": rng.integers(0, 2, n),
            "age": rng.integers(0, 100, n)}
    store = BitmapStore.build(recs, bsi=("age",))
    pred = P.and_(P.eq("city", 3), P.eq("sex", 1), P.range_("age", 18, 65))

    with obs.telemetry_scope(on=False):
        store.query(pred, fused=True)         # warm: compile + jit once

    def raw():
        # the pre-telemetry query() body: predicate compile, cache lookup,
        # jitted whole-call — zero obs code on the path
        expr = store.compile(pred)
        return store._query_fns[(expr, True, None)](store._stack)

    def instrumented():
        return store.query(pred, fused=True)

    repeats = 20 if quick else 40
    us_raw, us_dis, us_en = [], [], []
    for trial in range(7):
        with obs.telemetry_scope(on=False):
            pairs = [(us_raw, raw), (us_dis, instrumented)]
            if trial % 2:                     # kill ordering/thermal bias
                pairs.reverse()
            for acc, fn in pairs:
                acc.append(_t(fn, repeats))
        with obs.telemetry_scope():
            us_en.append(_t(instrumented, repeats))
    obs.reset_traces()                        # drop the spans we generated

    def med_ratio(a, b):
        return float(np.median(np.asarray(a) / np.asarray(b)))

    return [
        ("obs/query/raw_jitted", round(min(us_raw), 1), ""),
        ("obs/query/disabled", round(min(us_dis), 1),
         round(med_ratio(us_raw, us_dis), 2)),
        ("obs/query/enabled", round(min(us_en), 1),
         round(med_ratio(us_raw, us_en), 2)),
    ]


def crosscheck(quick: bool = False):
    import repro.index as index
    import repro.obs as obs
    from repro import roaring

    # tiny capacity: the crosscheck runs the EAGER engine (the jit cache
    # would swallow per-dispatch launch events), and eager combines pay a
    # per-tree-node compile on CPU — keep the arrays small
    C = 2
    rng = np.random.default_rng(7)
    slabs = [roaring.RoaringSlab.from_values(
        np.unique(rng.integers(0, C << 16, 3000)), C, 1 << 14)
        for _ in range(16)]
    stack = roaring.stack(slabs, capacity=C)

    us, ok = [], True
    for N in (4, 16):
        expr = index.and_(*[index.leaf(i) for i in range(N)])
        t0 = time.perf_counter()
        r = obs.launch_crosscheck(stack, expr)
        us.append((time.perf_counter() - t0) * 1e6)
        ok = ok and r["match"]
        print(f"# obs crosscheck and_n{N}: fused {r['fused_measured']}"
              f"/{r['fused_model']}  per-op {r['per_op_measured']}"
              f"/{r['per_op_model']}  match={r['match']}",
              file=sys.stderr, flush=True)
    return [("obs/crosscheck/fused_launches", round(sum(us), 1),
             1.0 if ok else 0.0)]


def run(quick: bool = False):
    return overhead_ab(quick) + crosscheck(quick)
