"""Roaring-paged KV cache + serving engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import RoaringBitmap
from repro.models import transformer as T
from repro.serve import PagedKVCache, Request, RoaringPageTable, ServeEngine


def test_page_table_alloc_release():
    t = RoaringPageTable(n_pages=16, page_size=4)
    p1 = t.alloc(1, 10)                    # 3 pages
    assert len(p1) == 3 and t.seq_len[1] == 10
    t.alloc(1, 2)                          # fits page 3 (12 <= 12)
    assert len(t.seq_pages[1]) == 3
    t.alloc(1, 1)                          # 13 tokens -> 4th page
    assert len(t.seq_pages[1]) == 4
    t.alloc(2, 16)
    assert t.utilization() == 0.5
    used = t.used_bitmap()
    assert len(used) == 8
    t.release(1)
    assert len(t.free) == 12
    # released pages are reusable
    p3 = t.alloc(3, 40)
    assert len(p3) == 10


def test_page_table_exhaustion():
    t = RoaringPageTable(n_pages=2, page_size=4)
    t.alloc(1, 8)
    with pytest.raises(MemoryError):
        t.alloc(2, 1)


def test_paged_decode_matches_dense_cache_decode():
    """decode_step_paged must equal the dense-cache decode path."""
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    B, steps, page_size, max_pages = 2, 6, 4, 8
    toks = jax.random.randint(rng, (B, steps), 0, cfg.vocab)

    dense_caches = T.init_decode_caches(cfg, B, s_max=steps)
    pools = T.init_paged_caches(cfg, n_pages=32, page_size=page_size)
    table = RoaringPageTable(32, page_size)

    for t in range(steps):
        for b in range(B):
            table.alloc(b, 1)
        page_idx, counts, lengths = table.gather_lists(list(range(B)), max_pages)
        pos = jnp.full((B,), t, jnp.int32)
        lg_d, dense_caches = T.decode_step(
            params, dense_caches, toks[:, t: t + 1], pos, cfg)
        lg_p, pools = T.decode_step_paged(
            params, pools, toks[:, t: t + 1], pos,
            jnp.asarray(page_idx), jnp.asarray(counts),
            jnp.asarray(lengths) - 1, cfg)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_serve_engine_end_to_end():
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(1)
    params = T.init_lm(rng, cfg)
    eng = ServeEngine(cfg, params, max_batch=2, n_pages=64, page_size=4,
                      max_pages_per_seq=16)
    reqs = [Request(req_id=i, prompt=np.asarray([5 + i, 9, 13]),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert all(0 <= g < cfg.vocab for r in reqs for g in r.generated)
    # all pages returned to the pool after completion
    assert eng.table.utilization() == 0.0


def test_serve_engine_greedy_matches_forward():
    """Engine's greedy continuation equals argmax over teacher-forced logits."""
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(2)
    params = T.init_lm(rng, cfg)
    prompt = np.asarray([3, 7, 11])
    eng = ServeEngine(cfg, params, max_batch=1, n_pages=64, page_size=4,
                      max_pages_per_seq=16)
    r = Request(req_id=0, prompt=prompt, max_new_tokens=3)
    eng.submit(r)
    eng.run_until_done(max_steps=50)
    # reference: grow the sequence with full forward each step
    seq = prompt.tolist()
    want = []
    for _ in range(3):
        logits, _ = T.forward(params, jnp.asarray([seq]), cfg)
        nxt = int(np.argmax(np.asarray(logits[0, -1], np.float32)))
        want.append(nxt)
        seq.append(nxt)
    assert r.generated == want


def test_page_table_device_views_and_sharing():
    """free/used device slabs + shared_pages (dispatch card fast path)."""
    pt = RoaringPageTable(n_pages=256, page_size=4)
    pt.alloc(1, 40)     # 10 pages
    pt.alloc(2, 20)     # 5 pages
    assert int(pt.free_slab().card()) == len(pt.free)
    assert int(pt.used_slab().card()) == 15
    assert pt.shared_pages(1, 2) == 0            # allocator never aliases
    assert pt.shared_pages(1, 1) == 10           # self-overlap = page count
    pt.release(1)
    assert int(pt.used_slab().card()) == 5
