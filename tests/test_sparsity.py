"""Roaring mask algebra -> kernel metadata."""

import numpy as np
import pytest

from repro.sparsity import (MaskBuilder, build_arch_mask, causal_mask,
                            compile_mask, doc_boundary_mask,
                            global_stripe_mask, local_window_mask,
                            mask_density)


def test_local_global_union_density():
    nb = 32
    m = build_arch_mask(nb, pattern="local_global", window_blocks=4, n_global=2)
    kv_idx, counts = compile_mask(m)
    d = mask_density(kv_idx, counts)
    full = MaskBuilder(causal_mask(nb))
    _, full_counts = compile_mask(full)
    d_full = full_counts.sum() / nb ** 2
    assert d < d_full * 0.5                      # sub-quadratic
    # row 0 sees itself; every row sees global block 0 and its local window
    assert counts[0] == 1
    for r in range(nb):
        row = set(kv_idx[r, : counts[r]].tolist())
        assert 0 in row and r in row
        for w in range(max(0, r - 3), r + 1):
            assert w in row


def test_mask_algebra_matches_set_algebra():
    nb = 16
    local = MaskBuilder(local_window_mask(nb, 3))
    glob = MaskBuilder(global_stripe_mask(nb, [0, 5]))
    union = local.union(glob)
    inter = local.intersect(glob)
    diff = union.subtract(local)
    for r in range(nb):
        sl = set(local.rows[r].to_array().tolist())
        sg = set(glob.rows[r].to_array().tolist())
        assert set(union.rows[r].to_array().tolist()) == sl | sg
        assert set(inter.rows[r].to_array().tolist()) == sl & sg
        assert set(diff.rows[r].to_array().tolist()) == (sl | sg) - sl


def test_union_many_rows():
    nb = 8
    pats = [MaskBuilder(local_window_mask(nb, w)) for w in (1, 2, 3)]
    merged = pats[0].union_many(pats[1:])
    want = MaskBuilder(local_window_mask(nb, 3))
    for r in range(nb):
        np.testing.assert_array_equal(merged.rows[r].to_array(),
                                      want.rows[r].to_array())


def test_doc_boundary_mask():
    nb = 12
    m = doc_boundary_mask(nb, doc_starts_blocks=[4, 9])
    # block 5 is in doc [4, 9): sees blocks 4..5 only
    np.testing.assert_array_equal(m[5].to_array(), [4, 5])
    np.testing.assert_array_equal(m[3].to_array(), [0, 1, 2, 3])


def test_compile_mask_500k_scale():
    """long_500k geometry: 4096 block rows compile fast and compress well."""
    nb = 4096                                    # 524288 / 128
    m = build_arch_mask(nb, pattern="local_global", window_blocks=8,
                        n_global=4)
    kv_idx, counts = compile_mask(m)
    assert kv_idx.shape[0] == nb
    d = mask_density(kv_idx, counts)
    assert d < 0.01                              # >100x sparser than dense
    # roaring mask footprint far below a dense boolean block matrix
    assert m.size_in_bytes() < nb * nb / 8 / 4


def test_mask_overlap_device_dispatch():
    """Device-side overlap/jaccard (jax_roaring dispatch) vs host sets."""
    from repro.sparsity import mask_jaccard, mask_overlap_cards
    nb = 24
    loc = MaskBuilder(local_window_mask(nb, 4))
    glb = MaskBuilder(global_stripe_mask(nb, [0, 1, 2]))
    cards = mask_overlap_cards(loc, glb)
    jac = mask_jaccard(loc, glb)
    for r in range(nb):
        a = set(loc.rows[r].to_array().tolist())
        b = set(glb.rows[r].to_array().tolist())
        assert cards[r] == len(a & b)
        assert jac[r] == pytest.approx(len(a & b) / len(a | b))
