"""The ``repro.roaring`` object API: pytree registration, operator algebra
vs the oracle, the portable serialization codec, retrace guards, and the
deprecation shims over the old ``slab_*`` free functions.

Covers the PR 5 checklist: flatten/unflatten round-trip through
``jax.tree_util``, ``serialize``/``deserialize`` identity across all four
container kinds including the 4095/4096/4097 and ``4*n_runs == 8192``
boundaries, operator-vs-oracle bit-identity on random slabs (hypothesis
when installed, the deterministic fallback otherwise), jit/vmap/shard_map
flow of ``a & b | c`` over stacked slabs, and jit-cache stability (no
retrace on same-shape inputs).
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro import index, roaring
from repro.core import RoaringBitmap
from repro.core import jax_roaring as jr
from repro.core import py_roaring as pr
from repro.roaring import RoaringFormatSpec, RoaringSlab

_KIND_OF = {pr.ArrayContainer: jr.KIND_ARRAY,
            pr.BitmapContainer: jr.KIND_BITMAP,
            pr.RunContainer: jr.KIND_RUN}


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def _slab_and_oracle(vals, cap=8):
    vals = np.asarray(sorted(set(int(v) for v in vals)), np.int64)
    rb = RoaringBitmap.from_sorted_unique(vals)
    return RoaringSlab.from_roaring(rb, cap), rb


def _assert_matches(slab: RoaringSlab, oracle: RoaringBitmap, tag=""):
    """values, card, kind, and packed payload must all match the oracle —
    the serialized byte streams are a complete proxy for all four."""
    assert int(slab.card()) == len(oracle), tag
    keys = np.asarray(slab.keys)
    kinds = np.asarray(slab.kinds)
    assert list(keys[kinds != jr.KIND_EMPTY]) == list(oracle.keys), tag
    assert slab.serialize() == RoaringFormatSpec.serialize(oracle), tag


# ------------------------------------------------------------------- pytree
def test_pytree_flatten_unflatten_round_trip():
    s, _ = _slab_and_oracle(_rand_set(5000, 1 << 18, 0))
    leaves, treedef = jax.tree_util.tree_flatten(s)
    assert len(leaves) == 5                       # keys/kinds/cards/nruns/payload
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, RoaringSlab) and back.C == s.C
    for a, b in zip(leaves, jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # tree_map preserves structure and static capacity
    mapped = jax.tree.map(lambda x: x, s)
    assert isinstance(mapped, RoaringSlab) and mapped.capacity == s.capacity
    # two same-capacity slabs share a treedef (jit cache key sanity)
    t, _ = _slab_and_oracle(_rand_set(100, 1 << 18, 1))
    assert jax.tree_util.tree_structure(s) == jax.tree_util.tree_structure(t)


def test_pytree_capacity_is_static_aux_data():
    s, _ = _slab_and_oracle(_rand_set(500, 1 << 18, 2), cap=4)
    t, _ = _slab_and_oracle(_rand_set(500, 1 << 18, 3), cap=8)
    assert jax.tree_util.tree_structure(s) != jax.tree_util.tree_structure(t)


@settings(max_examples=15)
@given(st.sets(st.integers(0, (1 << 18) - 1), max_size=400))
def test_pytree_round_trip_property(vals):
    s, _ = _slab_and_oracle(vals, cap=4)
    leaves, treedef = jax.tree_util.tree_flatten(s)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.serialize() == s.serialize()


# -------------------------------------------------------- operators vs oracle
def _pair(seed):
    r = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:     # scattered arrays
        va = _rand_set(3000, 1 << 18, seed)
        vb = _rand_set(4000, 1 << 18, seed + 100)
    elif kind == 1:   # dense bitmaps vs arrays
        va = _rand_set(60_000, 4 << 16, seed)
        vb = _rand_set(2500, 4 << 16, seed + 100)
    else:             # run-shaped vs scattered
        starts = np.sort(r.integers(0, 1 << 18, 25))
        ra = RoaringBitmap.from_ranges(
            [(int(s), int(s) + int(l)) for s, l in
             zip(starts, r.integers(1, 400, 25))])
        va = ra.to_array()
        vb = _rand_set(3000, 1 << 18, seed + 100)
    a, rba = _slab_and_oracle(va)
    b, rbb = _slab_and_oracle(vb)
    return a, b, rba, rbb


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_operators_bit_identical_to_oracle(seed):
    a, b, ra, rb = _pair(seed)
    _assert_matches(a & b, ra & rb, f"and {seed}")
    _assert_matches(a | b, ra | rb, f"or {seed}")
    _assert_matches(a ^ b, ra ^ rb, f"xor {seed}")
    _assert_matches(a - b, ra.andnot(rb), f"andnot {seed}")
    assert int(a.and_card(b)) == len(ra & rb)
    assert int(a.or_card(b)) == len(ra | rb)


@settings(max_examples=12)
@given(st.sets(st.integers(0, (1 << 17) - 1), max_size=300),
       st.sets(st.integers(0, (1 << 17) - 1), max_size=300))
def test_operator_property_random_slabs(va, vb):
    a, ra = _slab_and_oracle(va, cap=4)
    b, rb = _slab_and_oracle(vb, cap=4)
    _assert_matches(a & b, ra & rb, "and")
    _assert_matches(a | b, ra | rb, "or")
    _assert_matches(a ^ b, ra ^ rb, "xor")
    _assert_matches(a - b, ra.andnot(rb), "andnot")


def test_method_surface_matches_oracle():
    vals = np.concatenate([np.arange(1000, 9000),          # run-shaped chunk
                           (2 << 16) + _rand_set(300, 1 << 16, 7)])
    s, rb = _slab_and_oracle(vals, cap=4)
    s = s.run_optimize()
    rb.run_optimize()
    assert int(s.card()) == len(rb)
    assert int(s.size_in_bytes()) == rb.size_in_bytes()
    q = np.asarray([0, 1000, 8999, 9000, (2 << 16) + 1])
    got = np.asarray(s.contains(jnp.asarray(q)))
    assert got.tolist() == [rb.contains(int(x)) for x in q]
    assert int(s.rank(jnp.int32(8999))) == rb.rank(8999)
    assert int(s.select(jnp.int32(17))) == rb.select(17)
    assert int(s.select(jnp.int32(len(rb)))) == -1
    dense = s.to_dense()
    assert dense.sum() == len(rb) and dense[vals].all()
    idx, valid = s.to_indices(1 << 14)
    np.testing.assert_array_equal(np.asarray(idx)[np.asarray(valid)],
                                  rb.to_array())


# ------------------------------------------------------ serialization codec
def _codec_round_trip(rb: RoaringBitmap, cap=4):
    blob = RoaringFormatSpec.serialize(rb)
    back = RoaringFormatSpec.deserialize(blob)
    assert back.keys == rb.keys
    for c1, c2 in zip(back.containers, rb.containers):
        assert type(c1) is type(c2)
    np.testing.assert_array_equal(back.to_array(), rb.to_array())
    # slab side: byte-identical stream, kind-identical slab after reload
    s = RoaringSlab.from_roaring(rb, cap)
    assert s.serialize() == blob
    s2 = RoaringSlab.deserialize(blob, capacity=cap)
    np.testing.assert_array_equal(np.asarray(s2.kinds), np.asarray(s.kinds))
    np.testing.assert_array_equal(np.asarray(s2.cards), np.asarray(s.cards))
    assert s2.serialize() == blob


@pytest.mark.parametrize("card", [4095, 4096, 4097])
def test_serialize_array_bitmap_boundary(card):
    vals = np.arange(0, 2 * card, 2)[:card]           # no runs: 2-gaps
    rb = RoaringBitmap.from_sorted_unique(vals)
    want = pr.ArrayContainer if card <= 4096 else pr.BitmapContainer
    assert type(rb.containers[0]) is want
    _codec_round_trip(rb)


def test_serialize_all_four_kinds_one_stream():
    rb = RoaringBitmap.from_ranges([(0, 70000)])              # run rows
    rb.ior(RoaringBitmap.from_sorted_unique(
        (4 << 16) + _rand_set(200, 1 << 16, 0)))              # array row
    rb.ior(RoaringBitmap.from_sorted_unique(
        (5 << 16) + _rand_set(30000, 1 << 16, 1)))            # bitmap row
    kinds = {type(c) for c in rb.containers}
    assert kinds == {pr.ArrayContainer, pr.BitmapContainer, pr.RunContainer}
    _codec_round_trip(rb, cap=8)


def test_serialize_run_size_tie_boundary():
    """A container with 4*n_runs == 8192 (2048 runs): the codec must carry
    the run encoding verbatim, while runOptimize flips it — the strict
    best-of-three rule never keeps a run at the tie."""
    starts = np.arange(0, 4096, 2, dtype=np.int64)            # 2048 1-runs
    rb = RoaringBitmap()
    rb.keys.append(0)
    rb.containers.append(pr.RunContainer(starts, np.zeros(2048, np.int64)))
    assert 4 * rb.containers[0].n_runs == 8192
    _codec_round_trip(rb, cap=2)
    s = RoaringSlab.from_roaring(rb, 2)
    assert int(s.nruns[0]) == 2048
    opt = s.run_optimize()
    # card 2048 <= 4096 and 2*card = 4096 < 8192: array must win
    assert int(opt.kinds[0]) == jr.KIND_ARRAY


def test_serialize_small_run_stream_no_offset_header():
    """< NO_OFFSET_THRESHOLD containers with runs: the offset header is
    absent — layout must still round-trip."""
    rb = RoaringBitmap.from_ranges([(10, 5000), (70000, 70100)])
    assert len(rb.keys) < RoaringFormatSpec.NO_OFFSET_THRESHOLD
    _codec_round_trip(rb)


def test_serialize_empty_and_garbage():
    rb = RoaringBitmap()
    _codec_round_trip(rb, cap=1)
    with pytest.raises(ValueError):
        RoaringFormatSpec.deserialize(b"\x00\x01\x02\x03\x04")


@settings(max_examples=15)
@given(st.sets(st.integers(0, (1 << 18) - 1), max_size=500))
def test_serialize_round_trip_property(vals):
    s, rb = _slab_and_oracle(vals, cap=4)
    if len(rb.keys) == 0:
        _codec_round_trip(rb, cap=1)
        return
    _codec_round_trip(rb)
    assert RoaringSlab.deserialize(s.serialize()).serialize() == s.serialize()


# ------------------------------------------------- jit / vmap / shard_map
def _stacked_triple(cap=4, n=4):
    A = [_rand_set(3000, 1 << 18, 10 + i) for i in range(n)]
    B = [_rand_set(4000, 1 << 18, 20 + i) for i in range(n)]
    C = [_rand_set(2000, 1 << 18, 30 + i) for i in range(n)]
    st_ = lambda xs: roaring.stack(
        [RoaringSlab.from_values(x, cap, 1 << 14) for x in xs], align=False)
    want = [len((RoaringBitmap.from_sorted_unique(A[i])
                 & RoaringBitmap.from_sorted_unique(B[i]))
                | RoaringBitmap.from_sorted_unique(C[i]))
            for i in range(n)]
    return st_(A), st_(B), st_(C), want


def test_jit_vmap_expression_over_stacked_slabs():
    a, b, c, want = _stacked_triple()
    f = jax.jit(lambda a, b, c: (a & b | c).card())
    assert np.asarray(f(a, b, c)).tolist() == want
    g = jax.vmap(lambda a, b, c: (a & b | c).card())
    assert np.asarray(g(a, b, c)).tolist() == want
    # single & stacked broadcast
    one = a[0]
    sc = np.asarray(b.and_card(one))
    assert len(sc) == b.n_slabs


def test_shard_map_expression_over_stacked_slabs():
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    a, b, c, want = _stacked_triple()
    mesh = Mesh(np.asarray(jax.devices()).reshape(-1), ("data",))
    if a.n_slabs % mesh.shape["data"]:
        pytest.skip("slab axis must divide the mesh axis")
    f = jax.jit(shard_map(
        lambda a, b, c: (a & b | c).card(), mesh=mesh,
        in_specs=(P("data"),) * 3, out_specs=P("data")))
    assert np.asarray(f(a, b, c)).tolist() == want


# ------------------------------------------------------------ retrace guard
def test_jitted_ops_do_not_retrace_on_same_shapes():
    """Same-shape inputs must hit the jit cache (the PR 4 lesson: eager
    lax.cond closures re-trace every call — jitted entry points must not)."""
    f_and = jax.jit(lambda a, b: a & b)
    f_card = jax.jit(lambda a, b: a.and_card(b))
    for seed in (0, 1, 2):
        a, _ = _slab_and_oracle(_rand_set(2000, 1 << 18, 40 + seed))
        b, _ = _slab_and_oracle(_rand_set(3000, 1 << 18, 50 + seed))
        jax.block_until_ready(f_and(a, b).cards)
        jax.block_until_ready(f_card(a, b))
    assert f_and._cache_size() == 1, f_and._cache_size()
    assert f_card._cache_size() == 1, f_card._cache_size()


def test_jitted_execute_does_not_retrace_on_same_shapes():
    expr = index.and_(index.or_(index.leaf(0), index.leaf(1)), index.leaf(2))
    f = jax.jit(lambda st: index.execute_card(st, expr))
    g = jax.jit(lambda st: index.execute(st, expr).cards)
    for seed in (0, 1, 2):
        slabs = [RoaringSlab.from_values(_rand_set(2000, 1 << 18, seed + i), 4,
                                         1 << 14) for i in range(3)]
        stck = roaring.stack(slabs, capacity=4)
        jax.block_until_ready(f(stck))
        jax.block_until_ready(g(stck))
    assert f._cache_size() == 1, f._cache_size()
    assert g._cache_size() == 1, g._cache_size()


# -------------------------------------------------------- engine integration
def test_execute_with_slab_leaves_and_stack_members():
    sets = [_rand_set(2500 + 400 * i, 1 << 18, 60 + i) for i in range(4)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    slabs = [RoaringSlab.from_values(s, 8, 1 << 14) for s in sets]
    # slab leaves only, no stack bookkeeping
    got = index.execute(index.andnot(index.leaf(slabs[0]),
                                     index.or_(index.leaf(slabs[1]),
                                               index.leaf(slabs[2]))),
                        capacity=8)
    want = rbs[0].andnot(rbs[1] | rbs[2])
    assert isinstance(got, RoaringSlab)
    _assert_matches(got, want, "slab leaves")
    # int leaves over a stack still work and return the object type
    stck = roaring.stack(slabs, capacity=8)
    got2 = index.execute(stck, index.andnot(
        index.leaf(0), index.or_(index.leaf(1), index.leaf(2))))
    assert got2.serialize() == got.serialize()


def test_intersect_all_shared_keys_beyond_capacity():
    """Regression: alignment must use the *intersected* key set — with a
    union-key alignment, keys shared by all operands could be truncated
    past min(C) and silently dropped from the intersection."""
    va = np.concatenate([np.arange(3) << 16, [(100 << 16) + 7]])
    vb = np.concatenate([(np.arange(3, 6) << 16) + 1, [(100 << 16) + 7]])
    a = RoaringSlab.from_values(va, 4, 16)     # chunks {0,1,2,100}, C=4
    b = RoaringSlab.from_values(vb, 4, 16)     # chunks {3,4,5,100}, C=4
    # merged distinct keys exceed min(C)=4; only chunk 100 is shared
    got = roaring.intersect_all([a, b])
    assert int(got.card()) == int((a & b).card()) == 1
    assert int(got.select(jnp.int32(0))) == (100 << 16) + 7


def test_union_all_and_intersect_all():
    sets = [_rand_set(2000 + 300 * i, 1 << 18, 70 + i) for i in range(5)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    slabs = [RoaringSlab.from_values(s, 8, 1 << 14) for s in sets]
    from repro.core import union_many
    _assert_matches(roaring.union_all(slabs, capacity=8), union_many(rbs),
                    "union_all")
    want = rbs[0]
    for r in rbs[1:]:
        want = want & r
    _assert_matches(roaring.intersect_all(slabs), want, "intersect_all")


# --------------------------------------------------------- deprecation shims
def test_slab_free_functions_warn_and_still_work():
    va, vb = _rand_set(500, 1 << 17, 80), _rand_set(600, 1 << 17, 81)
    a = jr.from_dense_array(va, 4, 1 << 12)
    b = jr.from_dense_array(vb, 4, 1 << 12)
    ra = RoaringBitmap.from_sorted_unique(va)
    rb = RoaringBitmap.from_sorted_unique(vb)
    with pytest.warns(DeprecationWarning, match="slab_and is deprecated"):
        got = jr.slab_and(a, b, capacity=4)
    assert int(got.cardinality) == len(ra & rb)
    with pytest.warns(DeprecationWarning, match="slab_or "):
        assert int(jr.slab_or(a, b).cardinality) == len(ra | rb)
    with pytest.warns(DeprecationWarning, match="slab_and_card"):
        assert int(jr.slab_and_card(a, b)) == len(ra & rb)
    with pytest.warns(DeprecationWarning, match="slab_select"):
        assert int(jr.slab_select(a, 0)) == int(va[0])
    with pytest.warns(DeprecationWarning, match="slab_run_optimize"):
        jr.slab_run_optimize(a)
    with pytest.warns(DeprecationWarning, match="stack_from_slabs"):
        index.stack_from_slabs([RoaringSlab.from_values(va, 4, 1 << 12)],
                               capacity=4)
    with pytest.warns(DeprecationWarning, match="union_many_batched"):
        index.union_many_batched(
            [RoaringSlab.from_values(va, 4, 1 << 12)], capacity=4)


def test_object_api_emits_no_deprecation_warnings():
    va, vb = _rand_set(500, 1 << 17, 82), _rand_set(600, 1 << 17, 83)
    a = RoaringSlab.from_values(va, 4, 1 << 12)
    b = RoaringSlab.from_values(vb, 4, 1 << 12)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        (a & b).card()
        (a | b).serialize()
        a.and_card(b)
        a.run_optimize()
        roaring.union_all([a, b], capacity=8)
        index.execute(index.and_(index.leaf(a), index.leaf(b)), capacity=4)
