"""Optimizers, data pipeline, checkpointing, fault tolerance, grad comp."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import BitmapIndex, DataPipeline, PipelineState, SyntheticCorpus
from repro.models import transformer as T
from repro.optim import adamw, adafactor, adamw8bit, cosine_schedule


def _quad_problem(opt, steps=200, lr=0.05):
    """Minimize ||x - t||^2 with each optimizer; all must converge."""
    t = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
    params = {"w": jnp.zeros((256, 256), jnp.float32)}
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        g = jax.grad(lambda p: jnp.mean((p["w"] - t) ** 2))(params)
        upd, state = opt.update(g, state, params, i)
        params = jax.tree.map(lambda p, u: p - u, params, upd)
        return params, state

    for i in range(steps):
        params, state = step(params, state, i)
    return float(jnp.mean((params["w"] - t) ** 2))


def test_adamw_converges():
    assert _quad_problem(adamw(0.05, wd=0.0)) < 1e-2


def test_adafactor_converges():
    assert _quad_problem(adafactor(0.05)) < 1e-2


def test_adamw8bit_converges():
    assert _quad_problem(adamw8bit(0.05, wd=0.0)) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor(1e-3)
    params = {"w": jnp.zeros((512, 256)), "b": jnp.zeros((17,))}
    st = opt.init(params)
    assert set(st["w"].keys()) == {"vr", "vc"}
    assert st["w"]["vr"].shape == (512,) and st["w"]["vc"].shape == (256,)
    assert set(st["b"].keys()) == {"v"}          # small vectors unfactored


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) <= 0.11


# ------------------------------------------------------------------ data
def test_bitmap_index_query_matches_numpy():
    corpus = SyntheticCorpus(n_docs=50_000, vocab=1000, seed=3)
    idx = BitmapIndex(corpus)
    got = idx.query("lang=2&quality>=3&!dedup_dup").to_array()
    want = np.nonzero((corpus.lang == 2) & (corpus.quality >= 3)
                      & ~corpus.dedup_dup)[0]
    np.testing.assert_array_equal(got, want)
    got2 = idx.query("lang=0|lang=1").to_array()
    want2 = np.nonzero(corpus.lang <= 1)[0]
    np.testing.assert_array_equal(got2, want2)


def test_pipeline_determinism_and_restart():
    corpus = SyntheticCorpus(n_docs=2000, vocab=1000, seed=1, mean_len=100)
    idx = BitmapIndex(corpus)
    mk = lambda st: DataPipeline(idx, st, batch=4, seq_len=256)
    p1 = mk(PipelineState(query="quality>=1", seed=7))
    stream1 = [p1.next_batch()[0] for _ in range(6)]
    # replay from a mid-stream snapshot
    p2 = mk(PipelineState(query="quality>=1", seed=7))
    for _ in range(3):
        p2.next_batch()
    snap = p2.state.to_dict()
    p3 = mk(PipelineState.from_dict(snap))
    for i in range(3, 6):
        np.testing.assert_array_equal(p3.next_batch()[0], stream1[i])


def test_pipeline_shards_are_disjoint():
    corpus = SyntheticCorpus(n_docs=5000, vocab=1000, seed=2, mean_len=200)
    idx = BitmapIndex(corpus)
    a = DataPipeline(idx, PipelineState(query="quality>=0", seed=5),
                     batch=2, seq_len=128, n_shards=2, shard_id=0)
    b = DataPipeline(idx, PipelineState(query="quality>=0", seed=5),
                     batch=2, seq_len=128, n_shards=2, shard_id=1)
    ta = a.next_batch()[0]
    tb = b.next_batch()[0]
    assert not np.array_equal(ta, tb)


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"rng": 123})
    like = jax.tree.map(jnp.zeros_like, tree)
    got, extra, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7 and extra == {"rng": 123}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fault_tolerant_training_resumes(tmp_path):
    """Injected failures mid-run; final state must equal the failure-free run."""
    from repro.optim import adamw
    from repro.runtime import ResilientTrainer, simulate_failure
    from repro.train import TrainState, make_train_step

    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    opt = adamw(1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=())

    def batches(step):
        r = np.random.default_rng(step)
        toks = r.integers(0, cfg.vocab, (2, 33)).astype(np.int32)
        return {"tokens": jnp.asarray(toks),
                "mask": jnp.ones((2, 33), jnp.float32)}

    def run(failures, ckdir):
        state = TrainState(params, opt.init(params), 0)
        tr = ResilientTrainer(step_fn, ckdir, ckpt_every=4,
                              failure_source=simulate_failure(failures))
        state, _ = tr.run(state, batches, n_steps=10)
        return state, tr

    clean, _ = run(set(), str(tmp_path / "clean"))
    faulty, tr = run({3, 7}, str(tmp_path / "faulty"))
    assert tr.restarts == 2
    assert int(faulty["step"]) == 10
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_monitor():
    from repro.runtime import HeartbeatMonitor, StragglerPolicy
    mon = HeartbeatMonitor(StragglerPolicy(factor=2.0))
    for _ in range(10):
        mon.beat(0.1)
    assert mon.beat(0.5) is True
    assert mon.stragglers == 1
    assert mon.beat(0.1) is False


# ------------------------------------------------------------------ grad comp
def test_grad_compression_roundtrip():
    from repro.grad_comp import compress_leaf, decompress_leaf, compression_ratio
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000, 257)), jnp.float32)
    k = 1024
    c = compress_leaf(g, k)
    back = decompress_leaf(c, g.shape, g.dtype)
    # exact on the top-k coordinates, zero elsewhere
    flat = np.asarray(g).reshape(-1)
    idx = np.argsort(-np.abs(flat))[:k]
    bflat = np.asarray(back).reshape(-1)
    np.testing.assert_allclose(bflat[idx], flat[idx], rtol=1e-6)
    zero_idx = np.setdiff1d(np.arange(flat.size), idx)
    assert np.abs(bflat[zero_idx]).max() == 0.0
    assert compression_ratio(c, flat.size) < 0.05


def test_grad_compression_clustered_indices_use_bitmap_containers():
    """Hot-region gradients produce bitmap containers (better than 16b/idx)."""
    from repro.grad_comp import compress_leaf, compression_ratio
    g = np.zeros(300_000, np.float32)
    g[10_000:18_192] = np.random.default_rng(1).normal(size=8192) + 5
    c = compress_leaf(jnp.asarray(g), 8192)
    kinds = np.asarray(c.slab.kinds)
    assert (kinds == 2).sum() >= 1        # dense chunk -> bitmap container
    assert compression_ratio(c, g.size) < 0.06


def test_compressed_crosspod_mean_matches_dense_topk():
    """shard_map over a fake 2-pod mesh: compressed mean == mean of top-k."""
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:          # older jax: experimental namespace
        from jax.experimental.shard_map import shard_map
    from repro.grad_comp import compressed_crosspod_mean

    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env)")
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("pod",))
    g = jnp.asarray(np.random.default_rng(2).normal(size=(2, 4096)), jnp.float32)

    def f(gl):
        return compressed_crosspod_mean({"w": gl[0]}, axis_name="pod",
                                        ratio=0.1)["w"]

    out = shard_map(f, mesh=mesh, in_specs=(P("pod"),), out_specs=P())(g)
    assert np.isfinite(np.asarray(out)).all()


def test_leaf_overlap_and_jaccard():
    """Compressed-leaf index overlap via the cardinality-only dispatch path."""
    from repro.grad_comp import compress_leaf, leaf_jaccard, leaf_overlap
    g1 = jnp.asarray(np.random.default_rng(0).normal(size=8192), jnp.float32)
    g2 = jnp.asarray(g1).at[:4096].set(0.0)
    c1, c2 = compress_leaf(g1, 512), compress_leaf(g2, 512)
    i1 = set(np.asarray(jnp.sort(jnp.argsort(-jnp.abs(g1))[:512])).tolist())
    i2 = set(np.asarray(jnp.sort(jnp.argsort(-jnp.abs(g2))[:512])).tolist())
    assert int(leaf_overlap(c1, c2)) == len(i1 & i2)
    want_j = len(i1 & i2) / len(i1 | i2)
    assert float(leaf_jaccard(c1, c2)) == pytest.approx(want_j, rel=1e-6)
