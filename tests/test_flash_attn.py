"""flash_attn_jnp (custom VJP, blocked recompute) vs naive dense attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A


def _naive(q, k, v, scale, softcap, causal, window):
    B, S, H, hd = q.shape
    S_kv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(S)[:, None] + (S_kv - S)
    cols = jnp.arange(S_kv)[None, :]
    mask = jnp.ones((S, S_kv), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vf.astype(jnp.float32))


def _mk_cfg(softcap=None):
    cfg = get_config("stablelm-1.6b", reduced=True)
    object.__setattr__(cfg, "attn_softcap", softcap)
    return cfg


@pytest.mark.parametrize("softcap,causal,window,kvh", [
    (None, True, None, 2),
    (30.0, True, None, 2),
    (None, True, 512, 1),     # sliding window
    (None, False, None, 2),   # bidirectional
])
def test_flash_forward_matches_naive(softcap, causal, window, kvh):
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 2048, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, S, kvh, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, S, kvh, hd)), jnp.float32)
    cfg = _mk_cfg(softcap)
    got = A.flash_attn_jnp(q, k, v, cfg, causal=causal, window=window)
    want = _naive(q, k, v, hd ** -0.5, softcap, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("softcap,window", [(None, None), (25.0, None),
                                            (None, 600)])
def test_flash_grad_matches_naive(softcap, window):
    rng = np.random.default_rng(1)
    B, S, H, hd = 1, 2048, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    cfg = _mk_cfg(softcap)
    co = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)

    def f_flash(q, k, v):
        return jnp.sum(A.flash_attn_jnp(q, k, v, cfg, causal=True,
                                        window=window) * co)

    def f_naive(q, k, v):
        return jnp.sum(_naive(q, k, v, hd ** -0.5, softcap, True, window) * co)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-3)


def test_flash_decoupled_kv_length():
    """S_q != S_kv (prefill against an existing cache)."""
    rng = np.random.default_rng(2)
    B, Sq, Skv, H, hd = 1, 2048, 4096, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, Skv, H, hd)), jnp.float32)
    cfg = _mk_cfg(None)
    got = A.flash_attn_jnp(q, k, v, cfg, causal=True)
    want = _naive(q, k, v, hd ** -0.5, None, True, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)
