"""Unit + property tests: paper-faithful Roaring vs python set semantics."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.py_roaring import (
    ARRAY_MAX, CHUNK_SIZE, ArrayContainer, BitmapContainer, RoaringBitmap,
    array_to_bitmap, bitmap_to_array, bitmap_to_array_faithful,
    galloping_intersect_faithful, intersect_array_array,
    intersect_bitmap_bitmap, union_array_array, union_bitmap_bitmap,
    union_many,
)

rng = np.random.default_rng(0)


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


# ---------------------------------------------------------------- containers
def test_container_type_rules_bulk_build():
    # exactly 4096 -> array; 4097 -> bitmap (paper S2 threshold)
    v = np.arange(4096, dtype=np.int64)
    rb = RoaringBitmap.from_sorted_unique(v)
    assert isinstance(rb.containers[0], ArrayContainer)
    v = np.arange(4097, dtype=np.int64)
    rb = RoaringBitmap.from_sorted_unique(v)
    assert isinstance(rb.containers[0], BitmapContainer)


def test_dynamic_conversion_on_add_and_remove():
    rb = RoaringBitmap.from_array(range(4096))
    assert isinstance(rb.containers[0], ArrayContainer)
    rb.add(5000)
    assert isinstance(rb.containers[0], BitmapContainer)  # exceeds 4096
    rb.remove(5000)
    assert isinstance(rb.containers[0], ArrayContainer)   # reaches 4096
    assert rb.cardinality == 4096


def test_bitmap_to_array_faithful_matches_vectorized():
    words = rng.integers(0, 2**63, size=1024, dtype=np.uint64)
    np.testing.assert_array_equal(bitmap_to_array(words),
                                  bitmap_to_array_faithful(words))


def test_array_bitmap_roundtrip():
    arr = np.unique(rng.integers(0, CHUNK_SIZE, 3000)).astype(np.uint16)
    np.testing.assert_array_equal(bitmap_to_array(array_to_bitmap(arr)), arr)


def test_intersect_bitmap_bitmap_materializes_array_when_small():
    a = BitmapContainer(array_to_bitmap(np.arange(0, 65536, 8, dtype=np.uint16)))
    b = BitmapContainer(array_to_bitmap(np.arange(0, 65536, 13, dtype=np.uint16)))
    c = intersect_bitmap_bitmap(a, b)
    assert isinstance(c, ArrayContainer)       # |every 104th| = 631 <= 4096
    np.testing.assert_array_equal(c.arr, np.arange(0, 65536, 104, dtype=np.uint16))


def test_union_array_array_upgrade_rule():
    a = ArrayContainer(np.arange(0, 8192, 2, dtype=np.uint16))      # 4096
    b = ArrayContainer(np.arange(1, 8192, 2, dtype=np.uint16))      # 4096
    c = union_array_array(a, b)
    assert isinstance(c, BitmapContainer) and c.cardinality == 8192
    # overlapping arrays whose true union stays <= 4096 must downgrade back
    a = ArrayContainer(np.arange(3000, dtype=np.uint16))
    b = ArrayContainer(np.arange(1500, 4000, dtype=np.uint16))
    c = union_array_array(a, b)
    assert isinstance(c, ArrayContainer) and c.cardinality == 4000


def test_galloping_matches_merge():
    small = np.unique(rng.integers(0, CHUNK_SIZE, 50)).astype(np.uint16)
    large = np.unique(rng.integers(0, CHUNK_SIZE, 5000)).astype(np.uint16)
    got = galloping_intersect_faithful(small, large)
    want = intersect_array_array(ArrayContainer(small), ArrayContainer(large)).arr
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------ bitmap ops
@pytest.mark.parametrize("n1,n2,universe", [
    (100, 100, 1 << 10), (5000, 200, 1 << 20), (100000, 100000, 1 << 22),
    (300, 80000, 1 << 18),
])
def test_and_or_xor_andnot_vs_sets(n1, n2, universe):
    a = _rand_set(n1, universe, 1)
    b = _rand_set(n2, universe, 2)
    ra, rbm = RoaringBitmap.from_sorted_unique(a), RoaringBitmap.from_sorted_unique(b)
    sa, sb = set(a.tolist()), set(b.tolist())
    np.testing.assert_array_equal((ra & rbm).to_array(), sorted(sa & sb))
    np.testing.assert_array_equal((ra | rbm).to_array(), sorted(sa | sb))
    np.testing.assert_array_equal((ra ^ rbm).to_array(), sorted(sa ^ sb))
    np.testing.assert_array_equal(ra.andnot(rbm).to_array(), sorted(sa - sb))
    assert (ra & rbm).cardinality == len(sa & sb)
    assert (ra | rbm).cardinality == len(sa | sb)


def test_inplace_union_matches_functional():
    a = _rand_set(50000, 1 << 21, 3)
    b = _rand_set(60000, 1 << 21, 4)
    ra, rb = RoaringBitmap.from_sorted_unique(a), RoaringBitmap.from_sorted_unique(b)
    want = (ra | rb).to_array()
    ra.ior(rb)
    np.testing.assert_array_equal(ra.to_array(), want)


def test_union_many_matches_pairwise():
    sets = [_rand_set(20000, 1 << 20, 10 + i) for i in range(8)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    got = union_many(rbs)
    want = set()
    for s in sets:
        want |= set(s.tolist())
    np.testing.assert_array_equal(got.to_array(), sorted(want))
    assert got.cardinality == len(want)


# ------------------------------------------------------------------ access ops
def test_contains_add_remove_rank_select():
    vals = _rand_set(5000, 1 << 20, 5)
    rb = RoaringBitmap.from_sorted_unique(vals)
    s = set(vals.tolist())
    probes = rng.integers(0, 1 << 20, 2000)
    for p in probes.tolist():
        assert rb.contains(p) == (p in s)
    # rank/select duality
    arr = np.asarray(sorted(s))
    for j in [0, 17, len(arr) // 2, len(arr) - 1]:
        assert rb.select(j) == int(arr[j])
        assert rb.rank(int(arr[j])) == j + 1


def test_size_accounting_example_from_paper():
    # first 1000 multiples of 62 -> one array container, ~16.2 bits/int (S2)
    rb = RoaringBitmap.from_array([62 * i for i in range(1000)])
    assert rb.container_stats() == (1, 0)
    bits_per_int = rb.size_in_bytes() * 8 / 1000
    assert 16 <= bits_per_int < 17
    # all even numbers in [2*2^16, 3*2^16) -> one bitmap container (fig. 1)
    rb2 = RoaringBitmap.from_array(range(2 * CHUNK_SIZE, 3 * CHUNK_SIZE, 2))
    assert rb2.container_stats() == (0, 1)


# --------------------------------------------------------------- property tests
small_sets = st.sets(st.integers(0, 1 << 18), max_size=300)


@settings(max_examples=60, deadline=None)
@given(small_sets, small_sets)
def test_prop_ops_match_set_algebra(sa, sb):
    ra = RoaringBitmap.from_array(sa)
    rb = RoaringBitmap.from_array(sb)
    assert set((ra & rb).to_array().tolist()) == (sa & sb)
    assert set((ra | rb).to_array().tolist()) == (sa | sb)
    assert set((ra ^ rb).to_array().tolist()) == (sa ^ sb)
    assert set(ra.andnot(rb).to_array().tolist()) == (sa - sb)


@settings(max_examples=40, deadline=None)
@given(small_sets, st.lists(st.integers(0, 1 << 18), max_size=50))
def test_prop_dynamic_updates(sa, updates):
    ra = RoaringBitmap.from_array(sa)
    model = set(sa)
    for i, u in enumerate(updates):
        if i % 2 == 0:
            ra.add(u)
            model.add(u)
        else:
            ra.remove(u)
            model.discard(u)
    assert set(ra.to_array().tolist()) == model
    assert ra.cardinality == len(model)


@settings(max_examples=30, deadline=None)
@given(st.sets(st.integers(0, CHUNK_SIZE * 3 - 1), min_size=0, max_size=5000))
def test_prop_rank_select_roundtrip(s):
    rb = RoaringBitmap.from_array(s)
    arr = sorted(s)
    for j in range(0, len(arr), max(1, len(arr) // 7)):
        assert rb.select(j) == arr[j]
        assert rb.rank(arr[j]) == j + 1
