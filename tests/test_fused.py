"""Fused expression-tree execution (PR 7): the single-launch mega-kernel.

Contract under test: ``execute(..., fused=True)`` is byte-identical — values,
cards, kinds, serialized stream — to the per-op tree-reduce path AND to
``py_roaring`` set algebra, on all three backends (Pallas interpret, the
tape-mirroring XLA evaluator, and the per-op reference it degrades to);
plans retrace once per expression shape; the degradation ladder falls back
from the fused rung bit-identically; and the empty-column DMA skip holds for
the pairwise kernels and the fused kernel alike.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import index, roaring
from repro.core import jax_roaring as jr
from repro.core.py_roaring import RoaringBitmap
from repro.kernels.roaring import dispatch as D
from repro.kernels.roaring import fused as F
from repro.kernels.roaring import kernel as K
from repro.kernels.roaring import ops as kops
from repro.kernels.roaring import ref as kref
from repro.roaring import RoaringFormatSpec


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def _run_set(seed, universe, n_ranges=120, max_len=400):
    r = np.random.default_rng(seed)
    starts = np.sort(r.integers(0, universe, n_ranges))
    lens = r.integers(1, max_len, n_ranges)
    vals = np.concatenate([np.arange(s, min(s + l, universe))
                           for s, l in zip(starts, lens)])
    return np.unique(vals)


def _mixed_slabs(capacity=8, seed=0):
    """Operands covering all four kinds: sparse arrays, dense bitmaps, run
    rows, and slabs that leave whole chunks empty."""
    universe = capacity << 16
    vals = [
        _rand_set(1500, universe, seed + 1),          # array rows
        _rand_set(120_000, universe, seed + 2),       # bitmap rows
        _run_set(seed + 3, universe),                 # run rows
        _rand_set(3000, universe // capacity, seed + 4),  # chunk 0 only
    ]
    slabs = [roaring.RoaringSlab.from_values(v, capacity, 1 << 18)
             for v in vals]
    return slabs, [set(v.tolist()) for v in vals]


def _assert_matches(result, expect_set, tag=""):
    """Byte-level identity against the py_roaring oracle: serialized stream
    plus the decoded fields."""
    oracle = RoaringBitmap.from_array(np.fromiter(sorted(expect_set),
                                                  np.int64, len(expect_set)))
    assert result.serialize() == RoaringFormatSpec.serialize(oracle), tag
    assert int(result.card()) == len(expect_set), tag


def _check_tri_backend(stack, expr, expect_set, tag=""):
    """fused-pallas == fused-xla == per-op (values, cards, kinds, payload
    arrays) and all byte-identical to py_roaring."""
    per_op = index.execute(stack, expr, backend="xla")
    outs = {"fused-xla": index.execute(stack, expr, backend="xla",
                                       fused=True),
            "fused-pallas": index.execute(stack, expr, backend="pallas",
                                          fused=True)}
    for name, got in outs.items():
        np.testing.assert_array_equal(np.asarray(got.keys),
                                      np.asarray(per_op.keys),
                                      err_msg=f"{tag}/{name}")
        np.testing.assert_array_equal(np.asarray(got.kinds),
                                      np.asarray(per_op.kinds),
                                      err_msg=f"{tag}/{name}")
        np.testing.assert_array_equal(np.asarray(got.cards),
                                      np.asarray(per_op.cards),
                                      err_msg=f"{tag}/{name}")
        np.testing.assert_array_equal(np.asarray(got.payload),
                                      np.asarray(per_op.payload),
                                      err_msg=f"{tag}/{name}")
        _assert_matches(got, expect_set, f"{tag}/{name}")
        c = int(index.execute_card(stack, expr, fused=True,
                                   backend=name.split("-")[1]))
        assert c == len(expect_set), f"{tag}/{name}/card"
    _assert_matches(per_op, expect_set, f"{tag}/per_op")


# ------------------------------------------------------------------ planner
def test_plan_tape_left_fold_slots():
    plan = F.plan_tape(("and", 0, 1, 2, 3))
    # n-ary left fold: 2 slots regardless of width
    assert plan.n_slots == 2
    assert plan.n_loads == 4 and plan.n_ops == 3
    deep = F.plan_tape(("and", 0, ("or", 1, ("andnot", 2, ("and", 3, 4)))))
    assert plan.tape[0] == ("load", 0, 0)
    assert deep.n_slots == 5            # one live slot per nesting level


def test_plan_tape_hash_consed():
    a = F.plan_tape(("or", 0, ("and", 1, 2)))
    b = F.plan_tape(("or", 0, ("and", 1, 2)))
    assert a is b


def test_plan_tape_rejects_malformed():
    with pytest.raises(ValueError):
        F.plan_tape(("nand", 0, 1))
    with pytest.raises(ValueError):
        F.plan_tape(("andnot", 0, 1, 2))
    with pytest.raises(ValueError):
        F.plan_tape(("and",))


def test_plan_stats_model():
    plan = F.plan_tape(("and", 0, 1, 2, 3))
    stats = F.plan_stats(plan, 16)
    assert stats["launches_fused"] == 1
    assert stats["launches_per_op"] == 3
    assert stats["hbm_bytes_fused"] < stats["hbm_bytes_per_op"]


# ---------------------------------------------------- tri-backend identity
def test_all_kinds_tri_backend():
    slabs, vals = _mixed_slabs()
    stack = roaring.stack(slabs, capacity=8)
    expect = ((vals[0] | vals[1]) - (vals[2] & vals[3])) | vals[2]
    expr = index.or_(
        index.andnot(index.or_(index.leaf(0), index.leaf(1)),
                     index.and_(index.leaf(2), index.leaf(3))),
        index.leaf(2))
    _check_tri_backend(stack, expr, expect, "all_kinds")


def test_array_bitmap_boundaries():
    # result cardinalities straddling the 4096 array/bitmap threshold
    for n in (4095, 4096, 4097):
        a = np.arange(2 * n, dtype=np.int64)
        b = np.arange(0, 4 * n, 2, dtype=np.int64)[:n]
        sa = roaring.RoaringSlab.from_values(a, 2, 1 << 15)
        sb = roaring.RoaringSlab.from_values(b, 2, 1 << 15)
        stack = roaring.stack([sa, sb], capacity=2)
        expect = set(a.tolist()) & set(b.tolist())
        assert len(expect) == n
        _check_tri_backend(stack, index.and_(index.leaf(0), index.leaf(1)),
                           expect, f"boundary_{n}")


def test_deep_tree():
    slabs, vals = _mixed_slabs(seed=50)
    extra = [_rand_set(20_000, 8 << 16, 60 + i) for i in range(2)]
    slabs += [roaring.RoaringSlab.from_values(v, 8, 1 << 18) for v in extra]
    vals += [set(v.tolist()) for v in extra]
    stack = roaring.stack(slabs, capacity=8)
    # depth 5: andnot(or(and(or(and(l0,l1),l2),l3),l4),l5)
    expr = index.andnot(
        index.or_(
            index.and_(
                index.or_(
                    index.and_(index.leaf(0), index.leaf(1)),
                    index.leaf(2)),
                index.leaf(3)),
            index.leaf(4)),
        index.leaf(5))
    expect = (((vals[0] & vals[1]) | vals[2]) & vals[3] | vals[4]) - vals[5]
    _check_tri_backend(stack, expr, expect, "deep")


def test_wide_tree_n32():
    rng = np.random.default_rng(77)
    base = np.unique(rng.integers(0, 8 << 16, 50_000))
    keep_sets, slabs = [], []
    for i in range(32):
        keep = base[rng.random(base.size) > 0.02]
        keep_sets.append(set(keep.tolist()))
        slabs.append(roaring.RoaringSlab.from_values(keep, 8, 1 << 18))
    stack = roaring.stack(slabs, capacity=8)
    expect = set.intersection(*keep_sets)
    _check_tri_backend(stack, index.and_(*[index.leaf(i) for i in range(32)]),
                       expect, "wide_and")
    expect = set.union(*keep_sets)
    _check_tri_backend(stack, index.or_(*[index.leaf(i) for i in range(32)]),
                       expect, "wide_or")


def test_andnot_of_or():
    slabs, vals = _mixed_slabs(seed=90)
    stack = roaring.stack(slabs, capacity=8)
    expr = index.andnot(index.or_(index.leaf(0), index.leaf(1)),
                        index.or_(index.leaf(2), index.leaf(3)))
    _check_tri_backend(stack, expr, (vals[0] | vals[1]) - (vals[2] | vals[3]),
                       "andnot_of_or")


def test_slab_leaves_and_dedup():
    slabs, vals = _mixed_slabs(seed=130)
    q = slabs[1]
    # same leaf twice (deduped to one streamed operand) + a slab leaf
    expr = index.and_(index.leaf(0), index.leaf(0), index.leaf(q))
    stack = roaring.stack(slabs[:1] * 2, capacity=8)
    plan, data, _ = index.engine._fused_compile(
        stack, stack.keys[0],
        index.and_(index.leaf(0), index.leaf(0), index.leaf(q)))
    assert plan.n_operands == 2 and data.shape[0] == 2
    got = index.execute(stack, expr, fused=True)
    _assert_matches(got, vals[0] & vals[1], "dedup")


# ------------------------------------------------------------ retrace guard
def test_fused_retrace_once_per_shape():
    kops._fused_tree.clear_cache()
    F.plan_tape.cache_clear()
    for seed in (1, 2, 3):
        slabs = [roaring.RoaringSlab.from_values(
            _rand_set(4000, 4 << 16, seed * 10 + i), 4, 1 << 17)
            for i in range(4)]
        stack = roaring.stack(slabs, capacity=4)
        # fresh Expr objects each loop: equal structure must reuse the plan
        expr = index.andnot(index.or_(index.leaf(0), index.leaf(1)),
                            index.and_(index.leaf(2), index.leaf(3)))
        index.execute(stack, expr, fused=True)
        index.execute_card(stack, expr, fused=True)
    assert F.plan_cache_size() == 1
    assert kops._fused_tree._cache_size() == 1


# --------------------------------------------------------- fault injection
def test_fused_ladder_degrades_bit_identical():
    from repro.runtime.fault_tolerance import FaultPlan, fault_scope

    slabs, vals = _mixed_slabs(seed=170)
    stack = roaring.stack(slabs, capacity=8)
    expr = index.andnot(index.or_(index.leaf(0), index.leaf(1)),
                        index.and_(index.leaf(2), index.leaf(3)))
    good = index.execute(stack, expr, backend="xla")
    index.reset_degradation()
    with fault_scope(FaultPlan(every=1, backend="pallas")):
        degraded = index.execute(stack, expr, backend="pallas", fused=True)
    stats = index.degradation_stats()
    # fused-pallas (1 try + 1 retry) and per-op-pallas all fault; the
    # XLA-ref rung completes the query
    assert stats.fallbacks == 2
    assert stats.dispatch_failures == 3
    assert degraded.serialize() == good.serialize()
    np.testing.assert_array_equal(np.asarray(degraded.payload),
                                  np.asarray(good.payload))
    index.reset_degradation()


def test_fused_xla_rung_failure_propagates():
    from repro.runtime.fault_tolerance import FaultPlan, InjectedFault, \
        fault_scope

    slabs, _ = _mixed_slabs(seed=210)
    stack = roaring.stack(slabs, capacity=8)
    expr = index.and_(index.leaf(0), index.leaf(1))
    index.reset_degradation()
    with fault_scope(FaultPlan(every=1, backend="xla")):
        with pytest.raises(InjectedFault):
            index.execute(stack, expr, backend="xla", fused=True)
    index.reset_degradation()


# --------------------------------------------------- empty-column DMA skip
def test_skip_dead_rows_index_map():
    kinds = jnp.asarray([0, 0, 1, 0, 0, 2, 0, 0], jnp.int32)  # 4 pairs
    imap = K.skip_dead_rows(K._pair_live)
    got = [tuple(int(jnp.asarray(x)) for x in imap(i, kinds))
           for i in range(4)]
    assert got == [(0, 0, 0), (1, 0, 0), (2, 0, 0), (0, 0, 0)]
    both = K.skip_dead_rows(K._pair_both_live)
    meta = jnp.asarray([1, 0, 9, 0, 0, 0,     # a live, b empty -> dead
                        2, 2, 9, 9, 0, 0], jnp.int32)
    got = [tuple(int(jnp.asarray(x)) for x in both(i, meta))
           for i in range(2)]
    assert got == [(0, 0, 0), (1, 0, 0)]


def test_container_op_empty_columns_skip():
    rng = np.random.default_rng(5)
    C = 6
    a = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
    b = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
    kinds = np.full(2 * C, D.KIND_BITMAP, np.int32)
    kinds[2 * 1], kinds[2 * 1 + 1] = 0, 0            # column 1 fully empty
    kinds[2 * 4], kinds[2 * 4 + 1] = 0, 0            # column 4 fully empty
    kinds = jnp.asarray(kinds)
    out_p, card_p = K.container_op_pallas(a, b, kinds, "or", interpret=True)
    out_r, card_r = kref.container_op_ref(a, b, kinds, "or")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(card_p), np.asarray(card_r))
    assert int(card_p[1]) == 0 and int(card_p[4]) == 0


def test_dispatch_empty_columns_skip():
    rng = np.random.default_rng(6)
    C = 5
    a = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
    b = jnp.asarray(rng.integers(0, 1 << 16, (C, 4096)), jnp.uint16)
    ka = [D.KIND_BITMAP, D.KIND_EMPTY, D.KIND_BITMAP, D.KIND_EMPTY,
          D.KIND_BITMAP]
    kb = [D.KIND_BITMAP, D.KIND_EMPTY, D.KIND_EMPTY, D.KIND_BITMAP,
          D.KIND_BITMAP]
    meta = jnp.asarray(np.stack(
        [ka, kb, [4096] * C, [4096] * C, [0] * C, [0] * C],
        axis=1).reshape(-1), jnp.int32)
    hits_p, card_p = K.intersect_dispatch_pallas(a, b, meta, interpret=True)
    hits_r, card_r = kref.intersect_dispatch_ref(a, b, meta)
    np.testing.assert_array_equal(np.asarray(hits_p), np.asarray(hits_r))
    np.testing.assert_array_equal(np.asarray(card_p), np.asarray(card_r))
    for i in (1, 2, 3):
        assert int(card_p[i]) == 0


def test_fused_kernel_inherits_empty_skip():
    """Columns where every operand is empty must produce empty canonical
    rows through the fused Pallas kernel (whose index_map redirects their
    DMA) — identical to the XLA mirror and the per-op path."""
    # operands live only in chunk 0 of a 6-chunk stack: columns 1..5 dead
    slabs, vals = _mixed_slabs(capacity=6, seed=250)
    small = [roaring.RoaringSlab.from_values(
        _rand_set(2000, 1 << 16, 260 + i), 6, 1 << 17) for i in range(3)]
    sets = [set(np.asarray(_rand_set(2000, 1 << 16, 260 + i)).tolist())
            for i in range(3)]
    stack = roaring.stack(small, capacity=6)
    expr = index.or_(index.and_(index.leaf(0), index.leaf(1)),
                     index.leaf(2))
    _check_tri_backend(stack, expr, (sets[0] & sets[1]) | sets[2],
                       "fused_empty_cols")


# ----------------------------------------------------------- ops entry point
def test_fused_tree_entry_backend_scope():
    slabs, vals = _mixed_slabs(seed=300)
    stack = roaring.stack(slabs, capacity=8)
    expr = index.and_(index.leaf(0), index.leaf(1))
    plan, data, meta = index.engine._fused_compile(stack, stack.keys[0],
                                                   expr)
    with kops.backend_scope("xla"):
        bx, cx = kops.fused_tree(data, meta, plan)
    with kops.backend_scope("pallas"):
        bp, cp = kops.fused_tree(data, meta, plan)
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(bp))
    np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))
    assert int(jnp.sum(cx)) == len(vals[0] & vals[1])
