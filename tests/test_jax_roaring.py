"""TPU-native RoaringSlab vs the paper-faithful py_roaring oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import RoaringBitmap
from repro.core import jax_roaring as jr

# NOTE: deliberately no jax_enable_x64 here — a module-level config update
# leaks into every other test module at pytest collection time. jax_roaring
# is int32-safe (universes < 2^31) by design.


def _slab(values, capacity=32, max_elems=1 << 15):
    return jr.from_dense_array(np.asarray(sorted(values), dtype=np.int64),
                               capacity, max_elems)


def _values(slab, max_out=1 << 16):
    idx, valid = jr.to_indices(slab, max_out)
    return np.asarray(idx)[np.asarray(valid)]


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


# ------------------------------------------------------------------ roundtrip
@pytest.mark.parametrize("n,universe", [(10, 1 << 8), (3000, 1 << 18),
                                        (20000, 1 << 20), (9000, 1 << 14)])
def test_roundtrip(n, universe):
    vals = _rand_set(n, universe, seed=n)
    slab = _slab(vals)
    np.testing.assert_array_equal(_values(slab), vals)
    assert int(slab.cardinality) == vals.size


def test_container_kind_rules():
    # 9000 values in one chunk -> bitmap container; 100 -> array container
    dense = _slab(_rand_set(12000, jr.CHUNK_SIZE, 1))
    assert int(dense.kind[0]) == jr.KIND_BITMAP
    sparse = _slab(_rand_set(100, jr.CHUNK_SIZE, 2))
    assert int(sparse.kind[0]) == jr.KIND_ARRAY
    # exactly at threshold stays array (paper: > 4096 converts)
    exact = _slab(np.arange(jr.ARRAY_MAX))
    assert int(exact.kind[0]) == jr.KIND_ARRAY
    over = _slab(np.arange(jr.ARRAY_MAX + 1))
    assert int(over.kind[0]) == jr.KIND_BITMAP


def test_row_bits_array_roundtrip():
    vals = _rand_set(3000, jr.CHUNK_SIZE, 3).astype(np.uint16)
    row = np.zeros(jr.ROW_WORDS, np.uint16)
    row[: vals.size] = vals
    bits = jr.row_array_to_bits(jnp.asarray(row), jnp.int32(vals.size))
    back = jr.row_bits_to_array(bits)
    np.testing.assert_array_equal(np.asarray(back)[: vals.size], vals)
    assert int(jr.row_popcount(bits)) == vals.size


# ------------------------------------------------------------------ membership
def test_contains_and_rank():
    vals = _rand_set(30000, 1 << 20, 4)
    slab = _slab(vals, capacity=32, max_elems=1 << 16)
    probes = np.random.default_rng(0).integers(0, 1 << 20, 500)
    got = np.asarray(jr.contains(slab, jnp.asarray(probes)))
    want = np.isin(probes, vals)
    np.testing.assert_array_equal(got, want)
    s = set(vals.tolist())
    for p in probes[:20].tolist():
        want_rank = sum(1 for v in s if v <= p)
        assert int(jr.rank(slab, jnp.int64(p))) == want_rank


# ------------------------------------------------------------------ set algebra
@pytest.mark.parametrize("n1,n2,universe", [
    (100, 80, 1 << 10),
    (20000, 15000, 1 << 19),     # bitmap x bitmap chunks
    (200, 30000, 1 << 18),       # array x bitmap mixes
])
def test_slab_ops_vs_oracle(n1, n2, universe):
    a = _rand_set(n1, universe, 11)
    b = _rand_set(n2, universe, 22)
    sa, sb = _slab(a, 64), _slab(b, 64)
    ra, rb = RoaringBitmap.from_sorted_unique(a), RoaringBitmap.from_sorted_unique(b)
    np.testing.assert_array_equal(_values(jr.slab_and(sa, sb)), (ra & rb).to_array())
    np.testing.assert_array_equal(_values(jr.slab_or(sa, sb)), (ra | rb).to_array())
    np.testing.assert_array_equal(_values(jr.slab_xor(sa, sb)), (ra ^ rb).to_array())
    np.testing.assert_array_equal(_values(jr.slab_andnot(sa, sb)),
                                  ra.andnot(rb).to_array())
    # cardinality counters maintained through ops (paper S2)
    assert int(jr.slab_and(sa, sb).cardinality) == len(ra & rb)
    assert int(jr.slab_or(sa, sb).cardinality) == len(ra | rb)


def test_union_many_slabs():
    sets = [_rand_set(5000, 1 << 18, 50 + i) for i in range(6)]
    slabs = [_slab(s, 16) for s in sets]
    got = _values(jr.union_many_slabs(slabs, capacity=32))
    want = np.unique(np.concatenate(sets))
    np.testing.assert_array_equal(got, want)


def test_ops_are_jittable():
    a, b = _rand_set(5000, 1 << 18, 1), _rand_set(800, 1 << 18, 2)
    sa, sb = _slab(a, 16), _slab(b, 16)
    f = jax.jit(lambda x, y: jr.slab_and(x, y, capacity=16).cardinality)
    assert int(f(sa, sb)) == len(set(a.tolist()) & set(b.tolist()))


# ------------------------------------------------------------------ properties
@settings(max_examples=25, deadline=None)
@given(st.sets(st.integers(0, (1 << 18) - 1), max_size=200),
       st.sets(st.integers(0, (1 << 18) - 1), max_size=200))
def test_prop_slab_matches_set_algebra(sa, sb):
    xa, xb = _slab(sa, 16, 1 << 10), _slab(sb, 16, 1 << 10)
    assert set(_values(jr.slab_and(xa, xb), 1 << 10).tolist()) == (sa & sb)
    assert set(_values(jr.slab_or(xa, xb), 1 << 11).tolist()) == (sa | sb)
    assert set(_values(jr.slab_andnot(xa, xb), 1 << 10).tolist()) == (sa - sb)
