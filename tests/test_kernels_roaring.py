"""Pallas roaring-container kernels vs pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_roaring as jr
from repro.kernels.roaring import kernel as K
from repro.kernels.roaring import ref as R


def _row_pair(seed, n_a, n_b):
    rng = np.random.default_rng(seed)
    a = np.unique(rng.integers(0, jr.CHUNK_SIZE, n_a))
    b = np.unique(rng.integers(0, jr.CHUNK_SIZE, n_b))
    return a, b


def _bits_row(vals):
    row = np.zeros(jr.ROW_WORDS, np.uint16)
    lo = np.asarray(vals, np.int64)
    np.bitwise_or.at(row, lo >> 4, (np.uint16(1) << (lo & 15)).astype(np.uint16))
    return row


@pytest.mark.parametrize("op", ["and", "or", "xor", "andnot"])
def test_container_op_kernel_vs_ref(op):
    C = 5
    sizes = [(100, 200), (5000, 80), (8000, 9000), (0, 300), (0, 0)]
    a_bits = np.stack([_bits_row(_row_pair(i, *sizes[i])[0]) for i in range(C)])
    b_bits = np.stack([_bits_row(_row_pair(i, *sizes[i])[1]) for i in range(C)])
    kinds = []
    for i, (na, nb) in enumerate(sizes):
        kinds += [0 if na == 0 else (2 if na > 4096 else 1),
                  0 if nb == 0 else (2 if nb > 4096 else 1)]
    kinds = jnp.asarray(kinds, jnp.int32)
    a_bits = jnp.asarray(a_bits)
    b_bits = jnp.asarray(b_bits)
    got_bits, got_card = K.container_op_pallas(a_bits, b_bits, kinds, op,
                                               interpret=True)
    want_bits, want_card = R.container_op_ref(a_bits, b_bits, kinds, op)
    np.testing.assert_array_equal(np.asarray(got_bits), np.asarray(want_bits))
    np.testing.assert_array_equal(np.asarray(got_card), np.asarray(want_card))
    # cross-check against python-set semantics
    for i, (na, nb) in enumerate(sizes):
        va, vb = _row_pair(i, na, nb)
        sa, sb = set(va.tolist()), set(vb.tolist())
        want = {"and": sa & sb, "or": sa | sb, "xor": sa ^ sb,
                "andnot": sa - sb}[op]
        assert int(got_card[i]) == len(want)


@pytest.mark.parametrize("na,nb", [(50, 3000), (3000, 50), (1000, 1000),
                                   (4096, 4096), (1, 4096), (0, 100)])
def test_array_intersect_kernel_vs_ref(na, nb):
    va, vb = _row_pair(na * 7 + nb, max(na, 1), max(nb, 1))
    va, vb = va[:na], vb[:nb]
    def pack(v):
        row = np.full(jr.ROW_WORDS, 0xFFFF, np.uint16)
        row[: v.size] = v
        return row
    a = jnp.asarray(pack(va))[None]
    b = jnp.asarray(pack(vb))[None]
    cards = jnp.asarray([va.size, vb.size], jnp.int32)
    got_hits, got_n = K.array_intersect_pallas(a, b, cards, interpret=True)
    want_hits, want_n = R.array_intersect_ref(a, b, cards)
    np.testing.assert_array_equal(np.asarray(got_hits), np.asarray(want_hits))
    assert int(got_n[0]) == int(want_n[0]) == len(set(va) & set(vb))


def test_container_op_dtype_sweep():
    """uint16 rows are the storage dtype; verify popcount path on u32 too."""
    rng = np.random.default_rng(0)
    w16 = rng.integers(0, 1 << 16, size=(2, jr.ROW_WORDS), dtype=np.uint16)
    kinds = jnp.asarray([2, 2, 2, 2], jnp.int32)
    got, card = K.container_op_pallas(jnp.asarray(w16), jnp.asarray(w16),
                                      kinds, "and", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), w16)
    assert np.array_equal(np.asarray(card),
                          np.bitwise_count(w16).sum(axis=1).astype(np.int32))


def test_ops_wrapper_dispatch():
    from repro.kernels.roaring import container_op
    a = jnp.zeros((2, jr.ROW_WORDS), jnp.uint16)
    b = jnp.ones((2, jr.ROW_WORDS), jnp.uint16)
    kinds = jnp.asarray([1, 2, 1, 2], jnp.int32)
    bits_ref, card_ref = container_op(a, b, kinds, op="or", use_pallas=False)
    bits_pl, card_pl = container_op(a, b, kinds, op="or", interpret=True)
    np.testing.assert_array_equal(np.asarray(bits_ref), np.asarray(bits_pl))
    np.testing.assert_array_equal(np.asarray(card_ref), np.asarray(card_pl))
