"""Graceful degradation under injected faults.

Two planes:

  * the query engine — a ``runtime.fault_tolerance.FaultPlan`` makes the
    Pallas dispatch path raise at chosen launches; ``index.execute`` must
    retry, then degrade to the XLA reference backend and return a
    **bit-identical** result while ``degradation_stats()`` records the
    ladder;
  * the serving engine — a starved KV page pool must requeue requests
    instead of crashing, finish everything once pages free up, and leak
    zero pages (proved by the roaring page-table auditor).
"""

import numpy as np
import pytest

from repro import index
from repro.roaring import RoaringSlab
from repro.runtime import FaultPlan, InjectedFault, fault_scope
from repro.kernels.roaring import ops as kops


@pytest.fixture(autouse=True)
def _reset_stats():
    index.reset_degradation()
    yield
    index.reset_degradation()


def _slabs():
    rng = np.random.default_rng(0)
    out = []
    for _ in range(3):
        vals = np.unique(rng.integers(0, 200000, 5000)).astype(np.uint32)
        out.append(RoaringSlab.from_values(vals, capacity=8, max_elems=8192))
    return out


def _expr(slabs):
    return index.and_(index.leaf(slabs[0]),
                      index.or_(index.leaf(slabs[1]), index.leaf(slabs[2])))


def _arr(slab):
    return slab.to_roaring().to_array()


# =============================================================================
# query-engine ladder
# =============================================================================

def test_execute_degrades_to_xla_bit_identical():
    slabs = _slabs()
    expr = _expr(slabs)
    base = _arr(index.execute(expr, backend="xla"))
    assert index.degradation_stats().fallbacks == 0

    # every pallas dispatch fails -> retry also fails -> degrade to XLA-ref
    with fault_scope(FaultPlan(every=1, backend="pallas")) as plan:
        out = index.execute(expr, backend="pallas", max_retries=1)
    assert np.array_equal(_arr(out), base)
    s = index.degradation_stats()
    assert s.fallbacks == 1
    assert s.retries == 1
    assert s.dispatch_failures == 2          # first try + one retry
    assert plan.dispatches == 2 and plan.failures == 2


def test_execute_retry_recovers_without_fallback():
    slabs = _slabs()
    expr = _expr(slabs)
    base = _arr(index.execute(expr, backend="xla"))
    index.reset_degradation()

    # only the very first dispatch fails: the retry succeeds on pallas
    with fault_scope(FaultPlan(fail_on=frozenset({0}), backend="pallas")):
        out = index.execute(expr, backend="pallas", max_retries=2)
    assert np.array_equal(_arr(out), base)
    s = index.degradation_stats()
    assert s.fallbacks == 0 and s.retries == 1 and s.dispatch_failures == 1


def test_execute_card_runs_same_ladder():
    slabs = _slabs()
    expr = _expr(slabs)
    base = int(index.execute_card(expr, backend="xla"))
    index.reset_degradation()
    with fault_scope(FaultPlan(every=1, backend="pallas")):
        card = int(index.execute_card(expr, backend="pallas", max_retries=0))
    assert card == base
    assert index.degradation_stats().fallbacks == 1


def test_xla_failure_propagates():
    """The bottom rung has nothing to degrade to."""
    slabs = _slabs()
    with fault_scope(FaultPlan(every=1, backend="xla")):
        with pytest.raises(InjectedFault):
            index.execute(_expr(slabs), backend="xla")


def test_value_errors_do_not_degrade():
    """Shape/user errors must propagate, not silently fall back."""
    with pytest.raises(TypeError):
        index.execute(None, None)
    assert index.degradation_stats().fallbacks == 0


def test_fault_plan_scoping_restores_hook():
    plan = FaultPlan(every=1, backend="pallas")
    prev = kops.set_fault_hook(None)
    try:
        with fault_scope(plan):
            pass
        assert kops.set_fault_hook(None) is None    # hook restored
    finally:
        kops.set_fault_hook(prev)


def test_backend_scope_nesting():
    with kops.backend_scope("xla"):
        assert kops.current_backend() == "xla"
        with kops.backend_scope("pallas"):
            assert kops.current_backend() == "pallas"
        assert kops.current_backend() == "xla"
    with pytest.raises(ValueError):
        with kops.backend_scope("tpu-v9"):
            pass


def test_fault_plan_max_failures():
    plan = FaultPlan(every=1, backend="pallas", max_failures=1)
    with pytest.raises(InjectedFault):
        plan.on_dispatch("pallas")
    plan.on_dispatch("pallas")               # cap reached: no more raises
    plan.on_dispatch("xla")                  # other backend: ignored
    assert plan.failures == 1 and plan.dispatches == 2


# =============================================================================
# serving engine under page exhaustion
# =============================================================================

@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("stablelm-1.6b", reduced=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_serve_engine_requeues_under_page_exhaustion(tiny_model):
    """A pool too small for the whole batch: the engine must requeue starved
    requests (not crash), finish ALL of them, and leak zero pages."""
    from repro.serve import Request, ServeEngine
    cfg, params = tiny_model
    # 3 requests x (4 prompt + 6 new) = 10 tokens -> 3 pages each; the
    # 4-page pool fits roughly one sequence at a time
    eng = ServeEngine(cfg, params, max_batch=3, n_pages=4, page_size=4,
                      max_pages_per_seq=4)
    rng = np.random.default_rng(1)
    reqs = [Request(req_id=r,
                    prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=6) for r in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_steps=300)

    assert eng.requeues > 0                  # backpressure actually engaged
    assert not eng.queue and not eng.active
    assert all(r.done and len(r.generated) == 6 for r in reqs)
    report = eng.table.audit()               # zero leaked pages
    assert report.ok, report.summary()
    assert eng.utilization() == 0.0


def test_serve_engine_impossible_request_raises(tiny_model):
    """A single request larger than the entire pool can never fit: the
    engine must surface MemoryError (not requeue-spin forever) and still
    account for every page."""
    from repro.serve import Request, ServeEngine
    cfg, params = tiny_model
    eng = ServeEngine(cfg, params, max_batch=2, n_pages=2, page_size=4,
                      max_pages_per_seq=8)
    rng = np.random.default_rng(2)
    eng.submit(Request(req_id=9,
                       prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=16))
    with pytest.raises(MemoryError):
        eng.run_until_done(max_steps=50)
    assert eng.table.audit().ok


def test_page_table_audit_flags_synthetic_leak():
    """The auditor itself: fabricate a leak / double-alloc and watch it
    report machine-readable violations."""
    from repro.serve import RoaringPageTable
    t = RoaringPageTable(n_pages=8, page_size=4)
    t.alloc(1, 8)                            # pages {0, 1}
    assert t.audit().ok

    # leak: drop a page from the seq list without returning it
    leaked = t.seq_pages[1].pop()
    rep = t.audit()
    assert any(v.code == "page-leak" for v in rep.violations)
    t.seq_pages[1].append(leaked)

    # double-alloc: hand the same page to two sequences
    t.alloc(2, 4)
    t.seq_pages[2][0] = t.seq_pages[1][0]
    rep = t.audit()
    assert not rep.ok
    assert any(v.code in ("page-double-alloc", "page-leak")
               for v in rep.violations)
