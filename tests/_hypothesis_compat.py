"""Import shim: real hypothesis when installed, a minimal fallback otherwise.

The seed image does not ship ``hypothesis``, which used to kill pytest at
collection time. Tests import ``given``/``settings``/``st`` from here; with
hypothesis present they get the real thing, otherwise a tiny deterministic
random-example runner that supports exactly the strategy surface this suite
uses (``st.integers``, ``st.sets``, ``st.lists``). The fallback always runs
a minimal example first (empty sets/lists) so shrunk edge cases stay covered.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    _FALLBACK_CAP = 25          # examples per property without hypothesis

    class _Strategy:
        def __init__(self, gen, minimal):
            self.gen = gen          # rng -> value
            self.minimal = minimal  # () -> smallest value

    class st:  # noqa: N801 - mimics `strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             lambda: min_value)

        @staticmethod
        def sets(elements, min_size=0, max_size=20):
            def gen(rng):
                n = rng.randint(min_size, max_size)
                out = set()
                for _ in range(4 * n):
                    if len(out) >= n:
                        break
                    out.add(elements.gen(rng))
                return out

            def minimal():
                out = set()
                while len(out) < min_size:
                    out.add(elements.minimal() + len(out))
                return out

            return _Strategy(gen, minimal)

        @staticmethod
        def lists(elements, min_size=0, max_size=20):
            def gen(rng):
                n = rng.randint(min_size, max_size)
                return [elements.gen(rng) for _ in range(n)]

            return _Strategy(gen, lambda: [elements.minimal()] * min_size)

    def settings(max_examples=20, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            def runner():
                n = min(getattr(runner, "_max_examples",
                                getattr(fn, "_max_examples", 20)),
                        _FALLBACK_CAP)
                fn(*[s.minimal() for s in strategies])
                rng = random.Random(0xD15BA7C4)
                for _ in range(max(n - 1, 0)):
                    fn(*[s.gen(rng) for s in strategies])

            # deliberately no functools.wraps: pytest must see a zero-arg
            # function, not the strategy parameters (it would read them as
            # fixtures)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
