"""Wide horizontal ops: the tree reduction and the batched query engine.

Covers the PR 3 checklist: union_many / tree-reduce over 3+ slabs with
overlapping and disjoint keys, run-row inputs producing run-row outputs,
bit-identity of the tree reduction vs sequential pairwise folds vs
py_roaring, the expression executor (AND/OR/ANDNOT, card-only, top-k), the
stacked batched-meta dispatch, sharding, and the three migrated consumers.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import index, roaring
from repro.core import RoaringBitmap, union_many
from repro.core import jax_roaring as jr
from repro.core import py_roaring as pr

_KIND_OF = {pr.ArrayContainer: jr.KIND_ARRAY,
            pr.BitmapContainer: jr.KIND_BITMAP,
            pr.RunContainer: jr.KIND_RUN}


def _values(slab, max_out=1 << 17):
    if isinstance(slab, roaring.RoaringSlab):
        idx, valid = slab.to_indices(max_out)
    else:
        idx, valid = jr.to_indices(slab, max_out)
    return np.asarray(idx)[np.asarray(valid)]


def _fields(slab):
    """(keys, kinds, cards) of either the object API or the internal tuple."""
    if isinstance(slab, roaring.RoaringSlab):
        return (np.asarray(slab.keys), np.asarray(slab.kinds),
                np.asarray(slab.cards))
    return np.asarray(slab.keys), np.asarray(slab.kind), np.asarray(slab.card)


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def _rand_ranges(seed, n_ranges, universe, max_len=500):
    r = np.random.default_rng(seed)
    starts = np.sort(r.integers(0, universe, n_ranges))
    lens = r.integers(1, max_len, n_ranges)
    return [(int(s), int(min(s + l, universe)))
            for s, l in zip(starts, lens)]


def _check_canonical(slab, oracle, tag=""):
    """Values, card, kind, and packed payload must all match the oracle."""
    np.testing.assert_array_equal(_values(slab), oracle.to_array(),
                                  err_msg=tag)
    keys, kinds, cards = _fields(slab)
    assert int(cards.sum()) == len(oracle), tag
    assert list(keys[kinds != jr.KIND_EMPTY]) == list(oracle.keys), tag
    rt = (slab.to_roaring() if isinstance(slab, roaring.RoaringSlab)
          else jr.to_roaring(slab))
    for k, c, c2 in zip(oracle.keys, oracle.containers, rt.containers):
        row = int(np.searchsorted(keys, k))
        assert cards[row] == c.cardinality, (tag, k)
        assert kinds[row] == _KIND_OF[type(c)], (tag, k, int(kinds[row]))
        # packed payload bytes, via the kind-preserving reverse bridge
        if isinstance(c, pr.ArrayContainer):
            np.testing.assert_array_equal(c2.arr, c.arr, err_msg=tag)
        elif isinstance(c, pr.BitmapContainer):
            np.testing.assert_array_equal(c2.words, c.words, err_msg=tag)
        else:
            np.testing.assert_array_equal(c2.starts, c.starts, err_msg=tag)
            np.testing.assert_array_equal(c2.lengths, c.lengths, err_msg=tag)


# --------------------------------------------------------------- tree union
def test_tree_union_overlapping_keys():
    sets = [_rand_set(4000, 1 << 18, 100 + i) for i in range(5)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    slabs = [jr.from_dense_array(s, 8, 1 << 15) for s in sets]
    got = jr.union_many_slabs(slabs, capacity=8)
    _check_canonical(got, union_many(rbs), "overlapping")


def test_tree_union_disjoint_keys():
    # each slab occupies its own chunk: the merged key set is the concat
    sets = [np.arange(i << 16, (i << 16) + 300 + 37 * i) for i in range(4)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    slabs = [jr.from_dense_array(s, 2, 1 << 12) for s in sets]
    got = jr.union_many_slabs(slabs, capacity=8)
    _check_canonical(got, union_many(rbs), "disjoint")


@pytest.mark.parametrize("n_slabs", [3, 5, 8])
def test_tree_union_matches_pairwise_fold_and_oracle(n_slabs):
    """Tree reduction == sequential slab_or fold == py_roaring, including
    kinds and packed payloads (the deferred canonicalization must land
    exactly where per-step canonicalization does)."""
    sets = [_rand_set(2000 + 700 * i, 1 << 18, 200 + i)
            for i in range(n_slabs)]
    rbs = [RoaringBitmap.from_sorted_unique(s) for s in sets]
    slabs = [jr.from_dense_array(s, 8, 1 << 15) for s in sets]
    tree = jr.union_many_slabs(slabs, capacity=8)
    fold = slabs[0]
    for s in slabs[1:]:
        fold = jr.slab_or(fold, s, capacity=8)
    oracle = union_many(rbs)
    _check_canonical(tree, oracle, f"tree n={n_slabs}")
    _check_canonical(fold, oracle, f"fold n={n_slabs}")


def test_tree_union_run_rows_in_run_rows_out():
    """Run-row inputs union into run-row outputs: the root canonicalization
    re-detects run shape even though intermediates are word rows."""
    rsets = [RoaringBitmap.from_ranges(_rand_ranges(60 + i, 25, 1 << 18))
             for i in range(4)]
    slabs = [jr.from_roaring(x, 16) for x in rsets]
    for s in slabs:
        assert (np.asarray(s.kind) == jr.KIND_RUN).any()
    got = jr.union_many_slabs(slabs, capacity=16)
    _check_canonical(got, union_many(rsets), "runs")
    assert (np.asarray(got.kind) == jr.KIND_RUN).any()


def test_tree_union_empty_and_single():
    assert int(jr.union_many_slabs([], capacity=4).cardinality) == 0
    s = jr.from_dense_array(np.arange(0, 50000, 2), 4, 1 << 16)
    got = jr.union_many_slabs([s], capacity=4)
    _check_canonical(got, RoaringBitmap.from_sorted_unique(
        np.arange(0, 50000, 2)), "single")


# ------------------------------------------------------------ query engine
def _mixed_stack(seed=0, n=6, cap=8):
    rng = np.random.default_rng(seed)
    sets, slabs = [], []
    for i in range(n):
        if i % 3 == 2:                      # every third operand run-shaped
            rb = RoaringBitmap.from_ranges(
                _rand_ranges(seed + i, 20, 1 << 18))
            sets.append(rb)
            slabs.append(roaring.RoaringSlab.from_roaring(rb, cap))
        else:
            s = np.unique(rng.integers(0, 1 << 18, 3000 + 500 * i))
            sets.append(RoaringBitmap.from_sorted_unique(s))
            slabs.append(roaring.RoaringSlab.from_values(s, cap, 1 << 15))
    return sets, slabs, roaring.stack(slabs, capacity=cap)


def test_engine_wide_union_intersect():
    rbs, _, stack = _mixed_stack()
    _check_canonical(index.wide_union(stack), union_many(rbs), "wide_union")
    want = rbs[0]
    for r in rbs[1:]:
        want = want & r
    _check_canonical(index.wide_intersect(stack), want, "wide_intersect")


def test_engine_expression_tree():
    rbs, _, stack = _mixed_stack(seed=7)
    expr = index.andnot(
        index.and_(index.or_(index.leaf(0), index.leaf(2), index.leaf(4)),
                   index.leaf(1)),
        index.leaf(3))
    want = ((rbs[0] | rbs[2] | rbs[4]) & rbs[1]).andnot(rbs[3])
    _check_canonical(index.execute(stack, expr), want, "expr")
    assert int(index.execute_card(stack, expr)) == len(want)


def test_engine_is_jittable():
    _, _, stack = _mixed_stack(seed=9, n=4)
    expr = index.and_(index.or_(index.leaf(0), index.leaf(1)), index.leaf(2))
    f = jax.jit(lambda st: index.execute_card(st, expr))
    g = lambda st: index.execute_card(st, expr)
    assert int(f(stack)) == int(g(stack))


def test_engine_batched_scores_and_topk():
    rbs, slabs, stack = _mixed_stack(seed=3)
    q = slabs[4]
    scores = np.asarray(index.batched_and_card(stack, q))
    want = [len(r & rbs[4]) for r in rbs]
    assert scores.tolist() == want
    v, i = index.topk_by_card(stack, q, 3)
    assert int(i[0]) == 4 and int(v[0]) == want[4]


def test_engine_sharded_scores():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env)")
    from repro.launch.mesh import make_test_mesh
    rbs, slabs, stack = _mixed_stack(seed=5, n=8)
    mesh = make_test_mesh(2, 1) if jax.device_count() < 4 else \
        make_test_mesh(2, 2)
    got = np.asarray(index.batched_and_card_sharded(
        stack, slabs[1], mesh, axis="data"))
    want = [len(r & rbs[1]) for r in rbs]
    assert got.tolist() == want


# --------------------------------------------------------------- consumers
def test_kv_cache_rebuild_free_slab_matches_host_pool():
    from repro.serve.kv_cache import RoaringPageTable
    pt = RoaringPageTable(n_pages=50_000, page_size=4)
    pt.alloc(1, 4000)
    pt.alloc(2, 800)
    pt.alloc(3, 12)
    pt.release(2)
    rebuilt = pt.rebuild_free_slab()
    host = pt.free_slab()           # kind-preserving bridge of the host pool
    _check_canonical(rebuilt, pt.free, "rebuild_free")
    np.testing.assert_array_equal(np.asarray(rebuilt.kinds),
                                  np.asarray(host.kinds))
    # identical canonical payloads, compared through the portable codec
    assert rebuilt.serialize() == host.serialize()
    # engine wide-union path for the used pool, canonical vs host Alg. 4
    _check_canonical(pt.used_slab(), pt.used_bitmap(), "used_slab")


def test_kv_cache_shared_pages_many():
    from repro.serve.kv_cache import RoaringPageTable
    pt = RoaringPageTable(n_pages=10_000, page_size=4)
    pt.alloc(1, 400)
    pt.alloc(2, 200)
    pt.alloc(3, 100)
    got = pt.shared_pages_many(1, [1, 2, 3, 99])
    want = [pt.shared_pages(1, s) for s in (1, 2, 3, 99)]
    assert got.tolist() == want


def test_mask_union_many_device_matches_host():
    from repro.sparsity.masks import MaskBuilder, local_window_mask
    nb = 8
    pats = [MaskBuilder(local_window_mask(nb, w)) for w in (1, 2, 4)]
    dev = pats[0].union_many(pats[1:])
    host = pats[0].union_many(pats[1:], device=False)
    for r in range(nb):
        np.testing.assert_array_equal(dev.rows[r].to_array(),
                                      host.rows[r].to_array())
        assert [type(c) for c in dev.rows[r].containers] == \
               [type(c) for c in host.rows[r].containers], r


def test_grad_comp_leaf_overlap_many_matches_sequential():
    from repro.grad_comp import (compress_leaf, leaf_overlap,
                                 leaf_overlap_many, leaf_topk_overlap)
    rng = np.random.default_rng(4)
    c0 = compress_leaf(jnp.asarray(rng.normal(size=8192), jnp.float32), 512)
    cs = [compress_leaf(jnp.asarray(rng.normal(size=8192), jnp.float32), 512)
          for _ in range(5)]
    many = np.asarray(leaf_overlap_many(c0, cs))
    seq = [int(leaf_overlap(c0, c)) for c in cs]
    assert many.tolist() == seq
    v, i = leaf_topk_overlap(c0, cs, 2)
    assert int(v[0]) == max(seq) and seq[int(i[0])] == max(seq)


# ------------------------------------------------------ reverse bridge unit
def test_to_roaring_round_trip_all_kinds():
    rb = RoaringBitmap.from_ranges([(0, 70000)])              # run rows
    rb.ior(RoaringBitmap.from_sorted_unique(
        (4 << 16) + _rand_set(200, 1 << 16, 0)))              # array row
    rb.ior(RoaringBitmap.from_sorted_unique(
        (5 << 16) + _rand_set(30000, 1 << 16, 1)))            # bitmap row
    slab = jr.from_roaring(rb, 8)
    back = jr.to_roaring(slab)
    assert back.keys == rb.keys
    np.testing.assert_array_equal(back.to_array(), rb.to_array())
    for c1, c2 in zip(back.containers, rb.containers):
        assert type(c1) is type(c2)
    assert back.size_in_bytes() == rb.size_in_bytes()
