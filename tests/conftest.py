"""Suite-wide configuration.

``REPRO_STRICT_DEPRECATIONS=1`` turns any ``DeprecationWarning`` *raised
from inside* ``repro.*`` modules into an error (the module field of a
warnings filter matches the warning's caller, so test files may still
exercise the deprecated ``slab_*`` shims directly — only ``src/`` callers
fail). CI runs the suite once in this mode so no internal module silently
keeps calling the deprecated tuple-threading API.
"""

import os

import pytest


def pytest_configure(config):
    if os.environ.get("REPRO_STRICT_DEPRECATIONS"):
        config.addinivalue_line(
            "filterwarnings", r"error::DeprecationWarning:repro\.")


def _thunk_runtime_compile_bug() -> bool:
    """jaxlib 0.4.36's CPU thunk runtime segfaults inside backend_compile
    once a few hundred compiled executables are live in one process (this
    suite's compile-heavy dispatch property tests reliably hit it; every
    test passes in isolation — only the accumulation kills the compiler).
    The legacy runtime is no escape: it miscompiles the flash-attn softcap
    path outright. Fixed in later jaxlib releases."""
    try:
        import jaxlib
        major, minor, patch = (int(x) for x in
                               jaxlib.__version__.split(".")[:3])
        return (major, minor, patch) <= (0, 4, 36)
    except Exception:
        return False


_NEEDS_CACHE_SHED = _thunk_runtime_compile_bug()


@pytest.fixture(autouse=True)
def _shed_compiled_programs():
    """On affected jaxlib versions, drop compiled executables after each
    test so the live count stays below the thunk-runtime crash threshold.
    Costs recompiles, so it is version-gated to the buggy runtime only."""
    yield
    if _NEEDS_CACHE_SHED:
        import jax
        jax.clear_caches()
