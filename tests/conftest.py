"""Suite-wide configuration.

``REPRO_STRICT_DEPRECATIONS=1`` turns any ``DeprecationWarning`` *raised
from inside* ``repro.*`` modules into an error (the module field of a
warnings filter matches the warning's caller, so test files may still
exercise the deprecated ``slab_*`` shims directly — only ``src/`` callers
fail). CI runs the suite once in this mode so no internal module silently
keeps calling the deprecated tuple-threading API.
"""

import os


def pytest_configure(config):
    if os.environ.get("REPRO_STRICT_DEPRECATIONS"):
        config.addinivalue_line(
            "filterwarnings", r"error::DeprecationWarning:repro\.")
