"""The telemetry plane: spans, metrics, launch accounting, cost contract.

Covers the PR 9 acceptance surface:

  * span nesting / error marking / thread-local isolation, and the
    no-allocation no-op path when tracing is disabled;
  * the metrics registry (labels, ``total`` cross-label sums, histogram
    buckets, snapshot shape);
  * measured kernel-launch counters == the analytic model
    (``index.launch_model`` / ``fused.plan_stats``) for fused N=4/16 trees;
  * fallback rungs appearing as errored child spans under injected faults,
    with the ladder counters migrated onto the registry;
  * the ``degradation_stats()`` shim: warns, mirrors the registry, and no
    ``src/`` module calls it (AST-proved);
  * ``BitmapStore`` cache stats with eager-ladder fallbacks counted
    separately from cold compiles;
  * the off-by-default overhead guard: telemetry disabled stays within 5%
    of the pre-telemetry query body (median of alternating-order trials,
    the ``api_ab`` methodology).
"""

import ast
import json
import threading
import time
from pathlib import Path

import numpy as np
import pytest

import repro.obs as obs
from repro import index, roaring
from repro.kernels.roaring import ops as kops
from repro.obs import metrics as obs_metrics

ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _clean_telemetry():
    obs.disable()
    obs.reset_metrics()
    obs.reset_traces()
    yield
    obs.disable()
    obs.reset_metrics()
    obs.reset_traces()


# =============================================================================
# tracing
# =============================================================================

def test_span_nesting_and_events():
    obs.enable()
    with obs.span("outer", who="test") as sp:
        assert obs.current_span() is sp
        sp.add_event("tick", n=1)
        with obs.span("inner") as child:
            assert obs.current_span() is child
        time.sleep(0.001)
    trees = obs.span_trees()
    assert len(trees) == 1
    root = trees[0]
    assert root.name == "outer" and root.status == "ok"
    assert root.attrs["who"] == "test"
    assert root.duration_s >= 0.001
    assert [c.name for c in root.children] == ["inner"]
    assert root.children[0].duration_s is not None
    assert [e["name"] for e in root.events] == ["tick"]
    d = root.to_dict()
    json.dumps(d)                           # exportable as-is
    assert d["children"][0]["name"] == "inner"


def test_span_error_status_propagates_exception():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("no")
    (root,) = obs.span_trees()
    assert root.status == "error"
    assert root.attrs["error"] == "ValueError"


def test_disabled_spans_are_shared_noop():
    assert not obs.enabled()
    with obs.span("invisible") as sp:
        sp.set_attr("x", 1)
        sp.add_event("y")
        inner = obs.span("nested").__enter__()
        assert inner is sp                   # the one shared null span
    assert obs.current_span() is None
    assert obs.span_trees() == []


def test_spans_are_thread_local():
    obs.enable()
    seen = {}

    def worker():
        with obs.span("thread-root"):
            seen["inner"] = obs.current_span().name

    with obs.span("main-root"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert obs.current_span().name == "main-root"
    assert seen["inner"] == "thread-root"
    names = sorted(s.name for s in obs.span_trees())
    assert names == ["main-root", "thread-root"]  # two roots, no nesting


# =============================================================================
# metrics registry
# =============================================================================

def test_registry_counters_gauges_labels():
    reg = obs.registry()
    reg.counter("x.events", kind="a").inc()
    reg.counter("x.events", kind="a").inc(2)
    reg.counter("x.events", kind="b").inc()
    reg.gauge("x.depth").set(7)
    assert reg.value("x.events", kind="a") == 3
    assert reg.value("x.events", kind="b") == 1
    assert reg.value("x.events", kind="zzz") == 0
    assert reg.total("x.events") == 4
    assert reg.value("x.depth") == 7
    snap = reg.snapshot()
    assert snap["counters"]["x.events{kind=a}"] == 3
    assert snap["gauges"]["x.depth"] == 7
    reg.remove("x.events")
    assert reg.total("x.events") == 0


def test_histogram_log2_buckets():
    h = obs_metrics.Histogram()
    for v in (0.5, 1, 3, 900):
        h.record(v)
    d = h.to_dict()
    assert d["count"] == 4 and d["min"] == 0.5 and d["max"] == 900
    # 0.5 -> bucket 0, 1 -> 1, 3 -> 2, 900 -> 10
    assert d["buckets"] == {"<2^0": 1, "<2^1": 1, "<2^2": 1, "<2^10": 1}


def test_record_kinds_counts_and_tracer_guard():
    import jax

    obs.enable()
    obs.record_kinds("t.kinds", np.array([0, 2, 2, 1, 3]))
    reg = obs.registry()
    assert reg.value("t.kinds", kind="empty") == 1
    assert reg.value("t.kinds", kind="array") == 1
    assert reg.value("t.kinds", kind="bitmap") == 2
    assert reg.value("t.kinds", kind="run") == 1

    # under jit tracing the kinds are Tracers: must be skipped, not crash
    @jax.jit
    def traced(k):
        obs.record_kinds("t.traced", k)
        return k

    traced(np.array([1, 2]))
    assert reg.total("t.traced") == 0


# =============================================================================
# launch hooks + measured-vs-model accounting
# =============================================================================

def _stack(n, C=2, seed=7):
    rng = np.random.default_rng(seed)
    slabs = [roaring.RoaringSlab.from_values(
        np.unique(rng.integers(0, C << 16, 3000)), C, 1 << 14)
        for _ in range(n)]
    return roaring.stack(slabs, capacity=C)


def test_launch_hook_subscription_and_events():
    events = []
    kops.add_launch_hook(events.append)
    kops.add_launch_hook(events.append)      # idempotent
    try:
        stack = _stack(2)
        index.execute(stack, index.and_(index.leaf(0), index.leaf(1)))
        assert len(events) == 1
        assert events[0] == kops.LaunchEvent("intersect_dispatch", "xla")
    finally:
        kops.remove_launch_hook(events.append)
    before = len(events)
    index.execute(_stack(2), index.and_(index.leaf(0), index.leaf(1)))
    assert len(events) == before             # unsubscribed


def test_launch_counts_match_roofline_model_fused_n4_n16():
    """Acceptance: measured per-column launch counters == the analytic
    model, fused and per-op, for N=4 and N=16 AND trees."""
    from repro.kernels.roaring import fused

    stack = _stack(16)
    for N in (4, 16):
        expr = index.and_(*[index.leaf(i) for i in range(N)])
        r = obs.launch_crosscheck(stack, expr)
        assert r["match"], r
        assert r["fused_measured"] == 1      # whole tree, ONE launch
        assert r["per_op_measured"] == (N - 1).bit_length()
        # the roofline table's logical-combine count is the plan's n_ops
        plan = fused.plan_tape(("and",) + tuple(range(N)))
        assert r["per_op_combines"] == fused.plan_stats(
            plan, 2)["launches_per_op"] == N - 1
    assert not obs.enabled()                 # crosscheck restored the state


def test_launch_model_mixed_trees():
    e = index.or_(index.and_(*[index.leaf(i) for i in range(4)]),
                  index.andnot(index.leaf(4), index.leaf(5)))
    m = index.launch_model(e)
    assert m["n_operands"] == 6
    assert m["fused_launches"] == 1
    # OR/ANDNOT combine in jnp row algebra: only the AND's tree-reduce
    # dispatches (ceil(log2 4) = 2)
    assert m["per_op_dispatches"] == 2
    assert m["per_op_combines"] == 5         # N-1 logical combines


# =============================================================================
# degradation ladder on the registry + fault span trees
# =============================================================================

def test_fallback_rungs_appear_as_errored_child_spans():
    from repro.runtime import FaultPlan, fault_scope

    index.reset_degradation()
    stack = _stack(4)
    expr = index.and_(*[index.leaf(i) for i in range(4)])
    base = index.execute(stack, expr, backend="xla").to_roaring().to_array()

    obs.reset_metrics()                      # drop the baseline's counters
    obs.enable()
    # every pallas dispatch faults: fused rung fails, per-op pallas rung
    # fails, the query completes on the per-op XLA rung
    with fault_scope(FaultPlan(every=1, backend="pallas")):
        with obs.span("query-under-fault"):
            out = index.execute(stack, expr, fused=True, backend="pallas",
                                max_retries=0)
    assert np.array_equal(out.to_roaring().to_array(), base)

    reg = obs.registry()
    assert reg.value("index.fallbacks") == 2
    assert reg.value("index.dispatch_failures") == 2
    assert reg.value("index.rung_taken", kind="per_op", backend="xla") == 1
    assert reg.total("index.rung_taken") == 1

    (root,) = obs.span_trees()
    (exe,) = root.children
    assert exe.name == "index.execute"
    rungs = [c for c in exe.children if c.name == "index.rung"]
    assert [r.attrs["kind"] for r in rungs] == ["fused", "per_op", "per_op"]
    assert [r.status for r in rungs] == ["error", "error", "ok"]
    assert rungs[0].attrs["backend"] == "pallas"
    assert rungs[2].attrs["backend"] == "xla"
    # the winning rung carries the dispatch launch events
    assert {e["name"] for e in rungs[2].events} == {"launch"}


def test_degradation_shim_warns_and_mirrors_registry():
    index.reset_degradation()
    reg = obs.registry()
    reg.counter("index.dispatch_failures").inc(3)
    reg.counter("index.retries").inc(2)
    reg.counter("index.fallbacks").inc(1)
    with pytest.warns(DeprecationWarning, match="degradation_stats"):
        s = index.degradation_stats()
    assert (s.dispatch_failures, s.retries, s.fallbacks) == (3, 2, 1)
    index.reset_degradation()
    with pytest.warns(DeprecationWarning):
        s = index.degradation_stats()
    assert (s.dispatch_failures, s.retries, s.fallbacks) == (0, 0, 0)


def test_no_src_module_calls_degradation_stats():
    """Strict-mode proof: the deprecated accessor has zero call sites in
    ``src/`` (docstrings may mention it; AST calls may not)."""
    offenders = []
    for path in sorted((ROOT / "src").rglob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                f = node.func
                name = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else None)
                if name == "degradation_stats":
                    offenders.append(f"{path}:{node.lineno}")
    assert not offenders, offenders


# =============================================================================
# store cache stats + serve gauges
# =============================================================================

def _tiny_store():
    from repro.store import BitmapStore

    rng = np.random.default_rng(3)
    return BitmapStore.build({"c": rng.integers(0, 3, 400),
                              "v": rng.integers(0, 16, 400)}, bsi=("v",))


def test_store_cache_stats_hits_misses_fallbacks():
    from repro.store import predicate as P

    s = _tiny_store()
    pred = P.and_(P.eq("c", 1), P.range_("v", 2, 9))
    assert s.cache_stats() == {"hits": 0, "misses": 0, "fallbacks": 0,
                               "entries": 0,
                               "keyed_by": "(expr, fused, backend)"}
    base = s.count(pred, fused=True)
    assert s.cache_stats()["misses"] == 1
    s.count(pred, fused=True)
    st = s.cache_stats()
    assert st["hits"] == 1 and st["misses"] == 1 and st["entries"] == 1
    assert st["fallbacks"] == 0

    # poison the cached executor: the fallback must run the eager ladder,
    # count separately from cold compiles, and still answer correctly
    key = ("card", s.compile(pred), True, None)
    assert key in s._query_fns
    s._query_fns[key] = lambda stack: (_ for _ in ()).throw(
        RuntimeError("injected"))
    assert s.count(pred, fused=True) == base
    st = s.cache_stats()
    assert st["fallbacks"] == 1
    assert st["misses"] == 1                 # not conflated with a compile
    assert st["hits"] == 2                   # the poisoned lookup was a hit

    # gauges mirror the stats, labeled per store
    reg = obs.registry()
    sid = str(s._id)
    assert reg.value("store.query_cache.fallbacks", store=sid) == 1
    assert reg.value("store.query_cache.entries", store=sid) == 1


def test_store_query_span_tree_compile_execute_launch():
    """Acceptance: a traced fused ``store.query`` yields the
    compile -> execute -> launch span tree."""
    from repro.store import predicate as P

    s = _tiny_store()
    obs.enable()
    s.query(P.eq("c", 1), fused=True)
    (root,) = obs.span_trees()
    assert root.name == "store.query" and root.attrs["fused"] is True
    names = [c.name for c in root.children]
    assert names == ["store.compile", "store.execute"]
    assert root.children[1].attrs["cache"] == "miss"
    # the jitted call traces the engine exactly once: the launch event sits
    # on the execute subtree (via index.execute -> index.rung)
    def events(sp):
        out = [e["name"] for e in sp.events]
        for c in sp.children:
            out += events(c)
        return out
    assert "launch" in events(root.children[1])
    assert obs.registry().total("roaring.launches", entry="fused_tree") == 1


def test_serve_step_publishes_gauges():
    from repro.serve.engine import ServeEngine

    eng = ServeEngine.__new__(ServeEngine)   # gauge surface only, no model
    eng.queue, eng.active = [1, 2, 3], {}
    eng.slots = [None]
    eng.requeues, eng.steps_run = 1, 5
    eng.table = type("T", (), {"free": [0, 1],
                               "utilization": lambda self: 0.5})()
    obs.enable()
    eng._publish_gauges()
    reg = obs.registry()
    assert reg.value("serve.queue_depth") == 3
    assert reg.value("serve.page_pool.free_pages") == 2
    assert reg.value("serve.page_pool.utilization") == 0.5
    assert reg.value("serve.requeues") == 1
    assert reg.value("serve.steps") == 5


# =============================================================================
# report + cost contract
# =============================================================================

def test_report_collect_render_write(tmp_path):
    obs.enable()
    obs.registry().counter("roaring.launches", entry="fused_tree",
                           backend="xla").inc(4)
    with obs.span("store.query"):
        pass
    path = tmp_path / "telemetry.json"
    rep = obs.write_report(str(path), extra={"sections": {"obs": 1.5}})
    on_disk = json.loads(path.read_text())
    assert on_disk["sections"] == {"obs": 1.5}
    assert on_disk["environment"]["backend"] == rep["environment"]["backend"]
    assert on_disk["metrics"]["counters"][
        "roaring.launches{backend=xla,entry=fused_tree}"] == 4
    assert on_disk["spans"][0]["name"] == "store.query"
    text = obs.render_text(rep)
    assert "kernel launches" in text and "store.query" in text


def test_telemetry_scope_restores_state():
    assert not obs.enabled()
    with obs.telemetry_scope():
        assert obs.enabled()
        with obs.telemetry_scope(on=False):
            assert not obs.enabled()
        assert obs.enabled()
    assert not obs.enabled()


def test_overhead_guard_disabled_query_within_5pct():
    """The cost contract, asserted the ``api_ab`` way: per-trial ratios of
    the pre-telemetry query body vs the instrumented (telemetry-disabled)
    ``query()``, alternating measurement order, median compared — a single
    stalled measurement cannot fake an overhead."""
    import jax

    from repro.store import predicate as P

    s = _tiny_store()
    pred = P.and_(P.eq("c", 1), P.range_("v", 2, 9))
    s.query(pred, fused=True)                # warm: compile + jit once

    def raw():
        expr = s.compile(pred)
        return s._query_fns[(expr, True, None)](s._stack)

    def instrumented():
        return s.query(pred, fused=True)

    def timed(fn, reps=15):
        fn()
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return time.perf_counter() - t0

    us_raw, us_inst = [], []
    for trial in range(7):
        pair = [(us_raw, raw), (us_inst, instrumented)]
        if trial % 2:
            pair.reverse()
        for acc, fn in pair:
            acc.append(timed(fn))
    ratio = float(np.median(np.asarray(us_raw) / np.asarray(us_inst)))
    assert ratio >= 0.95, (ratio, us_raw, us_inst)
