"""Hardened-codec robustness tests: untrusted portable streams.

The contract under test (``repro.roaring.format``): for any byte string,
``RoaringFormatSpec.deserialize`` either returns a bitmap that re-serializes
byte-identically, or raises a ``RoaringFormatError`` subclass carrying
byte-offset context — never a bare numpy/struct error, never a silent wrong
answer. Golden fixtures under ``tests/corpus/`` pin the wire format
byte-for-byte.
"""

import struct
from pathlib import Path

import numpy as np
import pytest

from repro.core import py_roaring as pr
from repro.roaring import (DecodeLimits, RoaringFormatError, RoaringSlab,
                           validate)
from repro.roaring.format import (CookieError, DecodeLimitError,
                                  DescriptiveHeaderError, OffsetHeaderError,
                                  PayloadError, RoaringFormatSpec,
                                  TrailingDataError, TruncatedStreamError)

CORPUS = Path(__file__).parent / "corpus"
FS = RoaringFormatSpec


def rb_of(vals):
    rb = pr.RoaringBitmap.from_array(
        np.asarray(sorted(set(vals)), np.uint64))
    return rb.run_optimize()              # canonical best-of-three kinds


def golden_sets():
    """The exact value sets behind tests/corpus/golden_*.bin (committed
    byte-for-byte; regenerate only on a deliberate format change)."""
    rng = np.random.default_rng(0xC0FFEE)
    out = {}
    out["golden_array"] = list(range(0, 2000, 3))
    out["golden_bitmap"] = sorted(set(rng.integers(0, 65536, 9000).tolist()))
    out["golden_run"] = list(range(100, 5000))
    mixed = []
    mixed += [0x00000 + v for v in range(0, 1200, 2)]
    mixed += sorted(set((0x10000 + rng.integers(0, 65536, 9000)).tolist()))
    mixed += [0x20000 + v for v in range(50, 6000)]
    mixed += [0x30000 + v for v in (1, 5, 9, 400, 60000)]
    out["golden_mixed"] = mixed
    norun = []
    for hi in range(5):
        if hi == 2:
            norun += sorted(
                set((hi << 16 | rng.integers(0, 65536, 8000)).tolist()))
        else:
            norun += [(hi << 16) + int(v)
                      for v in rng.choice(65536, 300, replace=False)]
    out["golden_norun"] = norun
    return out


# =============================================================================
# golden interop fixtures
# =============================================================================

@pytest.mark.parametrize("name", ["golden_array", "golden_bitmap",
                                  "golden_run", "golden_mixed",
                                  "golden_norun"])
def test_golden_byte_exact(name):
    """serialize reproduces the committed fixture byte-for-byte, and
    deserialize + audit accepts it."""
    data = (CORPUS / f"{name}.bin").read_bytes()
    rb = rb_of(golden_sets()[name])
    assert FS.serialize(rb) == data
    back = FS.deserialize(data, check=True)
    assert np.array_equal(back.to_array(), rb.to_array())
    assert FS.serialize(back) == data
    assert validate.audit_bitmap(back, canonical=True).ok
    # trusted fast path agrees with the hardened path on valid input
    trusted = FS._deserialize_trusted(data)
    assert np.array_equal(trusted.to_array(), rb.to_array())
    # device round trip under audit
    slab = RoaringSlab.deserialize(data, check=True)
    assert slab.serialize() == data


# =============================================================================
# truncation at every boundary, every container type
# =============================================================================

@pytest.mark.parametrize("name", ["golden_array", "golden_bitmap",
                                  "golden_run", "golden_mixed",
                                  "golden_norun"])
def test_every_prefix_raises_format_error(name):
    """EVERY proper prefix of a valid stream must raise RoaringFormatError
    (a truncation can never decode silently); dense sweep near the front
    (cookie / run bitset / descriptive header / offset header), strided
    through the payloads, dense at the tail."""
    data = (CORPUS / f"{name}.bin").read_bytes()
    front = range(0, min(64, len(data)))
    mid = range(64, max(64, len(data) - 16), 97)
    tail = range(max(0, len(data) - 16), len(data))
    for ln in list(front) + list(mid) + list(tail):
        with pytest.raises(RoaringFormatError) as ei:
            FS.deserialize(data[:ln])
        # byte-offset context, not a bare numpy ValueError
        assert ei.value.offset is not None
        assert 0 <= ei.value.offset <= len(data)


def test_truncation_offsets_name_the_failing_section():
    """Cutting inside a specific stream section reports an offset inside
    (or at the end of) the bytes we kept."""
    data = (CORPUS / "golden_mixed.bin").read_bytes()
    # run-flag bitset: bytes [4, 5) for 4 containers
    with pytest.raises(TruncatedStreamError) as ei:
        FS.deserialize(data[:4])
    assert ei.value.offset == 4
    # descriptive header for 4 containers: [5, 21)
    with pytest.raises(TruncatedStreamError) as ei:
        FS.deserialize(data[:7])
    assert ei.value.offset == 5
    # offset header (present: n >= 4 with runs): [21, 37)
    with pytest.raises(TruncatedStreamError) as ei:
        FS.deserialize(data[:22])
    assert ei.value.offset == 21
    # mid-payload of the first (array) container
    with pytest.raises(TruncatedStreamError) as ei:
        FS.deserialize(data[:40])
    assert ei.value.container == 0
    assert ei.value.offset == 37


def test_truncation_mid_payload_every_kind():
    """Cut mid-payload in each container type; error carries the container
    index."""
    cases = {
        "golden_array": (16, 0),     # offsets end at 8+4+4=16 (1 container)
        "golden_bitmap": (16, 0),
        "golden_run": (9, 0),        # cookie 4 + bitset 1 + desc 4 (no offs)
    }
    for name, (payload_start, cont) in cases.items():
        data = (CORPUS / f"{name}.bin").read_bytes()
        cut = payload_start + (len(data) - payload_start) // 2
        with pytest.raises(TruncatedStreamError) as ei:
            FS.deserialize(data[:cut])
        assert ei.value.container == cont
        # the reported offset is where the failing payload read started
        # (for runs: the pair block after the u16 run count)
        assert payload_start <= ei.value.offset <= cut


# =============================================================================
# structural lies
# =============================================================================

def _run_stream(pairs, card, key=0):
    """Hand-build a 1-run-container stream (no offset header: n=1 < 4)."""
    return (struct.pack("<I", 12347) + b"\x01"
            + struct.pack("<HH", key, card - 1)
            + struct.pack("<H", len(pairs))
            + b"".join(struct.pack("<HH", s, l) for s, l in pairs))


def test_run_pair_out_of_range():
    with pytest.raises(PayloadError):
        FS.deserialize(_run_stream([(65500, 199)], card=200))


def test_run_pairs_unsorted_or_overlapping():
    with pytest.raises(PayloadError):      # out of order
        FS.deserialize(_run_stream([(100, 9), (0, 9)], card=20))
    with pytest.raises(PayloadError):      # overlapping / adjacent-merged
        FS.deserialize(_run_stream([(0, 9), (5, 9)], card=20))


def test_run_cardinality_lie():
    with pytest.raises(PayloadError):
        FS.deserialize(_run_stream([(0, 9)], card=11))


def test_run_count_zero_or_over_max():
    bad = (struct.pack("<I", 12347) + b"\x01" + struct.pack("<HH", 0, 9)
           + struct.pack("<H", 0))
    with pytest.raises(PayloadError):
        FS.deserialize(bad)
    bad = (struct.pack("<I", 12347) + b"\x01" + struct.pack("<HH", 0, 9)
           + struct.pack("<H", 3000))
    with pytest.raises(PayloadError):
        FS.deserialize(bad)


def test_keys_must_be_sorted_unique():
    # raw from_array (no run_optimize): two array containers, no-run cookie,
    # so the descriptive header sits at byte 8
    rb = pr.RoaringBitmap.from_array(
        np.asarray([1, 5, 9, 0x10000 + 5, 0x10000 + 9], np.uint64))
    data = bytearray(FS.serialize(rb))
    # no-run stream: desc header at 8; swap the two keys (u16 at 8 and 12)
    data[8:10], data[12:14] = data[12:14], data[8:10]
    with pytest.raises(DescriptiveHeaderError):
        FS.deserialize(bytes(data))
    # duplicate keys
    data = bytearray(FS.serialize(rb))
    data[12:14] = data[8:10]
    with pytest.raises(DescriptiveHeaderError):
        FS.deserialize(bytes(data))


def test_offset_header_verified_not_skipped():
    data = bytearray((CORPUS / "golden_norun.bin").read_bytes())
    # first offset entry is at byte 8 + 4*n_desc; n=5 -> 28
    data[28] ^= 0x02
    with pytest.raises(OffsetHeaderError) as ei:
        FS.deserialize(bytes(data))
    assert ei.value.container == 0


def test_bitmap_cardinality_lie():
    data = bytearray((CORPUS / "golden_bitmap.bin").read_bytes())
    data[10] ^= 0xFF                      # card-1 low byte in desc header
    with pytest.raises((PayloadError, OffsetHeaderError)):
        FS.deserialize(bytes(data))


def test_array_values_must_be_sorted():
    data = bytearray((CORPUS / "golden_array.bin").read_bytes())
    # payload starts at 16; swap first two u16 values
    data[16:18], data[18:20] = data[18:20], data[16:18]
    with pytest.raises(PayloadError):
        FS.deserialize(bytes(data))


def test_many_runs_vectorized_path():
    """>= 32 runs takes the numpy fast pass; violations still fall through
    to the Python walk for exact-offset errors."""
    pairs = [(i * 100, 9) for i in range(64)]          # 64 runs of length 10
    data = _run_stream(pairs, card=640)
    rb = FS.deserialize(data)
    assert FS.serialize(rb) == data

    bad = list(pairs)
    bad[40] = (bad[39][0], 9)                          # overlaps run 39
    with pytest.raises(PayloadError) as ei:
        FS.deserialize(_run_stream(bad, card=640))
    # 1-container run stream: payload at 9, pairs at 11, run j at 11 + 4j
    assert ei.value.container == 0 and ei.value.offset == 11 + 4 * 40

    oor = [(i * 100, 9) for i in range(63)] + [(65500, 199)]
    with pytest.raises(PayloadError):                  # 65500+199 > 65535
        FS.deserialize(_run_stream(oor, card=63 * 10 + 200))
    with pytest.raises(PayloadError):                  # cardinality lie
        FS.deserialize(_run_stream(pairs, card=641))


def test_many_arrays_batched_check():
    """> 12 array containers exercise the batched reduceat sortedness pass
    (including its exact-locate fallback on corruption)."""
    rng = np.random.default_rng(3)
    vals = [(hi << 16) + int(v) for hi in range(16)
            for v in rng.choice(65536, 500, replace=False)]
    rb = pr.RoaringBitmap.from_array(np.asarray(sorted(vals), np.uint64))
    data = FS.serialize(rb)
    assert FS.serialize(FS.deserialize(data)) == data

    # cookie+count 8 + desc 4*16 + offsets 4*16 = 136; container 10's
    # payload at 136 + 10*1000; swapping its first two (distinct, sorted)
    # values makes value[1] < value[0]
    buf = bytearray(data)
    p = 136 + 10 * 1000
    buf[p:p + 2], buf[p + 2:p + 4] = buf[p + 2:p + 4], buf[p:p + 2]
    with pytest.raises(PayloadError) as ei:
        FS.deserialize(bytes(buf))
    assert ei.value.container == 10 and ei.value.offset == p + 2


def test_batched_check_catches_full_wraparound_step():
    """Adversarial case for the wraparound diff-sum identity: a corrupted
    step of exactly -65535 (65535 -> 0) makes the per-step term 0, and only
    the segment-sum identity rejects it."""
    vals = [(hi << 16) + v for hi in range(16) for v in (0, 65535)]
    rb = pr.RoaringBitmap.from_array(np.asarray(vals, np.uint64))
    data = FS.serialize(rb)
    assert FS.serialize(FS.deserialize(data)) == data

    buf = bytearray(data)
    p = 136 + 5 * 4                       # container 5 payload: [0, 65535]
    buf[p:p + 2], buf[p + 2:p + 4] = buf[p + 2:p + 4], buf[p:p + 2]
    with pytest.raises(PayloadError) as ei:
        FS.deserialize(bytes(buf))
    assert ei.value.container == 5


def test_trailing_bytes_rejected():
    data = (CORPUS / "golden_array.bin").read_bytes()
    with pytest.raises(TrailingDataError):
        FS.deserialize(data + b"\x00")


def test_bad_cookie():
    with pytest.raises(CookieError):
        FS.deserialize(b"\x99\x99\x00\x00")


def test_empty_run_bitset_rejected():
    """A run-cookie stream whose bitset flags zero runs would re-serialize
    under the no-run cookie — reject it to keep accepted => byte-identical
    round trip."""
    nr = FS.serialize(rb_of(range(0, 100, 2)))
    evil = struct.pack("<I", 12347) + b"\x00" + nr[12:]
    with pytest.raises(CookieError):
        FS.deserialize(evil)


def test_empty_input():
    with pytest.raises(TruncatedStreamError):
        FS.deserialize(b"")


# =============================================================================
# decode limits
# =============================================================================

def test_decode_limits():
    data = (CORPUS / "golden_mixed.bin").read_bytes()   # 4 containers
    with pytest.raises(DecodeLimitError):
        FS.deserialize(data, limits=DecodeLimits(max_containers=3))
    with pytest.raises(DecodeLimitError):
        FS.deserialize(data, limits=DecodeLimits(max_stream_bytes=64))
    # generous limits accept
    FS.deserialize(data, limits=DecodeLimits(max_containers=4))
    with pytest.raises(ValueError):
        DecodeLimits(max_containers=0)


def test_header_claims_more_containers_than_stream_holds():
    """A hostile header count must fail bounds checks, not allocate."""
    evil = struct.pack("<II", 12346, 1 << 16)
    with pytest.raises(RoaringFormatError):
        FS.deserialize(evil)
    with pytest.raises(DecodeLimitError):
        FS.deserialize(evil, limits=DecodeLimits(max_containers=8))


def test_slab_deserialize_capacity_guard():
    data = (CORPUS / "golden_mixed.bin").read_bytes()   # 4 containers
    with pytest.raises(DecodeLimitError):
        RoaringSlab.deserialize(data, capacity=2)
    slab = RoaringSlab.deserialize(data, capacity=8, check=True)
    assert slab.serialize() == data


# =============================================================================
# the invariant auditor
# =============================================================================

def test_audit_clean_structures():
    rb = rb_of(list(range(0, 2000, 3)) + list(range(70000, 80000)))
    assert validate.audit_bitmap(rb, canonical=True).ok
    slab = RoaringSlab.from_roaring(rb, capacity=4, check=True)
    rep = validate.audit_slab(slab, canonical=True)
    assert rep.ok, rep.summary()


def test_audit_catches_card_lie():
    rb = rb_of(np.arange(0, 65536, 2))    # bitmap container
    assert isinstance(rb.containers[0], pr.BitmapContainer)
    rb.containers[0].cardinality = 99     # corrupt the tracked counter
    rep = validate.audit_bitmap(rb)
    assert not rep.ok
    assert any(v.code == "card-mismatch" for v in rep.violations)
    with pytest.raises(validate.InvariantViolation):
        rep.raise_on_violation()


def test_audit_catches_key_disorder():
    rb = rb_of([1, 0x10000 + 1])
    rb.keys = rb.keys[::-1].copy()
    rep = validate.audit_bitmap(rb)
    assert any(v.code == "key-order" for v in rep.violations)


def test_audit_report_is_machine_readable():
    rb = rb_of(np.arange(0, 65536, 2))
    rb.containers[0].cardinality = 42
    rep = validate.audit_bitmap(rb)
    v = rep.violations[0]
    assert isinstance(v.code, str) and isinstance(v.container, int)
    assert isinstance(rep.summary(), str)


# =============================================================================
# regression corpus (streams that previously mattered)
# =============================================================================

def test_regression_corpus_all_rejected():
    files = sorted((CORPUS / "regressions").glob("*.bin"))
    assert files, "regression corpus missing"
    for f in files:
        with pytest.raises(RoaringFormatError):
            FS.deserialize(f.read_bytes())
