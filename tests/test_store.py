"""Differential query-oracle suite for the columnar ``BitmapStore``.

Property under test, for every (records, predicate) pair: compiling the
predicate and executing it through the engine — per-op AND ``fused=True`` —
is **bit-identical** to filtering the raw records row by row with numpy:
same row ids, same cardinality, and the same serialized bytes as the
canonicalized oracle bitmap (so container *kinds* match too, not just
values). Schemas, records, and predicates are generated from seeded
randomness (via the ``_hypothesis_compat`` shim) on top of a fixed
census-like workload shared with ``benchmarks/store_bench.py``.

Also pinned here: the golden store corpus (``tests/corpus/golden_store_*``)
— deterministic builds whose ``save()`` bytes are committed, covering
array / bitmap / run / mixed posting containers and a bit-sliced column.
"""

import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "benchmarks"))
from synth import gen_census_like  # noqa: E402

from repro import store  # noqa: E402
from repro.core import py_roaring as pr  # noqa: E402
from repro.roaring.format import RoaringFormatSpec as FS  # noqa: E402

CORPUS = Path(__file__).parent / "corpus"


# ---------------------------------------------------------------------------
# the numpy row-filter oracle
# ---------------------------------------------------------------------------

def _oracle_mask(records: dict, n_rows: int, pred) -> np.ndarray:
    """Evaluate a store predicate directly over the raw columns."""
    if isinstance(pred, store.Eq):
        return np.asarray(records[pred.col]) == pred.value
    if isinstance(pred, store.In):
        arr = np.asarray(records[pred.col])
        mask = np.zeros(n_rows, bool)
        for v in pred.values:
            mask |= arr == v
        return mask
    if isinstance(pred, store.Range):
        arr = np.asarray(records[pred.col])
        mask = np.ones(n_rows, bool)
        if pred.lo is not None:
            mask &= arr >= pred.lo
        if pred.hi is not None:
            mask &= arr <= pred.hi
        return mask
    if isinstance(pred, store.AndP):
        return np.logical_and.reduce(
            [_oracle_mask(records, n_rows, c) for c in pred.children])
    if isinstance(pred, store.OrP):
        return np.logical_or.reduce(
            [_oracle_mask(records, n_rows, c) for c in pred.children])
    if isinstance(pred, store.NotP):
        return ~_oracle_mask(records, n_rows, pred.child)
    raise TypeError(pred)


def _oracle_rb(records: dict, n_rows: int, pred) -> pr.RoaringBitmap:
    ids = np.nonzero(_oracle_mask(records, n_rows, pred))[0]
    return pr.RoaringBitmap.from_sorted_unique(ids).run_optimize()


def _assert_matches(s: store.BitmapStore, records: dict, pred, *,
                    paths=(False, True), check_count: bool = False) -> int:
    """The differential property for one predicate. ``paths`` picks the
    executor paths (False = per-op, True = fused) — every predicate shape
    gets both somewhere in the suite, but each jitted tree pays a whole-
    tree XLA compile on first use (per-op trees compile ~5x slower than
    the fused tape), so the widest trees check one path each.
    Returns |result|."""
    oracle = _oracle_rb(records, s.n_rows, pred)
    want = oracle.to_array()
    want_bytes = FS.serialize(oracle)
    for fused in paths:
        rb = s.query(pred, fused=fused).to_roaring()
        np.testing.assert_array_equal(rb.to_array(), want)
        assert FS.serialize(rb) == want_bytes, \
            f"non-canonical result for {pred} (fused={fused})"
        if check_count:
            assert s.count(pred, fused=fused) == want.size
    if False in paths:
        np.testing.assert_array_equal(s.query_indices(pred), want)
    return want.size


# ---------------------------------------------------------------------------
# fixed census-like workload (shared generator with the benchmarks)
# ---------------------------------------------------------------------------

def _census_records(n_rows: int = 1500, seed: int = 1) -> dict:
    records = gen_census_like(n_rows, seed)
    # cap the integer columns to 5 / 4 bits: BSI tree *shapes* under test
    # don't depend on magnitude, and every extra bit inflates the whole-
    # tree XLA compile each jitted query pays on first use
    records["int0"] = records["int0"] % 28
    records["int1"] = records["int1"] % 13
    # a string column (region names) so vkind="str" is in the suite
    names = np.asarray(["east", "west", "north", "south"])
    records["region"] = names[np.asarray(records["cat2"]) % 4]
    return records


@pytest.fixture(scope="module")
def census():
    records = _census_records()
    s = store.BitmapStore.build(records, bsi=("int0", "int1"))
    return s, records


def test_census_schema(census):
    s, records = census
    assert s.n_rows == 1500
    c = s.column("cat1")
    assert c.vkind == "int" and list(c.values) == sorted(set(
        np.asarray(records["cat1"]).tolist()))
    assert s.column("region").vkind == "str"
    assert s.column("int0").bits == int(records["int0"].max()).bit_length()
    with pytest.raises(KeyError):
        s.column("nope")


def test_census_eq_in_queries(census):
    s, records = census
    hits = 0
    hits += _assert_matches(s, records, store.eq("cat0", 0),
                            check_count=True)
    hits += _assert_matches(s, records, store.eq("region", "north"))
    hits += _assert_matches(s, records, store.eq("cat3", 999))     # unseen
    hits += _assert_matches(
        s, records, store.in_("cat1", [0, 3, 5, 77]))              # mixed
    hits += _assert_matches(s, records, store.in_("cat2", []))     # empty IN
    hits += _assert_matches(s, records, store.eq("int0", 18),      # BSI eq
                            paths=(True,))
    hits += _assert_matches(
        s, records, store.in_("int0", [0, 12, 400]), paths=(False,))
    assert hits > 0


def test_census_boolean_queries(census):
    s, records = census
    _assert_matches(s, records, store.and_(
        store.eq("cat0", 1), store.eq("cat1", 2)))
    _assert_matches(s, records, store.or_(
        store.eq("cat2", 3), store.eq("cat2", 7), store.eq("region", "east")))
    # NOT over the full row universe: complement of nothing is every row
    assert _assert_matches(
        s, records, store.not_(store.eq("cat0", 999))) == s.n_rows
    # a provably-empty conjunction (a row has one cat0 value)
    assert _assert_matches(s, records, store.and_(
        store.eq("cat0", 0), store.eq("cat0", 1))) == 0
    # nested: (cat0=0 | cat0=1) & !(region="west")
    _assert_matches(s, records, store.and_(
        store.or_(store.eq("cat0", 0), store.eq("cat0", 1)),
        store.not_(store.eq("region", "west"))))


def test_census_range_queries(census):
    s, records = census
    # over an integer *equality* column: OR of stored values in bounds
    _assert_matches(s, records, store.range_("cat3", 10, 20))
    _assert_matches(s, records, store.range_("cat3", lo=25))
    _assert_matches(s, records, store.range_("cat3", hi=-1))       # empty
    # over bit-sliced columns: the O'Neil/Quass slice-comparison tree.
    # one closed range runs BOTH paths; the rest split per-op / fused to
    # bound the per-tree compile bill (every k is a distinct tree shape)
    _assert_matches(s, records, store.range_("int0", 5, 19))
    _assert_matches(s, records, store.range_("int0", lo=21),
                    paths=(False,))
    _assert_matches(s, records, store.range_("int1", hi=7), paths=(True,))
    _assert_matches(s, records, store.not_(store.range_("int1", 3, 9)),
                    paths=(True,))
    _assert_matches(s, records, store.and_(
        store.range_("int0", 4, 22), store.eq("cat0", 1)), paths=(True,))


def test_census_sum(census):
    s, records = census
    assert s.sum_("int0") == int(records["int0"].sum())
    pred = store.eq("cat0", 1)
    mask = _oracle_mask(records, s.n_rows, pred)
    assert s.sum_("int0", pred) == int(records["int0"][mask].sum())
    with pytest.raises(TypeError):
        s.sum_("cat0")


def test_census_save_load_roundtrip(census):
    s, records = census
    data = s.save()
    assert data[:8] == store.STORE_MAGIC
    s2 = store.BitmapStore.load(data, check=True)
    assert s2.save() == data
    assert s2.n_rows == s.n_rows and s2.columns == s.columns
    # slot-exact slabs (slab equality implies query equality, so the
    # reloaded store needs no re-compiled queries of its own)
    for slot in range(s.n_slabs):
        assert FS.serialize(s2.slot_bitmap(slot)) == \
            FS.serialize(s.slot_bitmap(slot)), f"slot {slot} drifted"
    assert s.index_size_in_bytes() == s2.index_size_in_bytes()


def test_schema_type_errors(census):
    s, _ = census
    with pytest.raises(TypeError):
        s.compile(store.range_("region", 0, 1))    # range over strings
    with pytest.raises(TypeError):
        s.compile(store.eq("cat0", "zero"))        # str value, int column
    with pytest.raises(TypeError):
        s.compile(store.eq("region", 3))           # int value, str column
    with pytest.raises(KeyError):
        s.compile(store.eq("nope", 1))
    with pytest.raises(ValueError):
        store.range_("cat0", 5, 1)                 # inverted bounds
    with pytest.raises(ValueError):
        store.range_("cat0")                       # no bounds
    with pytest.raises(TypeError):
        store.not_("cat0")                         # not a predicate


def test_build_input_validation():
    with pytest.raises(ValueError):
        store.BitmapStore.build({})
    with pytest.raises(ValueError):
        store.BitmapStore.build({"a": np.arange(3), "b": np.arange(4)})
    with pytest.raises(ValueError):
        store.BitmapStore.build({"a": np.asarray([-1, 2])}, bsi=("a",))
    with pytest.raises(TypeError):
        store.BitmapStore.build({"a": np.asarray(["x", "y"])}, bsi=("a",))
    with pytest.raises(ValueError):
        store.BitmapStore.build({"a": np.arange(3)}, bsi=("b",))
    with pytest.raises(TypeError):
        store.BitmapStore.build({"a": np.asarray([1.5, 2.5])})


def test_empty_store():
    """Zero rows: every query is empty, including NOT (empty universe)."""
    records = {"a": np.empty(0, np.int64), "b": np.empty(0, np.int64)}
    s = store.BitmapStore.build(records, bsi=("b",))
    for pred in (store.eq("a", 0), store.not_(store.eq("a", 0)),
                 store.range_("b", 0, 5)):
        assert _assert_matches(s, records, pred) == 0
    assert s.sum_("b") == 0
    data = s.save()
    assert store.BitmapStore.load(data).save() == data


def test_high_cardinality_column():
    """>4096 distinct values: more posting slabs than a chunk has array
    slots — the store must not conflate slab count with container limits."""
    n = 4500
    records = {"uid": np.arange(n, dtype=np.int64)}
    s = store.BitmapStore.build(records)
    assert len(s.column("uid").values) == n
    assert s.n_slabs == n + 2
    _assert_matches(s, records, store.eq("uid", 2048))
    _assert_matches(s, records, store.in_("uid", [0, 1, n - 1, n]),
                    paths=(False,))
    _assert_matches(s, records, store.range_("uid", 1000, 1010),
                    paths=(True,))
    data = s.save()
    assert store.BitmapStore.load(data).save() == data


# ---------------------------------------------------------------------------
# randomized schemas / records / predicates (seeded; shim-driven)
# ---------------------------------------------------------------------------

_STR_POOL = ("a", "b", "c", "dd", "e")


def _rand_records(rng: np.random.Generator):
    """A small random schema: 1-2 int equality columns, maybe a string
    column, maybe a narrow BSI column. Returns (records, bsi_names)."""
    n_rows = int(rng.integers(0, 260))
    records = {}
    for i in range(int(rng.integers(1, 3))):
        card = int(rng.integers(1, 9))
        records[f"c{i}"] = rng.integers(0, card, n_rows).astype(np.int64)
    if rng.random() < 0.5:
        records["s"] = np.asarray(_STR_POOL)[
            rng.integers(0, len(_STR_POOL), n_rows)]
    bsi = ()
    if rng.random() < 0.7:
        # 3-bit values: range trees stay a handful of nodes, so the whole-
        # tree compile each fresh shape pays stays in seconds
        records["v"] = rng.integers(0, 8, n_rows).astype(np.int64)
        bsi = ("v",)
    return records, bsi


def _rand_pred(rng: np.random.Generator, records: dict, bsi, depth: int):
    if depth <= 0 or rng.random() < 0.45:
        col = list(records)[int(rng.integers(0, len(records)))]
        arr = np.asarray(records[col])
        if col in bsi or arr.dtype.kind == "i":
            pool = [int(v) for v in
                    (arr[rng.integers(0, arr.size, 3)] if arr.size
                     else rng.integers(0, 9, 3))]
            pool.append(int(rng.integers(-2, 12)))       # maybe unseen
            k = rng.integers(0, 3)
            if k == 0:
                return store.eq(col, pool[int(rng.integers(0, len(pool)))])
            if k == 1:
                return store.in_(col, rng.permutation(pool)[
                    : int(rng.integers(0, 4))].tolist())
            lo, hi = sorted(pool[:2])
            which = rng.integers(0, 3)
            return store.range_(col, None if which == 0 else lo,
                                None if which == 1 else hi)
        v = _STR_POOL[int(rng.integers(0, len(_STR_POOL)))]
        if rng.integers(0, 2):
            return store.eq(col, v)
        return store.in_(col, [v, "zz"])
    k = rng.integers(0, 3)
    if k == 0:
        return store.not_(_rand_pred(rng, records, bsi, depth - 1))
    kids = [_rand_pred(rng, records, bsi, depth - 1)
            for _ in range(int(rng.integers(2, 4)))]
    return store.and_(*kids) if k == 1 else store.or_(*kids)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1 << 30))
def test_prop_random_store_queries(seed):
    """Random schema + records + predicates vs the numpy oracle: fused for
    every predicate (the cheap-compile path), per-op for the first — both
    paths covered with a bounded compile bill."""
    rng = np.random.default_rng(seed)
    records, bsi = _rand_records(rng)
    s = store.BitmapStore.build(records, bsi=bsi)
    for i in range(3):
        pred = _rand_pred(rng, records, bsi, depth=2)
        _assert_matches(s, records, pred,
                        paths=(True, False) if i == 0 else (True,))
    data = s.save()
    assert store.BitmapStore.load(data).save() == data


# ---------------------------------------------------------------------------
# golden store corpus: committed bytes of deterministic builds
# ---------------------------------------------------------------------------

def golden_recipes():
    """name -> (records, bsi) for each committed golden store. Every build
    input is derived from a seeded Generator, so the corpus is reproducible
    bit-for-bit (regenerate via ``python tests/test_store.py``)."""
    out = {}
    rng = np.random.default_rng(0x60_1D)
    # sparse postings -> array containers
    out["array"] = ({"a": rng.integers(0, 50, 3000).astype(np.int64)}, ())
    # two dense random values over 30k rows -> bitmap containers
    out["bitmap"] = ({"d": rng.integers(0, 2, 30000).astype(np.int64)}, ())
    # sorted rows -> every posting is one run -> run containers
    out["run"] = ({"r": np.repeat(np.arange(8, dtype=np.int64), 2500)}, ())
    # all three kinds plus strings in one store
    out["mixed"] = ({
        "a": rng.integers(0, 40, 20000).astype(np.int64),
        "d": rng.integers(0, 3, 20000).astype(np.int64),
        "r": np.repeat(np.arange(4, dtype=np.int64), 5000),
        "s": np.asarray(_STR_POOL)[rng.integers(0, len(_STR_POOL), 20000)],
    }, ())
    # a bit-sliced column (8 bits)
    out["bsi"] = ({"v": rng.integers(0, 200, 5000).astype(np.int64)}, ("v",))
    return out


@pytest.mark.parametrize("name", sorted(golden_recipes()))
def test_golden_store_corpus(name):
    """Committed golden bytes == a fresh deterministic build's ``save()``,
    and load -> save is byte-exact (the durable format is pinned)."""
    records, bsi = golden_recipes()[name]
    path = CORPUS / f"golden_store_{name}.bin"
    assert path.exists(), f"golden corpus missing: {path.name}"
    golden = path.read_bytes()
    s = store.BitmapStore.build(records, bsi=bsi)
    assert s.save() == golden, f"{path.name} drifted from a fresh build"
    assert store.BitmapStore.load(golden, check=True).save() == golden


def test_golden_corpus_kinds():
    """The corpus actually covers all three container kinds."""
    recipes = golden_recipes()
    kinds = set()
    for name in ("array", "bitmap", "run"):
        records, bsi = recipes[name]
        s = store.BitmapStore.build(records, bsi=bsi)
        for rb in (s.slot_bitmap(i) for i in range(2, s.n_slabs)):
            kinds.update(type(c).__name__ for c in rb.containers)
    assert {"ArrayContainer", "BitmapContainer", "RunContainer"} <= kinds


if __name__ == "__main__":
    CORPUS.mkdir(exist_ok=True)
    for name, (records, bsi) in golden_recipes().items():
        path = CORPUS / f"golden_store_{name}.bin"
        path.write_bytes(store.BitmapStore.build(records, bsi=bsi).save())
        print(f"wrote {path} ({path.stat().st_size} bytes)")
