"""Hybrid per-kind dispatch layer vs the py_roaring oracle.

Covers every (kind_a, kind_b) pair class, empty rows, and the
threshold-straddling cardinalities 4095/4096/4097, asserting that the
Pallas-interpret kernel, the XLA reference, and py_roaring agree on data,
card, kind, and key ordering.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import RoaringBitmap
from repro.core import jax_roaring as jr
from repro.core import py_roaring as pr
from repro.kernels.roaring import kernel as K
from repro.kernels.roaring import ref as R

_KIND_OF = {pr.ArrayContainer: jr.KIND_ARRAY,
            pr.BitmapContainer: jr.KIND_BITMAP,
            pr.RunContainer: jr.KIND_RUN}


def _slab(values, capacity=32, max_elems=1 << 16):
    return jr.from_dense_array(np.asarray(sorted(values), dtype=np.int64),
                               capacity, max_elems)


def _values(slab, max_out=1 << 17):
    idx, valid = jr.to_indices(slab, max_out)
    return np.asarray(idx)[np.asarray(valid)]


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def _oracle(vals):
    return RoaringBitmap.from_sorted_unique(np.asarray(sorted(vals), np.int64))


def _check_canonical(slab, oracle):
    """data + card + kind + key order all match the paper-faithful oracle."""
    np.testing.assert_array_equal(_values(slab), oracle.to_array())
    assert int(slab.cardinality) == len(oracle)
    keys = np.asarray(slab.keys)
    kinds = np.asarray(slab.kind)
    cards = np.asarray(slab.card)
    live = kinds != jr.KIND_EMPTY
    # live rows lead, sorted by key; dead rows are sentinel-keyed
    assert np.all(np.diff(keys) >= 0)
    assert np.all(keys[~live] == int(jr.KEY_SENTINEL))
    assert list(keys[live]) == list(oracle.keys)
    # container kind follows the best-of-three runOptimize rule exactly:
    # the slab's choice must equal the oracle's canonical container type
    for k, c in zip(oracle.keys, oracle.containers):
        row = int(np.searchsorted(keys, k))
        assert cards[row] == c.cardinality
        want_kind = _KIND_OF[type(c)]
        assert kinds[row] == want_kind, (k, int(kinds[row]), want_kind)
        # packed payloads are bit-identical to the oracle's
        if want_kind == jr.KIND_ARRAY:
            np.testing.assert_array_equal(
                np.asarray(slab.data[row][: c.cardinality]), c.to_array())
        elif want_kind == jr.KIND_RUN:
            d = np.asarray(slab.data[row]).reshape(-1, 2)
            np.testing.assert_array_equal(d[: c.n_runs, 0],
                                          c.starts.astype(np.uint16))
            np.testing.assert_array_equal(d[: c.n_runs, 1],
                                          c.lengths.astype(np.uint16))


# ------------------------------------------------------------ pair classes
PAIRS = {
    "array_array": (_rand_set(300, 1 << 17, 1), _rand_set(500, 1 << 17, 2)),
    "array_bitmap": (_rand_set(900, 1 << 17, 3), _rand_set(30000, 1 << 17, 4)),
    "bitmap_array": (_rand_set(30000, 1 << 17, 5), _rand_set(900, 1 << 17, 6)),
    "bitmap_bitmap": (_rand_set(40000, 1 << 18, 7), _rand_set(50000, 1 << 18, 8)),
    "empty_rows": (np.asarray([5, 100_000]), np.asarray([200_000])),
    "disjoint_chunks": (_rand_set(2000, 1 << 16, 9),
                        _rand_set(2000, 1 << 16, 10) + (1 << 17)),
}


@pytest.mark.parametrize("name", sorted(PAIRS))
def test_dispatch_ops_all_pair_kinds(name):
    a, b = PAIRS[name]
    sa, sb = _slab(a, 16), _slab(b, 16)
    ra, rb = _oracle(a), _oracle(b)
    _check_canonical(jr.slab_and(sa, sb), ra & rb)
    _check_canonical(jr.slab_or(sa, sb, capacity=24), ra | rb)
    _check_canonical(jr.slab_xor(sa, sb, capacity=24), ra ^ rb)
    _check_canonical(jr.slab_andnot(sa, sb), ra.andnot(rb))
    assert int(jr.slab_and_card(sa, sb)) == len(ra & rb)
    assert int(jr.slab_or_card(sa, sb)) == len(ra | rb)


@pytest.mark.parametrize("ca", [4095, 4096, 4097])
@pytest.mark.parametrize("cb", [4095, 4096, 4097])
def test_threshold_straddling(ca, cb):
    """Pairs whose inputs and outputs straddle the array/bitmap boundary —
    the exact cardinalities where kind selection flips."""
    a = np.arange(ca)
    b = np.arange(cb) + (ca - min(ca, cb) // 2)      # partial overlap
    sa, sb = _slab(a, 4), _slab(b, 4)
    ra, rb = _oracle(a), _oracle(b)
    _check_canonical(jr.slab_and(sa, sb), ra & rb)
    _check_canonical(jr.slab_or(sa, sb), ra | rb)
    _check_canonical(jr.slab_xor(sa, sb), ra ^ rb)
    _check_canonical(jr.slab_andnot(sa, sb), ra.andnot(rb))


def test_or_output_crosses_threshold_down():
    """Two >4096 bitmaps whose AND lands back under 4096 must down-convert
    (lazy canonicalization actually fires). The result here is a single
    contiguous stretch plus one point, so best-of-three picks run."""
    a = np.arange(4097)
    b = np.concatenate([np.arange(100), 4096 + np.arange(3997)])
    sa, sb = _slab(a, 4), _slab(b, 4)
    out = jr.slab_and(sa, sb)
    assert int(out.cardinality) == 101
    assert int(out.kind[0]) == jr.KIND_RUN
    _check_canonical(out, _oracle(a) & _oracle(b))

def test_and_output_lands_as_scattered_array():
    """A scattered sub-4096 bitmap x bitmap AND (no run structure) still
    down-converts to a packed array, not a run row."""
    rng = np.random.default_rng(0)
    a = np.unique(rng.integers(0, 1 << 16, 9000))
    b = np.unique(rng.integers(0, 1 << 16, 9000))
    sa, sb = _slab(a, 4), _slab(b, 4)
    out = jr.slab_and(sa, sb)
    assert int(out.kind[0]) == jr.KIND_ARRAY
    _check_canonical(out, _oracle(a) & _oracle(b))


def test_pallas_interpret_matches_ref_kernel():
    """The @pl.when dispatch kernel and the XLA reference are bit-identical
    on hits and card across a slab holding every pair class."""
    a = np.concatenate([_rand_set(500, 1 << 16, 11),                  # array
                        (1 << 16) + _rand_set(9000, 1 << 16, 12),     # bitmap
                        (3 << 16) + _rand_set(100, 1 << 16, 13)])     # a-only
    b = np.concatenate([_rand_set(7000, 1 << 16, 14),                 # bitmap
                        (1 << 16) + _rand_set(6000, 1 << 16, 15),     # bitmap
                        (2 << 16) + _rand_set(50, 1 << 16, 16)])      # b-only
    sa, sb = _slab(a, 8), _slab(b, 8)
    keys = jr._intersect_keys(sa, sb, 8)
    da, ca, ka = jr._gather_raw(sa, keys)
    db, cb, kb = jr._gather_raw(sb, keys)
    meta = jr._dispatch_meta(ka, kb, ca, cb)
    h_pl, c_pl = K.intersect_dispatch_pallas(da, db, meta, interpret=True)
    h_ref, c_ref = R.intersect_dispatch_ref(da, db, meta)
    np.testing.assert_array_equal(np.asarray(h_pl), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_ref))
    assert int(jnp.sum(c_pl)) == len(_oracle(a) & _oracle(b))


def test_batched_surfaces():
    q = _slab(_rand_set(3000, 1 << 18, 20), 16)
    fleet_vals = [_rand_set(n, 1 << 18, 21 + i)
                  for i, n in enumerate((50, 4000, 30000))]
    fleet = [_slab(v, 16) for v in fleet_vals]
    qs = set(_values(q).tolist())
    cards = jr.slab_and_card_many(q, fleet)
    stacked = jr.slab_and_many(q, fleet)
    for i, v in enumerate(fleet_vals):
        want = qs & set(v.tolist())
        assert int(cards[i]) == len(want)
        one = jr.RoaringSlab(*[x[i] for x in stacked])
        assert set(_values(one).tolist()) == want


def test_jaccard():
    a, b = np.arange(1000), np.arange(500, 2000)
    sa, sb = _slab(a, 4), _slab(b, 4)
    got = float(jr.slab_jaccard(sa, sb))
    assert got == pytest.approx(500 / 2000)
    assert float(jr.slab_jaccard(_slab([], 4), _slab([], 4))) == 0.0


def test_dispatch_matches_legacy_bitmap_domain():
    """The dispatch path and the retained bitmap-domain path are the same
    function extensionally (the A/B benchmark compares apples to apples)."""
    a = _rand_set(20000, 1 << 19, 30)
    b = _rand_set(15000, 1 << 19, 31)
    sa, sb = _slab(a, 32), _slab(b, 32)
    new = jr.slab_and(sa, sb)
    old = jr.slab_and_bitmap_domain(sa, sb)
    np.testing.assert_array_equal(_values(new), _values(old))
    assert int(new.cardinality) == int(old.cardinality)
    new_or = jr.slab_or(sa, sb)
    old_or = jr.slab_or_bitmap_domain(sa, sb)
    np.testing.assert_array_equal(_values(new_or), _values(old_or))


# ------------------------------------------------------------ properties
small_sets = st.sets(st.integers(0, (1 << 18) - 1), max_size=400)


@settings(max_examples=25, deadline=None)
@given(small_sets, small_sets)
def test_prop_dispatch_matches_set_algebra(sa_vals, sb_vals):
    xa, xb = _slab(sa_vals, 16, 1 << 10), _slab(sb_vals, 16, 1 << 10)
    assert set(_values(jr.slab_and(xa, xb)).tolist()) == (sa_vals & sb_vals)
    assert set(_values(jr.slab_or(xa, xb)).tolist()) == (sa_vals | sb_vals)
    assert set(_values(jr.slab_xor(xa, xb)).tolist()) == (sa_vals ^ sb_vals)
    assert set(_values(jr.slab_andnot(xa, xb)).tolist()) == (sa_vals - sb_vals)
    assert int(jr.slab_and_card(xa, xb)) == len(sa_vals & sb_vals)


@settings(max_examples=10, deadline=None)
@given(st.sets(st.integers(0, (1 << 17) - 1), max_size=300))
def test_prop_contains_after_dispatch(vals):
    other = _rand_set(5000, 1 << 17, 42)
    s = jr.slab_or(_slab(vals, 8, 1 << 10), _slab(other, 8))
    probes = np.concatenate([np.asarray(sorted(vals), np.int64)[:50],
                             _rand_set(100, 1 << 17, 43)])
    if probes.size == 0:
        return
    got = np.asarray(jr.contains(s, jnp.asarray(probes)))
    want = np.isin(probes, np.asarray(sorted(set(vals) | set(other.tolist()))))
    np.testing.assert_array_equal(got, want)


def test_contains_full_4096_array_container():
    """Regression: a card-4096 array container (still KIND_ARRAY) needs 13
    binary-search halvings; 12 left a size-1 window unresolved and returned
    false negatives."""
    s = _slab(np.arange(4096), 2, 8192)
    assert int(s.kind[0]) == jr.KIND_ARRAY and int(s.card[0]) == 4096
    probes = jnp.asarray(np.arange(4100))
    got = np.asarray(jr.contains(s, probes))
    np.testing.assert_array_equal(got, np.arange(4100) < 4096)


def test_pallas_aa_dispatch_full_4096_side():
    """Regression: array x array galloping against a full 4096-element side
    must find every hit (12-step search dropped lower-bound hits)."""
    a = np.asarray([1])
    b = np.arange(4096)
    sa, sb = _slab(a, 2, 8192), _slab(b, 2, 8192)
    keys = jr._intersect_keys(sa, sb, 2)
    da, ca, ka = jr._gather_raw(sa, keys)
    db, cb, kb = jr._gather_raw(sb, keys)
    meta = jr._dispatch_meta(ka, kb, ca, cb)
    _, c_pl = K.intersect_dispatch_pallas(da, db, meta, interpret=True)
    _, c_ref = R.intersect_dispatch_ref(da, db, meta)
    assert int(jnp.sum(c_pl)) == 1 == int(jnp.sum(c_ref))
    # both orders, and through the public surface
    assert int(jr.slab_and_card(sa, sb)) == 1
    assert int(jr.slab_and_card(sb, sa)) == 1
    np.testing.assert_array_equal(_values(jr.slab_and(sb, sa)), [1])
