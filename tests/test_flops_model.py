"""Validate the analytic FLOP model against XLA cost analysis.

Two parts:
 1. Demonstrate WHY the analytic model exists: cost_analysis counts a scan
    body once regardless of trip count.
 2. Cross-validate: on a small *unrolled* model (python loop over layers, no
    flash scans), the HLO FLOPs are complete — the analytic model must agree
    within tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import flops as F
from repro.models import transformer as T
from repro.models import attention as attn_mod, common, mlp as mlp_mod
from repro.models.config import ModelConfig


def _flops(compiled) -> float:
    """cost_analysis() returns a dict or a one-element list of dicts
    depending on the jax version/executable — normalize."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca["flops"]


def test_cost_analysis_ignores_scan_trip_count():
    def make(n):
        def g(x):
            y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=n)
            return y
        sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        return _flops(jax.jit(g).lower(sds).compile())

    # body counted once regardless of trip count (modulo loop bookkeeping)
    assert make(16) < 1.01 * make(1)     # the documented XLA limitation


def _unrolled_forward(params, tokens, cfg):
    """Layer loop in python (no scan) - complete HLO FLOP accounting."""
    x = common.embed(params["embed"], tokens).astype(jnp.float32)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kinds = cfg.block_kinds()
    for sb in range(cfg.n_superblocks):
        layer_params = jax.tree.map(lambda a: a[sb], params["blocks"])
        for j, kind in enumerate(kinds):
            x, _ = T._apply_sublayer(layer_params[j], x, kind, cfg,
                                     positions, None)
    x = common.rms_norm(params["final_norm"], x)
    return common.unembed(params["embed"], x)


@pytest.mark.parametrize("pattern,nl,extra", [
    ("dense", 4, {}),
    ("moe", 2, dict(n_experts=4, top_k=2)),
])
def test_analytic_flops_match_unrolled_hlo(pattern, nl, extra):
    cfg = ModelConfig(
        name="probe", n_layers=nl, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, layer_pattern=pattern,
        param_dtype="float32", compute_dtype="float32", **extra)
    B, S = 2, 256
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    compiled = jax.jit(
        lambda t: _unrolled_forward(params, t, cfg)).lower(tokens).compile()
    hlo_flops = _flops(compiled)

    fc = F.cell_flops(cfg, kind="prefill", seq_len=S, global_batch=B)
    ratio = fc.total / hlo_flops
    # matmul-dominated agreement; elementwise ops are approximated
    assert 0.7 < ratio < 1.4, (fc.total, hlo_flops, ratio)


def test_model_flops_reference_scaling():
    cfg = ModelConfig(name="p", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, d_ff=512, vocab=512)
    t = F.model_flops_reference(cfg, kind="train", seq_len=64, global_batch=2)
    p = F.model_flops_reference(cfg, kind="prefill", seq_len=64, global_batch=2)
    d = F.model_flops_reference(cfg, kind="decode", seq_len=64, global_batch=2)
    assert t == 3 * p                      # train = 3x forward
    assert abs(p / d - 64) < 1e-6          # prefill processes S tokens/seq
