"""Differential byte-mutation fuzzing of the store container format.

The PR-6 fuzz property, lifted to whole ``BitmapStore`` streams: for EVERY
input byte string, ``BitmapStore.load``

  * either returns a store or raises a typed ``RoaringFormatError``
    subclass (``StoreFormatError`` for container-level violations, the
    codec's own classes for slab-blob violations) — never a bare
    struct/json/numpy error, never unbounded allocation;
  * when it returns, ``save()`` is **byte-identical** to the input (the
    stream was genuinely canonical) and the slot bookkeeping is coherent.

Mutators: truncation, random bitflips, splices between store streams,
header lies (magic / metadata length / leading JSON bytes), metadata digit
lies (canonical-JSON-preserving value changes: shrunken ``n_rows``,
reordered eq values, inflated bit widths — the lies a wire attacker can
tell without breaking JSON), trailing garbage, and random blobs. Seeded
``np.random.Generator`` loop, ``REPRO_FUZZ_EXAMPLES``-scalable, like
``test_fuzz_format.py``.
"""

import os
import struct
from pathlib import Path

import numpy as np

from repro import store
from repro.roaring import DecodeLimits, RoaringFormatError

CORPUS = Path(__file__).parent / "corpus"

N_EXAMPLES = max(300, int(os.environ.get("REPRO_FUZZ_EXAMPLES", "300")))
LIMITS = DecodeLimits(max_containers=1 << 12, max_stream_bytes=1 << 22)


def _seed_streams():
    """Valid store streams covering eq/str/bsi columns, all container
    kinds, and the empty store."""
    rng = np.random.default_rng(0x57_0E)
    stores = [
        store.BitmapStore.build(
            {"a": rng.integers(0, 5, 400).astype(np.int64)}),
        store.BitmapStore.build({
            "r": np.repeat(np.arange(3, dtype=np.int64), 120),
            "s": np.asarray(["x", "y"])[rng.integers(0, 2, 360)],
        }),
        store.BitmapStore.build(
            {"v": rng.integers(0, 50, 300).astype(np.int64)}, bsi=("v",)),
        store.BitmapStore.build({"e": np.empty(0, np.int64)}),
    ]
    return [s.save() for s in stores]


def _mutate(data: bytes, rng: np.random.Generator, pool) -> bytes:
    buf = bytearray(data)
    kind = rng.integers(0, 7)
    if kind == 0 and len(buf) > 0:                     # truncate
        buf = buf[: rng.integers(0, len(buf))]
    elif kind == 1 and len(buf) > 0:                   # bitflips
        for _ in range(int(rng.integers(1, 8))):
            i = int(rng.integers(0, len(buf)))
            buf[i] ^= 1 << int(rng.integers(0, 8))
    elif kind == 2:                                    # splice two streams
        other = pool[int(rng.integers(0, len(pool)))]
        if len(buf) and len(other):
            buf = buf[: int(rng.integers(0, len(buf)))] + \
                bytearray(other[int(rng.integers(0, len(other))):])
    elif kind == 3 and len(buf) >= 13:                 # header lie
        i = int(rng.integers(0, 13))                   # magic + meta_len + {
        buf[i] = int(rng.integers(0, 256))
    elif kind == 4 and len(buf) >= 16:                 # metadata digit lie
        (meta_len,) = struct.unpack_from("<I", bytes(buf), 8)
        end = min(12 + meta_len, len(buf))
        digits = [i for i in range(12, end)
                  if 0x30 <= buf[i] <= 0x39]
        if digits:
            i = digits[int(rng.integers(0, len(digits)))]
            buf[i] = 0x30 + int(rng.integers(0, 10))
    elif kind == 5:                                    # trailing garbage
        buf += bytes(rng.integers(0, 256, int(rng.integers(1, 9)),
                                  dtype=np.uint8))
    else:                                              # random blob
        buf = bytearray(bytes(rng.integers(
            0, 256, int(rng.integers(0, 80)), dtype=np.uint8)))
    return bytes(buf)


def _check_one(data: bytes) -> str:
    """The store fuzz property for a single input."""
    try:
        s = store.BitmapStore.load(data, limits=LIMITS)
    except RoaringFormatError:
        return "rejected"                   # typed rejection: always fine
    # accepted: canonical (byte-identical re-save) and internally coherent
    assert s.save() == data, "accepted store did not re-save identically"
    assert s.n_slabs == 2 + sum(c.n_slabs for c in s.columns)
    assert len(s.slot_bitmap(store.UNIVERSE_SLOT)) == s.n_rows
    assert len(s.slot_bitmap(store.EMPTY_SLOT)) == 0
    for c in s.columns:
        for i in range(c.n_slabs):
            rb = s.slot_bitmap(c.base_slot + i)
            arr = rb.to_array()
            assert arr.size == 0 or int(arr[-1]) < s.n_rows
    return "accepted"


def test_fuzz_mutated_store_streams_never_crash():
    seeds = _seed_streams()
    rng = np.random.default_rng(0xF_57_02)
    outcomes = {"accepted": 0, "rejected": 0}
    for i in range(N_EXAMPLES):
        data = _mutate(seeds[i % len(seeds)], rng, seeds)
        if rng.integers(0, 4) == 0:         # stack a second mutation
            data = _mutate(data, rng, seeds)
        outcomes[_check_one(data)] += 1
    assert outcomes["rejected"] >= 50, outcomes
    # digit lies can land on a digit's current value, leaving the stream
    # intact — accepts happen; the seeds test pins the accept path anyway
    assert outcomes["accepted"] >= 0


def test_fuzz_pure_garbage_store():
    rng = np.random.default_rng(0xBAD_57)
    for _ in range(150):
        blob = bytes(rng.integers(0, 256, int(rng.integers(0, 128)),
                                  dtype=np.uint8))
        assert _check_one(blob) in ("accepted", "rejected")


def test_fuzz_valid_store_streams_accepted():
    for data in _seed_streams():
        assert _check_one(data) == "accepted"


def test_golden_store_corpus_replayed_through_fuzz_property():
    """Every committed golden store satisfies the fuzz property (and they
    are all accepts — the durable bytes stay canonical)."""
    files = sorted(CORPUS.glob("golden_store_*.bin"))
    assert files, "golden store corpus missing"
    for f in files:
        assert _check_one(f.read_bytes()) == "accepted", f.name


def test_allocation_bomb_metadata_rejected():
    """Canonical metadata declaring a near-2^32 row universe (or millions
    of posting values) must be rejected before any stack materializes."""
    meta = (b'{"columns":[{"bits":64,"kind":"bsi","name":"v"}],'
            b'"n_rows":4294967296,"version":1}')
    data = store.STORE_MAGIC + struct.pack("<I", len(meta)) + meta
    try:
        store.BitmapStore.load(data, limits=LIMITS)
        raise AssertionError("allocation-bomb metadata was accepted")
    except store.StoreFormatError as e:
        assert "cell" in str(e)


def test_non_canonical_metadata_rejected():
    """Same JSON value, different bytes (a space) -> typed rejection; the
    accept set is exactly the canonical encoders' output."""
    good = store.BitmapStore.build({"a": np.zeros(4, np.int64)}).save()
    (meta_len,) = struct.unpack_from("<I", good, 8)
    meta = good[12:12 + meta_len].replace(b'"version":1', b'"version": 1')
    bad = good[:8] + struct.pack("<I", len(meta)) + meta \
        + good[12 + meta_len:]
    try:
        store.BitmapStore.load(bad, limits=LIMITS)
        raise AssertionError("non-canonical metadata was accepted")
    except store.StoreFormatError:
        pass
