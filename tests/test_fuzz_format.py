"""Differential byte-mutation fuzzing of the hardened portable codec.

Property under test, for EVERY input byte string:

  * ``RoaringFormatSpec.deserialize`` either returns a bitmap or raises a
    ``RoaringFormatError`` subclass — no bare numpy/struct/overflow errors,
    no hangs, no unbounded allocation;
  * when it returns, the result re-serializes **byte-identically** (the
    stream was genuinely canonical) and agrees with the ``py_roaring``
    oracle: decoding, pushing through the device slab path, and coming back
    yields the exact same value set;
  * the structural auditor finds nothing wrong with any accepted decode.

Mutators: truncation, random byte flips, splices between streams, targeted
header lies (cookie / key / cardinality / offset / run-count fields), and
trailing garbage. The loop is a seeded ``np.random.Generator`` (hypothesis
is not in the image; the shim in ``_hypothesis_compat`` caps examples far
below the required volume), so every run covers the same >= 500 mutated
streams. ``REPRO_FUZZ_EXAMPLES`` scales the volume up for soak runs.
"""

import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import py_roaring as pr
from repro.roaring import DecodeLimits, RoaringFormatError, RoaringSlab, validate
from repro.roaring.format import RoaringFormatSpec as FS

CORPUS = Path(__file__).parent / "corpus"

# >= 500 mutated streams per acceptance criteria; env-scalable for soak runs
N_EXAMPLES = max(500, int(os.environ.get("REPRO_FUZZ_EXAMPLES", "500")))
LIMITS = DecodeLimits(max_containers=1 << 12, max_stream_bytes=1 << 22)


def _seed_streams():
    """Valid streams covering all container kinds and header shapes."""
    rng = np.random.default_rng(0x5EED)
    sets = [
        [],                                           # empty bitmap
        [0],
        list(range(0, 2000, 3)),                      # array
        sorted(set(rng.integers(0, 65536, 9000).tolist())),   # bitmap
        list(range(100, 5000)),                       # run
        list(range(0, 66)) + [100, 200, 300],         # short run + tail
        # 4 mixed containers -> run cookie WITH offset header
        ([v for v in range(0, 1200, 2)]
         + sorted(set((0x10000 + rng.integers(0, 65536, 9000)).tolist()))
         + [0x20000 + v for v in range(50, 6000)]
         + [0x30000 + v for v in (1, 5, 9)]),
        # 5 containers no runs -> no-run cookie + offsets
        [(hi << 16) + int(v) for hi in range(5)
         for v in rng.choice(65536, 200, replace=False)],
    ]
    out = []
    for vals in sets:
        rb = pr.RoaringBitmap.from_array(
            np.asarray(sorted(set(vals)), np.uint64)).run_optimize()
        out.append(FS.serialize(rb))
    return out


def _mutate(data: bytes, rng: np.random.Generator, pool) -> bytes:
    """One mutation step: truncate / bitflip / splice / header-lie /
    trailing garbage (occasionally stacked)."""
    buf = bytearray(data)
    kind = rng.integers(0, 6)
    if kind == 0 and len(buf) > 0:                     # truncate
        buf = buf[: rng.integers(0, len(buf))]
    elif kind == 1 and len(buf) > 0:                   # bitflips
        for _ in range(int(rng.integers(1, 8))):
            i = int(rng.integers(0, len(buf)))
            buf[i] ^= 1 << int(rng.integers(0, 8))
    elif kind == 2:                                    # splice two streams
        other = pool[int(rng.integers(0, len(pool)))]
        if len(buf) and len(other):
            cut_a = int(rng.integers(0, len(buf)))
            cut_b = int(rng.integers(0, len(other)))
            buf = buf[:cut_a] + bytearray(other[cut_b:])
    elif kind == 3 and len(buf) >= 16:                 # header-field lie
        i = int(rng.integers(0, min(64, len(buf))))    # cookie/desc/offsets
        buf[i] = int(rng.integers(0, 256))
    elif kind == 4:                                    # trailing garbage
        buf += bytes(rng.integers(0, 256, int(rng.integers(1, 9)),
                                  dtype=np.uint8))
    else:                                              # random byte blob
        buf = bytearray(bytes(rng.integers(
            0, 256, int(rng.integers(0, 64)), dtype=np.uint8)))
    return bytes(buf)


def _check_one(data: bytes) -> str:
    """The fuzz property for a single input. Returns the outcome tag."""
    try:
        rb = FS.deserialize(data, limits=LIMITS)
    except RoaringFormatError:
        return "rejected"                   # typed rejection: always fine
    # accepted: must be canonical — byte-identical round trip...
    again = FS.serialize(rb)
    assert again == data, "accepted stream did not re-serialize identically"
    # ...structurally clean...
    rep = validate.audit_bitmap(rb)
    assert rep.ok, rep.summary()
    # ...and bit-identical through the device slab path (differential)
    vals = rb.to_array()
    slab = RoaringSlab.from_roaring(rb, capacity=max(1, len(rb.keys)))
    assert np.array_equal(slab.to_roaring().to_array(), vals)
    return "accepted"


def test_fuzz_mutated_streams_never_crash():
    """>= N_EXAMPLES mutated streams: every outcome is a typed rejection or
    a verified bit-identical accept — zero uncaught exceptions."""
    seeds = _seed_streams()
    rng = np.random.default_rng(0xF0220)
    outcomes = {"accepted": 0, "rejected": 0}
    for i in range(N_EXAMPLES):
        base = seeds[i % len(seeds)]
        data = _mutate(base, rng, seeds)
        if rng.integers(0, 4) == 0:         # stack a second mutation
            data = _mutate(data, rng, seeds)
        outcomes[_check_one(data)] += 1
    # sanity on coverage: the mutator must exercise both outcomes (random
    # mutation rarely stays canonical, so accepts are scarce by nature —
    # the unmutated-seed test below pins the accept path exhaustively)
    assert outcomes["rejected"] >= 50, outcomes
    assert outcomes["accepted"] >= 1, outcomes


def test_fuzz_pure_garbage():
    """Purely random blobs (no valid scaffold) are all rejected cleanly."""
    rng = np.random.default_rng(0xBAD)
    for _ in range(200):
        n = int(rng.integers(0, 128))
        blob = bytes(rng.integers(0, 256, n, dtype=np.uint8))
        try:
            rb = FS.deserialize(blob, limits=LIMITS)
            assert FS.serialize(rb) == blob
        except RoaringFormatError:
            pass


def test_fuzz_valid_streams_always_accepted():
    """The mutator scaffolds themselves (unmutated) round-trip."""
    for data in _seed_streams():
        assert _check_one(data) == "accepted"


def test_regression_corpus_replayed_through_fuzz_property():
    """Every committed regression stream satisfies the fuzz property (they
    are all rejections today; the property, not the outcome, is pinned)."""
    files = sorted((CORPUS / "regressions").glob("*.bin"))
    assert files, "regression corpus missing"
    for f in files:
        _check_one(f.read_bytes())
