"""Format-level tests for the WAH / Concise / BitSet baselines."""

import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
from synth import gen_census_like

from repro.baselines import BitSet, ConciseBitmap, WahBitmap
from repro.baselines._groups import (groups_to_indices, indices_to_groups)
from repro.baselines.concise import decode_groups as concise_decode
from repro.baselines.concise import encode_groups as concise_encode
from repro.baselines.wah import decode_groups as wah_decode
from repro.baselines.wah import encode_groups as wah_encode


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def test_group_stream_roundtrip():
    idx = _rand_set(1000, 1 << 20, 0)
    np.testing.assert_array_equal(groups_to_indices(indices_to_groups(idx)), idx)


@pytest.mark.parametrize("codec_enc,codec_dec", [
    (wah_encode, wah_decode), (concise_encode, concise_decode)])
def test_codec_roundtrip_random(codec_enc, codec_dec):
    for seed in range(5):
        idx = _rand_set(2000, 1 << 18, seed)
        g = indices_to_groups(idx)
        got = codec_dec(codec_enc(g))
        np.testing.assert_array_equal(got, g)


def test_codec_roundtrip_runs():
    # long homogeneous runs of zeros and ones exercise fill splitting
    idx = np.concatenate([
        np.arange(0, 31 * 40),                 # ones run
        np.asarray([31 * 50000 + 3]),          # long zero gap
        np.arange(31 * 50010, 31 * 50200),     # another ones run
    ]).astype(np.int64)
    for cls in (WahBitmap, ConciseBitmap):
        b = cls.from_sorted_unique(idx)
        np.testing.assert_array_equal(b.to_array(), idx)


def test_wah_worst_case_size_vs_concise():
    """Paper S1: on {0, 62, 124, ...} WAH needs 64 bits/int, Concise 32."""
    idx = np.arange(0, 62 * 10000, 62, dtype=np.int64)
    wah = WahBitmap.from_sorted_unique(idx)
    con = ConciseBitmap.from_sorted_unique(idx)
    wah_bits = wah.size_in_bytes() * 8 / idx.size
    con_bits = con.size_in_bytes() * 8 / idx.size
    assert 63.5 <= wah_bits <= 64.5
    assert 31.5 <= con_bits <= 32.5
    # and Roaring halves Concise again (~16 bits/int), paper S1
    from repro.core import RoaringBitmap
    roar = RoaringBitmap.from_sorted_unique(idx)
    assert roar.size_in_bytes() * 8 / idx.size < 17


@pytest.mark.parametrize("cls", [WahBitmap, ConciseBitmap, BitSet])
def test_ops_vs_sets(cls):
    a = _rand_set(30000, 1 << 20, 1)
    b = _rand_set(1000, 1 << 20, 2)
    ba, bb = cls.from_sorted_unique(a), cls.from_sorted_unique(b)
    sa, sb = set(a.tolist()), set(b.tolist())
    np.testing.assert_array_equal(ba.and_(bb).to_array(), sorted(sa & sb))
    np.testing.assert_array_equal(ba.or_(bb).to_array(), sorted(sa | sb))


def test_wah_streaming_matches_expanded():
    a = _rand_set(5000, 1 << 18, 3)
    b = _rand_set(7000, 1 << 18, 4)
    wa, wb = WahBitmap.from_sorted_unique(a), WahBitmap.from_sorted_unique(b)
    got_and, touched = wa.and_streaming(wb)
    np.testing.assert_array_equal(got_and.to_array(), wa.and_(wb).to_array())
    assert touched > 0
    got_or, _ = wa.or_streaming(wb)
    np.testing.assert_array_equal(got_or.to_array(), wa.or_(wb).to_array())


@pytest.mark.parametrize("cls", [WahBitmap, ConciseBitmap, BitSet])
def test_append_and_remove(cls):
    vals = sorted(set(np.random.default_rng(5).integers(0, 200000, 3000).tolist()))
    b = cls.from_array(vals)
    model = set(vals)
    x = max(model)
    for step in range(50):
        x += 1 + (step * 37) % 400
        b.append(x)
        model.add(x)
    np.testing.assert_array_equal(b.to_array(), sorted(model))
    removals = list(model)[::97]
    for x in removals:
        b.remove(x)
        model.discard(x)
    np.testing.assert_array_equal(b.to_array(), sorted(model))


@settings(max_examples=40, deadline=None)
@given(st.sets(st.integers(0, 1 << 16), max_size=400),
       st.sets(st.integers(0, 1 << 16), max_size=400))
def test_prop_baseline_ops(sa, sb):
    for cls in (WahBitmap, ConciseBitmap):
        ba, bb = cls.from_array(sa), cls.from_array(sb)
        assert set(ba.and_(bb).to_array().tolist()) == (sa & sb)
        assert set(ba.or_(bb).to_array().tolist()) == (sa | sb)


def test_baselines_match_roaring_on_census_queries():
    """WAH / Concise / Roaring answer the same census-like predicate
    queries bit-identically — the baselines the store benchmarks race
    against are fair opponents, not strawmen."""
    from repro.core import RoaringBitmap

    records = gen_census_like(4000, 7)

    def postings(name):
        arr = np.asarray(records[name])
        return {int(v): np.nonzero(arr == v)[0] for v in np.unique(arr)}

    cat0, cat1, int0 = postings("cat0"), postings("cat1"), postings("int0")
    mid = sorted(int0)[len(int0) // 2]
    pairs = [
        ("and", cat0[0], cat1[sorted(cat1)[1]]),
        ("or", cat0[1], cat1[sorted(cat1)[0]]),
        ("and", int0[mid], cat0[0]),
        # range-style: (int0 in [mid, mid+5]) as an OR chain, AND a posting
        ("and", np.unique(np.concatenate(
            [int0[v] for v in sorted(int0) if mid <= v <= mid + 5])),
         cat0[1]),
    ]
    for op, a, b in pairs:
        want = np.intersect1d(a, b) if op == "and" else np.union1d(a, b)
        for cls in (WahBitmap, ConciseBitmap):
            ba, bb = cls.from_sorted_unique(a), cls.from_sorted_unique(b)
            got = (ba.and_(bb) if op == "and" else ba.or_(bb)).to_array()
            np.testing.assert_array_equal(got, want, err_msg=cls.__name__)
        ra = RoaringBitmap.from_sorted_unique(a)
        rb = RoaringBitmap.from_sorted_unique(b)
        got = (ra & rb if op == "and" else ra | rb).to_array()
        np.testing.assert_array_equal(got, want, err_msg="RoaringBitmap")


def test_bitset_doubling_overhead_visible():
    b = BitSet()
    for x in range(0, 100000, 7):
        b.add(x)
    assert b.size_in_bytes() >= b.trimmed_size_in_bytes()
