"""Roaring block-sparse flash attention kernel vs oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sparse_attn import kernel as K
from repro.kernels.sparse_attn import ref as R
from repro.kernels.sparse_attn import ops as O


def _dense_oracle(q, k, v, causal, softcap=None, scale=None):
    """Full dense attention (for full masks the sparse path must match)."""
    B, H, S, D = q.shape
    group = H // k.shape[1]
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    if scale is None:
        scale = D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        m = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)).astype(q.dtype)


def _full_blocklist(num_qb, num_kvb, causal):
    idx = np.zeros((num_qb, num_kvb), np.int32)
    cnt = np.zeros((num_qb,), np.int32)
    for r in range(num_qb):
        cols = [c for c in range(num_kvb) if (not causal) or c <= r]
        idx[r, : len(cols)] = cols
        cnt[r] = len(cols)
    return jnp.asarray(idx), jnp.asarray(cnt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,S,D,causal", [
    (1, 2, 2, 256, 64, True),
    (2, 4, 2, 256, 128, True),     # GQA
    (1, 2, 1, 384, 64, False),
])
def test_sparse_kernel_full_mask_matches_dense(B, H, KVH, S, D, causal, dtype):
    rng = np.random.default_rng(0)
    bq = bk = 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), dtype) * 0.3
    k = jnp.asarray(rng.normal(size=(B, KVH, S, D)), dtype) * 0.3
    v = jnp.asarray(rng.normal(size=(B, KVH, S, D)), dtype)
    kv_idx, counts = _full_blocklist(S // bq, S // bk, causal)
    got = K.sparse_flash_attention(q, k, v, kv_idx, counts, block_q=bq,
                                   block_kv=bk, causal=causal, interpret=True)
    want = _dense_oracle(q, k, v, causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("softcap", [None, 30.0])
def test_sparse_kernel_sparse_mask_matches_ref(softcap):
    rng = np.random.default_rng(1)
    B, H, KVH, S, D = 1, 2, 2, 512, 64
    bq = bk = 128
    num_qb = S // bq
    # roaring-style irregular mask: local window + a global stripe
    idx = np.zeros((num_qb, num_qb), np.int32)
    cnt = np.zeros((num_qb,), np.int32)
    for r in range(num_qb):
        cols = sorted(set([0] + [c for c in (r - 1, r) if c >= 0]))
        idx[r, : len(cols)] = cols
        cnt[r] = len(cols)
    kv_idx, counts = jnp.asarray(idx), jnp.asarray(cnt)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, KVH, S, D)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, KVH, S, D)), jnp.float32)
    got = K.sparse_flash_attention(q, k, v, kv_idx, counts, block_q=bq,
                                   block_kv=bk, causal=True, softcap=softcap,
                                   interpret=True)
    want = R.sparse_attention_ref(q, k, v, kv_idx, counts, block_q=bq,
                                  block_kv=bk, causal=True, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_sparse_attention_grad_runs():
    rng = np.random.default_rng(2)
    B, H, S, D = 1, 2, 256, 64
    bq = 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    kv_idx, counts = _full_blocklist(S // bq, S // bq, True)

    def loss(q, k, v):
        return jnp.sum(O.sparse_attention(q, k, v, kv_idx, counts, bq, bq,
                                          True, None, None, False) ** 2)

    g = jax.grad(loss)(q, k, v)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("G,page", [(4, 64), (1, 128)])
def test_paged_decode_kernel_vs_ref(G, page):
    rng = np.random.default_rng(3)
    B, KVH, D, P, maxp = 2, 2, 64, 16, 4
    q = jnp.asarray(rng.normal(size=(B, KVH, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KVH, D)), jnp.float32) * 0.3
    vp = jnp.asarray(rng.normal(size=(P, page, KVH, D)), jnp.float32)
    page_idx = jnp.asarray([[3, 7, 1, 0], [5, 2, 0, 0]], jnp.int32)
    counts = jnp.asarray([3, 2], jnp.int32)
    lengths = jnp.asarray([2 * page + 17, page + 5], jnp.int32)
    got = K.paged_decode_attention(q, kp, vp, page_idx, counts, lengths,
                                   interpret=True)
    want = R.paged_decode_ref(q, kp, vp, page_idx, counts, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_ignores_pages_beyond_count():
    """Garbage physical ids past `counts` must not affect the output."""
    rng = np.random.default_rng(4)
    B, KVH, G, D, P, page, maxp = 1, 1, 2, 64, 8, 64, 4
    q = jnp.asarray(rng.normal(size=(B, KVH, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KVH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KVH, D)), jnp.float32)
    counts = jnp.asarray([2], jnp.int32)
    lengths = jnp.asarray([page + 30], jnp.int32)
    a = K.paged_decode_attention(q, kp, vp, jnp.asarray([[1, 4, 0, 0]], jnp.int32),
                                 counts, lengths, interpret=True)
    b = K.paged_decode_attention(q, kp, vp, jnp.asarray([[1, 4, 7, 6]], jnp.int32),
                                 counts, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0, rtol=0)
