"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as T

B, S = 2, 64


def _inputs(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    extra = None
    memory = None
    if cfg.layer_pattern == "encdec":
        memory = jax.random.normal(rng, (B, 32, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        extra = jax.random.normal(rng, (B, 8, cfg.d_model), jnp.float32)
    return tokens, labels, extra, memory


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_lm(rng, cfg)
    tokens, labels, extra, memory = _inputs(cfg, rng)
    logits, aux = T.forward(params, tokens, cfg, extra_embeds=extra,
                            memory=memory)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = T.lm_loss(params, tokens, labels, cfg, extra_embeds=extra,
                     memory=memory)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["gemma2-2b", "jamba-1.5-large-398b",
                                  "rwkv6-1.6b", "dbrx-132b"])
def test_grad_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = jax.random.PRNGKey(1)
    params = T.init_lm(rng, cfg)
    tokens, labels, extra, memory = _inputs(cfg, rng)

    def loss_fn(p):
        return T.lm_loss(p, tokens, labels, cfg, extra_embeds=extra,
                         memory=memory)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = jax.random.PRNGKey(2)
    params = T.init_lm(rng, cfg)
    memory = None
    if cfg.layer_pattern == "encdec":
        memory = jax.random.normal(rng, (B, 16, cfg.d_model), jnp.float32)
    caches = T.init_decode_caches(cfg, B, s_max=32)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab)
    pos = jnp.zeros((B,), jnp.int32)
    logits, caches = T.decode_step(params, caches, tok, pos, cfg, memory=memory)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # a second step with updated position keeps caches consistent
    logits2, caches = T.decode_step(params, caches, tok, pos + 1, cfg,
                                    memory=memory)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward (dense arch)."""
    cfg = get_config("stablelm-1.6b", reduced=True)
    rng = jax.random.PRNGKey(3)
    params = T.init_lm(rng, cfg)
    S_test = 8
    tokens = jax.random.randint(rng, (1, S_test), 0, cfg.vocab)
    full_logits, _ = T.forward(params, tokens, cfg)
    caches = T.init_decode_caches(cfg, 1, s_max=S_test)
    outs = []
    for t in range(S_test):
        lg, caches = T.decode_step(params, caches, tokens[:, t: t + 1],
                                   jnp.asarray([t], jnp.int32), cfg)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_decode_matches_forward_rwkv():
    cfg = get_config("rwkv6-1.6b", reduced=True)
    rng = jax.random.PRNGKey(4)
    params = T.init_lm(rng, cfg)
    S_test = 6
    tokens = jax.random.randint(rng, (1, S_test), 0, cfg.vocab)
    full_logits, _ = T.forward(params, tokens, cfg)
    caches = T.init_decode_caches(cfg, 1, s_max=S_test)
    outs = []
    for t in range(S_test):
        lg, caches = T.decode_step(params, caches, tokens[:, t: t + 1],
                                   jnp.asarray([t], jnp.int32), cfg)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=3e-2, rtol=3e-2)
