"""Run containers (2016 follow-up paper) across every layer.

Covers the satellite checklist: the single-run-covering-2^16 extreme,
run <-> array <-> bitmap threshold flips under add/remove, all 7 new pair
classes bit-identical across py_roaring / XLA ref / Pallas-interpret, the
4095/4096/4097 boundary with runs, rank/select round trips, the
best-of-three size accounting, and the run-shaped consumers (KV page pool,
window/causal/doc masks) actually producing run rows.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import RoaringBitmap, RunContainer, union_many
from repro.core import jax_roaring as jr
from repro.core import py_roaring as pr
from repro.kernels.roaring import dispatch as D
from repro.kernels.roaring import kernel as K
from repro.kernels.roaring import ref as R

_KIND_OF = {pr.ArrayContainer: jr.KIND_ARRAY,
            pr.BitmapContainer: jr.KIND_BITMAP,
            pr.RunContainer: jr.KIND_RUN}


def _values(slab, max_out=1 << 17):
    idx, valid = jr.to_indices(slab, max_out)
    return np.asarray(idx)[np.asarray(valid)]


def _rand_set(n, universe, seed):
    r = np.random.default_rng(seed)
    return np.unique(r.integers(0, universe, size=n))


def _rand_ranges(seed, n_ranges, universe, max_len=500):
    r = np.random.default_rng(seed)
    starts = np.sort(r.integers(0, universe, n_ranges))
    lens = r.integers(1, max_len, n_ranges)
    return [(int(s), int(min(s + l, universe)))
            for s, l in zip(starts, lens)]


def _check_canonical(slab, oracle, tag=""):
    """Slab output must match the oracle on values, card, kind, and payload
    bits (the best-of-three canonical discipline)."""
    np.testing.assert_array_equal(_values(slab), oracle.to_array(),
                                  err_msg=tag)
    assert int(slab.cardinality) == len(oracle), tag
    keys = np.asarray(slab.keys)
    kinds = np.asarray(slab.kind)
    cards = np.asarray(slab.card)
    assert list(keys[kinds != jr.KIND_EMPTY]) == list(oracle.keys), tag
    for k, c in zip(oracle.keys, oracle.containers):
        row = int(np.searchsorted(keys, k))
        assert cards[row] == c.cardinality, (tag, k)
        assert kinds[row] == _KIND_OF[type(c)], (tag, k, int(kinds[row]))
        if isinstance(c, pr.RunContainer):
            d = np.asarray(slab.data[row]).reshape(-1, 2)
            np.testing.assert_array_equal(d[: c.n_runs, 0],
                                          c.starts.astype(np.uint16))
            np.testing.assert_array_equal(d[: c.n_runs, 1],
                                          c.lengths.astype(np.uint16))
        elif isinstance(c, pr.ArrayContainer):
            np.testing.assert_array_equal(
                np.asarray(slab.data[row])[: c.cardinality], c.to_array())


# ----------------------------------------------------------------- oracle
def test_single_run_covering_full_chunk():
    rb = RoaringBitmap.from_range(0, 1 << 16)
    c = rb.containers[0]
    assert isinstance(c, RunContainer)
    assert c.n_runs == 1 and int(c.lengths[0]) == 0xFFFF
    assert rb.cardinality == 1 << 16
    assert rb.size_in_bytes() == 8 + 4 + 4          # header + 1 run
    assert rb.contains(0) and rb.contains(65535) and not rb.contains(65536)
    # slab mirror: the (0, 0xFFFF) pair round-trips through every surface
    s = jr.from_roaring(rb, 2)
    assert int(s.kind[0]) == jr.KIND_RUN
    assert int(s.cardinality) == 1 << 16
    assert int(s.size_in_bytes()) == rb.size_in_bytes()
    assert bool(jr.contains(s, jnp.asarray([65535]))[0])
    assert int(jr.slab_select(s, jnp.int32(65535))) == 65535
    # AND with itself stays the same single run
    _check_canonical(jr.slab_and(s, s), rb & rb, "full-chunk")


def test_threshold_flips_under_add_remove():
    """run -> array -> run and run -> bitmap flips follow the strict
    best-of-three size rule during dynamic updates."""
    rb = RoaringBitmap.from_range(0, 5000)
    assert isinstance(rb.containers[0], RunContainer)
    # punch every other hole: 2500 singleton runs -> array is smaller
    for v in range(1, 5000, 2):
        rb.remove(v)
    assert isinstance(rb.containers[0], pr.ArrayContainer)
    assert rb.cardinality == 2500
    # refill: contiguous again; the 2014 array dynamics convert at >4096
    # (bitmap), and runOptimize recovers the single run
    for v in range(1, 5000, 2):
        rb.add(v)
    assert rb.cardinality == 5000
    rb.run_optimize()
    c = rb.containers[0]
    assert isinstance(c, RunContainer) and c.n_runs == 1
    # strictness: runs of length 2 cost exactly an array (4 == 2*2) and the
    # tie goes to array; length 3 is strictly smaller and flips to run
    tie = RoaringBitmap.from_ranges([(10 * i, 10 * i + 2) for i in range(40)])
    assert isinstance(tie.containers[0], pr.ArrayContainer)
    rb2 = RoaringBitmap.from_ranges([(10 * i, 10 * i + 3) for i in range(40)])
    assert isinstance(rb2.containers[0], RunContainer)
    for v in range(1000, 1400, 4):                  # scattered singletons
        rb2.add(v)
    assert isinstance(rb2.containers[0], pr.ArrayContainer)


def test_oracle_cross_kind_algebra_matches_sets():
    ra = RoaringBitmap.from_ranges(_rand_ranges(1, 50, 1 << 18))
    rbm = RoaringBitmap.from_sorted_unique(_rand_set(30000, 1 << 18, 2))
    arr = RoaringBitmap.from_sorted_unique(_rand_set(700, 1 << 18, 3))
    sa = set(ra.to_array().tolist())
    sb = set(rbm.to_array().tolist())
    sc = set(arr.to_array().tolist())
    for x, y, su, sv in [(ra, rbm, sa, sb), (rbm, ra, sb, sa),
                         (ra, arr, sa, sc), (arr, ra, sc, sa)]:
        assert set((x & y).to_array().tolist()) == (su & sv)
        assert set((x | y).to_array().tolist()) == (su | sv)
        assert set((x ^ y).to_array().tolist()) == (su ^ sv)
        assert set(x.andnot(y).to_array().tolist()) == (su - sv)


# ------------------------------------------------------- slab pair classes
# the 7 new grid cells: run x {run, array, bitmap, empty} both ways
RUN_PAIRS = {
    "run_run": (_rand_ranges(1, 60, 1 << 18), _rand_ranges(2, 70, 1 << 18)),
    "run_array": (_rand_ranges(3, 40, 1 << 18), _rand_set(800, 1 << 18, 4)),
    "array_run": (_rand_set(800, 1 << 18, 5), _rand_ranges(6, 40, 1 << 18)),
    "run_bitmap": (_rand_ranges(7, 50, 1 << 17), _rand_set(30000, 1 << 17, 8)),
    "bitmap_run": (_rand_set(30000, 1 << 17, 9), _rand_ranges(10, 50, 1 << 17)),
    "run_empty": (_rand_ranges(11, 30, 1 << 17), [(1 << 18, (1 << 18) + 50)]),
    "empty_run": ([(1 << 18, (1 << 18) + 50)], _rand_ranges(12, 30, 1 << 17)),
}


def _build(spec):
    if isinstance(spec, list):
        return RoaringBitmap.from_ranges(spec)
    return RoaringBitmap.from_sorted_unique(spec)


@pytest.mark.parametrize("name", sorted(RUN_PAIRS))
def test_slab_ops_run_pair_classes(name):
    oa, ob = (_build(s) for s in RUN_PAIRS[name])
    sa, sb = jr.from_roaring(oa, 16), jr.from_roaring(ob, 16)
    _check_canonical(jr.slab_and(sa, sb), oa & ob, name + "/and")
    _check_canonical(jr.slab_or(sa, sb, capacity=24), oa | ob, name + "/or")
    _check_canonical(jr.slab_xor(sa, sb, capacity=24), oa ^ ob, name + "/xor")
    _check_canonical(jr.slab_andnot(sa, sb), oa.andnot(ob), name + "/andnot")
    assert int(jr.slab_and_card(sa, sb)) == len(oa & ob)
    assert int(jr.slab_or_card(sa, sb)) == len(oa | ob)


def test_run_boundary_4095_4096_4097():
    """The array/bitmap threshold cardinalities, produced by run-shaped
    inputs and outputs (single runs of exactly 4095/4096/4097 elements)."""
    for n in (4095, 4096, 4097):
        ra = RoaringBitmap.from_range(0, n)
        rb = RoaringBitmap.from_range(n // 2, n // 2 + n)
        sa, sb = jr.from_roaring(ra, 4), jr.from_roaring(rb, 4)
        _check_canonical(jr.slab_and(sa, sb), ra & rb, f"and/{n}")
        _check_canonical(jr.slab_or(sa, sb), ra | rb, f"or/{n}")
        _check_canonical(jr.slab_xor(sa, sb), ra ^ rb, f"xor/{n}")
        _check_canonical(jr.slab_andnot(sa, sb), ra.andnot(rb), f"andnot/{n}")


def test_tri_backend_bit_identity_on_run_classes():
    """Pallas-interpret and the XLA ref are bit-identical on (hits, card)
    for one slab holding every run pair class, and the summed card matches
    the paper-faithful oracle."""
    a = RoaringBitmap.from_ranges(
        _rand_ranges(20, 40, 1 << 16)                           # run chunk 0
        + [(1 << 16, (1 << 16) + 3000)])                        # run chunk 1
    a.ior(RoaringBitmap.from_sorted_unique(
        (2 << 16) + _rand_set(900, 1 << 16, 21)))               # array chunk 2
    a.ior(RoaringBitmap.from_sorted_unique(
        (3 << 16) + _rand_set(30000, 1 << 16, 22)))             # bitmap chunk 3
    b = RoaringBitmap.from_ranges(
        _rand_ranges(23, 50, 1 << 16)                           # run x run
        + [((3 << 16) + 100, (3 << 16) + 40000)])               # bitmap x run
    b.ior(RoaringBitmap.from_sorted_unique(
        (1 << 16) + _rand_set(25000, 1 << 16, 24)))             # run x bitmap
    b.ior(RoaringBitmap.from_sorted_unique(
        (2 << 16) + _rand_set(400, 1 << 16, 25)))               # array x array
    sa, sb = jr.from_roaring(a, 8), jr.from_roaring(b, 8)
    keys = jr._intersect_keys(sa, sb, 8)
    da, ca, ka = jr._gather_raw(sa, keys)
    db, cb, kb = jr._gather_raw(sb, keys)
    meta = jr._dispatch_meta(ka, kb, ca, cb, jr._rows_nruns(da, ka),
                             jr._rows_nruns(db, kb))
    h_pl, c_pl = K.intersect_dispatch_pallas(da, db, meta, interpret=True)
    h_ref, c_ref = R.intersect_dispatch_ref(da, db, meta)
    np.testing.assert_array_equal(np.asarray(h_pl), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_ref))
    assert int(jnp.sum(c_pl)) == len(a & b)
    # and the full slab path (run-merge routed) agrees with the oracle
    _check_canonical(jr.slab_and(sa, sb), a & b, "tri-backend/and")


def test_row_canonicalize_matches_oracle_best_of_three():
    """The public single-row runOptimize (row_canonicalize) must pick the
    same kind and payload as the oracle's _canonical — the drift guard
    between _pick_kind/_finalize and the per-row API."""
    cases = [
        np.arange(100),                                  # 1 run -> run
        np.arange(4097),                                 # big run -> run
        _rand_set(600, 1 << 16, 60),                     # scattered -> array
        _rand_set(30000, 1 << 16, 61),                   # dense -> bitmap
        np.concatenate([np.arange(0, 65536, 2)]),        # 32768 runs -> bitmap
        np.arange(65536),                                # full chunk -> run
        np.asarray([65535]),                             # sentinel value
    ]
    for vals in cases:
        words = pr.array_to_bitmap(vals.astype(np.uint16))
        bits = jnp.asarray(words.view(np.uint16))    # little-endian u64->u16
        data, card, kind = jr.row_canonicalize(bits)
        oc = pr._canonical(pr.BitmapContainer(words))
        assert int(card) == oc.cardinality, len(vals)
        assert int(kind) == _KIND_OF[type(oc)], (len(vals), int(kind))
        if isinstance(oc, pr.RunContainer):
            d = np.asarray(data).reshape(-1, 2)
            np.testing.assert_array_equal(d[: oc.n_runs, 0],
                                          oc.starts.astype(np.uint16))
            np.testing.assert_array_equal(d[: oc.n_runs, 1],
                                          oc.lengths.astype(np.uint16))
        elif isinstance(oc, pr.ArrayContainer):
            np.testing.assert_array_equal(
                np.asarray(data)[: oc.cardinality], oc.to_array())
        else:
            np.testing.assert_array_equal(np.asarray(data), np.asarray(bits))


def test_run_merge_matches_coverage_kernel():
    """The slab layer's run-domain merge and the registry's coverage-AND
    formulation of run x run are the same function extensionally."""
    da = jr.from_roaring(RoaringBitmap.from_ranges(
        _rand_ranges(30, 80, 1 << 16)), 2)
    db = jr.from_roaring(RoaringBitmap.from_ranges(
        _rand_ranges(31, 90, 1 << 16)), 2)
    rowa, rowb = da.data[0], db.data[0]
    pairs, card, n_out = jr._run_merge_row(rowa, rowb)
    cov = np.asarray(jr.row_run_to_bits(rowa) & jr.row_run_to_bits(rowb))
    want_card = int(np.bitwise_count(cov).sum())
    assert int(card) == want_card
    got_bits = np.asarray(jr.row_run_to_bits(pairs))
    np.testing.assert_array_equal(got_bits, cov)


def test_run_merge_bitmap_tie_at_2048_runs():
    """run x run output landing exactly on the 4*nr == 8192 tie (nr == 2048,
    card > 4096) must canonicalize to a real bitmap row: _finalize's
    runs -> bits coverage lift, not the run-merge bits placeholder."""
    a_ranges = [(8 * i, 8 * i + 6) for i in range(1026)]
    b_ranges = [(8 * j + 3, 8 * j + 10) for j in range(1024)]
    a = jr.from_ranges(np.array(a_ranges), 4)
    b = jr.from_ranges(np.array(b_ranges), 4)
    oracle = (RoaringBitmap.from_ranges(a_ranges)
              & RoaringBitmap.from_ranges(b_ranges))
    assert isinstance(oracle.containers[0], pr.BitmapContainer)
    out = jr.slab_and(a, b)
    assert int(out.kind[0]) == jr.KIND_BITMAP
    _check_canonical(out, oracle, "2048-run tie")
    member = int(oracle.to_array()[0])
    assert bool(jr.contains(out, jnp.asarray([member]))[0])


# --------------------------------------------------------- access surfaces
def test_rank_select_roundtrip_with_runs():
    vals = np.unique(np.concatenate([
        np.arange(100, 70000),                       # runs across chunks
        (3 << 16) + _rand_set(20000, 1 << 16, 40),   # bitmap chunk
        (5 << 16) + _rand_set(300, 1 << 16, 41)]))   # array chunk
    rb = RoaringBitmap.from_sorted_unique(vals).run_optimize()
    s = jr.from_roaring(rb, 8)
    assert {jr.KIND_ARRAY, jr.KIND_BITMAP, jr.KIND_RUN} <= \
        set(np.asarray(s.kind).tolist())
    for j in [0, 1, 4096, len(vals) // 2, len(vals) - 1]:
        v = int(vals[j])
        assert int(jr.slab_select(s, jnp.int32(j))) == v == rb.select(j)
        assert int(jr.rank(s, jnp.asarray(v))) == rb.rank(v) == j + 1
    assert int(jr.slab_select(s, jnp.int32(len(vals)))) == -1


def test_size_in_bytes_matches_oracle():
    for seed in (0, 1):
        rb = RoaringBitmap.from_ranges(_rand_ranges(seed, 60, 1 << 18))
        rb.ior(RoaringBitmap.from_sorted_unique(
            (8 << 16) + _rand_set(10000, 1 << 16, seed + 10)))
        s = jr.from_roaring(rb, 16)
        assert int(s.size_in_bytes()) == rb.size_in_bytes()
        # per-kind accounting: 2*card / 8192 / 4*n_runs (+4/container +8)
        want = 8
        for c in rb.containers:
            want += 4 + c.size_in_bytes()
        assert rb.size_in_bytes() == want


def test_slab_run_optimize_and_union_many():
    dense = np.arange(0, 40000)
    s = jr.slab_run_optimize(jr.from_dense_array(dense, 4, 1 << 16))
    assert int(s.kind[0]) == jr.KIND_RUN
    np.testing.assert_array_equal(_values(s), dense)
    sets = [RoaringBitmap.from_ranges(_rand_ranges(50 + i, 30, 1 << 18))
            for i in range(4)]
    slabs = [jr.from_roaring(x, 16) for x in sets]
    got = jr.union_many_slabs(slabs, capacity=16)
    _check_canonical(got, union_many(sets), "union_many")
    assert (np.asarray(got.kind) == jr.KIND_RUN).any()


# ---------------------------------------------------------------- consumers
def test_kv_cache_free_slab_has_run_rows():
    from repro.serve.kv_cache import RoaringPageTable
    pt = RoaringPageTable(n_pages=100_000, page_size=4)
    # fresh pool: one run per chunk, zero per-page materialization
    fs = pt.free_slab()
    kinds = np.asarray(fs.kinds)
    assert (kinds[np.asarray(fs.keys) != int(jr.KEY_SENTINEL)]
            == jr.KIND_RUN).all()
    assert int(fs.card()) == 100_000
    pt.alloc(1, 400)                                 # 100 contiguous pages
    pt.alloc(2, 200)                                 # 50 more
    fs = pt.free_slab()
    us = pt.used_slab()
    assert (np.asarray(fs.kinds) == jr.KIND_RUN).any()
    assert (np.asarray(us.kinds) == jr.KIND_RUN).any()
    assert int(fs.card()) == len(pt.free)
    assert int(us.card()) == 150
    # free AND used must be empty (the allocator never aliases)
    assert int(fs.and_card(us)) == 0
    pt.release(1)
    assert int(pt.free_slab().card()) == 100_000 - 50


def test_mask_slabs_have_run_rows():
    from repro.sparsity.masks import (MaskBuilder, causal_mask,
                                      doc_boundary_mask, local_window_mask,
                                      mask_overlap_cards, rows_to_slabs)
    nb = 64
    loc = local_window_mask(nb, 8)
    # every window of more than 2 blocks is strictly smaller as one run
    # (cards 1-2 canonicalize to arrays — 4 bytes/run is not a win there)
    assert all(isinstance(c, RunContainer)
               for r in loc for c in r.containers if c.cardinality > 2)
    slabs = rows_to_slabs(loc)
    kinds = np.asarray(slabs.kinds)[:, 0]
    assert (kinds == jr.KIND_RUN).sum() >= nb - 2
    cau = causal_mask(nb)
    doc = doc_boundary_mask(nb, [13, 40])
    assert all(isinstance(r.containers[0], RunContainer)
               for r in cau if len(r) > 2)
    assert all(isinstance(r.containers[0], RunContainer)
               for r in doc if len(r) > 2)
    # device-side overlap over run rows agrees with host sets
    cards = mask_overlap_cards(MaskBuilder(loc), MaskBuilder(doc))
    for r in range(nb):
        a = set(loc[r].to_array().tolist())
        b = set(doc[r].to_array().tolist())
        assert cards[r] == len(a & b), r
