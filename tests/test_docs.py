"""Documentation guards: API-reference drift and markdown link integrity.

The doc-drift test is the contract behind docs/API.md — every symbol a
public module exports via ``__all__`` must appear there, so adding an
export without documenting it fails CI (and so does documenting a symbol
that no longer exists).
"""

import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_BACKTICKED = re.compile(r"`([^`]+)`")


def _api_symbols(text: str) -> set:
    """Every backticked token in docs/API.md, split on non-identifier
    boundaries so compound entries (``a`` / ``b(x)``) register each name."""
    syms = set()
    for tok in _BACKTICKED.findall(text):
        syms.update(re.findall(r"[A-Za-z_][A-Za-z0-9_]*", tok))
    return syms


def test_api_doc_covers_all_exports():
    import repro.core as core
    import repro.core.jax_roaring as jr
    import repro.index as ix
    import repro.kernels.roaring.dispatch as D
    import repro.kernels.roaring.fused as F
    import repro.obs as OBS
    import repro.roaring as roaring
    import repro.roaring.validate as V
    import repro.store as S

    text = (ROOT / "docs" / "API.md").read_text()
    documented = _api_symbols(text)
    for mod in (roaring, core, jr, D, F, ix, V, S, OBS):
        missing = [s for s in mod.__all__ if s not in documented]
        assert not missing, (mod.__name__, missing)


def test_api_doc_symbols_exist():
    """The reverse direction: every symbol the reference tables *claim* a
    module exports must actually exist there (catches stale docs)."""
    import importlib

    text = (ROOT / "docs" / "API.md").read_text()
    mods = {
        "repro.roaring": None, "repro.roaring.validate": None,
        "repro.core": None, "repro.core.jax_roaring": None,
        "repro.kernels.roaring.dispatch": None, "repro.index": None,
        "repro.kernels.roaring.ops": None,
        "repro.kernels.roaring.fused": None, "repro.store": None,
        "repro.obs": None,
    }
    current = None
    for line in text.splitlines():
        m = re.match(r"^## `([a-z_.]+)`", line)
        if m:
            current = m.group(1) if m.group(1) in mods else None
            continue
        if current is None:
            continue
        row = re.match(r"^\| `([A-Za-z_][A-Za-z0-9_]*)`", line)
        if row:
            mod = importlib.import_module(current)
            assert hasattr(mod, row.group(1)), (current, row.group(1))


def test_markdown_links_resolve():
    """Relative links in README/DESIGN/docs must point at real files."""
    md_files = [ROOT / "README.md", ROOT / "DESIGN.md",
                *sorted((ROOT / "docs").glob("*.md"))]
    link = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
    bad = []
    for md in md_files:
        for target in link.findall(md.read_text()):
            target = target.split("#")[0].strip()
            if not target or target.startswith(("http://", "https://",
                                                "mailto:")):
                continue
            if not (md.parent / target).exists():
                bad.append((md.name, target))
    assert not bad, bad


def test_readme_commands_reference_real_paths():
    """The README's quickstart commands must reference files that exist."""
    text = (ROOT / "README.md").read_text()
    for path in re.findall(r"(?:python|pytest)\s+((?:[\w./-]+)\.py)", text):
        assert (ROOT / path).exists(), path
